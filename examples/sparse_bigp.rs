//! Sparse big-p demo: the paper's EDPP protocol end to end on a
//! `CscMatrix` that is **never densified** — the matrix is generated
//! directly in CSC form, and screening, coordinate descent, warm starts and
//! the λ-grid all run through the matrix-free `DesignMatrix` trait — and
//! then the same path again **out-of-core**: the matrix is written to an
//! on-disk `dppcsc` shard and paged back through a window a fraction of
//! the data's size, reproducing the CSC solutions bit for bit.
//!
//! This is the paper's §1 motivation made concrete: at this density a dense
//! N×p buffer would be ~10× larger than the CSC arrays, nothing in the
//! pipeline requires it, and with the shard backend not even the CSC
//! arrays have to fit in memory.
//!
//!     cargo run --release --example sparse_bigp [--full]

use dpp_screen::data::convert::shard_from_design;
use dpp_screen::linalg::{mmap::ENTRY_BYTES, CscMatrix, DesignMatrix, MmapCscMatrix};
use dpp_screen::path::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};
use dpp_screen::util::rng::Rng;

/// Generate an N×p CSC design with ~`density` fill, column by column,
/// without ever allocating a dense buffer.
fn sparse_design(n: usize, p: usize, density: f64, rng: &mut Rng) -> CscMatrix {
    let mut col_ptr = Vec::with_capacity(p + 1);
    let mut row_idx: Vec<u32> = Vec::new();
    let mut values: Vec<f64> = Vec::new();
    col_ptr.push(0);
    for _ in 0..p {
        for i in 0..n {
            if rng.f64() < density {
                row_idx.push(i as u32);
                values.push(rng.normal());
            }
        }
        col_ptr.push(values.len());
    }
    CscMatrix::from_parts(n, p, col_ptr, row_idx, values)
}

fn main() {
    let full = std::env::args().any(|a| a == "--full")
        || dpp_screen::util::full_scale();
    // MNIST-like aspect ratio; --full pushes p to the paper's 50k scale
    let (n, p, density) = if full { (784, 50_000, 0.12) } else { (200, 8_000, 0.10) };
    let mut rng = Rng::new(0x5BA6);

    let x = sparse_design(n, p, density, &mut rng);
    let dense_bytes = n * p * 8;
    let csc_bytes = x.nnz() * 12 + (p + 1) * 8;
    println!(
        "design: {}×{} CSC, {} nnz ({:.1}% fill) — {:.1} MB vs {:.1} MB dense",
        n,
        p,
        CscMatrix::nnz(&x),
        x.density() * 100.0,
        csc_bytes as f64 / 1e6,
        dense_bytes as f64 / 1e6,
    );

    // planted sparse model: y = Xβ* + 0.1·ε through the trait's column ops
    let mut y = vec![0.0; n];
    let support: Vec<usize> = (0..p / 100).map(|k| (k * 9973) % p).collect();
    for &j in &support {
        x.col_axpy_into(j, 1.5 * rng.normal(), &mut y);
    }
    for v in y.iter_mut() {
        *v += 0.1 * rng.normal();
    }

    // the paper's protocol: 100 λ values on λ/λmax ∈ [0.05, 1], sequential
    // EDPP screening with warm-started CD — all on the CSC backend
    let grid_k = dpp_screen::util::grid_size(if full { 100 } else { 50 });
    let grid = LambdaGrid::relative(&x, &y, grid_k, 0.05, 1.0);
    let cfg = PathConfig::default();
    let edpp = solve_path(&x, &y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let base = solve_path(&x, &y, &grid, RuleKind::None, SolverKind::Cd, &cfg);

    println!("\n  λ/λmax   kept  discarded  rejection");
    for r in edpp.records.iter().step_by((grid_k / 10).max(1)) {
        println!(
            "  {:6.3}  {:5}  {:9}  {:9.3}",
            r.lam / grid.lam_max,
            r.kept,
            r.discarded,
            r.rejection_ratio()
        );
    }

    // EDPP is safe, so the screened path reproduces the baseline exactly
    let max_diff = edpp
        .betas
        .iter()
        .zip(base.betas.iter())
        .flat_map(|(a, b)| a.iter().zip(b.iter()).map(|(u, v)| (u - v).abs()))
        .fold(0.0f64, f64::max);

    println!("\nmean rejection ratio : {:.4}", edpp.mean_rejection_ratio());
    println!("max |β_edpp − β_base|: {max_diff:.2e}  (safe: identical solutions)");
    println!(
        "path time            : {:.3}s → {:.3}s  (speedup {:.1}×, screening {:.3}s)",
        base.total_secs(),
        edpp.total_secs(),
        base.total_secs() / edpp.total_secs().max(1e-12),
        edpp.total_screen_secs()
    );
    assert!(edpp.mean_rejection_ratio() <= 1.0 + 1e-12, "EDPP must stay safe");

    // --- the same path out-of-core: shard on disk, 1/16-nnz window ---
    let shard = std::env::temp_dir().join(format!("dpp-sparse-bigp-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&shard);
    shard_from_design(&x, Some(&y), &shard).expect("writing shard");
    let budget = (x.nnz() * ENTRY_BYTES / 16).max(4096);
    let paged = MmapCscMatrix::open_with_budget(&shard, budget).expect("opening shard");
    println!(
        "\nout-of-core shard    : {:.1} MB on disk, window budget {:.2} MB \
         ({}x smaller than the entry data)",
        (x.nnz() * ENTRY_BYTES) as f64 / 1e6,
        budget as f64 / 1e6,
        (x.nnz() * ENTRY_BYTES) / budget.max(1)
    );
    let oc = solve_path(&paged, &y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let oc_diff = oc
        .betas
        .iter()
        .zip(edpp.betas.iter())
        .flat_map(|(a, b)| a.iter().zip(b.iter()).map(|(u, v)| (u - v).abs()))
        .fold(0.0f64, f64::max);
    println!(
        "out-of-core EDPP path: mean rejection {:.4}, {:.3}s, max |β_mmap − β_csc| = {oc_diff:.1e}",
        oc.mean_rejection_ratio(),
        oc.total_secs()
    );
    assert!(oc_diff == 0.0, "mmap must reproduce the CSC path bit for bit");
    drop(paged);
    let _ = std::fs::remove_dir_all(&shard);
    println!("out-of-core check    : PASS (bit-identical to the in-RAM CSC backend)");
}
