//! Stability selection with screened paths — the other model-selection
//! workload the paper's introduction motivates (§1): B subsample rounds,
//! each solving a full λ-path, EDPP-screened; features ranked by their
//! selection frequency.
//!
//!     cargo run --release --example stability_selection

use dpp_screen::data::synthetic;
use dpp_screen::path::stability::{stability_selection, StabilityConfig};

fn main() {
    // planted-support problem: 12 true features among 400
    let ds = synthetic::synthetic1(80, 400, 12, 0.05, 123);
    let truth = ds.beta_true.clone().unwrap();
    let true_support: Vec<usize> =
        (0..ds.p()).filter(|&j| truth[j] != 0.0).collect();
    println!(
        "problem: {}×{} with {} planted features",
        ds.n(),
        ds.p(),
        true_support.len()
    );

    let cfg = StabilityConfig { rounds: 40, grid: 30, ..Default::default() };
    let out = stability_selection(&ds.x, &ds.y, &cfg);

    let selected = out.selected(0.7);
    let hits = selected.iter().filter(|j| true_support.contains(j)).count();
    println!(
        "\nstability selection ({} rounds, 30-pt grid, threshold 0.7):",
        cfg.rounds
    );
    println!("  selected {} features, {hits} of them planted", selected.len());
    println!("  mean EDPP rejection across rounds: {:.4}", out.mean_rejection);
    println!("  total screened-path time: {:.2}s", out.total_secs);

    // top-15 by score
    let mut ranked: Vec<(usize, f64)> =
        out.scores.iter().copied().enumerate().collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\n  rank  feature  score  planted?");
    for (rank, (j, s)) in ranked.iter().take(15).enumerate() {
        println!(
            "  {:4}  {:7}  {:5.2}  {}",
            rank + 1,
            j,
            s,
            if truth[*j] != 0.0 { "yes" } else { "" }
        );
    }
}
