//! End-to-end driver (DESIGN.md §End-to-end validation): model selection by
//! K-fold cross-validation over a 100-point λ-grid on a realistic
//! (simulated gene-expression) workload — the exact scenario the paper's
//! introduction motivates for sequential screening.
//!
//! The full system composes here: dataset generation → trial scheduler
//! (coordinator) → per-fold screened paths (EDPP + CD, warm starts) →
//! validation-error selection of λ̂ → headline metrics (rejection ratio,
//! speedup vs the unscreened baseline) printed and appended to results/.
//!
//!     cargo run --release --example crossval_path [--full]

use dpp_screen::coordinator::run_trials;
use dpp_screen::data::{Dataset, RealDataset};
use dpp_screen::linalg::DenseMatrix;
use dpp_screen::path::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};
use dpp_screen::util::benchkit::Report;
use dpp_screen::util::timer::timed;

/// Split rows into K folds; returns per-fold (train, valid) row indices.
fn kfold(n: usize, k: usize) -> Vec<(Vec<usize>, Vec<usize>)> {
    (0..k)
        .map(|f| {
            let valid: Vec<usize> = (0..n).filter(|i| i % k == f).collect();
            let train: Vec<usize> = (0..n).filter(|i| i % k != f).collect();
            (train, valid)
        })
        .collect()
}

/// Row-subset copy of a problem.
fn subset(ds: &Dataset, rows: &[usize]) -> (DenseMatrix, Vec<f64>) {
    let mut x = DenseMatrix::zeros(rows.len(), ds.p());
    for j in 0..ds.p() {
        let src = ds.x.dense().unwrap().col(j);
        let dst = x.col_mut(j);
        for (ri, &r) in rows.iter().enumerate() {
            dst[ri] = src[r];
        }
    }
    let y = rows.iter().map(|&r| ds.y[r]).collect();
    (x, y)
}

fn validation_mse(ds: &Dataset, rows: &[usize], beta: &[f64]) -> f64 {
    let mut err = 0.0;
    for &r in rows {
        let mut pred = 0.0;
        for j in 0..ds.p() {
            if beta[j] != 0.0 {
                pred += ds.x.get(r, j) * beta[j];
            }
        }
        let e = ds.y[r] - pred;
        err += e * e;
    }
    err / rows.len().max(1) as f64
}

fn main() {
    let full = std::env::args().any(|a| a == "--full")
        || dpp_screen::util::full_scale();
    let k_folds = 5;
    let grid_k = dpp_screen::util::grid_size(100);

    // a lung-cancer-like expression problem: the intro's motivating setting
    let ds = RealDataset::LungCancer.generate(full, 7);
    println!(
        "workload: {} ({}×{}), {k_folds}-fold CV over {grid_k} λ values",
        ds.name,
        ds.n(),
        ds.p()
    );

    let folds = kfold(ds.n(), k_folds);
    let cfg = PathConfig::default();

    // --- screened CV (EDPP), folds fanned out via the coordinator ---
    let ds_ref = &ds;
    let folds_ref = &folds;
    let (cv_results, edpp_secs) = timed(|| {
        run_trials(k_folds, dpp_screen::coordinator::default_workers(), |f| {
            let (x, y) = subset(ds_ref, &folds_ref[f].0);
            let grid = LambdaGrid::relative(&x, &y, grid_k, 0.05, 1.0);
            let out = solve_path(&x, &y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
            let errs: Vec<f64> = out
                .betas
                .iter()
                .map(|b| validation_mse(ds_ref, &folds_ref[f].1, b))
                .collect();
            (out.mean_rejection_ratio(), errs, grid.values.clone(), grid.lam_max)
        })
    });

    // --- unscreened baseline (same folds) for the speedup metric ---
    let (_, base_secs) = timed(|| {
        run_trials(k_folds, dpp_screen::coordinator::default_workers(), |f| {
            let (x, y) = subset(ds_ref, &folds_ref[f].0);
            let grid = LambdaGrid::relative(&x, &y, grid_k, 0.05, 1.0);
            solve_path(&x, &y, &grid, RuleKind::None, SolverKind::Cd, &cfg).total_secs()
        })
    });

    // aggregate CV curve (mean over folds at each λ index)
    let mut cv_curve = vec![0.0; grid_k];
    for (_, errs, _, _) in &cv_results {
        for (i, e) in errs.iter().enumerate() {
            cv_curve[i] += e / k_folds as f64;
        }
    }
    let best = cv_curve
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let frac = 1.0 - (1.0 - 0.05) * best as f64 / (grid_k - 1) as f64;
    let mean_rej: f64 =
        cv_results.iter().map(|(r, _, _, _)| r).sum::<f64>() / k_folds as f64;

    println!("\nselected λ̂/λmax = {frac:.3} (CV-MSE {:.4})", cv_curve[best]);
    println!("mean rejection ratio (EDPP): {mean_rej:.4}");
    println!(
        "CV wall time: {base_secs:.2}s unscreened → {edpp_secs:.2}s with EDPP  ({:.1}× speedup)",
        base_secs / edpp_secs.max(1e-12)
    );

    let mut rep = Report::new(
        "crossval_path end-to-end run",
        &["workload", "folds", "grid", "λ̂/λmax", "mean rejection", "base(s)", "edpp(s)", "speedup"],
    );
    rep.row(&[
        ds.name.clone(),
        k_folds.to_string(),
        grid_k.to_string(),
        format!("{frac:.3}"),
        format!("{mean_rej:.4}"),
        format!("{base_secs:.2}"),
        format!("{edpp_secs:.2}"),
        format!("{:.1}x", base_secs / edpp_secs.max(1e-12)),
    ]);
    rep.emit("end_to_end.md");
}
