//! The L3 coordinator as a deployable service, in two acts: the classic
//! single-session `ScreeningService` (batched concurrent λ-requests,
//! descending-λ within a batch so every request reuses the tightest
//! sequential anchor), then the multi-tenant serving protocol — one
//! `Coordinator`, three sessions, a deadline-bounded request answered with
//! a gap-tagged partial response (DESIGN.md §4).
//!
//!     cargo run --release --example screening_service

use std::time::Instant;

use dpp_screen::coordinator::service::ScreeningService;
use dpp_screen::coordinator::{Coordinator, Request, RequestOptions, SessionSpec};
use dpp_screen::data::RealDataset;
use dpp_screen::path::{PathConfig, RuleKind, SolverKind};
use dpp_screen::screening::ScreenPipeline;
use dpp_screen::solver::dual::lambda_max;

fn main() {
    let ds = RealDataset::ProstateCancer.generate(dpp_screen::util::full_scale(), 17);
    let lam_max = lambda_max(&ds.x, &ds.y);
    println!("serving {} ({}×{})", ds.name, ds.n(), ds.p());

    let svc = ScreeningService::spawn(
        ds.x.clone(),
        ds.y.clone(),
        RuleKind::Edpp,
        SolverKind::Cd,
        PathConfig::default(),
    );

    // Burst 1: a client sweeps λ descending (pathwise CV client).
    let t0 = Instant::now();
    let mut total_kept = 0usize;
    for i in 0..20 {
        let f = 1.0 - 0.045 * i as f64;
        let resp = svc.screen(f * lam_max);
        total_kept += resp.kept.len();
    }
    println!(
        "burst 1 (20 descending requests): {:.1} req/s, mean kept {:.0}/{}",
        20.0 / t0.elapsed().as_secs_f64(),
        total_kept as f64 / 20.0,
        ds.p()
    );

    // Burst 2: out-of-order concurrent arrivals — the service batches them
    // and internally reorders λ-descending.
    let t1 = Instant::now();
    let rxs: Vec<_> = [0.31, 0.72, 0.11, 0.55, 0.92, 0.23, 0.47, 0.66]
        .iter()
        .map(|f| svc.request(f * lam_max))
        .collect();
    let mut latencies = Vec::new();
    for rx in rxs {
        let resp = rx.recv().expect("service died");
        latencies.push(resp.latency_s);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "burst 2 (8 concurrent requests): wall {:.1}ms, p50 latency {:.1}ms, p99 {:.1}ms",
        t1.elapsed().as_secs_f64() * 1e3,
        latencies[latencies.len() / 2] * 1e3,
        latencies[latencies.len() - 1] * 1e3
    );

    let metrics = svc.shutdown();
    println!("service metrics: {}", metrics.summary());

    // Part 2: the same shape, multi-tenant (DESIGN.md §4) — one coordinator
    // serving three datasets concurrently on the shared worker pool, with a
    // deadline-bounded request answered by a gap-tagged partial response.
    let coord = Coordinator::new();
    let mut lam_maxes = Vec::new();
    for (i, seed) in [3u64, 5, 8].into_iter().enumerate() {
        let ds = dpp_screen::data::synthetic::synthetic1(60, 400 + 100 * i, 20, 0.1, seed);
        lam_maxes.push(lambda_max(&ds.x, &ds.y));
        coord
            .register(SessionSpec::new(
                format!("tenant-{i}"),
                ds.x.clone(),
                ds.y.clone(),
                ScreenPipeline::auto(ds.n(), ds.p(), 0.1, 8),
                SolverKind::Cd,
                PathConfig::default(),
            ))
            .expect("register session");
    }
    let t2 = Instant::now();
    let slots: Vec<_> = (0..9)
        .map(|k| {
            let i = k % 3;
            let lam = (0.9 - 0.1 * (k / 3) as f64) * lam_maxes[i];
            coord.submit(
                &format!("tenant-{i}"),
                Request::Screen { lam, opts: RequestOptions::default() },
            )
        })
        .collect();
    for slot in slots {
        slot.recv().expect("session answered");
    }
    println!(
        "multi-tenant: 9 requests across 3 sessions in {:.1}ms",
        t2.elapsed().as_secs_f64() * 1e3
    );
    // a 1ms deadline on a tight-tolerance solve → partial, gap-tagged
    let partial = coord
        .submit(
            "tenant-0",
            Request::Screen {
                lam: 0.1 * lam_maxes[0],
                opts: RequestOptions {
                    deadline: Some(std::time::Duration::from_millis(1)),
                    tol_gap: Some(1e-14),
                    ..Default::default()
                },
            },
        )
        .recv()
        .expect("deadline request answered");
    println!(
        "deadline request: partial={} achieved gap={:.2e}",
        partial.partial, partial.gap
    );
    for (name, m) in coord.shutdown() {
        println!("{name}: {}", m.summary());
    }
}
