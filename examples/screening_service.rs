//! The L3 coordinator as a deployable service: a screening/solve server
//! owning one dataset, batching concurrent λ-requests (descending-λ within
//! a batch so every request reuses the tightest sequential anchor), with
//! latency/throughput metrics — the model-selection-server shape described
//! in DESIGN.md §4.
//!
//!     cargo run --release --example screening_service

use std::time::Instant;

use dpp_screen::coordinator::service::ScreeningService;
use dpp_screen::data::RealDataset;
use dpp_screen::path::{PathConfig, RuleKind, SolverKind};
use dpp_screen::solver::dual::lambda_max;

fn main() {
    let ds = RealDataset::ProstateCancer.generate(dpp_screen::util::full_scale(), 17);
    let lam_max = lambda_max(&ds.x, &ds.y);
    println!("serving {} ({}×{})", ds.name, ds.n(), ds.p());

    let svc = ScreeningService::spawn(
        ds.x.clone(),
        ds.y.clone(),
        RuleKind::Edpp,
        SolverKind::Cd,
        PathConfig::default(),
    );

    // Burst 1: a client sweeps λ descending (pathwise CV client).
    let t0 = Instant::now();
    let mut total_kept = 0usize;
    for i in 0..20 {
        let f = 1.0 - 0.045 * i as f64;
        let resp = svc.screen(f * lam_max);
        total_kept += resp.kept.len();
    }
    println!(
        "burst 1 (20 descending requests): {:.1} req/s, mean kept {:.0}/{}",
        20.0 / t0.elapsed().as_secs_f64(),
        total_kept as f64 / 20.0,
        ds.p()
    );

    // Burst 2: out-of-order concurrent arrivals — the service batches them
    // and internally reorders λ-descending.
    let t1 = Instant::now();
    let rxs: Vec<_> = [0.31, 0.72, 0.11, 0.55, 0.92, 0.23, 0.47, 0.66]
        .iter()
        .map(|f| svc.request(f * lam_max))
        .collect();
    let mut latencies = Vec::new();
    for rx in rxs {
        let resp = rx.recv().expect("service died");
        latencies.push(resp.latency_s);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "burst 2 (8 concurrent requests): wall {:.1}ms, p50 latency {:.1}ms, p99 {:.1}ms",
        t1.elapsed().as_secs_f64() * 1e3,
        latencies[latencies.len() / 2] * 1e3,
        latencies[latencies.len() - 1] * 1e3
    );

    let metrics = svc.shutdown();
    println!("service metrics: {}", metrics.summary());
}
