//! Group-Lasso screening demo (paper §3 / §4.2): the group EDPP rule —
//! the first *safe* screening rule for group Lasso — against the heuristic
//! group strong rule, across group counts.
//!
//!     cargo run --release --example group_lasso [--full]

use dpp_screen::data::synthetic;
use dpp_screen::path::group::{solve_group_path, GroupRuleKind};
use dpp_screen::path::LambdaGrid;
use dpp_screen::solver::dual::group_lambda_max;
use dpp_screen::solver::SolveOptions;

fn main() {
    let full = std::env::args().any(|a| a == "--full")
        || dpp_screen::util::full_scale();
    // paper: X is 250×200000; scaled default keeps the demo seconds-scale
    let (n, p) = if full { (250, 200_000) } else { (80, 4_000) };
    let group_counts: [usize; 3] = if full { [10_000, 20_000, 40_000] } else { [200, 400, 800] };
    let grid_k = dpp_screen::util::grid_size(50);
    let opts = SolveOptions::default();

    println!("group-Lasso screening on {n}×{p} gaussian design (paper §4.2)\n");
    println!("  n_g   s_g   rule          mean-rejection  screen(s)  solve(s)  speedup");
    for ng in group_counts {
        let ds = synthetic::group_synthetic(n, p, ng, 99);
        let groups = ds.groups.clone().unwrap();
        let (glm, _) = group_lambda_max(&ds.x, &ds.y, &groups);
        let grid = LambdaGrid::relative_to(glm, grid_k, 0.05, 1.0);

        let base = solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::None, &opts);
        for rule in [GroupRuleKind::Strong, GroupRuleKind::Edpp] {
            let out = solve_group_path(&ds.x, &ds.y, &groups, &grid, rule, &opts);
            println!(
                "  {:5} {:4}  {:12}  {:14.4}  {:9.3}  {:8.3}  {:6.1}x",
                ng,
                p / ng,
                out.rule,
                out.mean_rejection_ratio(),
                out.total_screen_secs(),
                out.total_solve_secs(),
                base.total_secs() / out.total_secs().max(1e-12),
            );
        }
    }
    println!(
        "\nPaper Fig. 6 shape: rejection rises with n_g (smaller groups ⇒ tighter\n\
         dual estimate), and group-EDPP ≥ group-strong while staying safe."
    );
}
