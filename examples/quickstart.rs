//! Quickstart: solve a Lasso path with EDPP screening and inspect the two
//! paper metrics (rejection ratio, speedup).
//!
//! Every entry point (`LambdaGrid::relative`, `solve_path`,
//! `ScreenContext::new`, `LassoSolver::solve`) takes `&dyn DesignMatrix`,
//! so `&ds.x` (dense) and `&CscMatrix` are interchangeable — see
//! `examples/sparse_bigp.rs` for the sparse-backend version of this demo
//! and DESIGN.md §2 for the trait contract.
//!
//!     cargo run --release --example quickstart

use dpp_screen::data::synthetic;
use dpp_screen::path::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};

fn main() {
    // Synthetic-1 problem (paper §4.1.2, eq. (74)): y = Xβ* + 0.1·ε with a
    // sparse β*. 64×256 so the demo finishes instantly.
    let ds = synthetic::synthetic1(64, 256, 20, 0.1, 42);
    println!("problem: {} ({}×{})", ds.name, ds.n(), ds.p());

    // The paper's protocol: 100 λ values equally spaced on λ/λmax ∈ [0.05, 1].
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 100, 0.05, 1.0);
    let cfg = PathConfig::default();

    // Screened path (sequential EDPP, Corollary 17) vs unscreened baseline.
    let edpp = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let base = solve_path(&ds.x, &ds.y, &grid, RuleKind::None, SolverKind::Cd, &cfg);

    // The same protocol on the sparse backend — identical API, same
    // screening behaviour (the exact dense/CSC parity properties live in
    // rust/tests/backend_parity.rs; here we just demo the call).
    let csc = ds.x.to_csc();
    let sparse = solve_path(&csc, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    println!(
        "csc backend: mean rejection {:.4} (dense {:.4})",
        sparse.mean_rejection_ratio(),
        edpp.mean_rejection_ratio()
    );

    println!("\n  λ/λmax   kept  discarded  rejection");
    for r in edpp.records.iter().step_by(10) {
        println!(
            "  {:6.3}  {:5}  {:9}  {:9.3}",
            r.lam / grid.lam_max,
            r.kept,
            r.discarded,
            r.rejection_ratio()
        );
    }

    // screened solutions are *exactly* the unscreened ones (EDPP is safe)
    let max_diff = edpp
        .betas
        .iter()
        .zip(base.betas.iter())
        .flat_map(|(a, b)| a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()))
        .fold(0.0f64, f64::max);

    println!("\nmean rejection ratio : {:.4}", edpp.mean_rejection_ratio());
    println!("max |β_edpp − β_base|: {max_diff:.2e}  (safe: identical solutions)");
    println!(
        "solver time          : {:.3}s → {:.3}s  (speedup {:.1}×, screening {:.3}s)",
        base.total_secs(),
        edpp.total_secs(),
        base.total_secs() / edpp.total_secs().max(1e-12),
        edpp.total_screen_secs()
    );
}
