"""Repo-root pytest shim: make `pytest python/tests/ -q` work from the root
by putting the `python/` package directory on sys.path (the suite imports
`compile.*`)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
