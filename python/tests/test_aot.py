"""AOT pipeline checks: artifact inventory consistency and HLO-text format
(the rust runtime parses these files with xla_extension 0.5.1's text
parser — serialized protos would be rejected, DESIGN.md §4)."""

import os

import pytest

from compile import aot, shapes

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.tsv")),
    reason="run `make artifacts` first",
)


def test_spec_inventory_covers_shapes():
    specs = list(aot.artifact_specs())
    names = {(s[0], s[1], s[2]) for s in specs}
    for n, p in shapes.xt_w_shapes():
        assert ("xt_w", n, p) in names
    for n, p in shapes.xt_w_pallas_shapes():
        assert ("xt_w_pallas", n, p) in names
    for n, p in shapes.edpp_screen_shapes():
        assert ("edpp_screen", n, p) in names
    for n, p in shapes.fista_epoch_shapes():
        assert ("fista_epoch", n, p) in names
    # no duplicate (name, shape)
    assert len(names) == len(specs)


def test_small_shapes_match_rust_registry():
    """Guards the cross-language shape contract: these constants mirror
    RealDataset::small_shape in rust/src/data/mod.rs."""
    rust_src = os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "src", "data", "mod.rs"
    )
    text = open(rust_src).read()
    for name, (n, p) in shapes.SMALL_DATASET_SHAPES.items():
        assert f"({n}, {p})" in text, f"{name} small shape ({n},{p}) drifted from rust"
    for name, (n, p) in shapes.PAPER_DATASET_SHAPES.items():
        assert f"({n}, {p})" in text, f"{name} paper shape ({n},{p}) drifted from rust"


@needs_artifacts
def test_manifest_lists_existing_hlo_text_files():
    manifest = os.path.join(ARTIFACT_DIR, "manifest.tsv")
    entries = [
        line.split("\t")
        for line in open(manifest).read().splitlines()
        if line and not line.startswith("#")
    ]
    assert entries, "empty manifest"
    for name, n, p, fname in entries:
        path = os.path.join(ARTIFACT_DIR, fname)
        assert os.path.exists(path), fname
        head = open(path).read(64)
        assert head.startswith("HloModule"), f"{fname} is not HLO text"
        assert int(n) > 0 and int(p) > 0 and name


@needs_artifacts
def test_artifacts_cover_manifest_spec():
    manifest = os.path.join(ARTIFACT_DIR, "manifest.tsv")
    listed = {
        (f[0], int(f[1]), int(f[2]))
        for f in (
            line.split("\t")
            for line in open(manifest).read().splitlines()
            if line and not line.startswith("#")
        )
    }
    expected = {(s[0], s[1], s[2]) for s in aot.artifact_specs()}
    # manifest may be a superset (e.g. built with DPP_AOT_FULL=1)
    assert expected <= listed
