"""L1 correctness: Pallas kernels vs pure-jnp oracles, hypothesis-swept
over shapes (the CORE correctness signal for the kernel layer)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, screen_kernel

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


@given(
    n=st.integers(min_value=1, max_value=300),
    p=st.integers(min_value=1, max_value=400),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_xt_w_matches_ref_hypothesis(n, p, seed):
    x = rand((n, p), seed)
    w = rand((n,), seed + 1)
    got = np.asarray(screen_kernel.xt_w(jnp.array(x), jnp.array(w)))
    want = np.asarray(ref.xt_w_ref(jnp.array(x), jnp.array(w)))
    scale = np.abs(want).max() + 1.0
    np.testing.assert_allclose(got, want, rtol=0, atol=3e-5 * scale)


@pytest.mark.parametrize(
    "n,p",
    [(1, 1), (255, 127), (256, 128), (257, 129), (512, 384), (64, 256)],
)
def test_xt_w_tile_boundaries(n, p):
    """Exact tile multiples, off-by-one, and sub-tile shapes."""
    x = rand((n, p), 42)
    w = rand((n,), 43)
    got = np.asarray(screen_kernel.xt_w(jnp.array(x), jnp.array(w)))
    want = x.T @ w
    scale = np.abs(want).max() + 1.0
    np.testing.assert_allclose(got, want, rtol=0, atol=3e-5 * scale)


def test_xt_w_alternative_blocks():
    """Block-shape ablation: every legal tiling gives the same numbers."""
    x = rand((100, 200), 7)
    w = rand((100,), 8)
    want = x.T @ w
    for bn, bp in [(32, 32), (64, 128), (256, 128), (8, 8)]:
        got = np.asarray(
            screen_kernel.xt_w(jnp.array(x), jnp.array(w), block_n=bn, block_p=bp)
        )
        np.testing.assert_allclose(got, want, rtol=0, atol=3e-5 * (np.abs(want).max() + 1))


@given(
    p=st.integers(min_value=1, max_value=600),
    radius=st.floats(min_value=0.0, max_value=5.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_screen_mask_matches_ref_hypothesis(p, radius, seed):
    scores = rand((p,), seed)
    norms = np.abs(rand((p,), seed + 1)) + 0.01
    got = np.asarray(
        screen_mask := screen_kernel.screen_mask(
            jnp.array(scores), jnp.array(norms), jnp.float32(radius)
        )
    )
    want = np.asarray(
        ref.screen_mask_ref(jnp.array(scores), jnp.array(norms), radius)
    )
    # boundary disagreements possible only within float epsilon of the
    # threshold; exclude those lanes
    sup = np.abs(scores) + radius * norms
    inexact = np.abs(sup - 1.0) < 1e-5
    np.testing.assert_array_equal(got[~inexact], want[~inexact])
    assert screen_mask.dtype == jnp.float32


def test_screen_mask_keep_semantics():
    scores = jnp.array([0.99, 0.5, 1.01, -1.2], dtype=jnp.float32)
    norms = jnp.ones(4, dtype=jnp.float32)
    m = np.asarray(screen_kernel.screen_mask(scores, norms, jnp.float32(0.0)))
    np.testing.assert_array_equal(m, [0.0, 0.0, 1.0, 1.0])
    m = np.asarray(screen_kernel.screen_mask(scores, norms, jnp.float32(0.6)))
    np.testing.assert_array_equal(m, [1.0, 1.0, 1.0, 1.0])


def test_vmem_footprint_under_budget():
    """§Perf structural check: default tiling fits VMEM comfortably."""
    assert screen_kernel.vmem_footprint_bytes() < 16 * 1024 * 1024 // 4


def test_v2_perp_orthogonal():
    rng = np.random.default_rng(0)
    for _ in range(20):
        v1 = rng.standard_normal(30).astype(np.float32)
        v2 = rng.standard_normal(30).astype(np.float32)
        if float(np.dot(v1, v2)) < 0:
            v2 = -v2
        perp = np.asarray(ref.v2_perp_ref(jnp.array(v1), jnp.array(v2)))
        assert abs(float(np.dot(perp, v1))) < 1e-3 * (np.linalg.norm(v1) + 1)
        assert np.linalg.norm(perp) <= np.linalg.norm(v2) + 1e-5
