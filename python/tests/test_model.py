"""L2 correctness: the exported graphs against numpy references and
against each other (kernel-backed vs pure-jnp)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


def problem(n, p, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    return x, y


@given(
    n=st.integers(min_value=2, max_value=128),
    p=st.integers(min_value=2, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_edpp_screen_matches_oracle(n, p, seed):
    x, y = problem(n, p, seed)
    rng = np.random.default_rng(seed + 9)
    theta = (y / (np.abs(x.T @ y).max() + 1.0)).astype(np.float32)
    norms = np.linalg.norm(x, axis=0).astype(np.float32) + 1e-3
    inv_lam0 = np.float32(1.0 / (0.7 * np.abs(x.T @ y).max() + 1e-3))
    inv_lam = np.float32(inv_lam0 * rng.uniform(1.05, 3.0))
    got_scores, got_radius, got_mask = model.edpp_screen(
        jnp.array(x), jnp.array(y), jnp.array(theta), inv_lam0, inv_lam, jnp.array(norms)
    )
    want_scores, want_radius, want_mask = ref.edpp_screen_ref(
        jnp.array(x), jnp.array(y), jnp.array(theta), inv_lam0, inv_lam, jnp.array(norms)
    )
    s = float(np.abs(np.asarray(want_scores)).max()) + 1.0
    np.testing.assert_allclose(np.asarray(got_scores), np.asarray(want_scores), atol=5e-5 * s)
    np.testing.assert_allclose(float(got_radius), float(want_radius), rtol=1e-5, atol=1e-6)
    # masks agree except within epsilon of the decision boundary
    sup = np.abs(np.asarray(want_scores)) + float(want_radius) * norms
    inexact = np.abs(sup - 1.0) < 1e-4 * s
    np.testing.assert_array_equal(
        np.asarray(got_mask)[~inexact], np.asarray(want_mask)[~inexact]
    )


def test_edpp_radius_shrinks_ball_vs_dpp():
    """‖v₂⊥‖ ≤ ‖v₂‖ — Theorem 7's containment, on the L2 graph."""
    x, y = problem(40, 80, 3)
    lam_max = float(np.abs(x.T @ y).max())
    theta = (y / lam_max).astype(np.float32)
    norms = np.linalg.norm(x, axis=0).astype(np.float32)
    inv_lam0 = np.float32(1.0 / (0.8 * lam_max))
    inv_lam = np.float32(1.0 / (0.4 * lam_max))
    _, radius, _ = model.edpp_screen(
        jnp.array(x), jnp.array(y), jnp.array(theta), inv_lam0, inv_lam, jnp.array(norms)
    )
    v2 = y * float(inv_lam) - theta
    dpp_radius = 0.5 * np.linalg.norm(v2)  # EDPP radius is ½‖v₂⊥‖ ≤ ½‖v₂‖
    assert float(radius) <= dpp_radius + 1e-5


def test_fista_epoch_matches_oracle_and_descends():
    x, y = problem(60, 90, 4)
    lip = np.float32(np.linalg.norm(x, 2) ** 2 * 1.01)
    lam = np.float32(0.3 * np.abs(x.T @ y).max())
    beta = np.zeros(90, dtype=np.float32)
    w = beta.copy()
    t = np.float32(1.0)

    def obj(b):
        r = y - x @ b
        return 0.5 * float(r @ r) + float(lam) * float(np.abs(b).sum())

    prev = obj(beta)
    bj, wj, tj = jnp.array(beta), jnp.array(w), jnp.float32(t)
    for i in range(25):
        b_ref, w_ref, t_ref = ref.fista_epoch_ref(
            jnp.array(x), jnp.array(y), bj, wj, tj, 1.0 / lip, lam
        )
        bj, wj, tj = model.fista_epoch(
            jnp.array(x), jnp.array(y), bj, wj, tj, np.float32(1.0 / lip), lam
        )
        np.testing.assert_allclose(np.asarray(bj), np.asarray(b_ref), atol=1e-4)
        np.testing.assert_allclose(float(tj), float(t_ref), rtol=1e-6)
    # monotone-ish decrease over the run (FISTA is not strictly monotone,
    # but 25 iterations must improve on β = 0 substantially)
    assert obj(np.asarray(bj)) < prev * 0.9


def test_deploy_and_pallas_xt_w_agree():
    """Perf It.4 contract: the CPU-deployed XLA-native sweep and the Pallas
    (TPU-path) sweep are the same computation."""
    x, y = problem(70, 130, 11)
    a = np.asarray(model.xt_w(jnp.array(x), jnp.array(y))[0])
    b = np.asarray(model.xt_w_pallas(jnp.array(x), jnp.array(y))[0])
    np.testing.assert_allclose(a, b, atol=3e-5 * (np.abs(a).max() + 1))


def test_lowering_produces_hlo_text():
    import jax

    text = model.lower_to_hlo_text(
        model.xt_w,
        (
            jax.ShapeDtypeStruct((8, 16), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
        ),
    )
    assert text.startswith("HloModule")
    assert "f32[8,16]" in text
