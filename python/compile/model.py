"""L2 JAX graphs: the EDPP screening step and a FISTA epoch, built on the
L1 Pallas kernels and lowered once to HLO text by `aot.py`.

Python never runs on the request path: these functions exist only to be
`jax.jit(...).lower(...)`-ed into `artifacts/*.hlo.txt`, which the rust
runtime (`rust/src/runtime/`) loads and executes through PJRT.
"""

import jax
import jax.numpy as jnp

from .kernels import ref, screen_kernel
from .kernels.ref import v2_perp_ref


def xt_w(x, w):
    """The deployed correlation sweep: signed scores `Xᵀw` (length p).

    This is the artifact the rust `ArtifactSweep` binds to — it matches the
    native `DenseMatrix::gemv_t` contract exactly (signed, unnormalized).

    Backend selection (perf iteration 4, EXPERIMENTS.md §Perf): on the CPU
    PJRT plugin, interpret-mode Pallas lowers to a while-loop of dynamic
    slices that runs ~100× slower than XLA's fused dot; the deployed CPU
    artifact therefore uses the XLA-native lowering of the *same*
    computation, while `xt_w_pallas` exports the Pallas kernel (the real-TPU
    path) for cross-verification — `python/tests` pin them equal.
    """
    return (ref.xt_w_ref(x, w),)


def xt_w_pallas(x, w):
    """The L1 Pallas kernel as its own artifact (verification + the lowering
    that Mosaic compiles on real TPU)."""
    return (screen_kernel.xt_w(x, w),)


def edpp_screen(x, y, theta, inv_lam0, inv_lam, col_norms):
    """Full EDPP step for the interior case λ₀ ∈ (0, λmax) (Corollary 17).

    Inputs:  x (n,p), y (n,), theta = θ*(λ₀) (n,), scalars 1/λ₀ and 1/λ
             (passed as rank-0 arrays), col_norms (p,).
    Outputs: (scores, radius, mask) — scores = Xᵀ(θ*(λ₀) + ½v₂⊥),
             radius = ½‖v₂⊥‖, mask = fused sphere test.

    The rust side re-applies the threshold in f64 with the safety slack
    (DESIGN.md §1); the mask output is consumed by tests and by pure-PJRT
    demos.
    """
    v1 = y * inv_lam0 - theta
    v2 = y * inv_lam - theta
    perp = v2_perp_ref(v1, v2)
    center = theta + 0.5 * perp
    scores = screen_kernel.xt_w(x, center)
    radius = 0.5 * jnp.sqrt(jnp.vdot(perp, perp))
    mask = screen_kernel.screen_mask(scores, col_norms, radius)
    return scores, radius, mask


def fista_epoch(x, y, beta, w, t, inv_lip, lam):
    """One FISTA iteration over the full (fixed-shape) problem, with the
    gradient correlation `Xᵀr` routed through the Pallas kernel.

    Exported so a pure-PJRT solver loop can be driven from rust (used by the
    runtime integration tests and the `screening_service` example's
    warm-path); the production solvers operate on dynamically-shaped reduced
    problems and therefore stay native (DESIGN.md §1).
    """
    r = x @ w - y
    grad = screen_kernel.xt_w(x, r)
    z = w - inv_lip * grad
    thr = lam * inv_lip
    beta_new = jnp.sign(z) * jnp.maximum(jnp.abs(z) - thr, 0.0)
    t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
    w_new = beta_new + ((t - 1.0) / t_new) * (beta_new - beta)
    return beta_new, w_new, t_new


def lower_to_hlo_text(fn, example_args) -> str:
    """Lower a jax function to HLO **text** — the interchange format the
    image's xla_extension 0.5.1 accepts (jax ≥ 0.5 serialized protos carry
    64-bit instruction ids it rejects; the text parser reassigns ids)."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
