"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

Every kernel in this package must match its `*_ref` twin to float32
tolerance; `python/tests/test_kernels.py` sweeps shapes with hypothesis.
"""

import jax.numpy as jnp


def xt_w_ref(x, w):
    """Correlation sweep `Xᵀw` — the O(Np) hot spot of every screening rule."""
    return x.T @ w


def screen_mask_ref(scores, col_norms, radius):
    """Sphere test (paper eq. (14) / rule (R1')): keep feature i when
    `|score_i| + radius * ||x_i|| >= 1`. Returns float32 {0,1} keep mask."""
    return (jnp.abs(scores) + radius * col_norms >= 1.0).astype(jnp.float32)


def v2_perp_ref(v1, v2):
    """v2_perp = v2 - (<v1,v2>/||v1||^2) * v1 (paper eq. (19)), guarded like
    the rust implementation: fall back to v2 when <v1,v2> < 0."""
    ip = jnp.vdot(v1, v2)
    denom = jnp.vdot(v1, v1)
    coef = jnp.where((denom > 0.0) & (ip >= 0.0), ip / jnp.maximum(denom, 1e-30), 0.0)
    return v2 - coef * v1


def edpp_screen_ref(x, y, theta, inv_lam0, inv_lam, col_norms):
    """EDPP step (interior case lam0 < lam_max, Corollary 17) — oracle for
    the L2 `edpp_screen` graph. Returns (scores, radius, mask)."""
    v1 = y * inv_lam0 - theta
    v2 = y * inv_lam - theta
    perp = v2_perp_ref(v1, v2)
    center = theta + 0.5 * perp
    scores = xt_w_ref(x, center)
    radius = 0.5 * jnp.sqrt(jnp.vdot(perp, perp))
    mask = screen_mask_ref(scores, col_norms, radius)
    return scores, radius, mask


def fista_epoch_ref(x, y, beta, w, t, inv_lip, lam):
    """One FISTA iteration (oracle for the L2 `fista_epoch` graph)."""
    grad = x.T @ (x @ w - y)
    z = w - inv_lip * grad
    thr = lam * inv_lip
    beta_new = jnp.sign(z) * jnp.maximum(jnp.abs(z) - thr, 0.0)
    t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
    w_new = beta_new + ((t - 1.0) / t_new) * (beta_new - beta)
    return beta_new, w_new, t_new
