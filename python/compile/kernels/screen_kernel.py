"""L1 Pallas kernels: the tiled correlation sweep `Xᵀw` and the fused
sphere-test threshold.

Hardware adaptation (DESIGN.md §2): the paper's hot spot is the dense
correlation sweep over all p features. On TPU we tile X into
(BLOCK_N × BLOCK_P) panels held in VMEM via `BlockSpec`, stream panels
HBM→VMEM along the reduction (N) axis with a VMEM accumulator, and shape
each panel product as a (BLOCK_P × BLOCK_N)·(BLOCK_N) contraction so the
MXU systolic array is engaged. The threshold compare is fused into a second
elementwise kernel so the keep-mask never round-trips through HBM
separately from the scores.

All kernels run with `interpret=True`: the CPU image cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust PJRT CPU
client executes (see /opt/xla-example/README.md). Real-TPU tile-size
estimates are recorded in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU-friendly defaults: BLOCK_P is a multiple of the 128-lane vector width,
# BLOCK_N a multiple of 8 (sublane) — VMEM footprint per panel:
# 256·128·4B = 128 KiB, well under the ~16 MiB/core budget even with
# double-buffering.
BLOCK_N = 256
BLOCK_P = 128


def _xt_w_kernel(x_ref, w_ref, o_ref):
    """One (n-tile, p-tile) grid step: o[pb] += x[nb, pb]ᵀ · w[nb]."""
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BLOCK_P, BLOCK_N) · (BLOCK_N,) contraction — MXU-shaped on real TPU
    o_ref[...] += x_ref[...].T @ w_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_p"))
def xt_w(x, w, *, block_n: int = BLOCK_N, block_p: int = BLOCK_P):
    """Tiled `Xᵀw` for x of shape (n, p) and w of shape (n,).

    Shapes are padded up to tile multiples with zeros (zero rows/columns
    contribute nothing to the dot products, and padded output columns are
    sliced off).
    """
    n, p = x.shape
    n_pad = (-n) % block_n
    p_pad = (-p) % block_p
    if n_pad or p_pad:
        x = jnp.pad(x, ((0, n_pad), (0, p_pad)))
        w = jnp.pad(w, (0, n_pad))
    np_, pp = x.shape
    grid = (pp // block_p, np_ // block_n)
    out = pl.pallas_call(
        _xt_w_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_p), lambda pi, ni: (ni, pi)),
            pl.BlockSpec((block_n,), lambda pi, ni: (ni,)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda pi, ni: (pi,)),
        out_shape=jax.ShapeDtypeStruct((pp,), x.dtype),
        interpret=True,
    )(x, w)
    return out[:p]


def _mask_kernel(scores_ref, norms_ref, radius_ref, o_ref):
    """Fused sphere test: keep_i = |score_i| + radius·norm_i ≥ 1."""
    radius = radius_ref[0]
    sup = jnp.abs(scores_ref[...]) + radius * norms_ref[...]
    o_ref[...] = (sup >= 1.0).astype(jnp.float32)


@jax.jit
def screen_mask(scores, col_norms, radius):
    """Fused threshold over all p features; radius is a scalar (passed as a
    length-1 array so the kernel stays shape-polymorphic in p only)."""
    p = scores.shape[0]
    block = min(BLOCK_P, p) if p % BLOCK_P else BLOCK_P
    p_pad = (-p) % block
    if p_pad:
        scores = jnp.pad(scores, (0, p_pad))
        # pad norms with a huge value so padded lanes are "kept" and sliced off
        col_norms = jnp.pad(col_norms, (0, p_pad), constant_values=1e30)
    pp = scores.shape[0]
    radius_arr = jnp.reshape(radius.astype(jnp.float32), (1,))
    out = pl.pallas_call(
        _mask_kernel,
        grid=(pp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.float32),
        interpret=True,
    )(scores, col_norms, radius_arr)
    return out[:p]


def vmem_footprint_bytes(block_n: int = BLOCK_N, block_p: int = BLOCK_P) -> int:
    """Estimated VMEM bytes per grid step of `xt_w` (f32, double-buffered
    inputs + accumulator) — used by the §Perf structural check."""
    panel = block_n * block_p * 4
    w_tile = block_n * 4
    acc = block_p * 4
    return 2 * (panel + w_tile) + acc
