"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts)."""

from . import ref, screen_kernel  # noqa: F401
