"""Artifact shape registry: which (function, n, p) combinations `aot.py`
exports.

Screening always runs on the full, fixed-shape matrix, so one `xt_w`
executable per dataset shape suffices (DESIGN.md §1). The list mirrors the
scaled-down shapes of `rust/src/data/mod.rs::RealDataset::small_shape` plus
the synthetic/demo shapes used by examples and integration tests. Set
DPP_AOT_FULL=1 to additionally export the paper-scale shapes.
"""

import os

# (n, p) — keep in sync with RealDataset::small_shape on the rust side.
SMALL_DATASET_SHAPES = {
    "prostate": (96, 1600),
    "pie": (196, 1200),
    "mnist": (196, 2400),
    "colon": (62, 800),
    "lung": (128, 1400),
    "coil100": (196, 1008),
    "breast": (44, 1000),
    "leukemia": (52, 1200),
    "svhn": (300, 3000),
}

PAPER_DATASET_SHAPES = {
    "prostate": (132, 15154),
    "pie": (1024, 11553),
    "mnist": (784, 50000),
    "colon": (62, 2000),
    "lung": (203, 12600),
    "coil100": (1024, 7199),
    "breast": (44, 7129),
    "leukemia": (52, 11225),
    "svhn": (3072, 99288),
}

# demo / test shapes
DEMO_SHAPES = [(64, 256), (100, 1000), (100, 2000)]


def xt_w_shapes():
    shapes = list(DEMO_SHAPES) + sorted(set(SMALL_DATASET_SHAPES.values()))
    if os.environ.get("DPP_AOT_FULL") == "1":
        shapes += sorted(set(PAPER_DATASET_SHAPES.values()))
    return shapes


def xt_w_pallas_shapes():
    # the Pallas lowering kept as a verification artifact (CPU deploy uses
    # the XLA-native lowering — see model.xt_w)
    return [(64, 256), (300, 3000)]


def edpp_screen_shapes():
    # the full-graph artifact: demo shape + one dataset shape
    return [(64, 256), SMALL_DATASET_SHAPES["prostate"]]


def fista_epoch_shapes():
    return [(64, 256)]
