"""AOT pipeline: lower the L2 graphs (with their L1 Pallas kernels inlined)
to HLO text artifacts + a manifest the rust runtime consumes.

Run via `make artifacts` (no-op when inputs are unchanged — make tracks the
dependency on this package). Usage:

    cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp

from . import model, shapes


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def scalar():
    return jax.ShapeDtypeStruct((), jnp.float32)


def artifact_specs():
    """Yield (name, n, p, fn, example_args) for every export."""
    for n, p in shapes.xt_w_shapes():
        yield ("xt_w", n, p, model.xt_w, (f32((n, p)), f32((n,))))
    for n, p in shapes.xt_w_pallas_shapes():
        yield ("xt_w_pallas", n, p, model.xt_w_pallas, (f32((n, p)), f32((n,))))
    for n, p in shapes.edpp_screen_shapes():
        yield (
            "edpp_screen",
            n,
            p,
            model.edpp_screen,
            (f32((n, p)), f32((n,)), f32((n,)), scalar(), scalar(), f32((p,))),
        )
    for n, p in shapes.fista_epoch_shapes():
        yield (
            "fista_epoch",
            n,
            p,
            model.fista_epoch,
            (f32((n, p)), f32((n,)), f32((p,)), f32((p,)), scalar(), scalar(), scalar()),
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = [
        "# dpp-screen AOT manifest: name<TAB>n<TAB>p<TAB>file (HLO text)"
    ]
    for name, n, p, fn, ex_args in artifact_specs():
        fname = f"{name}_n{n}_p{p}.hlo.txt"
        path = os.path.join(args.out, fname)
        text = model.lower_to_hlo_text(fn, ex_args)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name}\t{n}\t{p}\t{fname}")
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest} ({len(manifest_lines) - 1} artifacts)")


if __name__ == "__main__":
    main()
