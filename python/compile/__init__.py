"""Build-time compile package: L1 Pallas kernels, L2 JAX graphs, AOT export.

Never imported at runtime - the rust binary consumes only the HLO-text
artifacts this package writes (DESIGN.md section 1).
"""
