//! CLI integration: drive the `dpp` binary end to end (env var
//! `CARGO_BIN_EXE_dpp` is provided by cargo for integration tests).

use std::process::Command;

fn dpp() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dpp"))
}

#[test]
fn info_lists_inventory() {
    let out = dpp().arg("info").output().expect("spawn dpp");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("edpp"));
    assert!(text.contains("synthetic1"));
    assert!(text.contains("solvers:"));
}

#[test]
fn no_args_prints_usage() {
    let out = dpp().output().expect("spawn dpp");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

#[test]
fn path_on_synthetic_reports_rejection() {
    let out = dpp()
        .args(["path", "--dataset", "synthetic1", "--grid", "8", "--seed", "3"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mean rejection ratio"), "{text}");
}

#[test]
fn path_on_csv_file() {
    // write a small CSV, run a path on it
    let ds = dpp_screen::data::synthetic::synthetic1(20, 30, 4, 0.1, 5);
    let dir = std::env::temp_dir().join("dpp-cli-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.csv");
    dpp_screen::data::io::write_csv(&ds, &path).unwrap();
    let out = dpp()
        .args(["path", "--file", path.to_str().unwrap(), "--grid", "5"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("20x30"));
}

/// Sparse fixture on disk for the sparse-input CLI tests.
fn write_sparse_svm(name: &str, seed: u64) -> std::path::PathBuf {
    let mut ds = dpp_screen::data::synthetic::synthetic1(25, 40, 5, 0.1, seed);
    for j in 0..40 {
        for v in ds.x.dense_mut().unwrap().col_mut(j).iter_mut() {
            if v.abs() < 0.6 {
                *v = 0.0;
            }
        }
    }
    let dir = std::env::temp_dir().join("dpp-cli-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    dpp_screen::data::io::write_libsvm(&ds, &path).unwrap();
    path
}

#[test]
fn libsvm_input_stays_sparse_and_backend_is_reported() {
    // the io fix end to end: a .svm file must reach the path driver on the
    // CSC backend (auto never densifies sparse input), reported on stderr
    let svm = write_sparse_svm("sparse-report.svm", 11);
    let out = dpp()
        .args(["path", "--file", svm.to_str().unwrap(), "--grid", "4"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("matrix=csc"), "{stdout}");
    assert!(stderr.contains("matrix backend: csc"), "{stderr}");
    assert!(stderr.contains("nnz="), "{stderr}");
}

#[test]
fn convert_then_mmap_path_end_to_end() {
    // acceptance criterion: `dpp convert` + `dpp path --matrix mmap` with a
    // window budget far below the shard's values+indices footprint
    let svm = write_sparse_svm("oc.svm", 9);
    let shard = std::env::temp_dir().join("dpp-cli-test").join("oc.dppcsc");
    let _ = std::fs::remove_dir_all(&shard);
    let out = dpp()
        .args(["convert", "--file", svm.to_str().unwrap(), "--out", shard.to_str().unwrap()])
        .output()
        .expect("spawn dpp convert");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("converted"));

    let out = dpp()
        .args([
            "path",
            "--file",
            shard.to_str().unwrap(),
            "--matrix",
            "mmap",
            "--grid",
            "5",
            "--mmap-budget",
            "512",
        ])
        .output()
        .expect("spawn dpp path");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("matrix=mmap"), "{stdout}");
    assert!(stdout.contains("mean rejection ratio"), "{stdout}");
    assert!(stderr.contains("matrix backend: mmap"), "{stderr}");
}

#[test]
fn mmap_without_a_shard_fails_with_guidance() {
    let svm = write_sparse_svm("no-shard.svm", 13);
    let out = dpp()
        .args(["path", "--file", svm.to_str().unwrap(), "--matrix", "mmap"])
        .output()
        .expect("spawn dpp");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("dpp convert"));
}

#[test]
fn service_reports_backend_on_stderr() {
    let svm = write_sparse_svm("svc.svm", 15);
    let out = dpp()
        .args(["service", "--file", svm.to_str().unwrap(), "--requests", "3"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("metrics:"));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("matrix backend: csc"), "{stderr}");
}

#[test]
fn convert_shard_then_sharded_path_and_service_end_to_end() {
    // the sharded acceptance path: convert → shard --shards 3 → run the
    // path and the service on `--matrix sharded` with a 2-thread pool
    let svm = write_sparse_svm("set.svm", 17);
    let root = std::env::temp_dir().join("dpp-cli-test");
    let shard = root.join("set.dppcsc");
    let set = root.join("set.shards");
    let _ = std::fs::remove_dir_all(&shard);
    let _ = std::fs::remove_dir_all(&set);

    let out = dpp()
        .args(["convert", "--file", svm.to_str().unwrap(), "--out", shard.to_str().unwrap()])
        .output()
        .expect("spawn dpp convert");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = dpp()
        .args([
            "shard",
            "--file",
            shard.to_str().unwrap(),
            "--out",
            set.to_str().unwrap(),
            "--shards",
            "3",
        ])
        .output()
        .expect("spawn dpp shard");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("3 row-range shard(s)"));

    let out = dpp()
        .env("DPP_POOL_THREADS", "2")
        .args(["path", "--file", set.to_str().unwrap(), "--matrix", "sharded", "--grid", "5"])
        .output()
        .expect("spawn dpp path");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("matrix=sharded"), "{stdout}");
    assert!(stdout.contains("mean rejection ratio"), "{stdout}");
    assert!(stderr.contains("matrix backend: sharded"), "{stderr}");

    let out = dpp()
        .env("DPP_POOL_THREADS", "2")
        .args([
            "service",
            "--file",
            set.to_str().unwrap(),
            "--matrix",
            "sharded",
            "--requests",
            "3",
        ])
        .output()
        .expect("spawn dpp service");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("metrics:"));
}

#[test]
fn sharded_without_a_shardset_fails_with_guidance() {
    let svm = write_sparse_svm("no-set.svm", 19);
    let out = dpp()
        .args(["path", "--file", svm.to_str().unwrap(), "--matrix", "sharded"])
        .output()
        .expect("spawn dpp");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("dpp shard"));
}

#[test]
fn f32_convert_runs_with_safety_slack() {
    let svm = write_sparse_svm("f32.svm", 23);
    let root = std::env::temp_dir().join("dpp-cli-test");
    let shard = root.join("f32.dppcsc");
    let _ = std::fs::remove_dir_all(&shard);
    let out = dpp()
        .args([
            "convert",
            "--file",
            svm.to_str().unwrap(),
            "--out",
            shard.to_str().unwrap(),
            "--f32",
        ])
        .output()
        .expect("spawn dpp convert --f32");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("dtype=f32"));
    let out = dpp()
        .args(["path", "--file", shard.to_str().unwrap(), "--matrix", "mmap", "--grid", "4"])
        .output()
        .expect("spawn dpp path on f32 shard");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // the CLI must announce the safety-slack widening for quantized values
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("slack"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn bench_screen_emits_json_baseline() {
    let root = std::env::temp_dir().join("dpp-cli-test");
    std::fs::create_dir_all(&root).unwrap();
    let json = root.join("BENCH_screen.json");
    let _ = std::fs::remove_file(&json);
    let out = dpp()
        .env("DPP_POOL_THREADS", "2")
        .args([
            "bench-screen",
            "--n",
            "30",
            "--p",
            "150",
            "--grid",
            "3",
            "--shards",
            "2",
            "--out",
            json.to_str().unwrap(),
        ])
        .output()
        .expect("spawn dpp bench-screen");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&json).expect("BENCH_screen.json written");
    assert!(text.contains("\"backend\": \"sharded\""), "{text}");
    assert!(text.contains("\"rejection_ratio\""), "{text}");
    assert!(text.contains("\"threads\": 2"), "{text}");
    // pipeline rows with per-stage rejection ratios ride along
    assert!(text.contains("\"rule\": \"hybrid:strong+edpp\""), "{text}");
    assert!(text.contains("\"rule\": \"dynamic:edpp\""), "{text}");
    assert!(text.contains("\"stages\""), "{text}");
}

#[test]
fn bad_rule_or_dataset_fail_cleanly() {
    let out = dpp().args(["path", "--dataset", "nope"]).output().unwrap();
    assert!(!out.status.success());
    let out = dpp().args(["exp", "figZZ"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn bad_pipeline_fails_with_grammar() {
    for bad in ["cascade:edpp", "hybrid:strong+sis", "edppp"] {
        let out = dpp()
            .args(["path", "--dataset", "synthetic1", "--grid", "3", "--rule", bad])
            .output()
            .unwrap();
        assert!(!out.status.success(), "--rule {bad} should fail");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains("grammar"), "--rule {bad}: {stderr}");
        assert!(stderr.contains("cascade:"), "--rule {bad} error must enumerate forms");
    }
}

#[test]
fn hybrid_dynamic_pipeline_path_end_to_end() {
    let out = dpp()
        .args([
            "path",
            "--dataset",
            "synthetic1",
            "--grid",
            "6",
            "--seed",
            "7",
            "--rule",
            "hybrid:strong+edpp",
            "--dynamic",
        ])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rule=dynamic:hybrid:strong+edpp"), "{text}");
    assert!(text.contains("mean rejection ratio"), "{text}");
    assert!(text.contains("per-stage rejection"), "{text}");
}

#[test]
fn cascade_pipeline_path_runs() {
    let out = dpp()
        .args([
            "path",
            "--dataset",
            "synthetic1",
            "--grid",
            "5",
            "--seed",
            "11",
            "--rule",
            "cascade:sis,edpp",
        ])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rule=cascade:sis,edpp"), "{text}");
    assert!(text.contains("mean rejection ratio"), "{text}");
}

#[test]
fn pipeline_service_runs() {
    let out = dpp()
        .args([
            "service",
            "--requests",
            "4",
            "--dataset",
            "synthetic1",
            "--rule",
            "dynamic:hybrid:strong+edpp",
        ])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("pipeline: dynamic:hybrid:strong+edpp"), "{text}");
    assert!(text.contains("metrics:"), "{text}");
}

#[test]
fn group_command_runs() {
    let out = dpp()
        .args(["group", "--ngroups", "40", "--grid", "6"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("mean rejection"));
}

#[test]
fn service_command_runs() {
    let out = dpp()
        .args(["service", "--requests", "5", "--dataset", "synthetic1"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("metrics:"));
}

#[test]
fn rule_auto_resolves_from_problem_shape() {
    let out = dpp()
        .args(["path", "--dataset", "synthetic1", "--grid", "6", "--rule", "auto"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--rule auto"), "auto pick not reported: {err}");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mean rejection ratio"), "{text}");
}

#[test]
fn serve_multi_session_with_deadline() {
    let out = dpp()
        .args([
            "serve",
            "--sessions",
            "3",
            "--ops",
            "9",
            "--deadline-ms",
            "40",
        ])
        .env("DPP_POOL_THREADS", "2")
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("session s0:"), "{text}");
    assert!(text.contains("session s2:"), "{text}");
    assert!(text.contains("sessions=3"), "{text}");
    assert!(text.contains("errors=0"), "{text}");
    assert!(text.contains("ops/s"), "{text}");
}

#[test]
fn bench_serve_emits_json_baseline() {
    let dir = std::env::temp_dir().join("dpp-cli-bench-serve");
    std::fs::create_dir_all(&dir).unwrap();
    let out_path = dir.join("BENCH_serve.json");
    let out = dpp()
        .args([
            "bench-serve",
            "--n",
            "40",
            "--p",
            "160",
            "--ops",
            "6",
            "--sessions",
            "2",
            "--out",
            out_path.to_str().unwrap(),
        ])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let json = std::fs::read_to_string(&out_path).expect("BENCH_serve.json written");
    assert!(json.contains("\"bench\": \"serve\""), "{json}");
    assert!(json.contains("\"sessions\": 2"), "{json}");
    assert!(json.contains("\"pipeline\": \"hybrid:strong+edpp\""), "{json}");
    assert!(json.contains("\"throughput_rps\""), "{json}");
    assert!(json.contains("\"p95_ms\""), "{json}");
    let _ = std::fs::remove_file(&out_path);
}
