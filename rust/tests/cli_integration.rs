//! CLI integration: drive the `dpp` binary end to end (env var
//! `CARGO_BIN_EXE_dpp` is provided by cargo for integration tests).

use std::process::Command;

fn dpp() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dpp"))
}

#[test]
fn info_lists_inventory() {
    let out = dpp().arg("info").output().expect("spawn dpp");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("edpp"));
    assert!(text.contains("synthetic1"));
    assert!(text.contains("solvers:"));
}

#[test]
fn no_args_prints_usage() {
    let out = dpp().output().expect("spawn dpp");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

#[test]
fn path_on_synthetic_reports_rejection() {
    let out = dpp()
        .args(["path", "--dataset", "synthetic1", "--grid", "8", "--seed", "3"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mean rejection ratio"), "{text}");
}

#[test]
fn path_on_csv_file() {
    // write a small CSV, run a path on it
    let ds = dpp_screen::data::synthetic::synthetic1(20, 30, 4, 0.1, 5);
    let dir = std::env::temp_dir().join("dpp-cli-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.csv");
    dpp_screen::data::io::write_csv(&ds, &path).unwrap();
    let out = dpp()
        .args(["path", "--file", path.to_str().unwrap(), "--grid", "5"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("20x30"));
}

#[test]
fn bad_rule_or_dataset_fail_cleanly() {
    let out = dpp().args(["path", "--dataset", "nope"]).output().unwrap();
    assert!(!out.status.success());
    let out = dpp().args(["exp", "figZZ"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn group_command_runs() {
    let out = dpp()
        .args(["group", "--ngroups", "40", "--grid", "6"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("mean rejection"));
}

#[test]
fn service_command_runs() {
    let out = dpp()
        .args(["service", "--requests", "5", "--dataset", "synthetic1"])
        .output()
        .expect("spawn dpp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("metrics:"));
}
