//! Tier-1 gate for `dpp audit` (DESIGN.md §5).
//!
//! Three guarantees, in order of importance:
//!
//! 1. the shipped tree audits clean — every lint family at zero findings,
//!    every policy exception a reasoned in-tree waiver;
//! 2. the committed `rust/wire.lock` is byte-identical to what
//!    `dpp audit --write-wire-lock` would emit from today's sources;
//! 3. each lint family actually fires — a fixture tree under
//!    `tests/fixtures/audit/` seeds one violation per family (plus the
//!    waiver edge cases) and the counts here are exact, so a lint that
//!    silently stops matching turns this test red, not the audit green.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use dpp_screen::analysis::{
    current_wire_consts, run_audit, wirecheck, AuditConfig, AuditReport,
};

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_root() -> PathBuf {
    crate_root().join("tests/fixtures/audit")
}

fn audit_fixtures(lock: Option<&str>) -> AuditReport {
    let cfg = AuditConfig {
        src_root: fixture_root().join("tree"),
        lock_path: lock.map(|name| fixture_root().join(name)),
    };
    run_audit(&cfg).expect("fixture tree scans")
}

fn count_by_code(report: &AuditReport) -> BTreeMap<&'static str, usize> {
    let mut by_code = BTreeMap::new();
    for f in &report.findings {
        *by_code.entry(f.code).or_insert(0) += 1;
    }
    by_code
}

/// Guarantee 1: the crate's own `src/` has zero findings. On failure the
/// full report is printed — the same text `dpp audit` would show.
#[test]
fn shipped_tree_audits_clean() {
    let cfg = AuditConfig::for_crate(env!("CARGO_MANIFEST_DIR"));
    let report = run_audit(&cfg).expect("crate sources scan");
    assert!(
        report.clean(),
        "`dpp audit` found violations in the shipped tree:\n{}",
        report.render_text(),
    );
    // The waiver ledger and the unsafe inventory are part of the contract:
    // both are known-nonempty today, and the sole unsafe block is the
    // documented lifetime-erasing transmute in runtime/pool.rs.
    assert!(!report.waivers.is_empty(), "waiver ledger unexpectedly empty");
    assert!(report.waivers.iter().all(|w| !w.reason.is_empty()));
    assert_eq!(
        report.unsafe_sites.len(),
        1,
        "unsafe inventory changed — update this pin alongside the new \
         SAFETY comment: {:?}",
        report.unsafe_sites,
    );
    assert_eq!(report.unsafe_sites[0].file, "runtime/pool.rs");
}

/// Guarantee 2: `rust/wire.lock` round-trips — rendering today's parsed
/// wire/frame constants reproduces the committed file byte-for-byte.
#[test]
fn wire_lock_matches_sources_exactly() {
    let root = crate_root();
    let consts = current_wire_consts(&root.join("src")).expect("wire sources parse");
    let rendered = wirecheck::render_lock(&consts);
    let committed = fs::read_to_string(root.join("wire.lock")).expect("wire.lock exists");
    assert_eq!(
        rendered, committed,
        "rust/wire.lock is stale — after a deliberate grammar change, bump \
         WIRE_VERSION and run `dpp audit --write-wire-lock > rust/wire.lock`",
    );
    // And the committed bytes parse back to the same entries.
    let parsed = wirecheck::parse_lock(&committed).expect("committed lock parses");
    assert_eq!(parsed.len(), consts.len());
}

/// Guarantee 3a: every lint family catches its seeded fixture violation,
/// with exact counts (no lock configured — the wire table has its own
/// fixtures below).
#[test]
fn fixture_tree_trips_every_lint_family() {
    let report = audit_fixtures(None);
    let by_code = count_by_code(&report);
    let expect: BTreeMap<&str, usize> = [
        ("determinism:float-sort", 1), // solver/bad_sort.rs
        ("determinism:clock", 1),      // path/clock_sum.rs
        ("determinism:float-sum", 1),  // path/clock_sum.rs
        ("determinism:hash-iter", 1),  // path/clock_sum.rs
        ("unsafe", 1),                 // runtime/raw.rs (undocumented one)
        ("panic", 1),                  // coordinator/handler.rs
        ("waiver", 1),                 // util/waived.rs (empty reason)
    ]
    .into_iter()
    .collect();
    assert_eq!(
        by_code.iter().map(|(&k, &v)| (k, v)).collect::<BTreeMap<_, _>>(),
        expect,
        "fixture findings drifted:\n{}",
        report.render_text(),
    );
    // The reasoned waiver silences its lint and lands in the ledger; both
    // unsafe blocks (documented or not) land in the inventory.
    assert_eq!(report.waivers.len(), 1);
    assert_eq!(report.waivers[0].code, "determinism:clock");
    assert_eq!(report.waivers[0].reason, "fixture-sanctioned timer shim");
    assert_eq!(report.unsafe_sites.len(), 2);
}

/// Guarantee 3b: a matching lock audits the fixture wire table clean...
#[test]
fn fixture_wire_lock_match_is_clean() {
    let report = audit_fixtures(Some("wire.lock.match"));
    assert!(
        !report.findings.iter().any(|f| f.code == "wire"),
        "matching fixture lock produced wire findings:\n{}",
        report.render_text(),
    );
}

/// ...and a stale lock (tag drift, version unchanged) demands a bump.
#[test]
fn fixture_wire_lock_drift_demands_version_bump() {
    let report = audit_fixtures(Some("wire.lock.stale"));
    let wire: Vec<_> = report.findings.iter().filter(|f| f.code == "wire").collect();
    assert_eq!(wire.len(), 1, "expected exactly one drift finding: {wire:?}");
    assert!(wire[0].message.contains("REQ_ECHO"), "{}", wire[0].message);
    assert!(
        wire[0].message.contains("requires a WIRE_VERSION bump"),
        "{}",
        wire[0].message,
    );
    assert_eq!(wire[0].file, "net/wire.rs");
}

/// The JSON rendering stays shell-pipeline friendly: one object, the three
/// arrays, and correctly escaped strings.
#[test]
fn json_report_shape() {
    let report = audit_fixtures(None);
    let json = report.to_json();
    assert!(json.starts_with('{') && json.ends_with('}'));
    for key in ["\"findings\":[", "\"waivers\":[", "\"unsafe\":["] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
    assert!(json.contains("determinism:float-sort"));
    // No raw newlines may survive inside the single-line JSON document.
    assert!(!json.contains('\n'));
}

/// The fixture tree is part of the test: if someone "fixes" the seeded
/// violations the counts above go stale silently — so pin the files too.
#[test]
fn fixture_tree_layout_is_intact() {
    let tree = fixture_root().join("tree");
    for rel in [
        "solver/bad_sort.rs",
        "path/clock_sum.rs",
        "runtime/raw.rs",
        "coordinator/handler.rs",
        "util/waived.rs",
        "net/wire.rs",
        "net/frame.rs",
    ] {
        assert!(
            Path::new(&tree).join(rel).is_file(),
            "fixture file missing: {rel}",
        );
    }
}
