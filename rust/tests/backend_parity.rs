//! Dense/sparse backend parity: the `DesignMatrix` redesign's contract is
//! that every rule and solver is backend-agnostic. These properties pin it
//! down: on the same data, every `ScreeningRule` must produce a
//! bit-identical keep-set on `DenseMatrix` vs `CscMatrix::from_dense`, CD
//! solutions must agree to gap tolerance, and a full EDPP path must run the
//! paper's protocol on CSC without densifying.

use dpp_screen::data::Dataset;
use dpp_screen::linalg::{CscMatrix, DenseMatrix, DesignMatrix};
use dpp_screen::path::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};
use dpp_screen::screening::{
    dome::DomeRule, dpp::DppRule, edpp::EdppRule, edpp::Improvement1Rule,
    edpp::Improvement2Rule, safe::SafeRule, sis::SisRule, strong::StrongRule,
    theta_from_solution, ScreenContext, ScreeningRule, StepInput,
};
use dpp_screen::solver::{cd::CdSolver, dual, LassoSolver, SolveOptions};
use dpp_screen::util::{prop, rng::Rng};

/// Sparse synthetic regression problem with unit-norm features (so DOME is
/// applicable alongside every other rule).
fn sparse_problem(n: usize, p: usize, density: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = DenseMatrix::zeros(n, p);
    for j in 0..p {
        for v in x.col_mut(j).iter_mut() {
            if rng.f64() < density {
                *v = rng.normal();
            }
        }
    }
    x.normalize_columns();
    let mut beta = vec![0.0; p];
    for j in 0..(p + 7) / 8 {
        beta[(j * 7919) % p] = rng.normal() * 2.0;
    }
    let mut y = vec![0.0; n];
    DesignMatrix::gemv(&x, &beta, &mut y);
    for v in y.iter_mut() {
        *v += 0.1 * rng.normal();
    }
    Dataset { name: "parity".into(), x, y, beta_true: Some(beta), groups: None }
}

fn all_rules(n_rows: usize) -> Vec<Box<dyn ScreeningRule>> {
    vec![
        Box::new(SafeRule),
        Box::new(DomeRule::default()),
        Box::new(DppRule),
        Box::new(Improvement1Rule),
        Box::new(Improvement2Rule),
        Box::new(EdppRule),
        Box::new(StrongRule),
        Box::new(SisRule::with_default_count(n_rows)),
    ]
}

#[test]
fn every_rule_keep_set_identical_on_dense_and_csc() {
    prop::check("rule keep-sets dense == csc", 0xBA17, 8, |rng| {
        let n = 20 + rng.usize(20);
        let p = 40 + rng.usize(60);
        let ds = sparse_problem(n, p, rng.uniform(0.1, 0.6), rng.next_u64());
        let csc = CscMatrix::from_dense(&ds.x);

        let dense_ctx = ScreenContext::new(&ds.x, &ds.y);
        let csc_ctx = ScreenContext::new(&csc, &ds.y);
        assert!(
            (dense_ctx.lam_max - csc_ctx.lam_max).abs() < 1e-12 * (1.0 + dense_ctx.lam_max),
            "λmax diverged across backends"
        );

        // exact sequential anchor: solve at λ₀ on the dense backend
        let f1 = rng.uniform(0.4, 1.0);
        let f2 = rng.uniform(0.15, f1 * 0.95);
        let lam0 = f1 * dense_ctx.lam_max;
        let lam = f2 * dense_ctx.lam_max;
        let cols: Vec<usize> = (0..p).collect();
        let opts = SolveOptions { tol_gap: 1e-11, ..Default::default() };
        let prev = CdSolver.solve(&ds.x, &ds.y, &cols, lam0, None, &opts).scatter(&cols, p);
        let theta = theta_from_solution(&ds.x, &ds.y, &prev, lam0);
        let step = StepInput { lam_prev: lam0, lam, theta_prev: &theta };

        // fresh rule instances per backend: DomeRule caches its
        // λ-independent Xᵀñ sweep on first use, and sharing one instance
        // would let the CSC run reuse the dense-derived cache, silently
        // skipping the sparse code path this test exists to exercise
        for (rule_d, rule_s) in all_rules(n).into_iter().zip(all_rules(n)) {
            let mut keep_dense = vec![true; p];
            let mut keep_csc = vec![true; p];
            rule_d.screen(&dense_ctx, &step, &mut keep_dense);
            rule_s.screen(&csc_ctx, &step, &mut keep_csc);
            assert_eq!(
                keep_dense,
                keep_csc,
                "{} keep-set diverged between dense and csc backends",
                rule_d.name()
            );
        }
    });
}

#[test]
fn cd_solutions_agree_across_backends_to_gap_tolerance() {
    prop::check("CD dense == CD csc (gap tolerance)", 0xBA18, 8, |rng| {
        let n = 20 + rng.usize(20);
        let p = 30 + rng.usize(50);
        let ds = sparse_problem(n, p, rng.uniform(0.1, 0.5), rng.next_u64());
        let csc = CscMatrix::from_dense(&ds.x);
        let lam = rng.uniform(0.2, 0.8) * dual::lambda_max(&ds.x, &ds.y);
        let cols: Vec<usize> = (0..p).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let de = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts);
        let sp = CdSolver.solve(&csc, &ds.y, &cols, lam, None, &opts);
        assert!(de.gap <= 1e-10, "dense gap {}", de.gap);
        assert!(sp.gap <= 1e-10, "csc gap {}", sp.gap);
        let o_de = dual::primal_objective(&ds.x, &ds.y, &cols, &de.beta, lam);
        let o_sp = dual::primal_objective(&csc, &ds.y, &cols, &sp.beta, lam);
        assert!(
            (o_de - o_sp).abs() < 1e-7 * (1.0 + o_de.abs()),
            "objectives diverged: dense {o_de} vs csc {o_sp}"
        );
        for j in 0..p {
            assert!(
                (de.beta[j] - sp.beta[j]).abs() < 1e-5 * (1.0 + de.beta[j].abs()),
                "β[{j}] diverged: {} vs {}",
                de.beta[j],
                sp.beta[j]
            );
        }
    });
}

#[test]
fn full_edpp_path_on_csc_matches_dense_and_stays_safe() {
    // the acceptance criterion: solve_path runs the full EDPP protocol on a
    // CscMatrix (no densify), and the sparse path reproduces the dense one
    let ds = sparse_problem(40, 200, 0.15, 99);
    let csc = CscMatrix::from_dense(&ds.x);
    let grid = LambdaGrid::relative(&csc, &ds.y, 12, 0.05, 1.0);
    let cfg = PathConfig::default();
    let sparse = solve_path(&csc, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let dense = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    assert!(sparse.mean_rejection_ratio() <= 1.0 + 1e-12);
    assert!(sparse.mean_rejection_ratio() > 0.8, "{}", sparse.mean_rejection_ratio());
    for (k, (bs, bd)) in sparse.betas.iter().zip(dense.betas.iter()).enumerate() {
        for j in 0..ds.p() {
            assert!(
                (bs[j] - bd[j]).abs() < 1e-4 * (1.0 + bd[j].abs()),
                "λ-index {k}, feature {j}: csc {} vs dense {}",
                bs[j],
                bd[j]
            );
        }
    }
    // screening effectiveness must match step by step; the two backends'
    // CD anchors agree only to solver tolerance, so allow a feature or two
    // of slack at the sphere boundary (keep-decisions are exact-equal when
    // the anchor θ is shared — see the rule-level parity test above)
    for (rs, rd) in sparse.records.iter().zip(dense.records.iter()) {
        let diff = rs.kept.abs_diff(rd.kept);
        assert!(diff <= 2, "kept counts diverged at λ={}: {} vs {}", rs.lam, rs.kept, rd.kept);
    }
}

#[test]
fn lars_and_fista_also_run_on_csc() {
    use dpp_screen::solver::{fista::FistaSolver, lars::LarsSolver};
    let ds = sparse_problem(25, 60, 0.25, 7);
    let csc = CscMatrix::from_dense(&ds.x);
    let lam = 0.3 * dual::lambda_max(&csc, &ds.y);
    let cols: Vec<usize> = (0..60).collect();
    let opts = SolveOptions { tol_gap: 1e-9, ..Default::default() };
    let cd = CdSolver.solve(&csc, &ds.y, &cols, lam, None, &opts);
    let la = LarsSolver.solve(&csc, &ds.y, &cols, lam, None, &opts);
    let fi = FistaSolver.solve(&csc, &ds.y, &cols, lam, None, &opts);
    let obj = |b: &[f64]| dual::primal_objective(&csc, &ds.y, &cols, b, lam);
    let (o_cd, o_la, o_fi) = (obj(&cd.beta), obj(&la.beta), obj(&fi.beta));
    let scale = o_cd.abs().max(1.0);
    assert!((o_cd - o_la).abs() < 1e-6 * scale, "cd={o_cd} lars={o_la}");
    assert!((o_cd - o_fi).abs() < 1e-6 * scale, "cd={o_cd} fista={o_fi}");
}

#[test]
fn group_path_runs_on_csc() {
    use dpp_screen::path::group::{solve_group_path, GroupRuleKind};
    use dpp_screen::solver::SolveOptions;
    let ds = dpp_screen::data::synthetic::group_synthetic(30, 120, 24, 3);
    let groups = ds.groups.clone().unwrap();
    let csc = CscMatrix::from_dense(&ds.x);
    let (glm_d, _) = dual::group_lambda_max(&ds.x, &ds.y, &groups);
    let (glm_s, _) = dual::group_lambda_max(&csc, &ds.y, &groups);
    assert!((glm_d - glm_s).abs() < 1e-12 * (1.0 + glm_d));
    let grid = LambdaGrid::relative_to(glm_s, 6, 0.1, 1.0);
    let opts = SolveOptions::default();
    let sp = solve_group_path(&csc, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
    let de = solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
    for (bs, bd) in sp.betas.iter().zip(de.betas.iter()) {
        for j in 0..ds.p() {
            assert!((bs[j] - bd[j]).abs() < 5e-3 * (1.0 + bd[j].abs()));
        }
    }
}
