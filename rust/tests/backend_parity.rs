//! Dense/sparse/out-of-core backend parity: the `DesignMatrix` redesign's
//! contract is that every rule and solver is backend-agnostic. These
//! properties pin it down: on the same data, every `ScreeningRule` must
//! produce a bit-identical keep-set on `DenseMatrix` vs
//! `CscMatrix::from_dense` vs a disk-paged `MmapCscMatrix` whose window
//! budget is far smaller than the data, CD solutions must agree to gap
//! tolerance, and a full EDPP path must run the paper's protocol on CSC
//! and on the shard without densifying. Because the mmap backend streams
//! each column's entries in the same order CSC stores them, its keep-sets
//! and CD trajectories are required to be **bit-identical** to CSC, not
//! just gap-close.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use dpp_screen::data::convert::{libsvm_to_shard, read_shard_y, shard_from_design};
use dpp_screen::data::io::{read_libsvm, write_libsvm};
use dpp_screen::data::Dataset;
use dpp_screen::linalg::mmap::ENTRY_BYTES;
use dpp_screen::linalg::{DenseMatrix, DesignMatrix, MmapCscMatrix};
use dpp_screen::path::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};
use dpp_screen::screening::{
    dome::DomeRule, dpp::DppRule, edpp::EdppRule, edpp::Improvement1Rule,
    edpp::Improvement2Rule, safe::SafeRule, sis::SisRule, strong::StrongRule,
    theta_from_solution, ScreenContext, ScreeningRule, StepInput,
};
use dpp_screen::solver::{cd::CdSolver, dual, LassoSolver, SolveOptions};
use dpp_screen::util::{prop, rng::Rng};

/// Sparse synthetic regression problem with unit-norm features (so DOME is
/// applicable alongside every other rule).
fn sparse_problem(n: usize, p: usize, density: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = DenseMatrix::zeros(n, p);
    for j in 0..p {
        for v in x.col_mut(j).iter_mut() {
            if rng.f64() < density {
                *v = rng.normal();
            }
        }
    }
    x.normalize_columns();
    let mut beta = vec![0.0; p];
    for j in 0..(p + 7) / 8 {
        beta[(j * 7919) % p] = rng.normal() * 2.0;
    }
    let mut y = vec![0.0; n];
    DesignMatrix::gemv(&x, &beta, &mut y);
    for v in y.iter_mut() {
        *v += 0.1 * rng.normal();
    }
    Dataset { name: "parity".into(), x: x.into(), y, beta_true: Some(beta), groups: None }
}

/// Fresh per-test shard dir (tests run concurrently in one process).
fn shard_dir(tag: &str) -> PathBuf {
    static COUNTER: AtomicUsize = AtomicUsize::new(0);
    let k = COUNTER.fetch_add(1, Ordering::Relaxed);
    let root = std::env::temp_dir().join("dpp-parity-tests");
    let _ = std::fs::create_dir_all(&root);
    let dir = root.join(format!("{tag}-{}-{k}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Write the dataset's matrix to a shard and reopen it with a window
/// budget deliberately smaller than the on-disk entry data.
fn mmap_backend(ds: &Dataset, tag: &str) -> (MmapCscMatrix, PathBuf) {
    let dir = shard_dir(tag);
    let nnz = shard_from_design(ds.x.as_design(), Some(&ds.y), &dir).unwrap().nnz;
    let budget = (nnz * ENTRY_BYTES / 8).max(ENTRY_BYTES);
    assert!(budget < nnz * ENTRY_BYTES, "budget must undercut the data");
    let mm = MmapCscMatrix::open_with_budget(&dir, budget).unwrap();
    (mm, dir)
}

fn all_rules(n_rows: usize) -> Vec<Box<dyn ScreeningRule>> {
    vec![
        Box::new(SafeRule),
        Box::new(DomeRule::default()),
        Box::new(DppRule),
        Box::new(Improvement1Rule),
        Box::new(Improvement2Rule),
        Box::new(EdppRule),
        Box::new(StrongRule),
        Box::new(SisRule::with_default_count(n_rows)),
    ]
}

#[test]
fn every_rule_keep_set_identical_on_dense_csc_and_mmap() {
    prop::check("rule keep-sets dense == csc == mmap", 0xBA17, 8, |rng| {
        let n = 20 + rng.usize(20);
        let p = 40 + rng.usize(60);
        let ds = sparse_problem(n, p, rng.uniform(0.1, 0.6), rng.next_u64());
        let csc = ds.x.to_csc();
        let (mmap, dir) = mmap_backend(&ds, "rules");

        let dense_ctx = ScreenContext::new(&ds.x, &ds.y);
        let csc_ctx = ScreenContext::new(&csc, &ds.y);
        let mmap_ctx = ScreenContext::new(&mmap, &ds.y);
        assert!(
            (dense_ctx.lam_max - csc_ctx.lam_max).abs() < 1e-12 * (1.0 + dense_ctx.lam_max),
            "λmax diverged across backends"
        );
        // same entries in the same order ⇒ the sparse λmax values are equal bits
        assert_eq!(csc_ctx.lam_max, mmap_ctx.lam_max, "csc vs mmap λmax");

        // exact sequential anchor: solve at λ₀ on the dense backend
        let f1 = rng.uniform(0.4, 1.0);
        let f2 = rng.uniform(0.15, f1 * 0.95);
        let lam0 = f1 * dense_ctx.lam_max;
        let lam = f2 * dense_ctx.lam_max;
        let cols: Vec<usize> = (0..p).collect();
        let opts = SolveOptions { tol_gap: 1e-11, ..Default::default() };
        let prev = CdSolver.solve(&ds.x, &ds.y, &cols, lam0, None, &opts).scatter(&cols, p);
        let theta = theta_from_solution(&ds.x, &ds.y, &prev, lam0);
        let step = StepInput { lam_prev: lam0, lam, theta_prev: &theta };

        // fresh rule instances per backend: DomeRule caches its
        // λ-independent Xᵀñ sweep on first use, and sharing one instance
        // would let later backends reuse the first backend's cache,
        // silently skipping the code paths this test exists to exercise
        for ((rule_d, rule_s), rule_m) in
            all_rules(n).into_iter().zip(all_rules(n)).zip(all_rules(n))
        {
            let mut keep_dense = vec![true; p];
            let mut keep_csc = vec![true; p];
            let mut keep_mmap = vec![true; p];
            rule_d.screen(&dense_ctx, &step, &mut keep_dense);
            rule_s.screen(&csc_ctx, &step, &mut keep_csc);
            rule_m.screen(&mmap_ctx, &step, &mut keep_mmap);
            assert_eq!(
                keep_dense,
                keep_csc,
                "{} keep-set diverged between dense and csc backends",
                rule_d.name()
            );
            assert_eq!(
                keep_csc,
                keep_mmap,
                "{} keep-set diverged between csc and mmap backends",
                rule_s.name()
            );
        }
        let _ = std::fs::remove_dir_all(dir);
    });
}

#[test]
fn cd_solutions_agree_across_backends_to_gap_tolerance() {
    prop::check("CD dense == CD csc == CD mmap (gap tolerance)", 0xBA18, 8, |rng| {
        let n = 20 + rng.usize(20);
        let p = 30 + rng.usize(50);
        let ds = sparse_problem(n, p, rng.uniform(0.1, 0.5), rng.next_u64());
        let csc = ds.x.to_csc();
        let (mmap, dir) = mmap_backend(&ds, "cd");
        let lam = rng.uniform(0.2, 0.8) * dual::lambda_max(&ds.x, &ds.y);
        let cols: Vec<usize> = (0..p).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let de = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts);
        let sp = CdSolver.solve(&csc, &ds.y, &cols, lam, None, &opts);
        let mm = CdSolver.solve(&mmap, &ds.y, &cols, lam, None, &opts);
        assert!(de.gap <= 1e-10, "dense gap {}", de.gap);
        assert!(sp.gap <= 1e-10, "csc gap {}", sp.gap);
        assert!(mm.gap <= 1e-10, "mmap gap {}", mm.gap);
        let o_de = dual::primal_objective(&ds.x, &ds.y, &cols, &de.beta, lam);
        let o_sp = dual::primal_objective(&csc, &ds.y, &cols, &sp.beta, lam);
        assert!(
            (o_de - o_sp).abs() < 1e-7 * (1.0 + o_de.abs()),
            "objectives diverged: dense {o_de} vs csc {o_sp}"
        );
        for j in 0..p {
            assert!(
                (de.beta[j] - sp.beta[j]).abs() < 1e-5 * (1.0 + de.beta[j].abs()),
                "β[{j}] diverged: {} vs {}",
                de.beta[j],
                sp.beta[j]
            );
            // identical kernels in identical order: csc and the shard are
            // bit-for-bit the same trajectory
            assert_eq!(sp.beta[j], mm.beta[j], "β[{j}] csc vs mmap");
        }
        assert_eq!(sp.iters, mm.iters, "csc vs mmap iteration counts");
        let _ = std::fs::remove_dir_all(dir);
    });
}

/// The acceptance criterion end to end: LIBSVM input → `dpp convert`'s
/// two-pass streaming converter → shard opened with a window budget the
/// entry data exceeds several times over → the full sequential EDPP path,
/// with keep-sets and solutions bit-identical to the CSC backend fed from
/// the same file.
#[test]
fn full_edpp_path_on_mmap_shard_matches_csc_bit_identical() {
    let ds = sparse_problem(40, 200, 0.15, 99);
    let dir = shard_dir("path");
    let svm = dir.with_extension("svm");
    write_libsvm(&ds, &svm).unwrap();

    let loaded = read_libsvm(&svm, Some(200)).unwrap();
    assert_eq!(loaded.x.backend_name(), "csc", "reader must not densify");
    let csc = loaded.x.to_csc();

    let summary = libsvm_to_shard(&svm, &dir, Some(200)).unwrap();
    assert_eq!(summary.nnz, csc.nnz(), "converter and reader disagree on nnz");
    let budget = 1024;
    assert!(
        summary.nnz * ENTRY_BYTES > 8 * budget,
        "values+indices ({} bytes) must exceed the window budget ({budget})",
        summary.nnz * ENTRY_BYTES
    );
    let mmap = MmapCscMatrix::open_with_budget(&dir, budget).unwrap();
    let y = read_shard_y(&dir).unwrap().expect("converter writes y.bin");
    assert_eq!(y, loaded.y, "y must round-trip bit-exactly");

    let grid = LambdaGrid::relative(&csc, &y, 12, 0.05, 1.0);
    let cfg = PathConfig::default();
    let sparse = solve_path(&csc, &y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let paged = solve_path(&mmap, &y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    assert!(sparse.mean_rejection_ratio() > 0.8, "{}", sparse.mean_rejection_ratio());
    for (k, (rs, rm)) in sparse.records.iter().zip(paged.records.iter()).enumerate() {
        assert_eq!(rs.kept, rm.kept, "kept count diverged at λ-index {k}");
        assert_eq!(rs.discarded, rm.discarded, "discard count diverged at λ-index {k}");
    }
    for (k, (bs, bm)) in sparse.betas.iter().zip(paged.betas.iter()).enumerate() {
        assert_eq!(bs, bm, "β diverged at λ-index {k}");
    }
    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_file(svm);
}

#[test]
fn full_edpp_path_on_csc_matches_dense_and_stays_safe() {
    // solve_path runs the full EDPP protocol on a CscMatrix (no densify),
    // and the sparse path reproduces the dense one
    let ds = sparse_problem(40, 200, 0.15, 99);
    let csc = ds.x.to_csc();
    let grid = LambdaGrid::relative(&csc, &ds.y, 12, 0.05, 1.0);
    let cfg = PathConfig::default();
    let sparse = solve_path(&csc, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let dense = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    assert!(sparse.mean_rejection_ratio() <= 1.0 + 1e-12);
    assert!(sparse.mean_rejection_ratio() > 0.8, "{}", sparse.mean_rejection_ratio());
    for (k, (bs, bd)) in sparse.betas.iter().zip(dense.betas.iter()).enumerate() {
        for j in 0..ds.p() {
            assert!(
                (bs[j] - bd[j]).abs() < 1e-4 * (1.0 + bd[j].abs()),
                "λ-index {k}, feature {j}: csc {} vs dense {}",
                bs[j],
                bd[j]
            );
        }
    }
    // screening effectiveness must match step by step; the two backends'
    // CD anchors agree only to solver tolerance, so allow a feature or two
    // of slack at the sphere boundary (keep-decisions are exact-equal when
    // the anchor θ is shared — see the rule-level parity test above)
    for (rs, rd) in sparse.records.iter().zip(dense.records.iter()) {
        let diff = rs.kept.abs_diff(rd.kept);
        assert!(diff <= 2, "kept counts diverged at λ={}: {} vs {}", rs.lam, rs.kept, rd.kept);
    }
}

#[test]
fn lars_and_fista_also_run_on_csc_and_mmap() {
    use dpp_screen::solver::{fista::FistaSolver, lars::LarsSolver};
    let ds = sparse_problem(25, 60, 0.25, 7);
    let csc = ds.x.to_csc();
    let (mmap, dir) = mmap_backend(&ds, "solvers");
    let lam = 0.3 * dual::lambda_max(&csc, &ds.y);
    let cols: Vec<usize> = (0..60).collect();
    let opts = SolveOptions { tol_gap: 1e-9, ..Default::default() };
    let cd = CdSolver.solve(&csc, &ds.y, &cols, lam, None, &opts);
    let la = LarsSolver.solve(&mmap, &ds.y, &cols, lam, None, &opts);
    let fi = FistaSolver.solve(&mmap, &ds.y, &cols, lam, None, &opts);
    let obj = |b: &[f64]| dual::primal_objective(&csc, &ds.y, &cols, b, lam);
    let (o_cd, o_la, o_fi) = (obj(&cd.beta), obj(&la.beta), obj(&fi.beta));
    let scale = o_cd.abs().max(1.0);
    assert!((o_cd - o_la).abs() < 1e-6 * scale, "cd={o_cd} lars={o_la}");
    assert!((o_cd - o_fi).abs() < 1e-6 * scale, "cd={o_cd} fista={o_fi}");
    let _ = std::fs::remove_dir_all(dir);
}

// ---------------------------------------------------------------------------
// Row-sharded backend parity (`ShardSetMatrix` + worker pool): the reduce is
// a deterministic shard-order fold with one accumulator per output element,
// so keep-sets, CD trajectories and full EDPP paths are required to be
// **bit-identical** to CSC at every shard count and every thread count.
// ---------------------------------------------------------------------------

use dpp_screen::data::convert::split_shard;
use dpp_screen::linalg::ShardSetMatrix;
use dpp_screen::runtime::pool::WorkerPool;
use std::sync::Arc;

#[test]
fn every_rule_keep_set_identical_on_csc_and_sharded_at_1_2_3_shards() {
    let ds = sparse_problem(36, 150, 0.25, 21);
    let csc = ds.x.to_csc();
    let csc_ctx = ScreenContext::new(&csc, &ds.y);

    // exact sequential anchor from a high-precision solve at λ₀
    let cols: Vec<usize> = (0..150).collect();
    let opts = SolveOptions { tol_gap: 1e-11, ..Default::default() };
    let lam0 = 0.7 * csc_ctx.lam_max;
    let lam = 0.35 * csc_ctx.lam_max;
    let prev = CdSolver.solve(&csc, &ds.y, &cols, lam0, None, &opts).scatter(&cols, 150);
    let theta = theta_from_solution(&csc, &ds.y, &prev, lam0);
    let step = StepInput { lam_prev: lam0, lam, theta_prev: &theta };

    for k in [1usize, 2, 3] {
        let sh = ShardSetMatrix::split_csc(&csc, k)
            .with_pool(Arc::new(WorkerPool::new(k.max(2))));
        let sh_ctx = ScreenContext::new(&sh, &ds.y);
        // λmax and Xᵀy are sweep outputs: equal bits, not just close
        assert_eq!(csc_ctx.lam_max, sh_ctx.lam_max, "λmax, k={k}");
        assert_eq!(csc_ctx.xty, sh_ctx.xty, "Xᵀy, k={k}");
        assert_eq!(csc_ctx.col_norms, sh_ctx.col_norms, "col_norms, k={k}");
        for (rule_c, rule_s) in all_rules(36).into_iter().zip(all_rules(36)) {
            let mut keep_c = vec![true; 150];
            let mut keep_s = vec![true; 150];
            rule_c.screen(&csc_ctx, &step, &mut keep_c);
            rule_s.screen(&sh_ctx, &step, &mut keep_s);
            assert_eq!(
                keep_c,
                keep_s,
                "{} keep-set diverged between csc and {k}-shard backends",
                rule_c.name()
            );
        }
    }
}

#[test]
fn cd_trajectories_bit_identical_on_sharded_at_1_2_3_shards() {
    let ds = sparse_problem(30, 90, 0.2, 22);
    let csc = ds.x.to_csc();
    let lam = 0.3 * dual::lambda_max(&csc, &ds.y);
    let cols: Vec<usize> = (0..90).collect();
    let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
    let base = CdSolver.solve(&csc, &ds.y, &cols, lam, None, &opts);
    for k in [1usize, 2, 3] {
        let sh = ShardSetMatrix::split_csc(&csc, k);
        let r = CdSolver.solve(&sh, &ds.y, &cols, lam, None, &opts);
        assert_eq!(base.iters, r.iters, "iteration counts, k={k}");
        assert_eq!(base.beta, r.beta, "CD trajectory, k={k}");
        assert_eq!(base.gap, r.gap, "gap certificate, k={k}");
    }
}

#[test]
fn shard_boundary_through_a_dense_row_and_empty_shards_stay_exact() {
    // rows 10..14 fully dense (every feature hit), and the boundary set
    // places cuts *inside* that dense row block plus two empty shards
    let mut ds = sparse_problem(24, 60, 0.15, 23);
    {
        let x = ds.x.dense_mut().unwrap();
        let mut rng = Rng::new(99);
        for j in 0..60 {
            for i in 10..14 {
                x.col_mut(j)[i] = rng.normal();
            }
        }
    }
    let csc = ds.x.to_csc();
    let sh = ShardSetMatrix::split_csc_at(&csc, &[0, 0, 11, 12, 13, 24, 24]);
    assert_eq!(sh.shard_count(), 6); // two empty, three cutting the dense block
    assert_eq!(sh.to_csc(), csc);

    let csc_ctx = ScreenContext::new(&csc, &ds.y);
    let sh_ctx = ScreenContext::new(&sh, &ds.y);
    assert_eq!(csc_ctx.lam_max, sh_ctx.lam_max);
    let theta: Vec<f64> = ds.y.iter().map(|v| v / csc_ctx.lam_max).collect();
    let step = StepInput {
        lam_prev: csc_ctx.lam_max,
        lam: 0.4 * csc_ctx.lam_max,
        theta_prev: &theta,
    };
    let mut keep_c = vec![true; 60];
    let mut keep_s = vec![true; 60];
    EdppRule.screen(&csc_ctx, &step, &mut keep_c);
    EdppRule.screen(&sh_ctx, &step, &mut keep_s);
    assert_eq!(keep_c, keep_s);
}

/// The sharded acceptance criterion end to end: LIBSVM → `dpp convert`'s
/// streaming converter → `dpp shard`'s splitter (3 row ranges) → the
/// out-of-core `ShardSetMatrix` under a starved window → full sequential
/// EDPP path + service-style solves, bit-identical to the CSC backend fed
/// from the same file, at 1 and 3 pool threads.
#[test]
fn full_edpp_path_on_shardset_matches_csc_bit_identical() {
    let ds = sparse_problem(40, 200, 0.15, 24);
    let dir = shard_dir("shardset");
    let svm = dir.with_extension("svm");
    write_libsvm(&ds, &svm).unwrap();

    let loaded = read_libsvm(&svm, Some(200)).unwrap();
    let csc = loaded.x.to_csc();
    let shard = dir.with_extension("dppcsc");
    let summary = libsvm_to_shard(&svm, &shard, Some(200)).unwrap();
    assert_eq!(summary.nnz, csc.nnz());

    let set_dir = dir.with_extension("shards");
    let set = split_shard(&shard, &set_dir, 3).unwrap();
    assert_eq!(set.shards, 3);
    assert_eq!(set.nnz, csc.nnz());
    let y = read_shard_y(&set_dir).unwrap().expect("y.bin travels with the set");
    assert_eq!(y, loaded.y);

    let budget = 512; // far below any shard's entry data
    let grid = LambdaGrid::relative(&csc, &y, 10, 0.05, 1.0);
    let cfg = PathConfig::default();
    let sparse = solve_path(&csc, &y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    assert!(sparse.mean_rejection_ratio() > 0.8);
    for threads in [1usize, 3] {
        let sh = ShardSetMatrix::open_with_budget(&set_dir, budget)
            .unwrap()
            .with_pool(Arc::new(WorkerPool::new(threads)));
        assert_eq!(sh.shard_count(), 3);
        assert_eq!(sh.to_csc(), csc, "shard set must reproduce the CSC exactly");
        let paged = solve_path(&sh, &y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
        for (k, (rs, rm)) in sparse.records.iter().zip(paged.records.iter()).enumerate() {
            assert_eq!(rs.kept, rm.kept, "kept diverged at λ-index {k} ({threads} threads)");
            assert_eq!(rs.discarded, rm.discarded, "discarded diverged at λ-index {k}");
        }
        for (k, (bs, bm)) in sparse.betas.iter().zip(paged.betas.iter()).enumerate() {
            assert_eq!(bs, bm, "β diverged at λ-index {k} ({threads} threads)");
        }
    }
    let _ = std::fs::remove_dir_all(&set_dir);
    let _ = std::fs::remove_dir_all(&shard);
    let _ = std::fs::remove_file(&svm);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn service_on_sharded_matches_service_on_csc() {
    use dpp_screen::coordinator::service::ScreeningService;
    let ds = sparse_problem(30, 120, 0.2, 25);
    let csc = ds.x.to_csc();
    let sh = ShardSetMatrix::split_csc(&csc, 3);
    let lam_max = dual::lambda_max(&csc, &ds.y);
    let svc_c = ScreeningService::spawn(
        csc,
        ds.y.clone(),
        RuleKind::Edpp,
        SolverKind::Cd,
        PathConfig::default(),
    );
    let svc_s = ScreeningService::spawn(
        sh,
        ds.y.clone(),
        RuleKind::Edpp,
        SolverKind::Cd,
        PathConfig::default(),
    );
    for f in [0.7, 0.45, 0.2] {
        let rc = svc_c.screen(f * lam_max);
        let rs = svc_s.screen(f * lam_max);
        assert_eq!(rc.kept, rs.kept, "kept sets at {f}λmax");
        assert_eq!(rc.beta, rs.beta, "solutions at {f}λmax");
        assert_eq!(rc.discarded, rs.discarded);
    }
    svc_c.shutdown();
    svc_s.shutdown();
}

/// Single-rule pipelines are bit-identical to the `RuleKind` entry point:
/// the stateful `Screener` lifecycle must thread exactly the same θ*(λ₀)
/// the legacy driver hand-threaded — keep-sets, CD trajectories and full
/// EDPP paths equal bits, on CSC and on the sharded backend.
#[test]
fn single_rule_pipeline_bit_identical_to_rulekind_paths() {
    use dpp_screen::path::{solve_path_pipeline, solve_path_with_screener};
    use dpp_screen::screening::ScreenPipeline;

    let ds = sparse_problem(36, 160, 0.2, 26);
    let csc = ds.x.to_csc();
    let grid = LambdaGrid::relative(&csc, &ds.y, 10, 0.05, 1.0);
    let cfg = PathConfig::default();

    for rule in [RuleKind::Edpp, RuleKind::Strong, RuleKind::Dpp] {
        let legacy = solve_path(&csc, &ds.y, &grid, rule, SolverKind::Cd, &cfg);
        let pipe = ScreenPipeline::single(rule.name());
        let piped = solve_path_pipeline(&csc, &ds.y, &grid, &pipe, SolverKind::Cd, &cfg);
        let ctx = ScreenContext::new(&csc, &ds.y);
        let mut screener = pipe.build(csc.n_rows(), cfg.sequential);
        let manual =
            solve_path_with_screener(&ctx, &grid, screener.as_mut(), SolverKind::Cd, &cfg);
        assert_eq!(legacy.rule, piped.rule);
        for (k, ((bl, bp), bm)) in legacy
            .betas
            .iter()
            .zip(piped.betas.iter())
            .zip(manual.betas.iter())
            .enumerate()
        {
            assert_eq!(bl, bp, "{}: rulekind vs pipeline β at λ-index {k}", rule.name());
            assert_eq!(bp, bm, "{}: pipeline vs screener β at λ-index {k}", rule.name());
        }
        for ((rl, rp), rm) in legacy
            .records
            .iter()
            .zip(piped.records.iter())
            .zip(manual.records.iter())
        {
            assert_eq!(rl.kept, rp.kept, "{} kept", rule.name());
            assert_eq!(rl.discarded, rp.discarded, "{} discarded", rule.name());
            assert_eq!(rl.solver_iters, rp.solver_iters, "{} iters", rule.name());
            assert_eq!(rp.kept, rm.kept);
            assert_eq!(rp.solver_iters, rm.solver_iters);
        }
    }

    // and on the sharded backend: pipeline == rulekind, still bit-identical
    let sh = ShardSetMatrix::split_csc(&csc, 3).with_pool(Arc::new(WorkerPool::new(2)));
    let pipe = ScreenPipeline::single("edpp");
    let a = solve_path(&sh, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let b = solve_path_pipeline(&sh, &ds.y, &grid, &pipe, SolverKind::Cd, &cfg);
    for (k, (ba, bb)) in a.betas.iter().zip(b.betas.iter()).enumerate() {
        assert_eq!(ba, bb, "sharded β diverged at λ-index {k}");
    }
}

#[test]
fn group_path_runs_on_csc() {
    use dpp_screen::path::group::{solve_group_path, GroupRuleKind};
    use dpp_screen::solver::SolveOptions;
    let ds = dpp_screen::data::synthetic::group_synthetic(30, 120, 24, 3);
    let groups = ds.groups.clone().unwrap();
    let csc = ds.x.to_csc();
    let (glm_d, _) = dual::group_lambda_max(&ds.x, &ds.y, &groups);
    let (glm_s, _) = dual::group_lambda_max(&csc, &ds.y, &groups);
    assert!((glm_d - glm_s).abs() < 1e-12 * (1.0 + glm_d));
    let grid = LambdaGrid::relative_to(glm_s, 6, 0.1, 1.0);
    let opts = SolveOptions::default();
    let sp = solve_group_path(&csc, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
    let de = solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
    for (bs, bd) in sp.betas.iter().zip(de.betas.iter()) {
        for j in 0..ds.p() {
            assert!((bs[j] - bd[j]).abs() < 5e-3 * (1.0 + bd[j].abs()));
        }
    }
}

// ---------------------------------------------------------------------------
// Working-set strategy parity (DESIGN.md §3b): the working-set engine is
// built entirely from backend-agnostic kernels — restricted CD solves plus
// complement KKT sweeps through `DesignMatrix` — so its certified paths
// inherit the same contract as screen-first: gap-certified and β-close on
// dense vs CSC, and **bit-identical** on CSC vs the row-sharded pool
// backend (whose fold is a deterministic shard-order reduce).
// ---------------------------------------------------------------------------

#[test]
fn working_set_path_on_csc_matches_dense_to_tolerance() {
    use dpp_screen::path::{solve_path_pipeline, PathStrategy};
    use dpp_screen::screening::ScreenPipeline;

    let ds = sparse_problem(30, 260, 0.2, 27);
    let csc = ds.x.to_csc();
    let grid = LambdaGrid::relative(&csc, &ds.y, 10, 0.05, 1.0);
    let cfg = PathConfig { strategy: PathStrategy::WorkingSet, ..PathConfig::default() };
    let pipe = ScreenPipeline::single("strong");
    let dense = solve_path_pipeline(&ds.x, &ds.y, &grid, &pipe, SolverKind::Cd, &cfg);
    let sparse = solve_path_pipeline(&csc, &ds.y, &grid, &pipe, SolverKind::Cd, &cfg);
    // every non-trivial step must carry the full-problem certificate on
    // both backends — the engine never returns a heuristic solution
    let tol = cfg.solve_opts.tol_gap;
    for (k, (rd, rs)) in dense.records.iter().zip(sparse.records.iter()).enumerate() {
        if rd.kkt_passes > 0 {
            assert!(rd.gap <= tol, "dense step {k} uncertified: gap {}", rd.gap);
        }
        if rs.kkt_passes > 0 {
            assert!(rs.gap <= tol, "csc step {k} uncertified: gap {}", rs.gap);
        }
    }
    for (k, (bd, bs)) in dense.betas.iter().zip(sparse.betas.iter()).enumerate() {
        for j in 0..ds.p() {
            assert!(
                (bs[j] - bd[j]).abs() < 1e-4 * (1.0 + bd[j].abs()),
                "λ-index {k}, feature {j}: csc {} vs dense {}",
                bs[j],
                bd[j]
            );
        }
    }
}

#[test]
fn working_set_path_on_sharded_matches_csc_bit_identical() {
    use dpp_screen::path::{solve_path_pipeline, PathStrategy};
    use dpp_screen::screening::ScreenPipeline;

    let ds = sparse_problem(30, 260, 0.2, 28);
    let csc = ds.x.to_csc();
    let grid = LambdaGrid::relative(&csc, &ds.y, 10, 0.05, 1.0);
    let cfg = PathConfig { strategy: PathStrategy::WorkingSet, ..PathConfig::default() };
    let pipe = ScreenPipeline::single("strong");
    let base = solve_path_pipeline(&csc, &ds.y, &grid, &pipe, SolverKind::Cd, &cfg);
    let sh = ShardSetMatrix::split_csc(&csc, 3).with_pool(Arc::new(WorkerPool::new(2)));
    let paged = solve_path_pipeline(&sh, &ds.y, &grid, &pipe, SolverKind::Cd, &cfg);
    // identical sweep bits ⇒ identical violator scores ⇒ the expansion
    // trajectory itself (not just the final β) is required to match
    for (k, (rb, rp)) in base.records.iter().zip(paged.records.iter()).enumerate() {
        assert_eq!(rb.kept, rp.kept, "kept diverged at λ-index {k}");
        assert_eq!(
            rb.working_set_size, rp.working_set_size,
            "working-set size diverged at λ-index {k}"
        );
        assert_eq!(rb.kkt_passes, rp.kkt_passes, "kkt passes diverged at λ-index {k}");
    }
    for (k, (bb, bp)) in base.betas.iter().zip(paged.betas.iter()).enumerate() {
        assert_eq!(bb, bp, "β diverged at λ-index {k}");
    }
}
