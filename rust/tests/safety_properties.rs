//! Cross-module property suite: the paper's correctness claims, checked on
//! randomized problems across every rule × dataset family (DESIGN.md §9),
//! plus the composed-pipeline safety invariants (DESIGN.md §3).

use dpp_screen::data::{synthetic, RealDataset};
use dpp_screen::path::group::{
    solve_group_path, solve_group_path_working_set, GroupRuleKind,
};
use dpp_screen::path::{
    solve_path, solve_path_pipeline, LambdaGrid, PathConfig, PathStrategy, RuleKind,
    SolverKind,
};
use dpp_screen::screening::{
    dome::DomeRule, dpp::DppRule, edpp::EdppRule, edpp::Improvement1Rule,
    edpp::Improvement2Rule, safe::SafeRule, theta_from_solution, ScreenContext,
    ScreenPipeline, ScreeningRule, StepInput,
};
use dpp_screen::solver::{cd::CdSolver, dual, LassoSolver, SolveOptions};
use dpp_screen::util::prop;

/// Every safe rule on every dataset family: a discarded feature is a true
/// zero of the high-precision reference solution (the paper's Theorem 16
/// correctness claim, and its analogues for each baseline).
#[test]
fn safe_rules_never_discard_active_features() {
    let rules: Vec<(&str, Box<dyn ScreeningRule>)> = vec![
        ("safe", Box::new(SafeRule)),
        ("dpp", Box::new(DppRule)),
        ("imp1", Box::new(Improvement1Rule)),
        ("imp2", Box::new(Improvement2Rule)),
        ("edpp", Box::new(EdppRule)),
    ];
    prop::check("safe rules on mixed generators", 0x5AFE7, 8, |rng| {
        let pick = rng.usize(4);
        let mut ds = match pick {
            0 => synthetic::synthetic1(20 + rng.usize(20), 40 + rng.usize(60), 8, 0.1, rng.next_u64()),
            1 => synthetic::synthetic2(20 + rng.usize(20), 40 + rng.usize(60), 8, 0.1, rng.next_u64()),
            2 => RealDataset::ColonCancer.generate(false, rng.next_u64()),
            _ => RealDataset::BreastCancer.generate(false, rng.next_u64()),
        };
        if pick >= 2 {
            // keep the real-sim problems small enough for a tight loop
            ds.normalize_features().expect("in-RAM backend");
        }
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let f1 = rng.uniform(0.4, 1.0);
        let f2 = rng.uniform(0.1, f1 * 0.95);
        let (lam0, lam) = (f1 * ctx.lam_max, f2 * ctx.lam_max);
        let p = ds.p();
        let cols: Vec<usize> = (0..p).collect();
        let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let prev = CdSolver.solve(&ds.x, &ds.y, &cols, lam0, None, &opts).scatter(&cols, p);
        let theta = theta_from_solution(&ds.x, &ds.y, &prev, lam0);
        let exact = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts).scatter(&cols, p);
        let step = StepInput { lam_prev: lam0, lam, theta_prev: &theta };
        for (name, rule) in &rules {
            let mut keep = vec![true; p];
            rule.screen(&ctx, &step, &mut keep);
            for j in 0..p {
                if !keep[j] {
                    assert_eq!(
                        exact[j], 0.0,
                        "{name} discarded active feature {j} (β={})",
                        exact[j]
                    );
                }
            }
        }
    });
}

/// DOME on unit-norm problems (its required preconditioning).
#[test]
fn dome_safe_on_unit_norm_problems() {
    prop::check("dome basic safety", 0xD0ED, 8, |rng| {
        let seed = rng.next_u64();
        let mut ds = synthetic::synthetic2(25 + rng.usize(15), 50 + rng.usize(50), 10, 0.1, seed);
        ds.normalize_features().expect("in-RAM backend");
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let lam = rng.uniform(0.1, 0.9) * ctx.lam_max;
        let p = ds.p();
        let theta_max: Vec<f64> = ds.y.iter().map(|v| v / ctx.lam_max).collect();
        let step = StepInput { lam_prev: ctx.lam_max, lam, theta_prev: &theta_max };
        let mut keep = vec![true; p];
        DomeRule::default().screen(&ctx, &step, &mut keep);
        let cols: Vec<usize> = (0..p).collect();
        let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let exact = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts).scatter(&cols, p);
        for j in 0..p {
            if !keep[j] {
                assert_eq!(exact[j], 0.0, "dome discarded active {j}");
            }
        }
    });
}

/// Full paths: screened (safe or repaired-heuristic) solutions equal the
/// unscreened reference along the whole grid, for every rule × solver.
#[test]
fn screened_paths_reproduce_reference_solutions() {
    let ds = synthetic::synthetic1(40, 160, 14, 0.1, 0xBEEF);
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 8, 0.05, 1.0);
    let cfg = PathConfig::default();
    let reference = solve_path(&ds.x, &ds.y, &grid, RuleKind::None, SolverKind::Cd, &cfg);
    for rule in [
        RuleKind::Safe,
        RuleKind::Dpp,
        RuleKind::Improvement1,
        RuleKind::Improvement2,
        RuleKind::Edpp,
        RuleKind::Strong,
    ] {
        let out = solve_path(&ds.x, &ds.y, &grid, rule, SolverKind::Cd, &cfg);
        for (k, (bs, bb)) in out.betas.iter().zip(reference.betas.iter()).enumerate() {
            for j in 0..ds.p() {
                assert!(
                    (bs[j] - bb[j]).abs() < 2e-4 * (1.0 + bb[j].abs()),
                    "{} diverged at λ-index {k}, feature {j}",
                    rule.name()
                );
            }
        }
    }
}

/// λmax boundary behaviour (paper eq. (7)–(9)): zero solution above λmax,
/// θ*(λmax) = y/λmax, and every rule discards everything at λ ≥ λmax.
#[test]
fn lambda_max_boundary() {
    prop::check("λmax boundary", 0x1AB, 10, |rng| {
        let ds = synthetic::synthetic1(
            10 + rng.usize(30),
            20 + rng.usize(60),
            6,
            0.1,
            rng.next_u64(),
        );
        let lam_max = dual::lambda_max(&ds.x, &ds.y);
        let cols: Vec<usize> = (0..ds.p()).collect();
        let res = CdSolver.solve(
            &ds.x,
            &ds.y,
            &cols,
            lam_max * (1.0 + 1e-9),
            None,
            &SolveOptions::default(),
        );
        assert!(res.beta.iter().all(|b| *b == 0.0));
    });
}

/// The dominance chain holds along full paths, not just single steps:
/// mean rejection EDPP ≥ Imp1, Imp2 ≥ DPP ≥ nothing.
#[test]
fn rejection_dominance_along_paths() {
    let ds = synthetic::synthetic2(35, 140, 12, 0.1, 0xCAFE);
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 10, 0.05, 1.0);
    let cfg = PathConfig::default();
    let mean = |rule| {
        solve_path(&ds.x, &ds.y, &grid, rule, SolverKind::Cd, &cfg).mean_rejection_ratio()
    };
    let dpp = mean(RuleKind::Dpp);
    let i1 = mean(RuleKind::Improvement1);
    let i2 = mean(RuleKind::Improvement2);
    let edpp = mean(RuleKind::Edpp);
    assert!(i1 >= dpp - 1e-9, "imp1 {i1} < dpp {dpp}");
    assert!(i2 >= dpp - 1e-9, "imp2 {i2} < dpp {dpp}");
    assert!(edpp >= i1 - 1e-9, "edpp {edpp} < imp1 {i1}");
    assert!(edpp >= i2 - 1e-9, "edpp {edpp} < imp2 {i2}");
}

/// Pipeline safety invariant: a composed *safe* pipeline's discard set is
/// the union of its stages' discards — per-stage counts add up to the
/// step's discards — and never contains an active feature of the exact
/// solution.
#[test]
fn composed_safe_pipeline_discards_union_and_never_active() {
    prop::check("cascade of safe rules stays safe", 0xCA5CAD, 6, |rng| {
        let n = 20 + rng.usize(20);
        let p = 40 + rng.usize(80);
        let ds = synthetic::synthetic1(n, p, p / 6 + 1, 0.1, rng.next_u64());
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let lam = rng.uniform(0.15, 0.85) * ctx.lam_max;

        let pipe = ScreenPipeline::parse("cascade:dpp,improvement2,edpp").unwrap();
        let mut scr = pipe.build(n, true);
        scr.init(&ctx);
        assert!(scr.is_safe(), "cascade of safe rules must be safe");
        let mut keep = vec![true; p];
        let stages = scr.screen_step(&ctx, lam, &mut keep);
        assert_eq!(stages.len(), 3);
        let staged: usize = stages.iter().map(|s| s.discarded).sum();
        let discarded = keep.iter().filter(|k| !**k).count();
        assert_eq!(staged, discarded, "stage counts must sum to the union");

        // no active feature of the exact solution is discarded
        let cols: Vec<usize> = (0..p).collect();
        let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let exact = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts).scatter(&cols, p);
        for j in 0..p {
            if !keep[j] {
                assert_eq!(exact[j], 0.0, "cascade discarded active feature {j}");
            }
        }
    });
}

/// Hybrid invariants along full paths: with a *safe* rule as its own
/// certifier (`hybrid:edpp+edpp`) the pipeline is safe, triggers zero KKT
/// repairs, and its keep-set is exactly the safe rule's; with a heuristic
/// proposer (`hybrid:strong+edpp`) the repaired path reproduces the
/// reference solutions and its final mask still discards everything the
/// certifier discards.
#[test]
fn hybrid_pipeline_certification_invariants() {
    let ds = synthetic::synthetic1(35, 140, 12, 0.1, 0x4B2D);
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 8, 0.05, 1.0);
    let cfg = PathConfig::default();
    let edpp = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let reference = solve_path(&ds.x, &ds.y, &grid, RuleKind::None, SolverKind::Cd, &cfg);

    // safe certifier certifying itself: exactly the safe rule's screen
    let self_pipe = ScreenPipeline::parse("hybrid:edpp+edpp").unwrap();
    let selfhyb = solve_path_pipeline(&ds.x, &ds.y, &grid, &self_pipe, SolverKind::Cd, &cfg);
    assert_eq!(selfhyb.total_kkt_repairs(), 0, "safe hybrid must not repair");
    for (h, e) in selfhyb.records.iter().zip(edpp.records.iter()) {
        assert_eq!(h.discarded, e.discarded, "λ={}: self-hybrid ≠ edpp keep-set", h.lam);
    }
    for (bh, be) in selfhyb.betas.iter().zip(edpp.betas.iter()) {
        assert_eq!(bh, be, "self-hybrid trajectory diverged from edpp");
    }

    // heuristic proposer: exact after repair, mask dominates the certifier
    let pipe = ScreenPipeline::parse("hybrid:strong+edpp").unwrap();
    let hyb = solve_path_pipeline(&ds.x, &ds.y, &grid, &pipe, SolverKind::Cd, &cfg);
    for (k, (bh, br)) in hyb.betas.iter().zip(reference.betas.iter()).enumerate() {
        for j in 0..ds.p() {
            assert!(
                (bh[j] - br[j]).abs() < 2e-4 * (1.0 + br[j].abs()),
                "hybrid diverged at λ-index {k}, feature {j}"
            );
        }
    }
    for (h, e) in hyb.records.iter().zip(edpp.records.iter()) {
        assert!(
            h.discarded >= e.discarded,
            "λ={}: hybrid discarded {} < certifier {}",
            h.lam,
            h.discarded,
            e.discarded
        );
    }
}

/// Dynamic (gap-safe) refinement is safe end to end: the dynamic pipeline
/// reproduces the reference solutions and every record stays within the
/// safe rejection bound.
#[test]
fn dynamic_pipeline_safe_along_paths() {
    let ds = synthetic::synthetic2(30, 120, 10, 0.1, 0xD12A);
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 8, 0.05, 1.0);
    let cfg = PathConfig::default();
    let reference = solve_path(&ds.x, &ds.y, &grid, RuleKind::None, SolverKind::Cd, &cfg);
    // dynamic:hybrid is the delicate combination: in-solver drops issued
    // against a possibly-unrepaired heuristic reduced problem must be
    // re-validated by the KKT check, so the path stays exact
    let hybrid_dyn = ScreenPipeline::parse("dynamic:hybrid:strong+edpp").unwrap();
    let hd = solve_path_pipeline(&ds.x, &ds.y, &grid, &hybrid_dyn, SolverKind::Cd, &cfg);
    for (k, (bd, br)) in hd.betas.iter().zip(reference.betas.iter()).enumerate() {
        for j in 0..ds.p() {
            assert!(
                (bd[j] - br[j]).abs() < 2e-3 * (1.0 + br[j].abs()),
                "dynamic:hybrid diverged at λ-index {k}, feature {j}"
            );
        }
    }
    for solver in [SolverKind::Cd, SolverKind::Fista] {
        let pipe = ScreenPipeline::parse("dynamic:edpp").unwrap();
        let dynp = solve_path_pipeline(&ds.x, &ds.y, &grid, &pipe, solver, &cfg);
        for (k, (bd, br)) in dynp.betas.iter().zip(reference.betas.iter()).enumerate() {
            for j in 0..ds.p() {
                assert!(
                    (bd[j] - br[j]).abs() < 2e-3 * (1.0 + br[j].abs()),
                    "{}: dynamic diverged at λ-index {k}, feature {j}",
                    solver.name()
                );
            }
        }
        for r in &dynp.records {
            assert!(
                r.rejection_ratio() <= 1.0 + 1e-12,
                "{}: unsafe dynamic discard at λ={}",
                solver.name(),
                r.lam
            );
        }
    }
}

/// Working-set equivalence suite (DESIGN.md §3b): along full paths on
/// randomized problems, the working-set engine's solutions are within the
/// duality-gap tolerance of the unscreened reference, every non-trivial
/// step carries a certified full-problem gap, and no truly-active feature
/// is ever excluded from the final working set (zero false exclusions —
/// the engine's analogue of the safe-rule guarantee, earned by
/// certification rather than geometry).
#[test]
fn working_set_paths_equivalent_and_never_exclude_active() {
    prop::check("working-set equivalence", 0x3B5E7, 5, |rng| {
        let n = 20 + rng.usize(15);
        let p = 80 + rng.usize(80);
        let ds = if rng.usize(2) == 0 {
            synthetic::synthetic1(n, p, p / 8 + 1, 0.1, rng.next_u64())
        } else {
            synthetic::synthetic2(n, p, p / 8 + 1, 0.1, rng.next_u64())
        };
        let grid = LambdaGrid::relative(&ds.x, &ds.y, 6, 0.1, 1.0);
        let cfg = PathConfig::default();
        let reference =
            solve_path(&ds.x, &ds.y, &grid, RuleKind::None, SolverKind::Cd, &cfg);
        let ws_cfg =
            PathConfig { strategy: PathStrategy::WorkingSet, ..Default::default() };
        let spec = if rng.usize(2) == 0 { "strong" } else { "cascade:sis,edpp" };
        let pipe = ScreenPipeline::parse(spec).unwrap();
        let ws = solve_path_pipeline(&ds.x, &ds.y, &grid, &pipe, SolverKind::Cd, &ws_cfg);
        let tol = cfg.solve_opts.tol_gap;
        for (k, (bw, br)) in ws.betas.iter().zip(reference.betas.iter()).enumerate() {
            for j in 0..ds.p() {
                assert!(
                    (bw[j] - br[j]).abs() < 2e-4 * (1.0 + br[j].abs()),
                    "{spec}: working-set diverged at λ-index {k}, feature {j}: {} vs {}",
                    bw[j],
                    br[j]
                );
                // zero false exclusions: a clearly-active reference feature
                // must sit inside the final working set (nonzero in bw —
                // excluded features are exactly zero by construction)
                if br[j].abs() > 1e-3 {
                    assert!(
                        bw[j] != 0.0,
                        "{spec}: active feature {j} excluded at λ-index {k} (ref β={})",
                        br[j]
                    );
                }
            }
            let r = &ws.records[k];
            if r.kkt_passes > 0 {
                assert!(r.gap <= tol, "{spec}: uncertified λ-index {k}: gap {}", r.gap);
                assert_eq!(r.working_set_size + r.discarded, ds.p());
            }
        }
    });
}

/// Group working-set equivalence: restricted group subproblems certified by
/// the full-problem max_g ‖X_gᵀr‖/√n_g check reproduce the unscreened
/// group-BCD path and never exclude a group with nonzero reference energy.
#[test]
fn group_working_set_equivalent_to_baseline() {
    let ds = synthetic::group_synthetic(40, 240, 48, 0x6AB5);
    let groups = ds.groups.clone().unwrap();
    let (glm, _) = dual::group_lambda_max(&ds.x, &ds.y, &groups);
    let grid = LambdaGrid::relative_to(glm, 8, 0.1, 1.0);
    let opts = SolveOptions::default();
    let base =
        solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::None, &opts);
    let ws = solve_group_path_working_set(
        &ds.x,
        &ds.y,
        &groups,
        &grid,
        GroupRuleKind::Strong,
        &opts,
    );
    for (k, (bw, bb)) in ws.betas.iter().zip(base.betas.iter()).enumerate() {
        for j in 0..bw.len() {
            assert!(
                (bw[j] - bb[j]).abs() < 5e-3 * (1.0 + bb[j].abs()),
                "group working-set diverged at λ-index {k}, coeff {j}: {} vs {}",
                bw[j],
                bb[j]
            );
        }
        // zero false exclusions at group granularity
        for (g, &(start, len)) in groups.iter().enumerate() {
            let ref_nrm = bb[start..start + len]
                .iter()
                .fold(0.0f64, |acc, v| acc + v * v)
                .sqrt();
            if ref_nrm > 1e-3 {
                let ws_nrm = bw[start..start + len]
                    .iter()
                    .fold(0.0f64, |acc, v| acc + v * v)
                    .sqrt();
                assert!(ws_nrm > 0.0, "active group {g} excluded at λ-index {k}");
            }
        }
    }
    for r in ws.records.iter().filter(|r| r.kkt_passes > 0) {
        assert!(r.gap <= opts.tol_gap, "uncertified group step λ={}: {}", r.lam, r.gap);
    }
}

/// Failure injection: feed the path driver a grid that dips below and then
/// jumps back above λmax — records must stay consistent (trivial steps).
#[test]
fn non_monotone_grid_handled() {
    let ds = synthetic::synthetic1(20, 60, 6, 0.1, 0xF00D);
    let lam_max = dual::lambda_max(&ds.x, &ds.y);
    let grid = LambdaGrid {
        lam_max,
        values: vec![lam_max * 2.0, lam_max, 0.5 * lam_max, lam_max * 1.5, 0.3 * lam_max],
    };
    let out = solve_path(
        &ds.x,
        &ds.y,
        &grid,
        RuleKind::Edpp,
        SolverKind::Cd,
        &PathConfig::default(),
    );
    assert_eq!(out.records.len(), 5);
    // λ ≥ λmax steps are trivial
    assert!(out.betas[0].iter().all(|b| *b == 0.0));
    assert!(out.betas[3].iter().all(|b| *b == 0.0));
    // the small-λ steps are exact
    let cols: Vec<usize> = (0..60).collect();
    let exact = CdSolver
        .solve(
            &ds.x,
            &ds.y,
            &cols,
            0.3 * lam_max,
            None,
            &SolveOptions { tol_gap: 1e-12, ..Default::default() },
        )
        .scatter(&cols, 60);
    for j in 0..60 {
        assert!((out.betas[4][j] - exact[j]).abs() < 2e-4 * (1.0 + exact[j].abs()));
    }
}
