//! Multi-tenant serving protocol tests (DESIGN.md §4): concurrent
//! multi-session load is bit-identical to isolated single-session runs,
//! deadline-bounded requests come back gap-tagged instead of blocking, and
//! every failure mode that used to panic a worker is a typed error —
//! including admission-control shedding (`Overloaded`) and idle-session
//! eviction (`SessionClosed` with the eviction reason).

use std::sync::Arc;
use std::time::Duration;

use dpp_screen::coordinator::{
    AdmissionConfig, Coordinator, Request, RequestError, RequestOptions, Response,
    ScreeningService, SessionSpec,
};
use dpp_screen::data::synthetic;
use dpp_screen::linalg::{CscMatrix, DesignMatrix, ShardSetMatrix};
use dpp_screen::path::{PathConfig, PathStrategy, RuleKind, SolverKind};
use dpp_screen::runtime::pool::WorkerPool;
use dpp_screen::screening::ScreenPipeline;
use dpp_screen::solver::dual;

/// A sparse problem in CSC form plus its λmax.
fn sparse_problem(n: usize, p: usize, seed: u64) -> (CscMatrix, Vec<f64>, f64) {
    let ds = synthetic::synthetic1(n, p, p / 10, 0.1, seed);
    let csc = ds.x.to_csc();
    let lam_max = dual::lambda_max(&csc, &ds.y);
    (csc, ds.y.clone(), lam_max)
}

/// The per-session request program used by the bit-identity test:
/// descending screens, then a predict, then a path fit — mixed enough to
/// exercise warm-start state, anchor propagation, and the non-λ requests.
fn session_program(lam_max: f64, p: usize) -> Vec<Request> {
    vec![
        Request::Screen { lam: 0.8 * lam_max, opts: RequestOptions::default() },
        Request::Screen { lam: 0.55 * lam_max, opts: RequestOptions::default() },
        Request::Screen { lam: 0.3 * lam_max, opts: RequestOptions::default() },
        Request::Predict {
            features: (0..p).map(|j| ((j % 7) as f64 - 3.0) / 3.0).collect(),
            lam: 0.25 * lam_max,
            opts: RequestOptions::default(),
        },
        Request::FitPath { grid: 4, lo: 0.2, opts: RequestOptions::default() },
    ]
}

/// ≥3 sessions (csc + sharded backends, different datasets and pipelines)
/// served concurrently by one coordinator must answer every request
/// bit-identically to an isolated single-session coordinator replaying the
/// same per-session program.
#[test]
fn multi_session_responses_bit_identical_to_isolated() {
    let (csc_a, y_a, lm_a) = sparse_problem(30, 120, 41);
    let (csc_b, y_b, lm_b) = sparse_problem(35, 150, 42);
    let (csc_c, y_c, lm_c) = sparse_problem(40, 100, 43);
    let p_of = [csc_a.n_cols(), csc_b.n_cols(), csc_c.n_cols()];
    let lam_maxes = [lm_a, lm_b, lm_c];
    let pipelines = [
        ScreenPipeline::single("edpp"),
        ScreenPipeline::parse("hybrid:strong+edpp").unwrap(),
        ScreenPipeline::parse("dynamic:edpp").unwrap(),
    ];
    // session 1 runs the pool-parallel sharded backend over dataset B
    let make_backend = |i: usize| -> Box<dyn DesignMatrix + Send> {
        match i {
            0 => Box::new(csc_a.clone()),
            1 => Box::new(ShardSetMatrix::split_csc(&csc_b, 3)),
            _ => Box::new(csc_c.clone()),
        }
    };
    let ys = [y_a.clone(), y_b.clone(), y_c.clone()];

    let register_all = |coord: &Coordinator, only: Option<usize>| {
        for i in 0..3 {
            if only.is_some_and(|o| o != i) {
                continue;
            }
            coord
                .register(SessionSpec::boxed(
                    format!("s{i}"),
                    make_backend(i),
                    ys[i].clone(),
                    pipelines[i].clone(),
                    SolverKind::Cd,
                    PathConfig::default(),
                ))
                .unwrap();
        }
    };

    // --- isolated reference runs: one coordinator per session, requests
    // submitted one at a time ---
    let mut reference: Vec<Vec<Response>> = Vec::new();
    for i in 0..3 {
        let coord = Coordinator::new();
        register_all(&coord, Some(i));
        let mut responses = Vec::new();
        for req in session_program(lam_maxes[i], p_of[i]) {
            responses.push(
                coord.submit(&format!("s{i}"), req).recv_response().unwrap(),
            );
        }
        coord.shutdown();
        reference.push(responses);
    }

    // --- multi-tenant run: all three sessions on one coordinator with a
    // 3-thread pool, requests interleaved round-robin and submitted
    // up-front so per-session batches actually form ---
    let coord = Coordinator::with_pool(Some(Arc::new(WorkerPool::new(3))));
    register_all(&coord, None);
    let programs: Vec<Vec<Request>> =
        (0..3).map(|i| session_program(lam_maxes[i], p_of[i])).collect();
    let mut slots: Vec<(usize, usize, dpp_screen::coordinator::PendingResponse)> =
        Vec::new();
    for step in 0..programs[0].len() {
        for (i, program) in programs.iter().enumerate() {
            slots.push((
                i,
                step,
                coord.submit(&format!("s{i}"), program[step].clone()),
            ));
        }
    }
    for (i, step, slot) in slots {
        let got = slot.recv_response().unwrap();
        match (&reference[i][step], &got) {
            (Response::Screen(want), Response::Screen(have)) => {
                assert_eq!(want.lam, have.lam, "s{i} step {step} λ");
                assert_eq!(want.kept, have.kept, "s{i} step {step} keep-set");
                assert_eq!(want.beta, have.beta, "s{i} step {step} solution bits");
                assert_eq!(want.discarded, have.discarded);
                assert_eq!(want.true_zeros, have.true_zeros);
                assert_eq!(want.stage_discards, have.stage_discards);
                assert_eq!(want.dynamic_discards, have.dynamic_discards);
                assert_eq!(want.gap, have.gap, "s{i} step {step} gap bits");
                assert!(!have.partial);
            }
            (Response::Predict(want), Response::Predict(have)) => {
                assert_eq!(want.yhat, have.yhat, "s{i} prediction bits");
                assert_eq!(want.gap, have.gap);
                assert!(!have.partial);
            }
            (Response::Path(want), Response::Path(have)) => {
                assert_eq!(want.steps, have.steps);
                assert_eq!(want.rule, have.rule);
                assert_eq!(
                    want.mean_rejection, have.mean_rejection,
                    "s{i} path rejection bits"
                );
            }
            (want, have) => {
                panic!("s{i} step {step}: kind mismatch {want:?} vs {have:?}")
            }
        }
    }
    coord.shutdown();
}

/// Multi-session responses must also be bit-identical to the *legacy*
/// single-session facade (the pre-protocol `ScreeningService` surface).
#[test]
fn facade_matches_coordinator_session() {
    let (csc, y, lam_max) = sparse_problem(30, 110, 44);
    let svc = ScreeningService::spawn(
        csc.clone(),
        y.clone(),
        RuleKind::Edpp,
        SolverKind::Cd,
        PathConfig::default(),
    );
    let coord = Coordinator::new();
    coord
        .register(SessionSpec::new(
            "m",
            csc.clone(),
            y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        ))
        .unwrap();
    for f in [0.7, 0.45, 0.2] {
        let a = svc.screen(f * lam_max);
        let b = coord
            .submit("m", Request::Screen { lam: f * lam_max, opts: Default::default() })
            .recv()
            .unwrap();
        assert_eq!(a.kept, b.kept, "keep-set at {f}λmax");
        assert_eq!(a.beta, b.beta, "solution bits at {f}λmax");
        assert_eq!(a.stage_discards, b.stage_discards);
    }
    svc.shutdown();
    coord.shutdown();
}

/// A deadline-bounded request returns a gap-tagged partial response instead
/// of blocking, and partial iterates never advance the session's sequential
/// anchor.
#[test]
fn deadline_returns_gap_tagged_partial() {
    let ds = synthetic::synthetic1(80, 600, 40, 0.1, 45);
    let csc = ds.x.to_csc();
    let lam_max = dual::lambda_max(&csc, &ds.y);
    let cfg = PathConfig {
        solve_opts: dpp_screen::solver::SolveOptions {
            tol_gap: 1e-10,
            ..Default::default()
        },
        ..Default::default()
    };
    let svc = ScreeningService::spawn(
        csc,
        ds.y.clone(),
        ScreenPipeline::parse("dynamic:edpp").unwrap(),
        SolverKind::Cd,
        cfg,
    );
    // exact request first: anchors the session at 0.5 λmax
    let exact = svc.screen(0.5 * lam_max);
    assert!(!exact.partial);
    assert!(exact.gap <= 1e-10, "exact solve certifies its gap: {}", exact.gap);

    // an (effectively expired) deadline: the solve stops at its first
    // budget check and hands back the achieved duality gap
    let partial = svc
        .request_with(0.1 * lam_max, RequestOptions::with_deadline(Duration::from_micros(1)))
        .recv()
        .unwrap();
    assert!(partial.partial, "deadline request must be tagged partial");
    assert!(partial.gap.is_finite());
    assert!(partial.gap > 1e-10, "partial gap reflects the unfinished solve");
    assert_eq!(partial.beta.len(), 600);

    // the partial iterate must not have advanced the sequential anchor
    let stats = match svc
        .coordinator()
        .submit(dpp_screen::coordinator::SERVICE_SESSION, Request::SessionStats)
        .recv_response()
        .unwrap()
    {
        Response::Stats(s) => s,
        other => panic!("expected stats, got {other:?}"),
    };
    assert_eq!(stats.anchor_lam, exact.lam, "partial advanced the anchor");
    assert_eq!(stats.metrics.partials, 1);

    // the same λ without a deadline still resolves exactly
    let redo = svc.screen(0.1 * lam_max);
    assert!(!redo.partial);
    assert!(redo.gap <= 1e-10);
    svc.shutdown();
}

/// CSC backend that forwards everything except `col_dot_w`, which panics —
/// simulating a worker-side failure mid-solve. The coordinator must turn it
/// into a typed `SessionClosed` carrying the panic payload instead of a
/// poisoned channel.
struct PanickyMatrix {
    inner: CscMatrix,
}

impl DesignMatrix for PanickyMatrix {
    fn n_rows(&self) -> usize {
        self.inner.n_rows()
    }
    fn n_cols(&self) -> usize {
        self.inner.n_cols()
    }
    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        self.inner.xt_w(w, out)
    }
    fn col_dot_w(&self, _j: usize, _w: &[f64]) -> f64 {
        panic!("injected col_dot_w failure")
    }
    fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]) {
        self.inner.col_axpy_into(j, a, out)
    }
    fn col_sq_norm(&self, j: usize) -> f64 {
        self.inner.col_sq_norm(j)
    }
    fn col_dot_col(&self, i: usize, j: usize) -> f64 {
        self.inner.col_dot_col(i, j)
    }
    fn col_into(&self, j: usize, out: &mut [f64]) {
        self.inner.col_into(j, out)
    }
    fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]) {
        self.inner.col_gather(j, rows, out)
    }
    fn nnz(&self) -> usize {
        self.inner.nnz()
    }
}

#[test]
fn worker_panic_becomes_typed_session_closed_with_reason() {
    let (csc, y, lam_max) = sparse_problem(25, 80, 46);
    let coord = Coordinator::new();
    coord
        .register(SessionSpec::new(
            "bad",
            PanickyMatrix { inner: csc.clone() },
            y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        ))
        .unwrap();
    coord
        .register(SessionSpec::new(
            "good",
            csc,
            y,
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        ))
        .unwrap();
    // first request trips the panic; the reason is the panic payload
    let err = coord
        .submit("bad", Request::Screen { lam: 0.5 * lam_max, opts: Default::default() })
        .recv()
        .unwrap_err();
    match &err {
        RequestError::SessionClosed { session, reason } => {
            assert_eq!(session, "bad");
            assert!(reason.contains("injected col_dot_w failure"), "reason: {reason}");
        }
        other => panic!("expected SessionClosed, got {other:?}"),
    }
    // the session stays closed with the same reason…
    let again = coord
        .submit("bad", Request::Screen { lam: 0.4 * lam_max, opts: Default::default() })
        .recv()
        .unwrap_err();
    assert_eq!(err, again);
    // …and the coordinator (plus its other sessions) survives
    let ok = coord
        .submit("good", Request::Screen { lam: 0.5 * lam_max, opts: Default::default() })
        .recv()
        .unwrap();
    assert!(!ok.beta.is_empty());
    coord.shutdown();
}

/// The facade's Result surface: NaN λ, worker death, and post-shutdown
/// submission are all typed errors (the old loop panicked on all three).
#[test]
fn facade_try_screen_surfaces_worker_death() {
    let (csc, y, lam_max) = sparse_problem(20, 60, 47);
    let svc = ScreeningService::spawn(
        PanickyMatrix { inner: csc },
        y,
        RuleKind::Edpp,
        SolverKind::Cd,
        PathConfig::default(),
    );
    match svc.try_screen(0.5 * lam_max) {
        Err(RequestError::SessionClosed { reason, .. }) => {
            assert!(reason.contains("injected"), "reason: {reason}")
        }
        other => panic!("expected SessionClosed, got {other:?}"),
    }
    svc.shutdown();
}

/// Warm / Predict / FitPath / SessionStats round-trips, including typed
/// validation of malformed requests.
#[test]
fn protocol_roundtrip_and_validation() {
    let (csc, y, lam_max) = sparse_problem(30, 90, 48);
    let p = csc.n_cols();
    let coord = Coordinator::new();
    coord
        .register(
            SessionSpec::new(
                "s",
                csc,
                y,
                RuleKind::Edpp,
                SolverKind::Cd,
                PathConfig::default(),
            )
            .with_backend_label("csc"),
        )
        .unwrap();
    let submit = |req: Request| coord.submit("s", req).recv_response().unwrap();

    // warm tightens the anchor without shipping β
    let warmed = match submit(Request::Warm { lam: 0.6 * lam_max }) {
        Response::Warmed(w) => w,
        other => panic!("expected warm, got {other:?}"),
    };
    assert!(warmed.gap <= 1e-7);
    let stats = match submit(Request::SessionStats) {
        Response::Stats(s) => s,
        other => panic!("expected stats, got {other:?}"),
    };
    assert_eq!(stats.session, "s");
    assert_eq!(stats.backend, "csc");
    assert_eq!(stats.pipeline, "edpp");
    assert_eq!(stats.anchor_lam, warmed.lam);
    assert_eq!(stats.metrics.requests, 1);

    // predict agrees with an explicit screen + dot product
    let screen = match submit(Request::Screen {
        lam: 0.4 * lam_max,
        opts: Default::default(),
    }) {
        Response::Screen(s) => s,
        other => panic!("expected screen, got {other:?}"),
    };
    let features: Vec<f64> = (0..p).map(|j| (j as f64).cos()).collect();
    let want: f64 =
        features.iter().zip(screen.beta.iter()).map(|(f, b)| f * b).sum();
    let pred = match submit(Request::Predict {
        features: features.clone(),
        lam: 0.4 * lam_max,
        opts: Default::default(),
    }) {
        Response::Predict(pr) => pr,
        other => panic!("expected predict, got {other:?}"),
    };
    assert!(
        (pred.yhat - want).abs() <= 1e-6 * (1.0 + want.abs()),
        "ŷ {} vs screen·dot {want}",
        pred.yhat
    );

    // a path fit reports its summary
    let path = match submit(Request::FitPath {
        grid: 5,
        lo: 0.2,
        opts: Default::default(),
    }) {
        Response::Path(ps) => ps,
        other => panic!("expected path, got {other:?}"),
    };
    assert_eq!(path.steps, 5);
    assert_eq!(path.rule, "edpp");
    assert!(path.mean_rejection <= 1.0 + 1e-12);

    // malformed requests are typed errors, not panics
    match submit(Request::Predict {
        features: vec![1.0; p + 1],
        lam: 0.4 * lam_max,
        opts: Default::default(),
    }) {
        Response::Error(RequestError::InvalidRequest(msg)) => {
            assert!(msg.contains("length"), "{msg}")
        }
        other => panic!("expected InvalidRequest, got {other:?}"),
    }
    match submit(Request::FitPath { grid: 0, lo: 0.2, opts: Default::default() }) {
        Response::Error(RequestError::InvalidRequest(_)) => {}
        other => panic!("expected InvalidRequest, got {other:?}"),
    }
    match submit(Request::Screen { lam: f64::NAN, opts: Default::default() }) {
        Response::Error(RequestError::InvalidLambda(_)) => {}
        other => panic!("expected InvalidLambda, got {other:?}"),
    }
    coord.shutdown();
}

/// Field-wise equality of two responses, ignoring `latency_s` (wall-clock
/// timings legitimately differ across transports — everything the solver
/// computed must not).
fn assert_same_payload(want: &Response, have: &Response, ctx: &str) {
    match (want, have) {
        (Response::Screen(w), Response::Screen(h)) => {
            assert_eq!(w.lam, h.lam, "{ctx} λ");
            assert_eq!(w.kept, h.kept, "{ctx} keep-set");
            assert_eq!(w.beta, h.beta, "{ctx} solution bits");
            assert_eq!(w.discarded, h.discarded, "{ctx} discarded");
            assert_eq!(w.true_zeros, h.true_zeros, "{ctx} true zeros");
            assert_eq!(w.stage_discards, h.stage_discards, "{ctx} stages");
            assert_eq!(w.dynamic_discards, h.dynamic_discards, "{ctx} dynamic");
            assert_eq!(w.gap, h.gap, "{ctx} gap bits");
            assert_eq!(w.partial, h.partial, "{ctx} partial tag");
        }
        (Response::Predict(w), Response::Predict(h)) => {
            assert_eq!(w.lam, h.lam, "{ctx} λ");
            assert_eq!(w.yhat, h.yhat, "{ctx} prediction bits");
            assert_eq!(w.gap, h.gap, "{ctx} gap bits");
            assert_eq!(w.partial, h.partial, "{ctx} partial tag");
        }
        (Response::Path(w), Response::Path(h)) => {
            assert_eq!(w.steps, h.steps, "{ctx} steps");
            assert_eq!(w.rule, h.rule, "{ctx} rule");
            assert_eq!(w.solver, h.solver, "{ctx} solver");
            assert_eq!(w.mean_rejection, h.mean_rejection, "{ctx} rejection bits");
            assert_eq!(w.max_gap, h.max_gap, "{ctx} max-gap bits");
            assert_eq!(w.partial, h.partial, "{ctx} partial tag");
        }
        (w, h) => panic!("{ctx}: kind mismatch {w:?} vs {h:?}"),
    }
}

/// The tentpole claim end-to-end: responses served over a loopback socket —
/// including a session whose `ShardSetMatrix` shards live in shard-node
/// threads behind real TCP connections — are bit-identical to the same
/// program served by an in-process coordinator (the design matrix of the
/// remote session never crosses into the serving process).
#[test]
fn socket_responses_bit_identical_to_in_process() {
    use dpp_screen::net::{spawn_shard_node, NetClient, NetServer};

    let (csc_a, y_a, lm_a) = sparse_problem(30, 120, 61);
    let (csc_b, y_b, lm_b) = sparse_problem(35, 140, 62);
    let (csc_c, y_c, lm_c) = sparse_problem(28, 100, 63);
    let p_of = [csc_a.n_cols(), csc_b.n_cols(), csc_c.n_cols()];
    let lam_maxes = [lm_a, lm_b, lm_c];
    let ys = [y_a, y_b, y_c];
    let pipelines = [
        ScreenPipeline::single("edpp"),
        ScreenPipeline::parse("hybrid:strong+edpp").unwrap(),
        ScreenPipeline::parse("dynamic:edpp").unwrap(),
    ];
    // session 1's shards, split once so the local reference and the remote
    // nodes hold the identical row ranges
    let local_set = ShardSetMatrix::split_csc(&csc_b, 2);

    // --- in-process reference: sequential per-session programs ---
    let reference: Vec<Vec<Response>> = (0..3)
        .map(|i| {
            let coord = Coordinator::new();
            let backend: Box<dyn DesignMatrix + Send> = match i {
                0 => Box::new(csc_a.clone()),
                1 => Box::new(ShardSetMatrix::split_csc(&csc_b, 2)),
                _ => Box::new(csc_c.clone()),
            };
            coord
                .register(SessionSpec::boxed(
                    format!("s{i}"),
                    backend,
                    ys[i].clone(),
                    pipelines[i].clone(),
                    SolverKind::Cd,
                    PathConfig::default(),
                ))
                .unwrap();
            let out = session_program(lam_maxes[i], p_of[i])
                .into_iter()
                .map(|req| {
                    coord.submit(&format!("s{i}"), req).recv_response().unwrap()
                })
                .collect();
            coord.shutdown();
            out
        })
        .collect();

    // --- socket run: session 1 backed by two live shard-node listeners ---
    let mut nodes = Vec::new();
    let mut addrs = Vec::new();
    for shard in local_set.shards() {
        let node = spawn_shard_node(shard.backend().clone(), "127.0.0.1:0").unwrap();
        addrs.push(node.addr().to_string());
        nodes.push(node);
    }
    let coord = Coordinator::new();
    for i in 0..3 {
        let backend: Box<dyn DesignMatrix + Send> = match i {
            0 => Box::new(csc_a.clone()),
            1 => Box::new(ShardSetMatrix::connect(&addrs).unwrap()),
            _ => Box::new(csc_c.clone()),
        };
        coord
            .register(SessionSpec::boxed(
                format!("s{i}"),
                backend,
                ys[i].clone(),
                pipelines[i].clone(),
                SolverKind::Cd,
                PathConfig::default(),
            ))
            .unwrap();
    }
    let server = NetServer::bind(coord, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let mut client = NetClient::connect(&addr).unwrap();
    let advertised: Vec<&str> = client.sessions().iter().map(|s| s.as_str()).collect();
    assert_eq!(advertised, ["s0", "s1", "s2"], "hello advertises sessions");

    // pipeline the whole interleaved burst, then read replies in order —
    // exercising frame sequencing, batch formation, and id matching at once
    let programs: Vec<Vec<Request>> =
        (0..3).map(|i| session_program(lam_maxes[i], p_of[i])).collect();
    let mut expected = Vec::new();
    for step in 0..programs[0].len() {
        for (i, program) in programs.iter().enumerate() {
            let id = client
                .submit(&format!("s{i}"), program[step].clone())
                .unwrap();
            expected.push((id, i, step));
        }
    }
    for (id, i, step) in expected {
        let (got_id, response) = client.recv_reply().unwrap();
        assert_eq!(got_id, id, "replies arrive in submission order");
        assert_same_payload(
            &reference[i][step],
            &response,
            &format!("s{i} step {step} over socket"),
        );
    }

    client.shutdown_server().unwrap();
    let metrics = server_thread.join().unwrap();
    assert_eq!(metrics.len(), 3, "shutdown reports every session's metrics");
    for node in nodes {
        node.stop();
        node.join();
    }
}

/// Deadline semantics survive the wire: a request with an (effectively
/// expired) deadline comes back gap-tagged partial through the socket,
/// and the following exact request is unaffected.
#[test]
fn deadline_over_socket_round_trips_partial() {
    use dpp_screen::net::{NetClient, NetServer};

    let ds = synthetic::synthetic1(80, 600, 40, 0.1, 64);
    let csc = ds.x.to_csc();
    let lam_max = dual::lambda_max(&csc, &ds.y);
    let cfg = PathConfig {
        solve_opts: dpp_screen::solver::SolveOptions {
            tol_gap: 1e-10,
            ..Default::default()
        },
        ..Default::default()
    };
    let coord = Coordinator::new();
    coord
        .register(SessionSpec::new(
            "d",
            csc,
            ds.y.clone(),
            ScreenPipeline::parse("dynamic:edpp").unwrap(),
            SolverKind::Cd,
            cfg,
        ))
        .unwrap();
    let server = NetServer::bind(coord, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());
    let mut client = NetClient::connect(&addr).unwrap();

    let exact = match client
        .request("d", Request::Screen { lam: 0.5 * lam_max, opts: Default::default() })
        .unwrap()
    {
        Response::Screen(s) => s,
        other => panic!("expected screen, got {other:?}"),
    };
    assert!(!exact.partial);
    assert!(exact.gap <= 1e-10);

    let partial = match client
        .request(
            "d",
            Request::Screen {
                lam: 0.1 * lam_max,
                opts: RequestOptions::with_deadline(Duration::from_micros(1)),
            },
        )
        .unwrap()
    {
        Response::Screen(s) => s,
        other => panic!("expected screen, got {other:?}"),
    };
    assert!(partial.partial, "expired deadline must come back partial-tagged");
    assert!(partial.gap.is_finite());
    assert!(partial.gap > 1e-10, "partial gap reflects the unfinished solve");

    client.shutdown_server().unwrap();
    server_thread.join().unwrap();
}

/// A server that vanishes mid-request surfaces as the typed
/// `RequestError::Disconnected` — no panic, no hang. The "server" here is
/// a raw listener that completes the hello handshake, reads one request,
/// and drops the socket without replying.
#[test]
fn peer_disconnect_mid_request_is_typed_disconnected() {
    use dpp_screen::net::frame::{read_frame, write_frame};
    use dpp_screen::net::wire::{
        decode_client_msg, encode_server_msg, ClientMsg, ServerMsg, WIRE_VERSION,
    };
    use dpp_screen::net::NetClient;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake_server = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let hello = read_frame(&mut stream).unwrap();
        assert!(matches!(
            decode_client_msg(&hello).unwrap(),
            ClientMsg::Hello { version: WIRE_VERSION }
        ));
        let reply = encode_server_msg(&ServerMsg::Hello {
            version: WIRE_VERSION,
            sessions: vec!["s0".to_string()],
        });
        write_frame(&mut stream, &reply).unwrap();
        // read the request, then hang up without answering
        let _ = read_frame(&mut stream).unwrap();
    });

    let mut client = NetClient::connect(&addr).unwrap();
    assert_eq!(client.sessions().len(), 1);
    assert_eq!(client.sessions()[0], "s0");
    let err = client
        .request("s0", Request::Screen { lam: 1.0, opts: Default::default() })
        .unwrap_err();
    match err {
        RequestError::Disconnected(msg) => {
            assert!(msg.contains("reading reply"), "actionable message: {msg}")
        }
        other => panic!("expected Disconnected, got {other:?}"),
    }
    fake_server.join().unwrap();
}

/// Heavy-tenant fairness must not cost determinism: one sharded session
/// with ~10× the work of each light session, served concurrently at 1, 2,
/// and 4 pool threads, answers every request bit-identically to isolated
/// single-session runs. The scheduler only changes *where* a session's
/// batches execute (and which idle workers its nested fork/join borrows) —
/// never *what* they compute.
#[test]
fn heavy_tenant_bit_identical_across_thread_counts() {
    let (heavy_csc, heavy_y, heavy_lm) = sparse_problem(60, 500, 71);
    let lights: Vec<(CscMatrix, Vec<f64>, f64)> =
        (0..3).map(|i| sparse_problem(30, 100, 72 + i)).collect();

    let name_of = |i: usize| -> String {
        if i == 0 { "heavy".to_string() } else { format!("light{}", i - 1) }
    };
    let make_spec = |i: usize| -> SessionSpec {
        if i == 0 {
            SessionSpec::new(
                name_of(i),
                ShardSetMatrix::split_csc(&heavy_csc, 3),
                heavy_y.clone(),
                ScreenPipeline::single("edpp"),
                SolverKind::Cd,
                PathConfig::default(),
            )
        } else {
            let (csc, y, _) = &lights[i - 1];
            SessionSpec::new(
                name_of(i),
                csc.clone(),
                y.clone(),
                ScreenPipeline::single("edpp"),
                SolverKind::Cd,
                PathConfig::default(),
            )
        }
    };
    let program_of = |i: usize| -> Vec<Request> {
        if i == 0 {
            session_program(heavy_lm, heavy_csc.n_cols())
        } else {
            let (csc, _, lm) = &lights[i - 1];
            session_program(*lm, csc.n_cols())
        }
    };

    // isolated references: one coordinator per session, sequential requests
    let reference: Vec<Vec<Response>> = (0..4)
        .map(|i| {
            let coord = Coordinator::new();
            coord.register(make_spec(i)).unwrap();
            let out = program_of(i)
                .into_iter()
                .map(|req| coord.submit(&name_of(i), req).recv_response().unwrap())
                .collect();
            coord.shutdown();
            out
        })
        .collect();

    for threads in [1usize, 2, 4] {
        let coord =
            Coordinator::with_pool(Some(Arc::new(WorkerPool::new(threads))));
        for i in 0..4 {
            coord.register(make_spec(i)).unwrap();
        }
        let programs: Vec<Vec<Request>> = (0..4).map(program_of).collect();
        let mut slots = Vec::new();
        for step in 0..programs[0].len() {
            for (i, program) in programs.iter().enumerate() {
                slots.push((
                    i,
                    step,
                    coord.submit(&name_of(i), program[step].clone()),
                ));
            }
        }
        for (i, step, slot) in slots {
            let got = slot.recv_response().unwrap();
            assert_same_payload(
                &reference[i][step],
                &got,
                &format!("{} step {step} at {threads} threads", name_of(i)),
            );
        }
        coord.shutdown();
    }
}

/// The admission depth cap sheds protocol-level load with the typed
/// `Overloaded` error and a deterministic retry hint — requests never
/// queue unboundedly. (`depth=0` makes every submit shed, so the test
/// never races the solver.)
#[test]
fn admission_cap_sheds_with_typed_overloaded() {
    let (csc, y, lam_max) = sparse_problem(25, 80, 75);
    let coord = Coordinator::with_config(
        None,
        AdmissionConfig { max_session_pending: Some(0), ..Default::default() },
    );
    coord
        .register(SessionSpec::new(
            "s",
            csc,
            y,
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        ))
        .unwrap();
    let err = coord
        .submit("s", Request::Screen { lam: 0.5 * lam_max, opts: Default::default() })
        .recv()
        .unwrap_err();
    match err {
        RequestError::Overloaded { retry_after_ms } => {
            assert!(retry_after_ms >= 25, "retry hint: {retry_after_ms}")
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    let stats = coord.admission_stats();
    assert_eq!(stats.shed, 1);
    coord.shutdown();
}

/// An idle session past its TTL is evicted by the router's sweep; later
/// requests to it get the typed `SessionClosed` carrying the eviction
/// reason — not the anonymous `UnknownSession`.
#[test]
fn evicted_session_requests_get_typed_eviction_reason() {
    let (csc, y, lam_max) = sparse_problem(25, 80, 76);
    let coord = Coordinator::with_config(
        None,
        AdmissionConfig {
            session_ttl: Some(Duration::from_millis(0)),
            ..Default::default()
        },
    );
    coord
        .register(SessionSpec::new(
            "tmp",
            csc,
            y,
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        ))
        .unwrap();
    // the sweep runs on the router's TTL tick; poll until it fires
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !coord.sessions().is_empty() {
        assert!(std::time::Instant::now() < deadline, "eviction never fired");
        std::thread::sleep(Duration::from_millis(5));
    }
    let err = coord
        .submit("tmp", Request::Screen { lam: 0.5 * lam_max, opts: Default::default() })
        .recv()
        .unwrap_err();
    match err {
        RequestError::SessionClosed { session, reason } => {
            assert_eq!(session, "tmp");
            assert!(reason.contains("evicted"), "reason: {reason}");
        }
        other => panic!("expected SessionClosed, got {other:?}"),
    }
    assert_eq!(coord.admission_stats().evicted, 1);
    coord.shutdown();
}

/// A FISTA-backed session serves certified answers over the protocol, and
/// the per-request solver override (`RequestOptions::solver`) runs without
/// disturbing the session. The first screen's keep-set is anchor-determined
/// (computed before any solve), so it must agree bit-for-bit with a CD
/// session on the identical problem.
#[test]
fn fista_session_serves_and_solver_override_round_trips() {
    let (csc, y, lam_max) = sparse_problem(30, 110, 77);
    let coord = Coordinator::new();
    for (name, solver) in [("f", SolverKind::Fista), ("c", SolverKind::Cd)] {
        coord
            .register(SessionSpec::new(
                name,
                csc.clone(),
                y.clone(),
                RuleKind::Edpp,
                solver,
                PathConfig::default(),
            ))
            .unwrap();
    }
    let screen = |name: &str, lam: f64, opts: RequestOptions| {
        match coord.submit(name, Request::Screen { lam, opts }).recv_response().unwrap()
        {
            Response::Screen(s) => s,
            other => panic!("expected screen, got {other:?}"),
        }
    };
    let fista = screen("f", 0.5 * lam_max, RequestOptions::default());
    let cd = screen("c", 0.5 * lam_max, RequestOptions::default());
    assert!(fista.gap <= 1e-6, "FISTA gap certifies: {}", fista.gap);
    assert_eq!(fista.kept, cd.kept, "anchor-determined keep-set is solver-independent");

    // per-request CD override on the FISTA session: typed, certified, and
    // the session keeps serving afterwards (momentum state is untouched —
    // pinned down in the registry unit tests)
    let opts = RequestOptions { solver: Some(SolverKind::Cd), ..Default::default() };
    let overridden = screen("f", 0.4 * lam_max, opts);
    assert!(overridden.gap <= 1e-6);
    let after = screen("f", 0.3 * lam_max, RequestOptions::default());
    assert!(after.gap <= 1e-6);
    coord.shutdown();
}

/// Under the working-set strategy a session's accumulated working set is
/// serving state: the first FitPath pays expansion rounds growing each λ's
/// restricted problem from the (deliberately tight) SIS seed, and a repeat
/// of the identical request seeds every λ from the active sets already
/// discovered — one complement sweep per λ certifies, so the second
/// request's total KKT passes are *strictly* smaller.
#[test]
fn repeat_fitpath_reuses_cached_working_set() {
    let (csc, y, _lam_max) = sparse_problem(30, 300, 85);
    let p = csc.n_cols();
    let coord = Coordinator::new();
    coord
        .register(SessionSpec::new(
            "w",
            csc,
            y,
            ScreenPipeline::single("sis"),
            SolverKind::Cd,
            PathConfig { strategy: PathStrategy::WorkingSet, ..PathConfig::default() },
        ))
        .unwrap();
    let fit = || match coord
        .submit("w", Request::FitPath { grid: 6, lo: 0.1, opts: Default::default() })
        .recv_response()
        .unwrap()
    {
        Response::Path(ps) => ps,
        other => panic!("expected path summary, got {other:?}"),
    };
    let first = fit();
    let second = fit();
    // both fits are exact-to-tolerance — the strategy never trades the gap
    // contract for speed
    let tol = PathConfig::default().solve_opts.tol_gap;
    assert!(!first.partial && !second.partial);
    assert!(first.max_gap <= tol, "first fit uncertified: {}", first.max_gap);
    assert!(second.max_gap <= tol, "second fit uncertified: {}", second.max_gap);
    // the cold fit needed expansion sweeps beyond one-per-λ; the warm fit
    // certifies from the cached working set in exactly one sweep per λ
    assert!(
        second.kkt_passes < first.kkt_passes,
        "repeat FitPath did not reuse the session working set: {} vs {} passes",
        second.kkt_passes,
        first.kkt_passes
    );
    // and it really ran restricted: the mean working set is a small slice
    // of p, not the full problem
    assert!(second.mean_working_set > 0.0);
    assert!(
        second.mean_working_set < p as f64,
        "working set degenerated to the full problem"
    );
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// Front tier (DESIGN.md §4c): session-affine routing across server processes
// ---------------------------------------------------------------------------

/// Register three standard sessions (distinct datasets and pipelines) on a
/// fresh coordinator, keeping the fixtures identical across backends.
fn front_fixture() -> Vec<(CscMatrix, Vec<f64>, f64)> {
    vec![sparse_problem(30, 120, 81), sparse_problem(35, 140, 82), sparse_problem(28, 100, 83)]
}

fn front_register(coord: &Coordinator, fixtures: &[(CscMatrix, Vec<f64>, f64)], which: &[usize]) {
    let pipelines = [
        ScreenPipeline::single("edpp"),
        ScreenPipeline::parse("hybrid:strong+edpp").unwrap(),
        ScreenPipeline::parse("dynamic:edpp").unwrap(),
    ];
    for &i in which {
        let (csc, y, _) = &fixtures[i];
        coord
            .register(SessionSpec::new(
                format!("s{i}"),
                csc.clone(),
                y.clone(),
                pipelines[i].clone(),
                SolverKind::Cd,
                PathConfig::default(),
            ))
            .unwrap();
    }
}

/// One backend: the interleaved multi-session program answered through a
/// `Front` is bit-identical, reply for reply, to the same program against
/// an identical backend over a direct socket — the routing hop adds no
/// observable behaviour.
#[test]
fn front_single_backend_bit_identical_to_direct_socket() {
    use dpp_screen::front::{Front, FrontConfig};
    use dpp_screen::net::{NetClient, NetServer};

    let fixtures = front_fixture();
    let programs: Vec<Vec<Request>> = fixtures
        .iter()
        .map(|(csc, _, lm)| session_program(*lm, csc.n_cols()))
        .collect();

    let run = |mut client: NetClient| -> Vec<Response> {
        let mut order = Vec::new();
        for step in 0..programs[0].len() {
            for (i, program) in programs.iter().enumerate() {
                let id = client.submit(&format!("s{i}"), program[step].clone()).unwrap();
                order.push(id);
            }
        }
        let out: Vec<Response> = order
            .iter()
            .map(|&id| {
                let (got, response) = client.recv_reply().unwrap();
                assert_eq!(got, id, "replies arrive in submission order");
                response
            })
            .collect();
        client.shutdown_server().unwrap();
        out
    };

    // direct: client → backend socket
    let direct = Coordinator::new();
    front_register(&direct, &fixtures, &[0, 1, 2]);
    let server = NetServer::bind(direct, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());
    let want = run(NetClient::connect(&addr).unwrap());
    server_thread.join().unwrap();

    // routed: client → front → identical backend
    let behind = Coordinator::new();
    front_register(&behind, &fixtures, &[0, 1, 2]);
    let server = NetServer::bind(behind, "127.0.0.1:0").unwrap();
    let backend_addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());
    let front =
        Front::bind("127.0.0.1:0", &[backend_addr.clone()], FrontConfig::default()).unwrap();
    let front_addr = front.local_addr().unwrap().to_string();
    let front_thread = std::thread::spawn(move || front.run());

    let client = NetClient::connect(&front_addr).unwrap();
    let advertised: Vec<&str> = client.sessions().iter().map(|s| s.as_str()).collect();
    assert_eq!(advertised, ["s0", "s1", "s2"], "front hello advertises the union");
    let have = run(client);
    let summary = front_thread.join().unwrap();
    assert_eq!(summary.forwarded, want.len() as u64);
    NetClient::connect(&backend_addr).unwrap().shutdown_server().unwrap();
    server_thread.join().unwrap();

    assert_eq!(want.len(), have.len());
    for (k, (w, h)) in want.iter().zip(&have).enumerate() {
        assert_same_payload(w, h, &format!("reply {k} through 1-backend front"));
    }
}

/// Two backends: sessions split across processes, traffic interleaved over
/// one front connection. Every reply is bit-identical to an isolated
/// in-process run, session-affinity keeps each session on the backend that
/// advertised it, and the front's stats rows show both backends up.
#[test]
fn front_two_backends_bit_identical_and_session_affine() {
    use dpp_screen::front::{Front, FrontConfig};
    use dpp_screen::net::{NetClient, NetServer};

    let fixtures = front_fixture();
    let programs: Vec<Vec<Request>> = fixtures
        .iter()
        .map(|(csc, _, lm)| session_program(*lm, csc.n_cols()))
        .collect();

    // isolated in-process references, one coordinator per session
    let reference: Vec<Vec<Response>> = (0..3)
        .map(|i| {
            let coord = Coordinator::new();
            front_register(&coord, &fixtures, &[i]);
            let out = programs[i]
                .iter()
                .map(|req| {
                    coord.submit(&format!("s{i}"), req.clone()).recv_response().unwrap()
                })
                .collect();
            coord.shutdown();
            out
        })
        .collect();

    // backend A hosts s0+s1, backend B hosts s2
    let coord_a = Coordinator::new();
    front_register(&coord_a, &fixtures, &[0, 1]);
    let srv_a = NetServer::bind(coord_a, "127.0.0.1:0").unwrap();
    let addr_a = srv_a.local_addr().unwrap().to_string();
    let join_a = std::thread::spawn(move || srv_a.run());
    let coord_b = Coordinator::new();
    front_register(&coord_b, &fixtures, &[2]);
    let srv_b = NetServer::bind(coord_b, "127.0.0.1:0").unwrap();
    let addr_b = srv_b.local_addr().unwrap().to_string();
    let join_b = std::thread::spawn(move || srv_b.run());

    let front = Front::bind(
        "127.0.0.1:0",
        &[addr_a.clone(), addr_b.clone()],
        FrontConfig::default(),
    )
    .unwrap();
    let front_addr = front.local_addr().unwrap().to_string();
    let front_thread = std::thread::spawn(move || front.run());

    let mut client = NetClient::connect(&front_addr).unwrap();
    let advertised: Vec<&str> = client.sessions().iter().map(|s| s.as_str()).collect();
    assert_eq!(advertised, ["s0", "s1", "s2"], "union of both backends' hellos");
    let rows = client.stats().unwrap();
    assert_eq!(rows.len(), 2, "one stats row per backend");
    assert_eq!(rows[0].backend, addr_a);
    assert_eq!(rows[1].backend, addr_b);
    assert!(rows[0].up && rows[1].up);
    assert_eq!(rows[0].sessions, 2, "hello-seeded load view");
    assert_eq!(rows[1].sessions, 1);

    let mut expected = Vec::new();
    for step in 0..programs[0].len() {
        for (i, program) in programs.iter().enumerate() {
            let id = client.submit(&format!("s{i}"), program[step].clone()).unwrap();
            expected.push((id, i, step));
        }
    }
    for (id, i, step) in expected {
        let (got, response) = client.recv_reply().unwrap();
        assert_eq!(got, id, "replies arrive in submission order");
        assert_same_payload(
            &reference[i][step],
            &response,
            &format!("s{i} step {step} through 2-backend front"),
        );
    }

    client.shutdown_server().unwrap();
    let summary = front_thread.join().unwrap();
    assert!(summary.backends.iter().all(|r| r.up), "both backends stayed up");
    // session-affinity: each backend only ever answered its own sessions,
    // so its admission counter matches its sessions' share of the program
    for (addr, join, want_ops) in
        [(addr_a, join_a, 2 * programs[0].len()), (addr_b, join_b, programs[0].len())]
    {
        let mut direct = NetClient::connect(&addr).unwrap();
        let row = direct.stats().unwrap();
        assert_eq!(row.len(), 1);
        assert_eq!(
            row[0].admission.submitted, want_ops as u64,
            "backend {addr} answered exactly its sessions' requests"
        );
        direct.shutdown_server().unwrap();
        join.join().unwrap();
    }
}

/// Killing a backend mid-run surfaces typed errors through the front — no
/// hang, no panic, no silent re-homing: the dead backend's session answers
/// `SessionClosed { reason: backend … down }` from then on, while sessions
/// on the surviving backend keep serving bit-identically.
#[test]
fn front_backend_death_is_typed_and_scoped_to_its_sessions() {
    use dpp_screen::front::{Front, FrontConfig};
    use dpp_screen::net::{NetClient, NetServer};

    let fixtures = front_fixture();
    let coord_a = Coordinator::new();
    front_register(&coord_a, &fixtures, &[0]);
    let srv_a = NetServer::bind(coord_a, "127.0.0.1:0").unwrap();
    let addr_a = srv_a.local_addr().unwrap().to_string();
    let join_a = std::thread::spawn(move || srv_a.run());
    let coord_b = Coordinator::new();
    front_register(&coord_b, &fixtures, &[1]);
    let srv_b = NetServer::bind(coord_b, "127.0.0.1:0").unwrap();
    let addr_b = srv_b.local_addr().unwrap().to_string();
    let join_b = std::thread::spawn(move || srv_b.run());

    let front = Front::bind(
        "127.0.0.1:0",
        &[addr_a.clone(), addr_b.clone()],
        FrontConfig::default(),
    )
    .unwrap();
    let front_addr = front.local_addr().unwrap().to_string();
    let front_thread = std::thread::spawn(move || front.run());
    let mut client = NetClient::connect(&front_addr).unwrap();

    let screen = |c: &mut NetClient, i: usize, f: f64| {
        let lam = f * fixtures[i].2;
        c.request(&format!("s{i}"), Request::Screen { lam, opts: Default::default() })
    };
    // both sessions serve through the front before the failure
    assert!(matches!(screen(&mut client, 0, 0.6), Ok(Response::Screen(_))));
    assert!(matches!(screen(&mut client, 1, 0.6), Ok(Response::Screen(_))));

    // kill backend B out from under the front
    NetClient::connect(&addr_b).unwrap().shutdown_server().unwrap();
    join_b.join().unwrap();
    // the link notices from its own socket; poll the front's view until the
    // row flips (bounded — this is failure detection, not a timing claim)
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let rows = client.stats().unwrap();
        if rows.iter().any(|r| r.backend == addr_b && !r.up) {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "front never marked {addr_b} down");
        std::thread::sleep(Duration::from_millis(5));
    }

    // the dead backend's session: typed SessionClosed naming the backend
    match screen(&mut client, 1, 0.5) {
        Ok(Response::Error(RequestError::SessionClosed { session, reason })) => {
            assert_eq!(session, "s1");
            assert!(reason.contains("down"), "reason names the failure: {reason}");
        }
        other => panic!("expected typed SessionClosed through front, got {other:?}"),
    }
    // the survivor keeps serving — same request twice stays deterministic
    let w = screen(&mut client, 0, 0.4).unwrap();
    let h = screen(&mut client, 0, 0.4).unwrap();
    assert_same_payload(&w, &h, "surviving backend after peer death");

    client.shutdown_server().unwrap();
    let summary = front_thread.join().unwrap();
    let down: Vec<&str> = summary
        .backends
        .iter()
        .filter(|r| !r.up)
        .map(|r| r.backend.as_str())
        .collect();
    assert_eq!(down, vec![addr_b.as_str()], "exactly the killed backend is down");
    NetClient::connect(&addr_a).unwrap().shutdown_server().unwrap();
    join_a.join().unwrap();
}

/// `NetClient::request_with_retry` against a shed-everything backend: every
/// attempt is answered `Overloaded` with the deterministic hint, the retry
/// budget bounds the attempts exactly, and exhaustion propagates the typed
/// error (not a panic, not an anonymous failure). The server's own
/// admission counters — read over the new control-plane stats probe —
/// prove the retry count.
#[test]
fn client_retry_budget_is_bounded_and_typed_on_shed_everything_backend() {
    use dpp_screen::net::{NetClient, NetServer};

    let (csc, y, lam_max) = sparse_problem(25, 80, 84);
    let coord = Coordinator::with_config(
        None,
        AdmissionConfig { max_session_pending: Some(0), ..Default::default() },
    );
    coord
        .register(SessionSpec::new(
            "s",
            csc,
            y,
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        ))
        .unwrap();
    let server = NetServer::bind(coord, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());
    let mut client = NetClient::connect(&addr).unwrap();

    // deadline budget present → retries wait the (capped) hint; 2 retries
    // means exactly 3 attempts hit the admission gate
    let opts = RequestOptions::with_deadline(Duration::from_millis(1));
    let resp = client
        .request_with_retry("s", Request::Screen { lam: 0.5 * lam_max, opts }, 2)
        .unwrap();
    match resp {
        Response::Error(RequestError::Overloaded { retry_after_ms }) => {
            assert!(retry_after_ms >= 25, "deterministic hint: {retry_after_ms}")
        }
        other => panic!("expected typed Overloaded after budget, got {other:?}"),
    }
    let rows = client.stats().unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].backend, "", "a server reports itself");
    assert_eq!(rows[0].admission.shed, 3, "budget of 2 retries = 3 attempts");

    // no deadline → clock-free immediate retries, same typed exhaustion
    let resp = client
        .request_with_retry(
            "s",
            Request::Screen { lam: 0.5 * lam_max, opts: Default::default() },
            1,
        )
        .unwrap();
    assert!(matches!(resp, Response::Error(RequestError::Overloaded { .. })));
    assert_eq!(client.stats().unwrap()[0].admission.shed, 5);

    client.shutdown_server().unwrap();
    server_thread.join().unwrap();
}
