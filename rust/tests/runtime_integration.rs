//! Integration: rust PJRT runtime × AOT artifacts (requires `make artifacts`).
//!
//! Verifies the whole interchange: jax/Pallas → HLO text → xla-crate compile
//! → execute → numbers match the native f64 implementations within the
//! documented f32 slack, and that screening through the artifact sweep stays
//! *safe* end-to-end.

use dpp_screen::data::synthetic;
use dpp_screen::linalg::DenseMatrix;
use dpp_screen::path::{solve_path_with_ctx, LambdaGrid, PathConfig, RuleKind, SolverKind};
use dpp_screen::linalg::DesignMatrix;
use dpp_screen::runtime::{ArtifactRuntime, ArtifactSweep};
use dpp_screen::screening::ScreenContext;
use dpp_screen::util::rng::Rng;

fn runtime() -> Option<ArtifactRuntime> {
    let rt = ArtifactRuntime::load_default();
    if rt.is_none() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
    }
    rt
}

fn demo_matrix(n: usize, p: usize, seed: u64) -> (DenseMatrix, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut data = vec![0.0; n * p];
    rng.fill_normal(&mut data);
    let x = DenseMatrix::from_col_major(n, p, data);
    let mut w = vec![0.0; n];
    rng.fill_normal(&mut w);
    (x, w)
}

fn to_row_major_f32(x: &DenseMatrix) -> Vec<f32> {
    let (n, p) = (x.n_rows(), x.n_cols());
    let mut out = vec![0f32; n * p];
    for j in 0..p {
        let c = x.col(j);
        for i in 0..n {
            out[i * p + j] = c[i] as f32;
        }
    }
    out
}

#[test]
fn xt_w_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let (x, w) = demo_matrix(64, 256, 1);
    let sweep = rt.sweep_for(&x).expect("xt_w artifact for 64x256");
    let mut from_artifact = vec![0.0; 256];
    sweep.xt_w(&w, &mut from_artifact);
    let mut native = vec![0.0; 256];
    x.gemv_t(&w, &mut native);
    let scale = native.iter().fold(0.0f64, |m, v| m.max(v.abs())) + 1.0;
    for j in 0..256 {
        assert!(
            (from_artifact[j] - native[j]).abs() < 1e-4 * scale,
            "feature {j}: artifact {} vs native {}",
            from_artifact[j],
            native[j]
        );
    }
}

#[test]
fn sweep_reusable_across_calls() {
    // the matrix buffer stays resident; repeated sweeps must agree
    let Some(rt) = runtime() else { return };
    let (x, _) = demo_matrix(64, 256, 2);
    let sweep = rt.sweep_for(&x).unwrap();
    let mut rng = Rng::new(3);
    for _ in 0..5 {
        let mut w = vec![0.0; 64];
        rng.fill_normal(&mut w);
        let mut a = vec![0.0; 256];
        let mut b = vec![0.0; 256];
        sweep.xt_w(&w, &mut a);
        x.gemv_t(&w, &mut b);
        let scale = b.iter().fold(0.0f64, |m, v| m.max(v.abs())) + 1.0;
        for j in 0..256 {
            assert!((a[j] - b[j]).abs() < 1e-4 * scale);
        }
    }
}

#[test]
fn no_artifact_for_unknown_shape() {
    let Some(rt) = runtime() else { return };
    let (x, _) = demo_matrix(13, 17, 4);
    assert!(rt.sweep_for(&x).is_none());
}

#[test]
fn edpp_screen_artifact_matches_native_rule() {
    // full-graph artifact (edpp_screen 64x256) vs the rust EDPP internals
    let Some(rt) = runtime() else { return };
    if !rt.has("edpp_screen", 64, 256) {
        return;
    }
    let ds = synthetic::synthetic1(64, 256, 20, 0.1, 5);
    let ctx = ScreenContext::new(&ds.x, &ds.y);
    // interior-case anchor: exact solve at 0.6·λmax
    use dpp_screen::solver::{cd::CdSolver, LassoSolver, SolveOptions};
    let cols: Vec<usize> = (0..256).collect();
    let lam0 = 0.6 * ctx.lam_max;
    let lam = 0.4 * ctx.lam_max;
    let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
    let prev = CdSolver.solve(&ds.x, &ds.y, &cols, lam0, None, &opts).scatter(&cols, 256);
    let theta = dpp_screen::screening::theta_from_solution(&ds.x, &ds.y, &prev, lam0);

    // native EDPP pieces
    let step = dpp_screen::screening::StepInput { lam_prev: lam0, lam, theta_prev: &theta };
    let v1 = dpp_screen::screening::v1(&ctx, &step);
    let v2 = dpp_screen::screening::v2(&ctx, &step);
    let perp = dpp_screen::screening::v2_perp(&v1, &v2);
    let native_radius = 0.5 * dpp_screen::linalg::nrm2(&perp);

    // artifact inputs (f32, row-major X)
    let (n, p) = (64usize, 256usize);
    let x32 = to_row_major_f32(ds.x.dense().unwrap());
    let y32: Vec<f32> = ds.y.iter().map(|v| *v as f32).collect();
    let th32: Vec<f32> = theta.iter().map(|v| *v as f32).collect();
    let norms32: Vec<f32> = ctx.col_norms.iter().map(|v| *v as f32).collect();
    let inv0 = [1.0f32 / lam0 as f32];
    let invl = [1.0f32 / lam as f32];
    let outs = rt
        .execute_f32(
            "edpp_screen",
            n,
            p,
            &[
                (&x32, vec![n, p]),
                (&y32, vec![n]),
                (&th32, vec![n]),
                (&inv0, vec![]),
                (&invl, vec![]),
                (&norms32, vec![p]),
            ],
        )
        .expect("edpp_screen execution");
    assert_eq!(outs.len(), 3, "scores, radius, mask");
    let scores = &outs[0];
    let radius = outs[1][0] as f64;
    let mask = &outs[2];
    assert!(
        (radius - native_radius).abs() < 1e-3 * (1.0 + native_radius),
        "radius {radius} vs {native_radius}"
    );
    // native scores at the same center
    let center: Vec<f64> =
        theta.iter().zip(perp.iter()).map(|(t, w)| t + 0.5 * w).collect();
    let mut native_scores = vec![0.0; p];
    ds.x.gemv_t(&center, &mut native_scores);
    let scale = native_scores.iter().fold(0.0f64, |m, v| m.max(v.abs())) + 1.0;
    for j in 0..p {
        assert!(
            (scores[j] as f64 - native_scores[j]).abs() < 2e-4 * scale,
            "score {j}"
        );
    }
    // mask sanity: anything the artifact clearly discards must be a true zero
    let exact = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts).scatter(&cols, 256);
    for j in 0..p {
        let sup = (scores[j] as f64).abs() + radius * ctx.col_norms[j];
        if mask[j] == 0.0 && sup < 1.0 - 1e-3 {
            assert_eq!(exact[j], 0.0, "artifact mask unsafe at {j}");
        }
    }
}

#[test]
fn fista_epoch_artifact_steps_match_native_objective() {
    let Some(rt) = runtime() else { return };
    if !rt.has("fista_epoch", 64, 256) {
        return;
    }
    let ds = synthetic::synthetic1(64, 256, 20, 0.1, 6);
    let ctx = ScreenContext::new(&ds.x, &ds.y);
    let lam = 0.3 * ctx.lam_max;
    let cols: Vec<usize> = (0..256).collect();
    let lip = ds.x.op_norm_sq_subset(&cols, 40, 9) * 1.01;

    let (n, p) = (64usize, 256usize);
    let x32 = to_row_major_f32(ds.x.dense().unwrap());
    let y32: Vec<f32> = ds.y.iter().map(|v| *v as f32).collect();
    let mut beta = vec![0f32; p];
    let mut w = vec![0f32; p];
    let mut t = 1.0f32;
    for _ in 0..60 {
        let outs = rt
            .execute_f32(
                "fista_epoch",
                n,
                p,
                &[
                    (&x32, vec![n, p]),
                    (&y32, vec![n]),
                    (&beta, vec![p]),
                    (&w, vec![p]),
                    (&[t], vec![]),
                    (&[(1.0 / lip) as f32], vec![]),
                    (&[lam as f32], vec![]),
                ],
            )
            .expect("fista_epoch execution");
        beta = outs[0].clone();
        w = outs[1].clone();
        t = outs[2][0];
    }
    // objective from the PJRT loop ≈ native CD optimum
    use dpp_screen::solver::{cd::CdSolver, dual, LassoSolver, SolveOptions};
    let beta64: Vec<f64> = beta.iter().map(|v| *v as f64).collect();
    let obj_pjrt = dual::primal_objective(&ds.x, &ds.y, &cols, &beta64, lam);
    let exact = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &SolveOptions::default());
    let obj_cd = dual::primal_objective(&ds.x, &ds.y, &cols, &exact.beta, lam);
    assert!(
        obj_pjrt <= obj_cd * 1.05 + 1e-6,
        "PJRT FISTA objective {obj_pjrt} vs CD {obj_cd}"
    );
}

#[test]
fn full_path_through_artifact_sweep_is_safe_and_exact() {
    // end-to-end: EDPP path where every Xᵀw sweep runs through XLA
    let Some(rt) = runtime() else { return };
    let ds = synthetic::synthetic1(64, 256, 20, 0.1, 7);
    let Some(sweep) = rt.sweep_for(ds.x.dense().unwrap()) else { return };
    let ctx = ScreenContext::with_sweep_slack(
        &ds.x,
        &ds.y,
        &sweep,
        ArtifactSweep::SAFETY_SLACK,
    );
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 20, 0.05, 1.0);
    let cfg = PathConfig::default();
    let out = solve_path_with_ctx(&ctx, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let native_ctx = ScreenContext::new(&ds.x, &ds.y);
    let base = solve_path_with_ctx(&native_ctx, &grid, RuleKind::None, SolverKind::Cd, &cfg);
    for (bs, bb) in out.betas.iter().zip(base.betas.iter()) {
        for j in 0..ds.p() {
            assert!(
                (bs[j] - bb[j]).abs() < 1e-4 * (1.0 + bb[j].abs()),
                "artifact-swept path diverged at {j}"
            );
        }
    }
    // f32 safety slack + modest grid density cost a little rejection
    assert!(out.mean_rejection_ratio() > 0.7, "ratio {}", out.mean_rejection_ratio());
}
