// Audit fixture (never compiled): one panicking call on a request path.
pub fn handle(req: Option<u32>) -> u32 {
    req.unwrap()
}
