// Audit fixture (never compiled): seeds one clock hit, one raw float-sum
// hit and one hashed-collection hit, all outside their sanctioned homes.
pub fn summarize(v: &[f64]) -> f64 {
    let _t = std::time::Instant::now();
    v.iter().sum::<f64>()
}

pub fn index(keys: &[u64]) -> std::collections::HashMap<u64, usize> {
    keys.iter().copied().enumerate().map(|(i, k)| (k, i)).collect()
}
