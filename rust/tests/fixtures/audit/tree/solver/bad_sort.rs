// Audit fixture (never compiled): seeds one determinism:float-sort hit.
pub fn sort_desc(v: &mut [f64]) {
    v.sort_by(|a, b| b.partial_cmp(a).unwrap());
}
