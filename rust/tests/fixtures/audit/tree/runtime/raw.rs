// Audit fixture (never compiled): two unsafe inventory entries, one of
// them undocumented.
pub fn first(p: *const u8) -> u8 {
    unsafe { *p }
}

// SAFETY: caller guarantees `p` points at two readable bytes.
pub fn second(p: *const u8) -> u8 {
    unsafe { *p.add(1) }
}
