// Audit fixture (never compiled): a miniature wire grammar for the
// wirecheck tests — see ../../wire.lock.match and wire.lock.stale.
pub const WIRE_VERSION: u32 = 3;

pub mod tag {
    pub const REQ_PING: u8 = 0;
    pub const REQ_ECHO: u8 = 1;
    pub const RESP_PONG: u8 = 0;
}
