// Audit fixture (never compiled): framing constants for the wirecheck
// tests.
pub const MAGIC: [u8; 4] = *b"TEST";
pub const FRAME_VERSION: u8 = 1;
