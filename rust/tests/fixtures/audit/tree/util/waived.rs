// Audit fixture (never compiled): one reasoned waiver that silences its
// lint, and one empty-reason waiver that is itself a finding.
pub fn timed() -> std::time::Instant {
    // audit:allow(determinism:clock, fixture-sanctioned timer shim)
    std::time::Instant::now()
}

pub fn stamp() -> std::time::Instant {
    // audit:allow(determinism:clock)
    std::time::Instant::now()
}
