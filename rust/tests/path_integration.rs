//! Integration: pathwise driver × coordinator × dataset generators —
//! the paper's experimental protocol end to end at test scale.

use dpp_screen::coordinator::run_trials;
use dpp_screen::data::{synthetic, RealDataset};
use dpp_screen::path::group::{solve_group_path, GroupRuleKind};
use dpp_screen::path::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};
use dpp_screen::solver::SolveOptions;

#[test]
fn edpp_dominates_safe_on_simulated_real_data() {
    // Fig. 4's qualitative claim at test scale: EDPP rejects far more than
    // SAFE on every dataset family
    for d in [RealDataset::BreastCancer, RealDataset::ColonCancer] {
        let ds = d.generate(false, 11);
        // sequential screening tightens with grid density (Remark 2); use a
        // moderately dense grid as the paper's 100-point protocol does
        let grid = LambdaGrid::relative(&ds.x, &ds.y, 30, 0.05, 1.0);
        let cfg = PathConfig::default();
        let safe = solve_path(&ds.x, &ds.y, &grid, RuleKind::Safe, SolverKind::Cd, &cfg);
        let edpp = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
        assert!(
            edpp.mean_rejection_ratio() >= safe.mean_rejection_ratio(),
            "{}: edpp {} < safe {}",
            d.name(),
            edpp.mean_rejection_ratio(),
            safe.mean_rejection_ratio()
        );
        assert!(
            edpp.mean_rejection_ratio() > 0.85,
            "{}: edpp rejection only {}",
            d.name(),
            edpp.mean_rejection_ratio()
        );
    }
}

#[test]
fn edpp_reduces_solver_work_massively() {
    // the mechanism behind the paper's speedups: total kept features along
    // the path is a small fraction of p × grid
    let ds = RealDataset::Leukemia.generate(false, 5);
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 12, 0.05, 1.0);
    let cfg = PathConfig::default();
    let edpp = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let kept: usize = edpp.records.iter().map(|r| r.kept).sum();
    let total = ds.p() * edpp.records.len();
    assert!(
        (kept as f64) < 0.25 * total as f64,
        "kept {kept}/{total} — screening ineffective"
    );
}

#[test]
fn trials_scheduler_composes_with_paths() {
    // multi-trial protocol: deterministic per-seed results through the pool
    let run = |seed: u64| {
        let ds = synthetic::synthetic1(25, 80, 8, 0.1, seed);
        let grid = LambdaGrid::relative(&ds.x, &ds.y, 5, 0.1, 1.0);
        solve_path(
            &ds.x,
            &ds.y,
            &grid,
            RuleKind::Edpp,
            SolverKind::Cd,
            &PathConfig::default(),
        )
        .mean_rejection_ratio()
    };
    let a = run_trials(4, 2, |t| run(100 + t as u64));
    let b = run_trials(4, 1, |t| run(100 + t as u64));
    assert_eq!(a, b, "trial results must be deterministic per seed");
}

#[test]
fn group_path_protocol() {
    // Fig. 6's qualitative claims at test scale: more groups (smaller
    // groups) ⇒ higher rejection; EDPP ≥ strong in rejection
    let opts = SolveOptions::default();
    let mut prev_ratio = 0.0;
    for ng in [20usize, 40, 80] {
        let ds = synthetic::group_synthetic(40, 320, ng, 77);
        let groups = ds.groups.clone().unwrap();
        let (glm, _) =
            dpp_screen::solver::dual::group_lambda_max(&ds.x, &ds.y, &groups);
        let grid = LambdaGrid::relative_to(glm, 8, 0.1, 1.0);
        let edpp =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
        let ratio = edpp.mean_rejection_ratio();
        assert!(
            ratio >= prev_ratio - 0.15,
            "rejection should trend up with n_g: {ratio} after {prev_ratio}"
        );
        prev_ratio = ratio;
    }
}

#[test]
fn solver_swap_invariance_of_rejection() {
    // rejection ratios are a property of the rule, not the solver (§4.1.2
    // "the rejection ratios of screening methods are irrelevant to the
    // solvers")
    let ds = synthetic::synthetic1(30, 100, 10, 0.1, 21);
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 6, 0.1, 1.0);
    let cfg = PathConfig::default();
    let cd = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
    let fista = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Fista, &cfg);
    let lars = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Lars, &cfg);
    for ((a, b), c) in cd.records.iter().zip(&fista.records).zip(&lars.records) {
        assert_eq!(a.kept, b.kept, "cd vs fista kept");
        assert_eq!(a.kept, c.kept, "cd vs lars kept");
    }
}

#[test]
fn sis_with_kkt_repair_recovers_exactness() {
    // SIS is aggressively wrong by design; the repair loop must fix it
    let ds = synthetic::synthetic1(30, 100, 10, 0.1, 31);
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 6, 0.1, 1.0);
    let cfg = PathConfig::default();
    let sis = solve_path(&ds.x, &ds.y, &grid, RuleKind::Sis, SolverKind::Cd, &cfg);
    let reference = solve_path(&ds.x, &ds.y, &grid, RuleKind::None, SolverKind::Cd, &cfg);
    for (bs, bb) in sis.betas.iter().zip(reference.betas.iter()) {
        for j in 0..ds.p() {
            assert!(
                (bs[j] - bb[j]).abs() < 2e-4 * (1.0 + bb[j].abs()),
                "SIS+repair diverged"
            );
        }
    }
    // and repairs must actually have fired at small λ
    assert!(sis.total_kkt_repairs() > 0, "expected KKT repairs for SIS");
}
