//! Minimal in-tree `anyhow` shim (vendored, DESIGN.md §6).
//!
//! The offline build image bakes the real `anyhow` into its cargo cache,
//! but a fresh clone has no network to fetch it — and a registry entry in
//! `Cargo.lock` would pin a checksum this repo cannot verify offline. So
//! the workspace path-depends on this shim instead: the subset of the
//! `anyhow` 1.x API this crate actually uses, with the same semantics.
//!
//! Covered: [`Error`] (context chain, `{}`/`{:#}`/`{:?}` formatting,
//! `From<E: std::error::Error>` capturing the source chain), the
//! [`Result`] alias, the [`Context`] extension for `Result` and `Option`,
//! and the [`anyhow!`]/[`bail!`] macros. Not covered (unused here):
//! downcasting, backtraces, `ensure!`.

use std::fmt;

/// Error with an ordered context chain: `chain[0]` is the outermost
/// context, the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error in an outer context layer (like
    /// `anyhow::Error::context`).
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    /// `{}` prints the outermost context; `{:#}` the full `a: b: c` chain
    /// (matching real `anyhow`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    /// `{:?}` (what `unwrap`/`expect` print) shows the cause chain.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, c) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

// NOTE: deliberately NOT `impl std::error::Error for Error` — exactly like
// real `anyhow`. That keeps the blanket `From` below coherent and lets
// `Context` cover `Result<_, Error>` and `Result<_, E: std::error::Error>`
// with one `Into<Error>` bound.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with `context`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap lazily — `f` runs only on the failure path.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// `return Err(anyhow!(…))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let text = std::fs::read_to_string("/definitely/not/here")
            .context("reading config")?;
        Ok(text)
    }

    #[test]
    fn context_chain_formats() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        let full = format!("{err:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        assert!(format!("{err:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context_and_macros() {
        let missing: Option<u8> = None;
        let err = missing.context("no byte").unwrap_err();
        assert_eq!(format!("{err}"), "no byte");

        let n = 3;
        let err = anyhow!("bad count {n}");
        assert_eq!(format!("{err}"), "bad count 3");
        let err = anyhow!("bad {} of {}", 1, 2);
        assert_eq!(format!("{err}"), "bad 1 of 2");

        fn bails() -> Result<()> {
            bail!("nope {}", 7)
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "nope 7");
    }

    #[test]
    fn context_stacks_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("root cause")
        }
        let err = inner().with_context(|| "outer layer").unwrap_err();
        assert_eq!(format!("{err}"), "outer layer");
        assert_eq!(format!("{err:#}"), "outer layer: root cause");
    }
}
