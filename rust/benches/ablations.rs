//! Ablation benches for the design choices DESIGN.md §7 calls out:
//!
//! · λ-grid density vs rejection (sequential rules tighten with density —
//!   Remark 2's mechanism, quantified)
//! · basic vs sequential EDPP (the §4.1.1 comparison as one number)
//! · elastic-net EDPP (γ sweep): the paper's §5 extension direction
//! · sparse (CSC) vs dense screening sweep at matched shapes
//! · warm-start on/off for the screened path
//!
//! Run: `cargo bench --bench ablations` → results/ablations.md

use dpp_screen::data::synthetic;
use dpp_screen::linalg::{CscMatrix, DesignMatrix};
use dpp_screen::path::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};
use dpp_screen::solver::dual;
use dpp_screen::solver::enet::{screen_enet_edpp, EnetCdSolver};
use dpp_screen::solver::{LassoSolver, SolveOptions};
use dpp_screen::util::benchkit::{black_box, Bench, Report};
use dpp_screen::util::rng::Rng;

fn main() {
    grid_density();
    basic_vs_sequential();
    enet_gamma_sweep();
    sparse_vs_dense_sweep();
    warm_start();
}

fn grid_density() {
    let ds = synthetic::synthetic1(100, 1500, 60, 0.1, 0xA0);
    let cfg = PathConfig::default();
    let mut rep = Report::new(
        "ablation: λ-grid density vs EDPP rejection (100×1500)",
        &["grid points", "mean rejection", "total secs"],
    );
    for k in [10usize, 25, 50, 100, 200] {
        let grid = LambdaGrid::relative(&ds.x, &ds.y, k, 0.05, 1.0);
        let out = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
        rep.row(&[
            k.to_string(),
            format!("{:.4}", out.mean_rejection_ratio()),
            format!("{:.3}", out.total_secs()),
        ]);
    }
    rep.emit("ablations.md");
}

fn basic_vs_sequential() {
    let ds = synthetic::synthetic1(100, 1500, 60, 0.1, 0xA1);
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 100, 0.05, 1.0);
    let mut rep = Report::new(
        "ablation: basic vs sequential (100-pt grid, 100×1500)",
        &["rule", "mode", "mean rejection"],
    );
    for rule in [RuleKind::Safe, RuleKind::Dpp, RuleKind::Edpp] {
        for (mode, sequential) in [("basic", false), ("sequential", true)] {
            let cfg = PathConfig { sequential, ..Default::default() };
            let out = solve_path(&ds.x, &ds.y, &grid, rule, SolverKind::Cd, &cfg);
            rep.row(&[
                rule.name().to_string(),
                mode.to_string(),
                format!("{:.4}", out.mean_rejection_ratio()),
            ]);
        }
    }
    rep.emit("ablations.md");
}

fn enet_gamma_sweep() {
    let ds = synthetic::synthetic1(80, 800, 40, 0.1, 0xA2);
    let lam_max = dual::lambda_max(&ds.x, &ds.y);
    let cols: Vec<usize> = (0..ds.p()).collect();
    let opts = SolveOptions { tol_gap: 1e-9, ..Default::default() };
    let mut rep = Report::new(
        "ablation: elastic-net EDPP across γ (80×800, λ₀=0.5→λ=0.45·λmax)",
        &["γ", "rejected", "support at λ", "safe?"],
    );
    for gamma in [0.0, 0.1, 1.0, 10.0] {
        let solver = EnetCdSolver { gamma };
        let prev = solver
            .solve(&ds.x, &ds.y, &cols, 0.5 * lam_max, None, &opts)
            .scatter(&cols, ds.p());
        let mut keep = vec![true; ds.p()];
        screen_enet_edpp(
            &ds.x, &ds.y, gamma, &prev, 0.5 * lam_max, 0.45 * lam_max, lam_max, &mut keep,
        );
        let exact = solver
            .solve(&ds.x, &ds.y, &cols, 0.45 * lam_max, None, &opts)
            .scatter(&cols, ds.p());
        let rejected = keep.iter().filter(|k| !**k).count();
        let support = exact.iter().filter(|b| **b != 0.0).count();
        let safe = (0..ds.p()).all(|j| keep[j] || exact[j].abs() < 1e-9);
        rep.row(&[
            format!("{gamma}"),
            rejected.to_string(),
            support.to_string(),
            safe.to_string(),
        ]);
    }
    rep.emit("ablations.md");
}

fn sparse_vs_dense_sweep() {
    // stroke-like sparse data at 15% density
    let mut rng = Rng::new(0xA3);
    let (n, p) = (300, 3000);
    let mut x = dpp_screen::linalg::DenseMatrix::zeros(n, p);
    for j in 0..p {
        let c = x.col_mut(j);
        for v in c.iter_mut() {
            if rng.f64() < 0.15 {
                *v = rng.normal();
            }
        }
    }
    let csc = CscMatrix::from_dense(&x);
    let mut w = vec![0.0; n];
    rng.fill_normal(&mut w);
    let mut out = vec![0.0; p];
    let bench = Bench::new(3, 10);
    let m_dense = bench.run("dense sweep", || {
        x.gemv_t(&w, &mut out);
        black_box(out[0])
    });
    let m_sparse = bench.run("csc sweep", || {
        csc.xt_w(&w, &mut out);
        black_box(out[0])
    });
    let mut rep = Report::new(
        &format!(
            "ablation: sparse vs dense sweep ({}×{}, density {:.0}%)",
            n,
            p,
            csc.density() * 100.0
        ),
        &["kernel", "mean", "speedup"],
    );
    rep.row(&["dense gemv_t".into(), format!("{:.3}ms", m_dense.mean_s * 1e3), "1.00x".into()]);
    rep.row(&[
        "csc gemv_t".into(),
        format!("{:.3}ms", m_sparse.mean_s * 1e3),
        format!("{:.2}x", m_dense.mean_s / m_sparse.mean_s),
    ]);
    rep.emit("ablations.md");
}

fn warm_start() {
    let ds = synthetic::synthetic1(100, 1500, 60, 0.1, 0xA4);
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 50, 0.05, 1.0);
    let mut rep = Report::new(
        "ablation: warm starts on the screened path (100×1500, 50-pt grid)",
        &["warm start", "total secs", "total solver iters"],
    );
    for warm in [true, false] {
        let cfg = PathConfig { warm_start: warm, ..Default::default() };
        let out = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
        let iters: usize = out.records.iter().map(|r| r.solver_iters).sum();
        rep.row(&[
            warm.to_string(),
            format!("{:.3}", out.total_secs()),
            iters.to_string(),
        ]);
    }
    rep.emit("ablations.md");
}
