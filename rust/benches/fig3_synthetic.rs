//! Bench: Fig. 3 + Table 2 — synthetic datasets.
//! Regenerates the paper artifact via the shared experiment harness
//! (dpp_screen::experiments). Output: stdout + results/*.md.
//! Scale knobs: DPP_SCALE=full, DPP_TRIALS=…, DPP_GRID=…

fn main() {
    println!("== Fig. 3 + Table 2 — synthetic datasets ==");
    dpp_screen::experiments::fig3_synthetic();
}
