//! Microbenches for the §Perf pass: the screening hot path at each layer.
//!
//! · native gemv_t (unrolled) vs a naive per-column loop — L3 ablation
//! · full EDPP screen step vs one bare sweep — the "screening overhead ≤
//!   1.3× one sweep" target of DESIGN.md §10
//! · dense vs CSC backend for the sweep and a full EDPP path — the
//!   `DesignMatrix` backend ablation
//! · PJRT artifact sweep vs native — the AOT-vs-native ablation
//! · end-to-end screened vs unscreened path at bench scale
//!
//! Run: `cargo bench --bench kernels` (results appended to results/perf.md)

use dpp_screen::data::synthetic;
use dpp_screen::linalg::{dot, CscMatrix, DenseMatrix, DesignMatrix};
use dpp_screen::path::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};
use dpp_screen::runtime::ArtifactRuntime;
use dpp_screen::screening::{edpp::EdppRule, ScreenContext, ScreeningRule, StepInput};
use dpp_screen::util::benchkit::{black_box, Bench, Report};
use dpp_screen::util::rng::Rng;

fn naive_gemv_t(x: &DenseMatrix, w: &[f64], out: &mut [f64]) {
    for j in 0..x.n_cols() {
        out[j] = dot(x.col(j), w);
    }
}

fn main() {
    let bench = Bench::new(3, 10);
    let mut rep = Report::new(
        "kernel microbenches (§Perf)",
        &["case", "mean", "min", "σ", "vs-baseline"],
    );

    // --- L3: sweep kernels at a representative shape ---
    let (n, p) = (300, 3000);
    let mut rng = Rng::new(1);
    let mut data = vec![0.0; n * p];
    rng.fill_normal(&mut data);
    let x = DenseMatrix::from_col_major(n, p, data);
    let mut w = vec![0.0; n];
    rng.fill_normal(&mut w);
    let mut out = vec![0.0; p];

    let m_naive = bench.run("gemv_t naive", || {
        naive_gemv_t(&x, &w, &mut out);
        black_box(out[0])
    });
    let m_fast = bench.run("gemv_t unrolled", || {
        x.gemv_t(&w, &mut out);
        black_box(out[0])
    });
    rep.row(&[
        format!("gemv_t naive {n}x{p}"),
        format!("{:.3}ms", m_naive.mean_s * 1e3),
        format!("{:.3}ms", m_naive.min_s * 1e3),
        format!("{:.3}ms", m_naive.std_s * 1e3),
        "1.00x".into(),
    ]);
    rep.row(&[
        format!("gemv_t unrolled {n}x{p}"),
        format!("{:.3}ms", m_fast.mean_s * 1e3),
        format!("{:.3}ms", m_fast.min_s * 1e3),
        format!("{:.3}ms", m_fast.std_s * 1e3),
        format!("{:.2}x", m_naive.mean_s / m_fast.mean_s),
    ]);

    // --- EDPP step overhead vs one sweep (target ≤ ~1.3×) ---
    let ds = synthetic::synthetic1(n, p, p / 10, 0.1, 2);
    let ctx = ScreenContext::new(&ds.x, &ds.y);
    let theta: Vec<f64> = ds.y.iter().map(|v| v / ctx.lam_max).collect();
    let step = StepInput {
        lam_prev: 0.6 * ctx.lam_max,
        lam: 0.5 * ctx.lam_max,
        theta_prev: &theta,
    };
    let mut keep = vec![true; p];
    let m_edpp = bench.run("edpp screen step", || {
        EdppRule.screen(&ctx, &step, &mut keep);
        black_box(keep[0])
    });
    let m_sweep = bench.run("bare sweep", || {
        ds.x.gemv_t(&theta, &mut out);
        black_box(out[0])
    });
    rep.row(&[
        format!("EDPP step {n}x{p}"),
        format!("{:.3}ms", m_edpp.mean_s * 1e3),
        format!("{:.3}ms", m_edpp.min_s * 1e3),
        format!("{:.3}ms", m_edpp.std_s * 1e3),
        format!("{:.2}x one sweep", m_edpp.mean_s / m_sweep.mean_s),
    ]);

    // --- DesignMatrix backends: dense vs CSC on sparse data ---
    {
        // stroke-like 10%-dense data at the same representative shape
        let mut srng = Rng::new(5);
        let mut xs = DenseMatrix::zeros(n, p);
        for j in 0..p {
            for v in xs.col_mut(j).iter_mut() {
                if srng.f64() < 0.10 {
                    *v = srng.normal();
                }
            }
        }
        let csc = CscMatrix::from_dense(&xs);
        // out-of-core shard of the same data, window-limited to ~1/8 nnz
        let shard = std::env::temp_dir().join(format!("dpp-bench-shard-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&shard);
        dpp_screen::data::convert::shard_from_design(&csc, None, &shard)
            .expect("writing bench shard");
        let budget = (csc.nnz() * dpp_screen::linalg::mmap::ENTRY_BYTES / 8).max(4096);
        let mm = dpp_screen::linalg::MmapCscMatrix::open_with_budget(&shard, budget)
            .expect("opening bench shard");
        let mut ws = vec![0.0; n];
        srng.fill_normal(&mut ws);
        let m_dense = bench.run("sweep dense backend", || {
            DesignMatrix::xt_w(&xs, &ws, &mut out);
            black_box(out[0])
        });
        let m_csc = bench.run("sweep csc backend", || {
            DesignMatrix::xt_w(&csc, &ws, &mut out);
            black_box(out[0])
        });
        rep.row(&[
            format!("xt_w dense {n}x{p} (10% fill)"),
            format!("{:.3}ms", m_dense.mean_s * 1e3),
            format!("{:.3}ms", m_dense.min_s * 1e3),
            format!("{:.3}ms", m_dense.std_s * 1e3),
            "1.00x".into(),
        ]);
        rep.row(&[
            format!("xt_w csc {n}x{p} (10% fill)"),
            format!("{:.3}ms", m_csc.mean_s * 1e3),
            format!("{:.3}ms", m_csc.min_s * 1e3),
            format!("{:.3}ms", m_csc.std_s * 1e3),
            format!("{:.2}x dense", m_dense.mean_s / m_csc.mean_s),
        ]);
        let m_mmap = bench.run("sweep mmap backend", || {
            DesignMatrix::xt_w(&mm, &ws, &mut out);
            black_box(out[0])
        });
        rep.row(&[
            format!("xt_w mmap {n}x{p} (10% fill, 1/8-nnz window)"),
            format!("{:.3}ms", m_mmap.mean_s * 1e3),
            format!("{:.3}ms", m_mmap.min_s * 1e3),
            format!("{:.3}ms", m_mmap.std_s * 1e3),
            format!("{:.2}x dense", m_dense.mean_s / m_mmap.mean_s),
        ]);
        // full EDPP path on each backend — same protocol, different kernels
        let mut beta = vec![0.0; p];
        for j in (0..p).step_by(p / 24 + 1) {
            beta[j] = srng.normal();
        }
        let mut ys = vec![0.0; n];
        DesignMatrix::gemv(&xs, &beta, &mut ys);
        for v in ys.iter_mut() {
            *v += 0.05 * srng.normal();
        }
        let sgrid = LambdaGrid::relative(&xs, &ys, 10, 0.1, 1.0);
        let quick = Bench::new(1, 3);
        let m_pd = quick.run("edpp path dense backend", || {
            black_box(
                solve_path(&xs, &ys, &sgrid, RuleKind::Edpp, SolverKind::Cd, &PathConfig::default())
                    .total_secs(),
            )
        });
        let m_pc = quick.run("edpp path csc backend", || {
            black_box(
                solve_path(&csc, &ys, &sgrid, RuleKind::Edpp, SolverKind::Cd, &PathConfig::default())
                    .total_secs(),
            )
        });
        let m_pm = quick.run("edpp path mmap backend", || {
            black_box(
                solve_path(&mm, &ys, &sgrid, RuleKind::Edpp, SolverKind::Cd, &PathConfig::default())
                    .total_secs(),
            )
        });
        rep.row(&[
            "10-λ EDPP path dense (10% fill)".into(),
            format!("{:.3}s", m_pd.mean_s),
            format!("{:.3}s", m_pd.min_s),
            format!("{:.3}s", m_pd.std_s),
            "1.00x".into(),
        ]);
        rep.row(&[
            "10-λ EDPP path csc (10% fill)".into(),
            format!("{:.3}s", m_pc.mean_s),
            format!("{:.3}s", m_pc.min_s),
            format!("{:.3}s", m_pc.std_s),
            format!("{:.2}x dense", m_pd.mean_s / m_pc.mean_s),
        ]);
        rep.row(&[
            "10-λ EDPP path mmap (10% fill, 1/8-nnz window)".into(),
            format!("{:.3}s", m_pm.mean_s),
            format!("{:.3}s", m_pm.min_s),
            format!("{:.3}s", m_pm.std_s),
            format!("{:.2}x dense", m_pd.mean_s / m_pm.mean_s),
        ]);
        drop(mm);
        let _ = std::fs::remove_dir_all(&shard);

        // --- sharded backend: xt_w scaling with the worker-pool size ---
        // (4 row-range shards in RAM; the per-column shard-order fold keeps
        // every thread count bit-identical to the csc numbers above)
        {
            use dpp_screen::linalg::ShardSetMatrix;
            use dpp_screen::runtime::pool::WorkerPool;
            use std::sync::Arc;
            let mut m1 = None;
            for threads in [1usize, 2, 4] {
                let sh = ShardSetMatrix::split_csc(&csc, 4)
                    .with_pool(Arc::new(WorkerPool::new(threads)));
                let m_sh = bench.run("sweep sharded backend", || {
                    DesignMatrix::xt_w(&sh, &ws, &mut out);
                    black_box(out[0])
                });
                let base = *m1.get_or_insert(m_sh.mean_s);
                rep.row(&[
                    format!("xt_w sharded {n}x{p} (4 shards, {threads} thr)"),
                    format!("{:.3}ms", m_sh.mean_s * 1e3),
                    format!("{:.3}ms", m_sh.min_s * 1e3),
                    format!("{:.3}ms", m_sh.std_s * 1e3),
                    format!("{:.2}x 1-thr", base / m_sh.mean_s),
                ]);
            }
        }
    }

    // --- PJRT artifact sweep vs native, small AND large shapes ---
    if let Some(rt) = ArtifactRuntime::load_default() {
        // large shape (300×3000): amortizes the per-dispatch overhead
        if let Some(sweep_big) = rt.sweep_for(&x) {
            let mut ob = vec![0.0; p];
            let m_art = bench.run("pjrt sweep big", || {
                sweep_big.xt_w(&w, &mut ob);
                black_box(ob[0])
            });
            rep.row(&[
                format!("xt_w artifact (PJRT) {n}x{p}"),
                format!("{:.1}us", m_art.mean_s * 1e6),
                format!("{:.1}us", m_art.min_s * 1e6),
                format!("{:.1}us", m_art.std_s * 1e6),
                format!("{:.2}x native", m_art.mean_s / m_fast.mean_s),
            ]);
        }
        let dsq = synthetic::synthetic1(64, 256, 20, 0.1, 3);
        if let Some(sweep) = rt.sweep_for(dsq.x.dense().unwrap()) {
            let mut w2 = vec![0.0; 64];
            Rng::new(4).fill_normal(&mut w2);
            let mut o2 = vec![0.0; 256];
            let m_art = bench.run("pjrt sweep", || {
                sweep.xt_w(&w2, &mut o2);
                black_box(o2[0])
            });
            let m_nat = bench.run("native sweep 64x256", || {
                dsq.x.gemv_t(&w2, &mut o2);
                black_box(o2[0])
            });
            rep.row(&[
                "xt_w artifact (PJRT) 64x256".into(),
                format!("{:.1}us", m_art.mean_s * 1e6),
                format!("{:.1}us", m_art.min_s * 1e6),
                format!("{:.1}us", m_art.std_s * 1e6),
                format!("{:.2}x native", m_art.mean_s / m_nat.mean_s),
            ]);
        }
    } else {
        eprintln!("(artifacts not built — skipping PJRT ablation)");
    }

    // --- end-to-end: screened vs unscreened path at bench scale ---
    let grid = LambdaGrid::relative(&ds.x, &ds.y, 20, 0.05, 1.0);
    let cfg = PathConfig::default();
    let quick = Bench::new(1, 3);
    let m_base = quick.run("path no screening", || {
        black_box(
            solve_path(&ds.x, &ds.y, &grid, RuleKind::None, SolverKind::Cd, &cfg).total_secs(),
        )
    });
    let m_scr = quick.run("path edpp", || {
        black_box(
            solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg).total_secs(),
        )
    });
    rep.row(&[
        format!("20-λ path {n}x{p} (no screen)"),
        format!("{:.3}s", m_base.mean_s),
        format!("{:.3}s", m_base.min_s),
        format!("{:.3}s", m_base.std_s),
        "1.00x".into(),
    ]);
    rep.row(&[
        format!("20-λ path {n}x{p} (EDPP)"),
        format!("{:.3}s", m_scr.mean_s),
        format!("{:.3}s", m_scr.min_s),
        format!("{:.3}s", m_scr.std_s),
        format!("{:.1}x faster", m_base.mean_s / m_scr.mean_s),
    ]);

    rep.emit("perf.md");
}
