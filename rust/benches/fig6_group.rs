//! Bench: Fig. 6 + Table 5 — group Lasso.
//! Regenerates the paper artifact via the shared experiment harness
//! (dpp_screen::experiments). Output: stdout + results/*.md.
//! Scale knobs: DPP_SCALE=full, DPP_TRIALS=…, DPP_GRID=…

fn main() {
    println!("== Fig. 6 + Table 5 — group Lasso ==");
    dpp_screen::experiments::fig6_group();
}
