//! Bench: Fig. 2 — basic SAFE / DOME / strong / EDPP.
//! Regenerates the paper artifact via the shared experiment harness
//! (dpp_screen::experiments). Output: stdout + results/*.md.
//! Scale knobs: DPP_SCALE=full, DPP_TRIALS=…, DPP_GRID=…

fn main() {
    println!("== Fig. 2 — basic SAFE / DOME / strong / EDPP ==");
    dpp_screen::experiments::fig2_basic_rules();
}
