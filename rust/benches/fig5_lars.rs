//! Bench: Fig. 5 + Table 4 — LARS solver.
//! Regenerates the paper artifact via the shared experiment harness
//! (dpp_screen::experiments). Output: stdout + results/*.md.
//! Scale knobs: DPP_SCALE=full, DPP_TRIALS=…, DPP_GRID=…

fn main() {
    println!("== Fig. 5 + Table 4 — LARS solver ==");
    dpp_screen::experiments::fig5_lars();
}
