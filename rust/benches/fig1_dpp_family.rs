//! Bench: Fig. 1 + Table 1 — DPP family (DPP, Improvement 1/2, EDPP).
//! Regenerates the paper artifact via the shared experiment harness
//! (dpp_screen::experiments). Output: stdout + results/*.md.
//! Scale knobs: DPP_SCALE=full, DPP_TRIALS=…, DPP_GRID=…

fn main() {
    println!("== Fig. 1 + Table 1 — DPP family (DPP, Improvement 1/2, EDPP) ==");
    dpp_screen::experiments::fig1_dpp_family();
}
