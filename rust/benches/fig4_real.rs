//! Bench: Fig. 4 + Table 3 — six (simulated) real datasets.
//! Regenerates the paper artifact via the shared experiment harness
//! (dpp_screen::experiments). Output: stdout + results/*.md.
//! Scale knobs: DPP_SCALE=full, DPP_TRIALS=…, DPP_GRID=…

fn main() {
    println!("== Fig. 4 + Table 3 — six (simulated) real datasets ==");
    dpp_screen::experiments::fig4_real();
}
