//! `dpp` — CLI for the dpp-screen library (leader entrypoint).
//!
//! Subcommands:
//!   info                         environment + artifact inventory
//!   path      --dataset … --rule … --solver …      run a screened λ-path
//!   group     --ngroups …        run a group-Lasso screened path
//!   service   --requests …       demo the batching screening service
//!   serve     --sessions K --ops M   multi-tenant serving demo (DESIGN.md §4)
//!   serve     --listen ADDR [--shard-nodes A1,A2]  framed TCP server (DESIGN.md §4b)
//!   serve/bench-serve --max-sessions K --admission depth=D,total=T,ttl-ms=MS
//!             admission control: registration cap, queue-depth load shedding
//!             (typed `Overloaded` replies with a retry hint), idle-session TTL
//!   front     --listen ADDR --backend A1,A2,…  session-affine routing tier
//!             (DESIGN.md §4c): rendezvous placement biased by probed load,
//!             per-session FIFO forwarding, bounded Overloaded retries
//!   client    --connect ADDR [--ops K] [--deadline-ms D] [--retry R]
//!             [--stats] [--shutdown]  socket client (server or front)
//!   shard-node --listen ADDR --file shard.dppcsc [--in-ram]  host one remote shard
//!   shard-node --connect ADDR --stop   stop a running shard node
//!   convert   --file in.svm --out shard.dppcsc [--f32]  stream to an on-disk shard
//!   shard     --file shard.dppcsc --shards K   split into a row-range shard set
//!   audit [--json]               run the in-repo invariant auditor (DESIGN.md §5)
//!   bench-screen                 perf harness → BENCH_screen.json
//!   bench-serve [--listen ADDR]  serving perf harness → BENCH_serve.json
//!   exp       <fig1|fig2|fig3|fig4|fig5|fig6|all>  regenerate paper tables/figures
//!
//! `--rule` accepts the full screening-pipeline grammar (DESIGN.md §3):
//! a plain rule (`edpp`, `strong`, …), `cascade:<r1>,<r2>[,…]`,
//! `hybrid:<heuristic>+<safe>` (e.g. `hybrid:strong+edpp`), a
//! `dynamic:` prefix — or the `--dynamic` flag — for in-solver gap-safe
//! refinement, and `auto`, which picks a pipeline from the loaded problem's
//! shape (n, p, density, λ-grid size — `ScreenPipeline::auto`).
//!
//! `path`, `service` and `serve` also accept `--strategy screen|working-set`
//! (DESIGN.md §3b): `working-set` grows a restricted subproblem from the
//! pipeline survivors and certifies every answer against the full-problem
//! duality gap. Default `screen`; `--rule auto` picks `working-set` itself
//! on very wide problems (p ≥ 8n) with long λ-grids, and an explicit
//! `--strategy` always wins.
//!
//! `path` and `service` accept `--matrix dense|csc|mmap|sharded|auto`
//! (default auto): auto keeps an already-sparse input sparse (a LIBSVM
//! file loads as CSC, a shard directory as the out-of-core mmap backend, a
//! shard-set manifest as the pool-parallel sharded backend) and picks CSC
//! for dense data sparse enough that the O(nnz) sweep wins. `mmap`
//! requires a shard produced by `dpp convert`, `sharded` a shard set
//! produced by `dpp shard`; `--mmap-budget BYTES` bounds the resident
//! window (per shard for a set), `DPP_POOL_THREADS` sizes the sweep pool.
//! The chosen backend is reported on stderr.

use std::path::Path;
use std::sync::Arc;

use dpp_screen::coordinator::service::ScreeningService;
use dpp_screen::data::{convert, synthetic, Dataset, RealDataset};
use dpp_screen::linalg::{CscMatrix, DesignMatrix, DesignStore, MmapCscMatrix, ShardSetMatrix};
use dpp_screen::path::group::{
    solve_group_path, solve_group_path_working_set, GroupRuleKind,
};
use dpp_screen::path::{
    solve_path_pipeline, LambdaGrid, PathConfig, PathStrategy, RuleKind, SolverKind,
};
use dpp_screen::runtime::pool::{self, WorkerPool};
use dpp_screen::runtime::{ArtifactRuntime, ArtifactSweep};
use dpp_screen::screening::ScreenPipeline;
use dpp_screen::solver::SolveOptions;
use dpp_screen::util::benchkit::{black_box, Bench};
use dpp_screen::util::cli::Args;
use dpp_screen::util::{benchkit, full_scale, grid_size};

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("info") => cmd_info(),
        Some("path") => cmd_path(&args),
        Some("group") => cmd_group(&args),
        Some("service") => cmd_service(&args),
        Some("serve") => cmd_serve(&args),
        Some("front") => cmd_front(&args),
        Some("client") => cmd_client(&args),
        Some("shard-node") => cmd_shard_node(&args),
        Some("convert") => cmd_convert(&args),
        Some("shard") => cmd_shard(&args),
        Some("bench-screen") => cmd_bench_screen(&args),
        Some("bench-serve") => cmd_bench_serve(&args),
        Some("exp") => cmd_exp(&args),
        Some("audit") => cmd_audit(&args),
        _ => {
            eprintln!(
                "usage: dpp <info|path|group|service|serve|front|client|shard-node|convert|shard|bench-screen|bench-serve|exp|audit> [--options]\n\
                 \n\
                 dpp path --dataset pie --rule edpp --solver cd --grid 100\n\
                 dpp path --dataset mnist --matrix csc      # sparse backend\n\
                 dpp path --rule hybrid:strong+edpp --dynamic  # composed pipeline\n\
                 dpp path --rule auto                       # shape-picked pipeline\n\
                 dpp path --strategy working-set            # working-set solve engine\n\
                 dpp convert --file data.svm --out data.dppcsc [--f32]\n\
                 dpp path --file data.dppcsc --matrix mmap  # out-of-core backend\n\
                 dpp shard --file data.dppcsc --out data.shards --shards 4\n\
                 dpp path --file data.shards --matrix sharded  # pool-parallel shard set\n\
                 dpp group --ngroups 100 --rule group-edpp\n\
                 dpp service --requests 20 --rule dynamic:edpp --matrix auto\n\
                 dpp serve --sessions 3 --ops 24 --deadline-ms 50  # multi-tenant demo\n\
                 dpp serve --sessions 3 --max-sessions 8 --admission depth=8,ttl-ms=30000\n\
                 dpp serve --listen 127.0.0.1:7700          # framed TCP server\n\
                 dpp client --connect 127.0.0.1:7700 --ops 12 --deadline-ms 50\n\
                 dpp client --connect 127.0.0.1:7700 --retry 3   # honor Overloaded hints\n\
                 dpp client --connect 127.0.0.1:7700 --stats  # per-backend admission stats\n\
                 dpp client --connect 127.0.0.1:7700 --shutdown\n\
                 dpp front --listen 127.0.0.1:7790 \\\n\
                           --backend 127.0.0.1:7700,127.0.0.1:7701  # session-affine router\n\
                 dpp shard-node --listen 127.0.0.1:7701 --file data.shards/shard-0000\n\
                 dpp serve --listen :7700 --shard-nodes 127.0.0.1:7701,127.0.0.1:7702 \\\n\
                           --file data.shards   # distributed-shard session `remote`\n\
                 dpp bench-screen --p 4000   # perf baseline -> BENCH_screen.json\n\
                 dpp bench-serve --ops 40    # serving baseline -> BENCH_serve.json\n\
                 dpp bench-serve --listen 127.0.0.1:0   # adds socket-transport rows\n\
                 dpp bench-serve --front     # adds front-tier routing rows\n\
                 dpp exp fig1        # regenerate a paper figure/table\n\
                 dpp exp all\n\
                 dpp audit           # invariant auditor: determinism/unsafe/wire/panic\n\
                 dpp audit --json    # machine-readable findings\n\
                 \n\
                 {}",
                ScreenPipeline::grammar()
            );
            std::process::exit(2);
        }
    }
}

/// Parse `--rule` (+ `--dynamic`) into a screening pipeline and
/// `--strategy screen|working-set` into the per-λ solve strategy, exiting
/// with the full grammar on error. `--rule auto` resolves through
/// [`ScreenPipeline::auto_with_strategy`] using the loaded problem's shape
/// — (n, p, density) from the backend, `grid` = how many λ-evaluations the
/// command is about to run — and reports both picks on stderr. An explicit
/// `--strategy` always wins over the auto pick.
fn parse_pipeline(
    args: &Args,
    default: &str,
    shape: (usize, usize, f64),
    grid: usize,
) -> (ScreenPipeline, PathStrategy) {
    let explicit = args.get("strategy").map(|s| match PathStrategy::from_name(s) {
        Some(st) => st,
        None => {
            eprintln!("unknown --strategy `{s}` (screen | working-set)");
            std::process::exit(2);
        }
    });
    let spec = args.get_or("rule", default);
    if spec == "auto" {
        let (n, p, density) = shape;
        let (mut pipe, auto_strategy) =
            ScreenPipeline::auto_with_strategy(n, p, density, grid);
        if args.flag("dynamic") && !pipe.dynamic {
            pipe = pipe.with_dynamic(true);
        }
        let strategy = explicit.unwrap_or(auto_strategy);
        eprintln!(
            "[dpp] --rule auto ({n}x{p}, density {density:.4}, {grid} λ) → {}, \
             strategy {}{}",
            pipe.name(),
            strategy.name(),
            if explicit.is_some() { " (forced by --strategy)" } else { "" }
        );
        return (pipe, strategy);
    }
    let pipe = match ScreenPipeline::parse(&spec) {
        Ok(p) => {
            if args.flag("dynamic") && !p.dynamic {
                p.with_dynamic(true)
            } else {
                p
            }
        }
        Err(e) => {
            eprintln!("bad --rule: {e}");
            std::process::exit(2);
        }
    };
    (pipe, explicit.unwrap_or_default())
}

/// Auto-pick threshold: below this fill fraction the O(nnz) CSC sweep beats
/// the unrolled dense kernel comfortably (see benches/kernels.rs).
const AUTO_CSC_DENSITY: f64 = 0.25;

/// Resolve `--matrix dense|csc|mmap|sharded|auto` against whatever backend
/// the loader produced. An already-sparse input is never densified to "measure
/// density" — auto keeps it as-is; only an explicit `--matrix dense`
/// materializes a dense copy.
fn pick_backend(x: DesignStore, choice: &str) -> DesignStore {
    match choice {
        "dense" => DesignStore::Dense(x.into_dense()),
        "csc" => match x {
            c @ DesignStore::Csc(_) => c,
            other => DesignStore::Csc(other.into_csc()),
        },
        "mmap" => match x {
            m @ DesignStore::Mmap(_) => m,
            other => {
                eprintln!(
                    "--matrix mmap needs an on-disk shard, not a {} input: run \
                     `dpp convert --file data.svm --out data.dppcsc` and pass \
                     `--file data.dppcsc`",
                    other.backend_name()
                );
                std::process::exit(2);
            }
        },
        "sharded" => match x {
            s @ DesignStore::Sharded(_) => s,
            other => {
                eprintln!(
                    "--matrix sharded needs a shard set, not a {} input: run \
                     `dpp convert` then `dpp shard --file data.dppcsc --out \
                     data.shards --shards K` and pass `--file data.shards`",
                    other.backend_name()
                );
                std::process::exit(2);
            }
        },
        "auto" => match x {
            DesignStore::Dense(d) => {
                // count first, convert after: building the CSC just to
                // measure density would spike peak memory ~2.5x on large
                // dense data — exactly the datasets where memory matters
                let nnz = d.data().iter().filter(|v| **v != 0.0).count();
                let density = nnz as f64 / d.data().len().max(1) as f64;
                if density < AUTO_CSC_DENSITY {
                    DesignStore::Csc(CscMatrix::from_dense(&d))
                } else {
                    DesignStore::Dense(d)
                }
            }
            sparse => sparse,
        },
        other => {
            eprintln!("unknown --matrix `{other}` (dense|csc|mmap|sharded|auto)");
            std::process::exit(2);
        }
    }
}

/// One-line backend report, identical for `path` and `service`, on stderr
/// so it never disturbs parseable stdout tables.
fn report_backend(cmd: &str, x: &DesignStore) {
    eprintln!(
        "[dpp {cmd}] matrix backend: {} ({}x{}, nnz={}, density={:.4})",
        x.backend_name(),
        x.n_rows(),
        x.n_cols(),
        x.nnz(),
        x.density()
    );
}

/// Does `--file` point at a dppcsc shard (directory or `.dppcsc` suffix)?
fn is_shard_path(path: &str) -> bool {
    path.ends_with(".dppcsc") || Path::new(path).join("meta.txt").exists()
}

/// Does `--file` point at a shard-set directory (`shardset.txt` manifest)?
fn is_shardset_path(path: &str) -> bool {
    path.ends_with(".shards")
        || Path::new(path).join(dpp_screen::linalg::sharded::SHARDSET_FILE).exists()
}

fn load_shard(path: &str, args: &Args) -> anyhow::Result<Dataset> {
    let budget = args.get_parse::<usize>(
        "mmap-budget",
        dpp_screen::linalg::mmap::default_budget(),
    );
    let x = MmapCscMatrix::open_with_budget(path, budget)?;
    let y = convert::read_shard_y(path)?.ok_or_else(|| {
        anyhow::anyhow!("shard {path} has no y.bin (convert from a labeled dataset)")
    })?;
    if y.len() != x.n_rows() {
        anyhow::bail!(
            "shard {path}: y.bin has {} entries, matrix has {} rows",
            y.len(),
            x.n_rows()
        );
    }
    Ok(Dataset { name: path.to_string(), x: x.into(), y, beta_true: None, groups: None })
}

fn load_shardset(path: &str, args: &Args) -> anyhow::Result<Dataset> {
    let budget = args.get_parse::<usize>(
        "mmap-budget",
        dpp_screen::linalg::mmap::default_budget(),
    );
    let x = ShardSetMatrix::open_with_budget(path, budget)?;
    let y = convert::read_shard_y(path)?.ok_or_else(|| {
        anyhow::anyhow!("shard set {path} has no y.bin (split a labeled shard)")
    })?;
    if y.len() != x.n_rows() {
        anyhow::bail!(
            "shard set {path}: y.bin has {} entries, matrix has {} rows",
            y.len(),
            x.n_rows()
        );
    }
    Ok(Dataset { name: path.to_string(), x: x.into(), y, beta_true: None, groups: None })
}

fn load_dataset(args: &Args) -> Dataset {
    // user-supplied data: --file data.csv (y,x1,…,xp), data.svm (LIBSVM,
    // loads as CSC), a data.dppcsc shard (loads out-of-core), or a
    // data.shards shard set (loads as the pool-parallel sharded backend)
    if let Some(path) = args.get("file") {
        let res = if is_shardset_path(path) {
            load_shardset(path, args)
        } else if is_shard_path(path) {
            load_shard(path, args)
        } else if path.ends_with(".svm") || path.ends_with(".libsvm") {
            dpp_screen::data::io::read_libsvm(path, None)
        } else {
            dpp_screen::data::io::read_csv(path)
        };
        match res {
            Ok(ds) => return ds,
            Err(e) => {
                eprintln!("failed to load {path}: {e:#}");
                std::process::exit(2);
            }
        }
    }
    let name = args.get_or("dataset", "synthetic1");
    let seed = args.get_parse::<u64>("seed", 42);
    let full = full_scale() || args.flag("full");
    match name.as_str() {
        "synthetic1" => {
            let (n, p) = if full { (250, 10000) } else { (100, 1000) };
            synthetic::synthetic1(n, p, args.get_parse("nnz", p / 10), 0.1, seed)
        }
        "synthetic2" => {
            let (n, p) = if full { (250, 10000) } else { (100, 1000) };
            synthetic::synthetic2(n, p, args.get_parse("nnz", p / 10), 0.1, seed)
        }
        other => match RealDataset::from_name(other) {
            Some(d) => d.generate(full, seed),
            None => {
                eprintln!("unknown dataset `{other}`");
                std::process::exit(2);
            }
        },
    }
}

fn cmd_info() {
    println!("dpp-screen — Lasso screening via dual polytope projection (NIPS'13)");
    println!(
        "datasets: synthetic1 synthetic2 {}",
        RealDataset::ALL.map(|d| d.name()).join(" ")
    );
    println!("rules:    {} none", RuleKind::ALL_LASSO.map(|r| r.name()).join(" "));
    println!(
        "pipelines: cascade:<r1>,<r2>[,…]  hybrid:<heur>+<safe>  dynamic:<pipeline> \
         (--dynamic)  auto (shape-picked)"
    );
    println!("solvers:  cd fista lars");
    println!(
        "matrix:   dense csc mmap sharded auto (shards via `dpp convert`, shard \
         sets via `dpp shard`; sweeps use {} pool thread(s))",
        pool::configured_threads()
    );
    match ArtifactRuntime::load_default() {
        Some(rt) => {
            println!("artifacts ({}):", rt.artifact_dir().display());
            for (name, n, p) in rt.available() {
                println!("  {name}  {n}x{p}");
            }
        }
        None => println!("artifacts: none (run `make artifacts`; native fallback active)"),
    }
}

fn cmd_path(args: &Args) {
    let ds = load_dataset(args);
    let solver = SolverKind::from_name(&args.get_or("solver", "cd")).expect("bad --solver");
    let k = args.get_parse("grid", grid_size(100));
    let (pipeline, strategy) =
        parse_pipeline(args, "edpp", (ds.n(), ds.p(), ds.x.density()), k);
    let lo = args.get_parse("lo", 0.05);
    let mut cfg =
        PathConfig { sequential: !args.flag("basic"), strategy, ..Default::default() };
    let name = ds.name.clone();
    let (n, p) = (ds.n(), ds.p());
    let y = ds.y.clone();
    // decided on the *loaded* store: rematerializing an f32 shard as
    // csc/dense does not un-quantize the values, so the slack must survive
    // the --matrix choice
    let reduced_precision = ds.x.is_reduced_precision();
    let backend = pick_backend(ds.x, &args.get_or("matrix", "auto"));
    if reduced_precision {
        // f32-stored values: widen keep-decisions exactly like the PJRT
        // f32 sweep does (DESIGN.md §1)
        cfg.safety_slack = ArtifactSweep::SAFETY_SLACK;
        eprintln!(
            "[dpp path] f32-stored values: screening widened by slack {:.0e}",
            cfg.safety_slack
        );
    }
    report_backend("path", &backend);
    let x = backend.as_design();
    let grid = LambdaGrid::relative(x, &y, k, lo, 1.0);
    println!(
        "dataset={} ({}x{}), matrix={}, rule={}, solver={}, strategy={}, \
         grid={}x[{}..1.0]·λmax",
        name,
        n,
        p,
        backend.backend_name(),
        pipeline.name(),
        solver.name(),
        cfg.strategy.name(),
        k,
        lo
    );
    let out = solve_path_pipeline(x, &y, &grid, &pipeline, solver, &cfg);
    let mut report = benchkit::Report::new(
        &format!(
            "path: {name} / {} / {} [{}]",
            out.rule,
            solver.name(),
            backend.backend_name()
        ),
        &[
            "λ/λmax", "kept", "discarded", "rejection", "screen(s)", "solve(s)", "iters",
            "repairs", "dyn",
        ],
    );
    for r in &out.records {
        report.row(&[
            format!("{:.3}", r.lam / grid.lam_max),
            r.kept.to_string(),
            r.discarded.to_string(),
            format!("{:.3}", r.rejection_ratio()),
            format!("{:.4}", r.screen_secs),
            format!("{:.4}", r.solve_secs),
            r.solver_iters.to_string(),
            r.kkt_repairs.to_string(),
            r.dynamic_discards.to_string(),
        ]);
    }
    report.emit("path_runs.md");
    println!(
        "mean rejection ratio: {:.4}   total screen {:.3}s   total solve {:.3}s",
        out.mean_rejection_ratio(),
        out.total_screen_secs(),
        out.total_solve_secs()
    );
    if cfg.strategy == PathStrategy::WorkingSet {
        println!(
            "working-set: mean size {:.1} of p={p}   total kkt passes {}",
            out.mean_working_set(),
            out.total_kkt_passes()
        );
    }
    let stages = out.mean_stage_rejections();
    if stages.len() > 1 || out.total_dynamic_discards() > 0 {
        let parts: Vec<String> =
            stages.iter().map(|(s, v)| format!("{s}={v:.4}")).collect();
        println!(
            "per-stage rejection: {}   dynamic discards: {}",
            parts.join("  "),
            out.total_dynamic_discards()
        );
    }
}

fn cmd_group(args: &Args) {
    let seed = args.get_parse::<u64>("seed", 42);
    let full = full_scale() || args.flag("full");
    let (n, p) = if full { (250, 200_000) } else { (80, 2000) };
    let ngroups = args.get_parse("ngroups", if full { 10_000 } else { 400 });
    let ds = synthetic::group_synthetic(n, p, ngroups, seed);
    let groups = ds.groups.clone().unwrap();
    let (glm, _) = dpp_screen::solver::dual::group_lambda_max(&ds.x, &ds.y, &groups);
    let grid =
        LambdaGrid::relative_to(glm, args.get_parse("grid", grid_size(100)), 0.05, 1.0);
    let rule = match args.get_or("rule", "group-edpp").as_str() {
        "group-edpp" => GroupRuleKind::Edpp,
        "group-strong" => GroupRuleKind::Strong,
        "none" => GroupRuleKind::None,
        other => {
            eprintln!("unknown group rule `{other}`");
            std::process::exit(2);
        }
    };
    let strategy = args
        .get("strategy")
        .map(|s| match PathStrategy::from_name(s) {
            Some(st) => st,
            None => {
                eprintln!("unknown --strategy `{s}` (screen | working-set)");
                std::process::exit(2);
            }
        })
        .unwrap_or_default();
    let out = if strategy == PathStrategy::WorkingSet {
        solve_group_path_working_set(
            &ds.x,
            &ds.y,
            &groups,
            &grid,
            rule,
            &SolveOptions::default(),
        )
    } else {
        solve_group_path(&ds.x, &ds.y, &groups, &grid, rule, &SolveOptions::default())
    };
    println!(
        "group path: {} groups of size {}, rule={}, strategy={} → mean rejection {:.4}, screen {:.3}s, solve {:.3}s",
        ngroups,
        p / ngroups,
        out.rule,
        strategy.name(),
        out.mean_rejection_ratio(),
        out.total_screen_secs(),
        out.total_solve_secs()
    );
}

fn cmd_service(args: &Args) {
    let ds = load_dataset(args);
    let n_req = args.get_parse("requests", 20usize);
    // for `auto`, the request count plays the λ-grid-size role
    let (pipeline, strategy) =
        parse_pipeline(args, "edpp", (ds.n(), ds.p(), ds.x.density()), n_req.max(1));
    let y = ds.y.clone();
    // decided before pick_backend — see cmd_path
    let reduced_precision = ds.x.is_reduced_precision();
    let backend = pick_backend(ds.x, &args.get_or("matrix", "auto"));
    report_backend("service", &backend);
    let mut cfg = PathConfig { strategy, ..PathConfig::default() };
    if reduced_precision {
        cfg.safety_slack = ArtifactSweep::SAFETY_SLACK;
        eprintln!(
            "[dpp service] f32-stored values: screening widened by slack {:.0e}",
            cfg.safety_slack
        );
    }
    let lam_max = dpp_screen::solver::dual::lambda_max(backend.as_design(), &y);
    println!("service backend: {}  pipeline: {}", backend.backend_name(), pipeline.name());
    let svc = ScreeningService::spawn_boxed(
        backend.into_boxed(),
        y,
        pipeline,
        SolverKind::Cd,
        cfg,
    );
    // fire a burst of requests across the λ range (arrivals out of order)
    let mut rxs = Vec::new();
    for i in 0..n_req {
        let f = 0.05 + 0.9 * ((i * 7919) % n_req) as f64 / n_req as f64;
        rxs.push(svc.request(f * lam_max));
    }
    for rx in rxs {
        let resp = rx.recv().expect("service died");
        let stages: Vec<String> = resp
            .stage_discards
            .iter()
            .map(|s| format!("{}={}", s.stage, s.discarded))
            .collect();
        println!(
            "λ/λmax={:.3} kept={} discarded={} latency={:.2}ms{}{}",
            resp.lam / lam_max,
            resp.kept.len(),
            resp.discarded,
            resp.latency_s * 1e3,
            if stages.len() > 1 {
                format!("  stages[{}]", stages.join(" "))
            } else {
                String::new()
            },
            if resp.dynamic_discards > 0 {
                format!("  dyn={}", resp.dynamic_discards)
            } else {
                String::new()
            }
        );
    }
    let m = svc.shutdown();
    println!("metrics: {}", m.summary());
}

/// Build the serving sessions for `dpp serve` / CI smoke runs: session 0
/// optionally comes from `--file` (honoring `--matrix`, so a shard set
/// runs the sharded backend — its sweeps parallelize when the tick leaves
/// pool workers to spare, see `coordinator::service`), the rest are
/// synthetic datasets with alternating dense/CSC backends — a genuinely
/// mixed multi-dataset tenant set. Returns per-session (name, λmax, p).
fn serve_register_sessions(
    coord: &dpp_screen::coordinator::Coordinator,
    args: &Args,
    n_sessions: usize,
    ops: usize,
) -> Vec<(String, f64, usize)> {
    let mut out = Vec::new();
    for i in 0..n_sessions {
        let name = format!("s{i}");
        let (backend, y, mut cfg) = if i == 0 && args.get("file").is_some() {
            let ds = load_dataset(args);
            let y = ds.y.clone();
            let reduced = ds.x.is_reduced_precision();
            let backend = pick_backend(ds.x, &args.get_or("matrix", "auto"));
            let mut cfg = PathConfig::default();
            if reduced {
                cfg.safety_slack = ArtifactSweep::SAFETY_SLACK;
            }
            (backend, y, cfg)
        } else {
            let ds =
                synthetic::synthetic1(50 + 10 * i, 300 + 120 * i, 16, 0.1, 1000 + i as u64);
            let y = ds.y.clone();
            let backend = if i % 2 == 0 {
                DesignStore::Csc(ds.x.into_csc())
            } else {
                DesignStore::Dense(ds.x.into_dense())
            };
            (backend, y, PathConfig::default())
        };
        let (n, p, density) =
            (backend.n_rows(), backend.n_cols(), backend.density());
        let (pipeline, strategy) =
            parse_pipeline(args, "auto", (n, p, density), ops.max(1));
        cfg.strategy = strategy;
        let lam_max = dpp_screen::solver::dual::lambda_max(backend.as_design(), &y);
        let label = backend.backend_name().to_string();
        println!(
            "session {name}: {n}x{p} backend={label} pipeline={} strategy={}",
            pipeline.name(),
            cfg.strategy.name()
        );
        if let Err(e) = coord.register(
            dpp_screen::coordinator::SessionSpec::boxed(
                name.clone(),
                backend.into_boxed(),
                y,
                pipeline,
                SolverKind::from_name(&args.get_or("solver", "cd")).expect("bad --solver"),
                cfg,
            )
            .with_backend_label(label),
        ) {
            eprintln!("failed to register session {name}: {e}");
            std::process::exit(2);
        }
        out.push((name, lam_max, p));
    }
    out
}

/// Parse the admission knobs shared by `dpp serve` and `dpp bench-serve`:
/// `--admission depth=D,total=T,ttl-ms=MS` (queue-depth caps + idle TTL,
/// see `coordinator::admission`) plus the standalone `--max-sessions K`
/// registration cap. Defaults to fully open — the pre-admission behavior.
fn admission_from_args(args: &Args) -> dpp_screen::coordinator::AdmissionConfig {
    use dpp_screen::coordinator::AdmissionConfig;
    let mut cfg = match args.get("admission").map(AdmissionConfig::parse) {
        Some(Ok(cfg)) => cfg,
        Some(Err(e)) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
        None => AdmissionConfig::default(),
    };
    if let Some(cap) = args.get("max-sessions") {
        let Ok(k) = cap.parse::<usize>() else {
            eprintln!("bad --max-sessions `{cap}`: expected an integer");
            std::process::exit(2);
        };
        cfg.max_sessions = Some(k);
    }
    cfg
}

/// Multi-tenant serving demo: K concurrent sessions on one coordinator,
/// driven by a mixed Screen/Predict/Warm/FitPath workload, with an optional
/// deadline-bounded request demonstrating gap-tagged partial responses.
fn cmd_serve(args: &Args) {
    use dpp_screen::coordinator::{Request, RequestOptions, Response};

    if args.get("listen").is_some() {
        return cmd_serve_listen(args);
    }
    let n_sessions = args.get_parse("sessions", 3usize).max(1);
    let ops = args.get_parse("ops", 24usize).max(1);
    let deadline_ms = args.get_parse("deadline-ms", 0u64);
    let admission = admission_from_args(args);
    let coord =
        dpp_screen::coordinator::Coordinator::with_config(None, admission.clone());
    let sessions = serve_register_sessions(&coord, args, n_sessions, ops);
    println!(
        "serving {n_sessions} session(s) on {} pool thread(s), {ops} mixed ops",
        pool::configured_threads()
    );

    // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
    let t0 = std::time::Instant::now();
    let mut slots = Vec::new();
    for k in 0..ops {
        let (name, lam_max, p) = &sessions[k % sessions.len()];
        let f = 0.05 + 0.9 * ((k * 7919) % ops) as f64 / ops as f64;
        let lam = f * lam_max;
        // the first op optionally carries a deadline (gap-tagged partial
        // responses instead of blocking)
        let opts = if deadline_ms > 0 && k == 0 {
            RequestOptions::with_deadline(std::time::Duration::from_millis(deadline_ms))
        } else {
            RequestOptions::default()
        };
        let request = match k % 6 {
            3 => Request::Predict { features: vec![1.0; *p], lam, opts },
            4 => Request::Warm { lam },
            5 => Request::FitPath { grid: 5, lo: 0.2, opts },
            _ => Request::Screen { lam, opts },
        };
        slots.push((name.clone(), k, coord.submit(name, request)));
    }
    let mut partials = 0usize;
    let mut errors = 0usize;
    for (name, k, slot) in slots {
        match slot.recv_response() {
            Ok(Response::Screen(r)) => {
                if r.partial {
                    partials += 1;
                }
                println!(
                    "op {k:3} {name}: screen λ={:.4} kept={} discarded={} gap={:.1e}{}",
                    r.lam,
                    r.kept.len(),
                    r.discarded,
                    r.gap,
                    if r.partial { "  PARTIAL (deadline)" } else { "" }
                );
            }
            Ok(Response::Predict(pr)) => {
                if pr.partial {
                    partials += 1;
                }
                println!(
                    "op {k:3} {name}: predict λ={:.4} ŷ={:.4}{}",
                    pr.lam,
                    pr.yhat,
                    if pr.partial { "  PARTIAL (deadline)" } else { "" }
                );
            }
            Ok(Response::Warmed(w)) => {
                println!("op {k:3} {name}: warm λ={:.4} gap={:.1e}", w.lam, w.gap);
            }
            Ok(Response::Path(ps)) => {
                if ps.partial {
                    partials += 1;
                }
                println!(
                    "op {k:3} {name}: fit-path {} steps rule={} mean_rejection={:.3}{}",
                    ps.steps,
                    ps.rule,
                    ps.mean_rejection,
                    if ps.partial { "  PARTIAL (deadline)" } else { "" }
                );
            }
            Ok(Response::Stats(_)) => {}
            Ok(Response::Error(e)) | Err(e) => {
                errors += 1;
                println!("op {k:3} {name}: ERROR {e}");
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    for (name, _, _) in &sessions {
        if let Ok(Response::Stats(st)) =
            coord.submit(name, Request::SessionStats).recv_response()
        {
            println!(
                "session {name} [{} {}x{} {}]: {}",
                st.backend,
                st.n,
                st.p,
                st.pipeline,
                st.metrics.summary()
            );
        }
    }
    println!(
        "served {ops} ops across sessions={n_sessions} in {wall:.3}s → {:.1} ops/s \
         (partials={partials}, errors={errors})",
        ops as f64 / wall
    );
    if admission.is_active() {
        let a = coord.admission_stats();
        println!(
            "admission: submitted={} shed={} evicted={}",
            a.submitted, a.shed, a.evicted
        );
    }
    coord.shutdown();
}

/// `dpp serve --listen ADDR`: the multi-tenant coordinator behind the
/// framed TCP protocol (DESIGN.md §4b.3). Sessions are registered exactly
/// as in the in-process demo; `--shard-nodes A1,A2` adds a session named
/// `remote` whose [`ShardSetMatrix`] shards live in `dpp shard-node`
/// processes (the labels come from `--file <set.shards>`; the design
/// matrix never leaves its nodes). Serves until a client sends shutdown,
/// then prints per-session metrics and a `clean shutdown` line.
fn cmd_serve_listen(args: &Args) {
    let listen = args.get("listen").expect("--listen checked by caller");
    let n_sessions = args.get_parse("sessions", 3usize).max(1);
    let ops = args.get_parse("ops", 24usize).max(1);
    let coord = dpp_screen::coordinator::Coordinator::with_config(
        None,
        admission_from_args(args),
    );
    serve_register_sessions(&coord, args, n_sessions, ops);
    if let Some(nodes) = args.get("shard-nodes") {
        let addrs: Vec<String> = nodes
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        match register_remote_session(&coord, args, &addrs) {
            Ok((n, p)) => println!(
                "session remote: {n}x{p} backend=remote-shards across {} node(s)",
                addrs.len()
            ),
            Err(e) => {
                eprintln!("failed to register remote session: {e:#}");
                std::process::exit(2);
            }
        }
    }
    let server = match dpp_screen::net::NetServer::bind(coord, listen) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve --listen failed: {e:#}");
            std::process::exit(2);
        }
    };
    let addr = server
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| listen.to_string());
    println!(
        "listening on {addr} ({} pool thread(s)) — stop with \
         `dpp client --connect {addr} --shutdown`",
        pool::configured_threads()
    );
    let metrics = server.run();
    for (name, m) in &metrics {
        println!("session {name}: {}", m.summary());
    }
    println!("clean shutdown");
}

/// Register the `remote` session for `--shard-nodes`: connect to every
/// node, assemble the [`ShardSetMatrix`], and pair it with the labels from
/// the local shard-set directory (`--file`), which is the only part of the
/// dataset that leaves this process.
fn register_remote_session(
    coord: &dpp_screen::coordinator::Coordinator,
    args: &Args,
    addrs: &[String],
) -> anyhow::Result<(usize, usize)> {
    let x = ShardSetMatrix::connect(addrs)?;
    let file = args.get("file").ok_or_else(|| {
        anyhow::anyhow!(
            "--shard-nodes needs --file <set.shards> for y.bin \
             (the labels stay with the shard-set manifest)"
        )
    })?;
    let y = convert::read_shard_y(file)?
        .ok_or_else(|| anyhow::anyhow!("shard set {file} has no y.bin"))?;
    if y.len() != x.n_rows() {
        anyhow::bail!(
            "shard nodes host {} row(s) total, y.bin at {file} has {} entries",
            x.n_rows(),
            y.len()
        );
    }
    let (n, p, density) = (x.n_rows(), x.n_cols(), x.density());
    let (pipeline, strategy) = parse_pipeline(args, "auto", (n, p, density), 8);
    coord
        .register(
            dpp_screen::coordinator::SessionSpec::new(
                "remote",
                x,
                y,
                pipeline,
                SolverKind::from_name(&args.get_or("solver", "cd")).expect("bad --solver"),
                PathConfig { strategy, ..PathConfig::default() },
            )
            .with_backend_label("remote-shards"),
        )
        .map_err(|e| anyhow::anyhow!("registering remote session: {e}"))?;
    Ok((n, p))
}

/// `dpp front`: the session-affine routing tier (DESIGN.md §4c). Connects
/// to every `--backend` `dpp serve --listen` process, then routes client
/// connections: each session is placed on one backend by load-biased
/// rendezvous hashing and all of its frames forward there in FIFO order
/// (responses stay bit-identical to a direct backend). Health/load probes
/// run every `--probe-ms`; `Overloaded` answers are retried up to
/// `--retry` times per request. Runs until a client sends shutdown —
/// which stops the front only; backends keep their sessions.
fn cmd_front(args: &Args) {
    use dpp_screen::front::{Front, FrontConfig};

    let Some(listen) = args.get("listen") else {
        eprintln!(
            "usage: dpp front --listen ADDR --backend A1,A2,… \
             [--probe-ms MS] [--retry R]"
        );
        std::process::exit(2);
    };
    let backends: Vec<String> = args
        .get_or("backend", "")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if backends.is_empty() {
        eprintln!("dpp front needs --backend ADDR1[,ADDR2,…]");
        std::process::exit(2);
    }
    let cfg = FrontConfig {
        probe_interval: std::time::Duration::from_millis(
            args.get_parse("probe-ms", 500u64).max(1),
        ),
        retry_budget: args.get_parse("retry", 3u32),
        ..FrontConfig::default()
    };
    let front = match Front::bind(&listen, &backends, cfg) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("front failed to start: {e:#}");
            std::process::exit(2);
        }
    };
    let addr = front
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| listen.to_string());
    println!(
        "front listening on {addr} routing {} backend(s): {} — stop with \
         `dpp client --connect {addr} --shutdown`",
        backends.len(),
        backends.join(" ")
    );
    let summary = front.run();
    for b in &summary.backends {
        println!(
            "backend {}: up={} sessions={} {}",
            b.backend,
            b.up,
            b.sessions,
            b.admission.summary()
        );
    }
    println!(
        "front forwarded {} request(s), {} overload retr{} — clean shutdown",
        summary.forwarded,
        summary.retries,
        if summary.retries == 1 { "y" } else { "ies" }
    );
}

/// `dpp shard-node`: host one shard of a shard set for a remote
/// [`ShardSetMatrix`] (DESIGN.md §4b.4), or stop a running node with
/// `--connect ADDR --stop`. The shard serves its slice over the fold RPCs
/// until stopped; `--in-ram` materializes the mmap shard as an in-RAM CSC
/// (widening f32-stored values to f64).
fn cmd_shard_node(args: &Args) {
    use dpp_screen::linalg::sharded::ShardBackend;
    use dpp_screen::net::{spawn_shard_node, stop_shard_node};

    if let Some(addr) = args.get("connect") {
        if args.flag("stop") {
            match stop_shard_node(addr) {
                Ok(()) => {
                    println!("shard node at {addr} acknowledged shutdown");
                    return;
                }
                Err(e) => {
                    eprintln!("stopping shard node at {addr}: {e:#}");
                    std::process::exit(2);
                }
            }
        }
        eprintln!("dpp shard-node --connect only supports --stop");
        std::process::exit(2);
    }
    let Some(listen) = args.get("listen") else {
        eprintln!(
            "usage: dpp shard-node --listen ADDR --file shard.dppcsc [--in-ram]\n\
             \x20      dpp shard-node --connect ADDR --stop"
        );
        std::process::exit(2);
    };
    let Some(file) = args.get("file") else {
        eprintln!(
            "shard-node needs --file <shard dir> (one `shard-NNNN` directory \
             from `dpp shard`, or any `dpp convert` output)"
        );
        std::process::exit(2);
    };
    let backend = match MmapCscMatrix::open(file) {
        Ok(m) if args.flag("in-ram") => ShardBackend::Csc(m.to_csc()),
        Ok(m) => ShardBackend::Mmap(m),
        Err(e) => {
            eprintln!("opening shard {file}: {e:#}");
            std::process::exit(2);
        }
    };
    let (n, p, nnz) = (backend.n_rows(), backend.n_cols(), backend.nnz());
    match spawn_shard_node(backend, listen) {
        Ok(handle) => {
            let addr = handle.addr();
            println!(
                "shard node hosting {file} ({n}x{p}, nnz={nnz}) on {addr} — stop \
                 with `dpp shard-node --connect {addr} --stop`"
            );
            handle.join();
            println!("shard node stopped");
        }
        Err(e) => {
            eprintln!("shard node failed to start: {e:#}");
            std::process::exit(2);
        }
    }
}

/// `dpp client`: drive a `dpp serve --listen` server (or a `dpp front`
/// router — the protocol is identical) over the socket with the same mixed
/// Screen/Predict/Warm/FitPath workload as the in-process demo, then
/// optionally (`--shutdown`) stop it. λ values come from the session's own
/// `SessionStats` (λmax lives server-side). `--stats` prints one
/// control-plane row per backend (a plain server reports itself as
/// `self`); `--retry R` re-submits `Overloaded` answers up to R times,
/// waiting the server's deterministic hint when a deadline budget exists.
fn cmd_client(args: &Args) {
    use dpp_screen::coordinator::{Request, RequestError, RequestOptions, Response};
    use dpp_screen::net::NetClient;

    let Some(addr) = args.get("connect") else {
        eprintln!(
            "usage: dpp client --connect ADDR [--session NAME] [--ops K] \
             [--deadline-ms D] [--retry R] [--stats] [--shutdown]"
        );
        std::process::exit(2);
    };
    let mut client = match NetClient::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(2);
        }
    };
    println!("connected to {addr}; sessions: {}", client.sessions().join(" "));
    if args.flag("stats") {
        match client.stats() {
            Ok(rows) => {
                for r in &rows {
                    let who = if r.backend.is_empty() { "self" } else { r.backend.as_str() };
                    println!(
                        "backend {who}: up={} sessions={} {}",
                        r.up,
                        r.sessions,
                        r.admission.summary()
                    );
                }
            }
            Err(e) => {
                eprintln!("stats failed: {e}");
                std::process::exit(2);
            }
        }
    }
    let default_ops =
        if args.flag("shutdown") || args.flag("stats") { 0usize } else { 12usize };
    let ops = args.get_parse("ops", default_ops);
    let retries = args.get_parse("retry", 0u32);
    let deadline_ms = args.get_parse("deadline-ms", 0u64);
    let mut partials = 0usize;
    let mut errors = 0usize;
    if ops > 0 {
        let session = match args.get("session") {
            Some(s) => s.to_string(),
            None => match client.sessions().first() {
                Some(s) => s.clone(),
                None => {
                    eprintln!("server advertises no sessions");
                    std::process::exit(2);
                }
            },
        };
        let (lam_max, p) = match client.request_with_retry(
            &session,
            Request::SessionStats,
            retries,
        ) {
            Ok(Response::Stats(st)) => (st.lam_max, st.p),
            // a server shedding everything still gets driven: each op
            // surfaces the typed error below instead of aborting here
            Ok(Response::Error(RequestError::Overloaded { .. })) => {
                println!(
                    "session stats for `{session}` shed by admission control; \
                     driving anyway"
                );
                (1.0, 1)
            }
            Ok(Response::Error(e)) | Err(e) => {
                eprintln!("session stats for `{session}` failed: {e}");
                std::process::exit(2);
            }
            Ok(other) => {
                eprintln!("unexpected reply to SessionStats: {other:?}");
                std::process::exit(2);
            }
        };
        println!("driving session {session} (p={p}, λmax={lam_max:.4}) for {ops} ops");
        for k in 0..ops {
            let f = 0.05 + 0.9 * ((k * 7919) % ops) as f64 / ops as f64;
            let lam = f * lam_max;
            let opts = if deadline_ms > 0 && k == 0 {
                RequestOptions::with_deadline(std::time::Duration::from_millis(
                    deadline_ms,
                ))
            } else {
                RequestOptions::default()
            };
            let request = match k % 6 {
                3 => Request::Predict { features: vec![1.0; p], lam, opts },
                4 => Request::Warm { lam },
                5 => Request::FitPath { grid: 5, lo: 0.2, opts },
                _ => Request::Screen { lam, opts },
            };
            match client.request_with_retry(&session, request, retries) {
                Ok(Response::Screen(r)) => {
                    if r.partial {
                        partials += 1;
                    }
                    println!(
                        "op {k:3}: screen λ={:.4} kept={} discarded={}{}",
                        r.lam,
                        r.kept.len(),
                        r.discarded,
                        if r.partial { "  PARTIAL (deadline)" } else { "" }
                    );
                }
                Ok(Response::Predict(pr)) => {
                    if pr.partial {
                        partials += 1;
                    }
                    println!("op {k:3}: predict λ={:.4} ŷ={:.4}", pr.lam, pr.yhat);
                }
                Ok(Response::Warmed(w)) => {
                    println!("op {k:3}: warm λ={:.4} gap={:.1e}", w.lam, w.gap);
                }
                Ok(Response::Path(ps)) => {
                    if ps.partial {
                        partials += 1;
                    }
                    println!(
                        "op {k:3}: fit-path {} steps mean_rejection={:.3}",
                        ps.steps, ps.mean_rejection
                    );
                }
                Ok(Response::Stats(_)) => {}
                Ok(Response::Error(e)) | Err(e) => {
                    errors += 1;
                    println!("op {k:3}: ERROR {e}");
                }
            }
        }
        println!("client ran {ops} ops on {session} (partials={partials}, errors={errors})");
    }
    if args.flag("shutdown") {
        match client.shutdown_server() {
            Ok(()) => println!("server acknowledged shutdown"),
            Err(e) => {
                eprintln!("shutdown failed: {e:#}");
                std::process::exit(2);
            }
        }
    }
}

/// Serving perf harness: throughput + latency percentiles per
/// (session count × pipeline), written as `BENCH_serve.json` so future PRs
/// diff serving changes against a pinned baseline (companion of
/// `BENCH_screen.json`).
fn cmd_bench_serve(args: &Args) {
    use dpp_screen::coordinator::{
        Coordinator, Request, RequestError, RequestOptions, SessionSpec,
    };

    let n = args.get_parse("n", 100usize);
    let p = args.get_parse("p", 800usize);
    let density = args.get_parse("density", 0.1f64);
    let ops = args.get_parse("ops", 40usize).max(1);
    let out_path = args.get_or("out", "BENCH_serve.json");
    let max_sessions = args.get_parse("sessions", 3usize).max(1);
    let admission = admission_from_args(args);

    // one sparse synthetic regression problem per session slot (the shared
    // bench fixture), reused across cells so rows are comparable
    let mut datasets: Vec<(CscMatrix, Vec<f64>, f64)> = Vec::new();
    for s in 0..max_sessions {
        let (csc, y, _) = bench_problem(n, p, density, 7000 + s as u64);
        let lam_max = dpp_screen::solver::dual::lambda_max(&csc, &y);
        datasets.push((csc, y, lam_max));
    }

    let session_counts: Vec<usize> = (1..=max_sessions).collect();
    let pipelines = ["edpp", "hybrid:strong+edpp", "dynamic:edpp"];
    let mut cases: Vec<String> = Vec::new();
    let mut rep = benchkit::Report::new(
        "bench-serve (sessions × pipeline × transport)",
        &["sessions", "pipeline", "transport", "ops", "ops/s", "p50", "p95", "p99"],
    );
    for &sc in &session_counts {
        for pipe_name in &pipelines {
            let pipe = ScreenPipeline::parse(pipe_name).expect("bench pipeline");
            let coord = Coordinator::with_config(None, admission.clone());
            for (i, (csc, y, _)) in datasets.iter().take(sc).enumerate() {
                coord
                    .register(
                        SessionSpec::new(
                            format!("s{i}"),
                            csc.clone(),
                            y.clone(),
                            pipe.clone(),
                            SolverKind::Cd,
                            PathConfig::default(),
                        )
                        .with_backend_label("csc"),
                    )
                    .expect("bench session");
            }
            // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
            let t0 = std::time::Instant::now();
            let mut slots = Vec::with_capacity(ops);
            for k in 0..ops {
                let i = k % sc;
                let f = 0.05 + 0.9 * ((k * 7919) % ops) as f64 / ops as f64;
                let lam = f * datasets[i].2;
                slots.push(coord.submit(
                    &format!("s{i}"),
                    Request::Screen { lam, opts: RequestOptions::default() },
                ));
            }
            let mut latencies: Vec<f64> = Vec::with_capacity(ops);
            for slot in slots {
                match slot.recv() {
                    Ok(resp) => latencies.push(resp.latency_s),
                    // shed ops don't produce a latency sample (only
                    // possible when --admission caps are set)
                    Err(RequestError::Overloaded { .. }) => {}
                    Err(e) => {
                        eprintln!("bench-serve op failed: {e}");
                        std::process::exit(2);
                    }
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            coord.shutdown();
            let throughput = ops as f64 / wall.max(1e-12);
            let (p50, p95, p99) = (
                dpp_screen::util::stats::quantile(&latencies, 0.50),
                dpp_screen::util::stats::quantile(&latencies, 0.95),
                dpp_screen::util::stats::quantile(&latencies, 0.99),
            );
            cases.push(format!(
                "    {{\"sessions\": {sc}, \"pipeline\": \"{pipe_name}\", \
                 \"transport\": \"inproc\", \"ops\": {ops}, \
                 \"wall_secs\": {wall:.6}, \"throughput_rps\": {throughput:.3}, \
                 \"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \"p99_ms\": {:.4}}}",
                p50 * 1e3,
                p95 * 1e3,
                p99 * 1e3
            ));
            rep.row(&[
                sc.to_string(),
                pipe_name.to_string(),
                "inproc".to_string(),
                ops.to_string(),
                format!("{throughput:.1}"),
                format!("{:.2}ms", p50 * 1e3),
                format!("{:.2}ms", p95 * 1e3),
                format!("{:.2}ms", p99 * 1e3),
            ]);
        }
    }

    // --listen ADDR: the same grid again over the framed TCP transport (one
    // server + one sequential blocking client per cell, so the socket rows
    // price the full request→frame→wire→reply round trip). Prefer port 0 —
    // each cell binds afresh, and a fixed port can sit in TIME_WAIT between
    // cells.
    if let Some(listen) = args.get("listen") {
        use dpp_screen::net::{NetClient, NetServer};
        for &sc in &session_counts {
            for pipe_name in &pipelines {
                let pipe = ScreenPipeline::parse(pipe_name).expect("bench pipeline");
                let coord = Coordinator::with_config(None, admission.clone());
                for (i, (csc, y, _)) in datasets.iter().take(sc).enumerate() {
                    coord
                        .register(
                            SessionSpec::new(
                                format!("s{i}"),
                                csc.clone(),
                                y.clone(),
                                pipe.clone(),
                                SolverKind::Cd,
                                PathConfig::default(),
                            )
                            .with_backend_label("csc"),
                        )
                        .expect("bench session");
                }
                let server = match NetServer::bind(coord, listen) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("bench-serve --listen {listen}: {e:#} (try port 0)");
                        std::process::exit(2);
                    }
                };
                let addr = server
                    .local_addr()
                    .expect("bench server address")
                    .to_string();
                let handle = std::thread::spawn(move || server.run());
                let mut client = match NetClient::connect(&addr) {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("bench-serve client: {e:#}");
                        std::process::exit(2);
                    }
                };
                // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
                let t0 = std::time::Instant::now();
                let mut latencies: Vec<f64> = Vec::with_capacity(ops);
                for k in 0..ops {
                    let i = k % sc;
                    let f = 0.05 + 0.9 * ((k * 7919) % ops) as f64 / ops as f64;
                    let lam = f * datasets[i].2;
                    // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
                    let t = std::time::Instant::now();
                    let resp = client.request(
                        &format!("s{i}"),
                        Request::Screen { lam, opts: RequestOptions::default() },
                    );
                    latencies.push(t.elapsed().as_secs_f64());
                    match resp {
                        Ok(dpp_screen::coordinator::Response::Screen(_)) => {}
                        Ok(dpp_screen::coordinator::Response::Error(
                            RequestError::Overloaded { .. },
                        ))
                        | Err(RequestError::Overloaded { .. }) => {}
                        other => {
                            eprintln!("bench-serve socket op {k}: {other:?}");
                            std::process::exit(2);
                        }
                    }
                }
                let wall = t0.elapsed().as_secs_f64();
                client.shutdown_server().expect("bench server shutdown");
                let _ = handle.join();
                let throughput = ops as f64 / wall.max(1e-12);
                let (p50, p95, p99) = (
                    dpp_screen::util::stats::quantile(&latencies, 0.50),
                    dpp_screen::util::stats::quantile(&latencies, 0.95),
                    dpp_screen::util::stats::quantile(&latencies, 0.99),
                );
                cases.push(format!(
                    "    {{\"sessions\": {sc}, \"pipeline\": \"{pipe_name}\", \
                     \"transport\": \"socket\", \"ops\": {ops}, \
                     \"wall_secs\": {wall:.6}, \"throughput_rps\": {throughput:.3}, \
                     \"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \"p99_ms\": {:.4}}}",
                    p50 * 1e3,
                    p95 * 1e3,
                    p99 * 1e3
                ));
                rep.row(&[
                    sc.to_string(),
                    pipe_name.to_string(),
                    "socket".to_string(),
                    ops.to_string(),
                    format!("{throughput:.1}"),
                    format!("{:.2}ms", p50 * 1e3),
                    format!("{:.2}ms", p95 * 1e3),
                    format!("{:.2}ms", p99 * 1e3),
                ]);
            }
        }
    }
    // Heavy-tenant scenario: one sharded session with ~10× the work of each
    // light session, all driven concurrently. Per-session dispatch queues
    // keep the heavy tenant's batches from head-of-line-blocking the light
    // tenants (its nested fork/join borrows idle pool workers instead), so
    // the light-class p99 row is the one to watch across baselines.
    {
        let light = datasets.len().min(3);
        let (heavy_csc, heavy_y, _) = bench_problem(n, 10 * p, density, 7900);
        let heavy_lam = dpp_screen::solver::dual::lambda_max(&heavy_csc, &heavy_y);
        let coord = Coordinator::with_config(None, admission.clone());
        coord
            .register(
                SessionSpec::new(
                    "heavy",
                    ShardSetMatrix::split_csc(&heavy_csc, 4),
                    heavy_y,
                    ScreenPipeline::parse("edpp").expect("bench pipeline"),
                    SolverKind::Cd,
                    PathConfig::default(),
                )
                .with_backend_label("sharded"),
            )
            .expect("bench session");
        for (i, (csc, y, _)) in datasets.iter().take(light).enumerate() {
            coord
                .register(
                    SessionSpec::new(
                        format!("s{i}"),
                        csc.clone(),
                        y.clone(),
                        ScreenPipeline::parse("edpp").expect("bench pipeline"),
                        SolverKind::Cd,
                        PathConfig::default(),
                    )
                    .with_backend_label("csc"),
                )
                .expect("bench session");
        }
        let total_ops = 2 * ops;
        // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
        let t0 = std::time::Instant::now();
        let mut slots = Vec::with_capacity(total_ops);
        for k in 0..total_ops {
            let slot = k % (light + 1);
            let (name, lam_max) = if slot == 0 {
                ("heavy".to_string(), heavy_lam)
            } else {
                (format!("s{}", slot - 1), datasets[slot - 1].2)
            };
            let f = 0.05 + 0.9 * ((k * 7919) % total_ops) as f64 / total_ops as f64;
            slots.push((
                slot == 0,
                coord.submit(
                    &name,
                    Request::Screen { lam: f * lam_max, opts: RequestOptions::default() },
                ),
            ));
        }
        let mut heavy_lat: Vec<f64> = Vec::new();
        let mut light_lat: Vec<f64> = Vec::new();
        let mut shed = 0usize;
        for (is_heavy, slot) in slots {
            match slot.recv() {
                Ok(r) if is_heavy => heavy_lat.push(r.latency_s),
                Ok(r) => light_lat.push(r.latency_s),
                Err(RequestError::Overloaded { .. }) => shed += 1,
                Err(e) => {
                    eprintln!("bench-serve heavy-tenant op: {e}");
                    std::process::exit(2);
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        coord.shutdown();
        for (class, lat) in [("heavy", &heavy_lat), ("light", &light_lat)] {
            let (p50, p95, p99) = (
                dpp_screen::util::stats::quantile(lat, 0.50),
                dpp_screen::util::stats::quantile(lat, 0.95),
                dpp_screen::util::stats::quantile(lat, 0.99),
            );
            cases.push(format!(
                "    {{\"scenario\": \"heavy-tenant\", \"class\": \"{class}\", \
                 \"sessions\": {}, \"pipeline\": \"edpp\", \
                 \"transport\": \"inproc\", \"ops\": {}, \"shed\": {shed}, \
                 \"wall_secs\": {wall:.6}, \
                 \"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \"p99_ms\": {:.4}}}",
                light + 1,
                lat.len(),
                p50 * 1e3,
                p95 * 1e3,
                p99 * 1e3
            ));
            rep.row(&[
                format!("1+{light}"),
                format!("heavy-tenant:{class}"),
                "inproc".to_string(),
                lat.len().to_string(),
                format!("{:.1}", lat.len() as f64 / wall.max(1e-12)),
                format!("{:.2}ms", p50 * 1e3),
                format!("{:.2}ms", p95 * 1e3),
                format!("{:.2}ms", p99 * 1e3),
            ]);
        }
    }

    // --front: the same workloads again through the routing tier
    // (DESIGN.md §4c). The one-backend rows price the extra hop against a
    // direct socket client on the *same* server process; the two-backend
    // rows rerun the heavy-tenant scenario with the heavy session on its
    // own backend process, where the light-class p99 shows what
    // cross-process placement buys on top of per-session queues.
    if args.flag("front") {
        use dpp_screen::front::{Front, FrontConfig};
        use dpp_screen::net::{NetClient, NetServer};

        // one backend: direct socket vs through the front
        let sc = max_sessions;
        let pipe = ScreenPipeline::parse("edpp").expect("bench pipeline");
        let coord = Coordinator::with_config(None, admission.clone());
        for (i, (csc, y, _)) in datasets.iter().take(sc).enumerate() {
            coord
                .register(
                    SessionSpec::new(
                        format!("s{i}"),
                        csc.clone(),
                        y.clone(),
                        pipe.clone(),
                        SolverKind::Cd,
                        PathConfig::default(),
                    )
                    .with_backend_label("csc"),
                )
                .expect("bench session");
        }
        let server =
            NetServer::bind(coord, "127.0.0.1:0").expect("bench front backend");
        let backend_addr =
            server.local_addr().expect("bench backend address").to_string();
        let backend = std::thread::spawn(move || server.run());
        let front =
            Front::bind("127.0.0.1:0", &[backend_addr.clone()], FrontConfig::default())
                .expect("bench front");
        let front_addr = front.local_addr().expect("bench front address").to_string();
        let router = std::thread::spawn(move || front.run());
        for (transport, dial) in
            [("socket-direct", backend_addr.clone()), ("front", front_addr.clone())]
        {
            let mut client = match NetClient::connect(&dial) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("bench-serve front client: {e:#}");
                    std::process::exit(2);
                }
            };
            // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
            let t0 = std::time::Instant::now();
            let mut latencies: Vec<f64> = Vec::with_capacity(ops);
            for k in 0..ops {
                let i = k % sc;
                let f = 0.05 + 0.9 * ((k * 7919) % ops) as f64 / ops as f64;
                let lam = f * datasets[i].2;
                // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
                let t = std::time::Instant::now();
                let resp = client.request(
                    &format!("s{i}"),
                    Request::Screen { lam, opts: RequestOptions::default() },
                );
                latencies.push(t.elapsed().as_secs_f64());
                match resp {
                    Ok(dpp_screen::coordinator::Response::Screen(_)) => {}
                    Ok(dpp_screen::coordinator::Response::Error(
                        RequestError::Overloaded { .. },
                    ))
                    | Err(RequestError::Overloaded { .. }) => {}
                    other => {
                        eprintln!("bench-serve front op {k}: {other:?}");
                        std::process::exit(2);
                    }
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            drop(client); // keep the server up for the next transport
            let throughput = ops as f64 / wall.max(1e-12);
            let (p50, p95, p99) = (
                dpp_screen::util::stats::quantile(&latencies, 0.50),
                dpp_screen::util::stats::quantile(&latencies, 0.95),
                dpp_screen::util::stats::quantile(&latencies, 0.99),
            );
            cases.push(format!(
                "    {{\"scenario\": \"front\", \"backends\": 1, \
                 \"sessions\": {sc}, \"pipeline\": \"edpp\", \
                 \"transport\": \"{transport}\", \"ops\": {ops}, \
                 \"wall_secs\": {wall:.6}, \"throughput_rps\": {throughput:.3}, \
                 \"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \"p99_ms\": {:.4}}}",
                p50 * 1e3,
                p95 * 1e3,
                p99 * 1e3
            ));
            rep.row(&[
                sc.to_string(),
                "edpp".to_string(),
                transport.to_string(),
                ops.to_string(),
                format!("{throughput:.1}"),
                format!("{:.2}ms", p50 * 1e3),
                format!("{:.2}ms", p95 * 1e3),
                format!("{:.2}ms", p99 * 1e3),
            ]);
        }
        match NetClient::connect(&front_addr) {
            Ok(c) => c.shutdown_server().expect("bench front shutdown"),
            Err(e) => {
                eprintln!("bench-serve front shutdown: {e:#}");
                std::process::exit(2);
            }
        }
        let _ = router.join();
        match NetClient::connect(&backend_addr) {
            Ok(c) => c.shutdown_server().expect("bench backend shutdown"),
            Err(e) => {
                eprintln!("bench-serve backend shutdown: {e:#}");
                std::process::exit(2);
            }
        }
        let _ = backend.join();

        // two backends: heavy tenant on its own process, light sessions on
        // the other; one pipelined client drives both through the front
        let light = datasets.len().min(3);
        let (heavy_csc, heavy_y, _) = bench_problem(n, 10 * p, density, 7900);
        let heavy_lam = dpp_screen::solver::dual::lambda_max(&heavy_csc, &heavy_y);
        let coord_a = Coordinator::with_config(None, admission.clone());
        coord_a
            .register(
                SessionSpec::new(
                    "heavy",
                    ShardSetMatrix::split_csc(&heavy_csc, 4),
                    heavy_y,
                    ScreenPipeline::parse("edpp").expect("bench pipeline"),
                    SolverKind::Cd,
                    PathConfig::default(),
                )
                .with_backend_label("sharded"),
            )
            .expect("bench session");
        let coord_b = Coordinator::with_config(None, admission.clone());
        for (i, (csc, y, _)) in datasets.iter().take(light).enumerate() {
            coord_b
                .register(
                    SessionSpec::new(
                        format!("s{i}"),
                        csc.clone(),
                        y.clone(),
                        ScreenPipeline::parse("edpp").expect("bench pipeline"),
                        SolverKind::Cd,
                        PathConfig::default(),
                    )
                    .with_backend_label("csc"),
                )
                .expect("bench session");
        }
        let srv_a =
            NetServer::bind(coord_a, "127.0.0.1:0").expect("bench front backend");
        let addr_a = srv_a.local_addr().expect("bench backend address").to_string();
        let join_a = std::thread::spawn(move || srv_a.run());
        let srv_b =
            NetServer::bind(coord_b, "127.0.0.1:0").expect("bench front backend");
        let addr_b = srv_b.local_addr().expect("bench backend address").to_string();
        let join_b = std::thread::spawn(move || srv_b.run());
        let front = Front::bind(
            "127.0.0.1:0",
            &[addr_a.clone(), addr_b.clone()],
            FrontConfig::default(),
        )
        .expect("bench front");
        let front_addr = front.local_addr().expect("bench front address").to_string();
        let router = std::thread::spawn(move || front.run());
        let mut client = match NetClient::connect(&front_addr) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("bench-serve front client: {e:#}");
                std::process::exit(2);
            }
        };
        let total_ops = 2 * ops;
        // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
        let t0 = std::time::Instant::now();
        let mut classes = Vec::with_capacity(total_ops);
        for k in 0..total_ops {
            let slot = k % (light + 1);
            let (name, lam_max) = if slot == 0 {
                ("heavy".to_string(), heavy_lam)
            } else {
                (format!("s{}", slot - 1), datasets[slot - 1].2)
            };
            let f = 0.05 + 0.9 * ((k * 7919) % total_ops) as f64 / total_ops as f64;
            match client.submit(
                &name,
                Request::Screen { lam: f * lam_max, opts: RequestOptions::default() },
            ) {
                Ok(_) => classes.push(slot == 0),
                Err(e) => {
                    eprintln!("bench-serve front heavy-tenant submit: {e}");
                    std::process::exit(2);
                }
            }
        }
        let mut heavy_lat: Vec<f64> = Vec::new();
        let mut light_lat: Vec<f64> = Vec::new();
        let mut shed = 0usize;
        for &is_heavy in &classes {
            match client.recv_reply() {
                Ok((_, dpp_screen::coordinator::Response::Screen(r))) => {
                    if is_heavy {
                        heavy_lat.push(r.latency_s);
                    } else {
                        light_lat.push(r.latency_s);
                    }
                }
                Ok((
                    _,
                    dpp_screen::coordinator::Response::Error(
                        RequestError::Overloaded { .. },
                    ),
                )) => shed += 1,
                other => {
                    eprintln!("bench-serve front heavy-tenant reply: {other:?}");
                    std::process::exit(2);
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        client.shutdown_server().expect("bench front shutdown");
        let _ = router.join();
        for addr in [addr_a, addr_b] {
            match NetClient::connect(&addr) {
                Ok(c) => c.shutdown_server().expect("bench backend shutdown"),
                Err(e) => {
                    eprintln!("bench-serve backend shutdown: {e:#}");
                    std::process::exit(2);
                }
            }
        }
        let _ = join_a.join();
        let _ = join_b.join();
        for (class, lat) in [("heavy", &heavy_lat), ("light", &light_lat)] {
            let (p50, p95, p99) = (
                dpp_screen::util::stats::quantile(lat, 0.50),
                dpp_screen::util::stats::quantile(lat, 0.95),
                dpp_screen::util::stats::quantile(lat, 0.99),
            );
            cases.push(format!(
                "    {{\"scenario\": \"heavy-tenant\", \"class\": \"{class}\", \
                 \"backends\": 2, \"sessions\": {}, \"pipeline\": \"edpp\", \
                 \"transport\": \"front\", \"ops\": {}, \"shed\": {shed}, \
                 \"wall_secs\": {wall:.6}, \
                 \"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \"p99_ms\": {:.4}}}",
                light + 1,
                lat.len(),
                p50 * 1e3,
                p95 * 1e3,
                p99 * 1e3
            ));
            rep.row(&[
                format!("1+{light}"),
                format!("heavy-tenant:{class}"),
                "front".to_string(),
                lat.len().to_string(),
                format!("{:.1}", lat.len() as f64 / wall.max(1e-12)),
                format!("{:.2}ms", p50 * 1e3),
                format!("{:.2}ms", p95 * 1e3),
                format!("{:.2}ms", p99 * 1e3),
            ]);
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"n\": {n},\n  \"p\": {p},\n  \
         \"density\": {density},\n  \"ops\": {ops},\n  \
         \"pool_threads\": {},\n  \"cases\": [\n{}\n  ]\n}}\n",
        pool::configured_threads(),
        cases.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => {
            rep.emit("bench_serve.md");
            println!("wrote {out_path} ({} cases)", cases.len());
        }
        Err(e) => {
            eprintln!("failed to write {out_path}: {e}");
            std::process::exit(2);
        }
    }
}

fn cmd_convert(args: &Args) {
    let Some(input) = args.get("file") else {
        eprintln!(
            "usage: dpp convert --file data.svm|data.csv [--out data.dppcsc] [--p N] [--f32]"
        );
        std::process::exit(2);
    };
    let out = args
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{input}.dppcsc"));
    let p_hint = args.get("p").map(|v| match v.parse::<usize>() {
        Ok(p) => p,
        Err(_) => {
            // a typo'd --p must not silently fall back to inferring the
            // feature count from the data
            eprintln!("bad --p `{v}` (expected a feature count)");
            std::process::exit(2);
        }
    });
    let f32_values = args.flag("f32");
    match convert::convert_to_shard_opts(input, &out, p_hint, f32_values) {
        Ok(s) => {
            println!(
                "converted {input} -> {out}: {}x{} matrix, nnz={}, dtype={} ({:.1} MB on \
                 disk; one bounded-memory pass per direction)",
                s.n_rows,
                s.n_cols,
                s.nnz,
                if s.f32_values { "f32" } else { "f64" },
                s.disk_bytes() as f64 / 1e6
            );
            println!("run it out-of-core:  dpp path --file {out} --matrix mmap");
        }
        Err(e) => {
            eprintln!("convert failed: {e:#}");
            std::process::exit(2);
        }
    }
}

fn cmd_shard(args: &Args) {
    let Some(input) = args.get("file") else {
        eprintln!("usage: dpp shard --file data.dppcsc [--out data.shards] --shards K");
        std::process::exit(2);
    };
    let k = args.get_parse::<usize>("shards", 2);
    let out = args
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{}.shards", input.trim_end_matches(".dppcsc")));
    match convert::split_shard(input, &out, k) {
        Ok(s) => {
            println!(
                "sharded {input} -> {out}: {}x{} matrix, nnz={}, {} row-range shard(s), \
                 dtype={}",
                s.n_rows,
                s.n_cols,
                s.nnz,
                s.shards,
                if s.f32_values { "f32" } else { "f64" }
            );
            println!("run it sharded:  dpp path --file {out} --matrix sharded");
        }
        Err(e) => {
            eprintln!("shard failed: {e:#}");
            std::process::exit(2);
        }
    }
}

/// Sparse synthetic regression fixture shared by the bench harnesses
/// (bench-screen and bench-serve use the same construction so their rows
/// are comparable): random sparse X, planted β every `p/25 + 1` features,
/// noisy y = Xβ + ε. Returns the RNG too, for callers that draw further
/// vectors from the same stream.
fn bench_problem(
    n: usize,
    p: usize,
    density: f64,
    seed: u64,
) -> (CscMatrix, Vec<f64>, dpp_screen::util::rng::Rng) {
    let mut rng = dpp_screen::util::rng::Rng::new(seed);
    let mut xd = dpp_screen::linalg::DenseMatrix::zeros(n, p);
    for j in 0..p {
        for v in xd.col_mut(j).iter_mut() {
            if rng.f64() < density {
                *v = rng.normal();
            }
        }
    }
    let csc = CscMatrix::from_dense(&xd);
    let mut beta = vec![0.0; p];
    for j in (0..p).step_by(p / 25 + 1) {
        beta[j] = rng.normal() * 2.0;
    }
    let mut y = vec![0.0; n];
    DesignMatrix::gemv(&csc, &beta, &mut y);
    for v in y.iter_mut() {
        *v += 0.1 * rng.normal();
    }
    (csc, y, rng)
}

/// Perf harness feeding the bench trajectory: screen-path wall-clock and
/// rejection ratio per rule/backend/thread-count, plus raw `xt_w` sweep
/// timings, written as `BENCH_screen.json` in the working directory (the
/// repo root in CI) so future PRs diff against a pinned baseline.
fn cmd_bench_screen(args: &Args) {
    let n = args.get_parse("n", 200usize);
    let p = args.get_parse("p", 2000usize);
    let density = args.get_parse("density", 0.1f64);
    let grid_k = args.get_parse("grid", 15usize);
    let shards = args.get_parse("shards", 3usize);
    let out_path = args.get_or("out", "BENCH_screen.json");

    // sparse synthetic regression problem (same construction as the
    // backend-parity fixtures; shared with bench-serve)
    let (csc, y, mut rng) = bench_problem(n, p, density, args.get_parse("seed", 17u64));
    let mut w = vec![0.0; n];
    rng.fill_normal(&mut w);

    let max_threads = pool::configured_threads();
    let mut thread_counts = vec![1usize];
    let mut t = 2;
    while t < max_threads {
        thread_counts.push(t);
        t *= 2;
    }
    if max_threads > 1 {
        thread_counts.push(max_threads);
    }

    let bench = Bench::new(2, 8);
    let grid = LambdaGrid::relative(&csc, &y, grid_k, 0.05, 1.0);
    let cfg = PathConfig::default();
    // plain rules plus the composed pipelines the redesign unlocks — the
    // hybrid and dynamic rows are the headline comparison vs plain EDPP
    let pipelines: Vec<ScreenPipeline> = [
        "edpp",
        "dpp",
        "strong",
        "hybrid:strong+edpp",
        "dynamic:edpp",
        "cascade:sis,edpp",
    ]
    .iter()
    .map(|s| ScreenPipeline::parse(s).expect("bench pipeline"))
    .collect();
    // working-set comparison rows: same pipeline, same backend, same thread
    // count — the screen-first row with the matching key is the direct
    // wall-clock baseline the strategy must beat at p ≥ 8n, grid ≥ 50
    let ws_pipelines: Vec<ScreenPipeline> = ["strong", "cascade:sis,edpp"]
        .iter()
        .map(|s| ScreenPipeline::parse(s).expect("bench pipeline"))
        .collect();
    let ws_cfg =
        PathConfig { strategy: PathStrategy::WorkingSet, ..PathConfig::default() };
    let mut cases: Vec<String> = Vec::new();
    let mut rep = benchkit::Report::new(
        "bench-screen (pipeline × strategy × backend × threads)",
        &[
            "pipeline", "strategy", "backend", "threads", "xt_w", "path", "rejection",
            "stages/dyn",
        ],
    );

    let mut record = |pipe_name: &str,
                      strategy: &str,
                      backend: &str,
                      threads: usize,
                      xt_w_secs: f64,
                      path_secs: f64,
                      run: &dpp_screen::path::PathOutput,
                      rep: &mut benchkit::Report| {
        let rejection = run.mean_rejection_ratio();
        let stages = run.mean_stage_rejections();
        let stage_json: Vec<String> = stages
            .iter()
            .map(|(s, v)| format!("{{\"stage\": \"{s}\", \"rejection\": {v:.6}}}"))
            .collect();
        cases.push(format!(
            "    {{\"rule\": \"{pipe_name}\", \"strategy\": \"{strategy}\", \
             \"backend\": \"{backend}\", \"threads\": {threads}, \
             \"xt_w_secs\": {xt_w_secs:.9}, \"path_secs\": {path_secs:.6}, \
             \"rejection_ratio\": {rejection:.6}, \"mean_working_set\": {:.3}, \
             \"kkt_passes\": {}, \"dynamic_discards\": {}, \
             \"stages\": [{}]}}",
            run.mean_working_set(),
            run.total_kkt_passes(),
            run.total_dynamic_discards(),
            stage_json.join(", ")
        ));
        let stage_txt: Vec<String> =
            stages.iter().map(|(s, v)| format!("{s}={v:.3}")).collect();
        rep.row(&[
            pipe_name.to_string(),
            strategy.to_string(),
            backend.to_string(),
            threads.to_string(),
            format!("{:.3}ms", xt_w_secs * 1e3),
            format!("{path_secs:.3}s"),
            format!("{rejection:.4}"),
            format!("{} dyn={}", stage_txt.join(" "), run.total_dynamic_discards()),
        ]);
    };

    // CSC baseline (single-threaded by construction)
    let mut out = vec![0.0; p];
    let m_sweep = bench.run("xt_w csc", || {
        DesignMatrix::xt_w(&csc, &w, &mut out);
        black_box(out[0])
    });
    for pipe in &pipelines {
        // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
        let t0 = std::time::Instant::now();
        let run = solve_path_pipeline(&csc, &y, &grid, pipe, SolverKind::Cd, &cfg);
        record(
            &pipe.name(),
            "screen",
            "csc",
            1,
            m_sweep.mean_s,
            t0.elapsed().as_secs_f64(),
            &run,
            &mut rep,
        );
    }
    for pipe in &ws_pipelines {
        // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
        let t0 = std::time::Instant::now();
        let run = solve_path_pipeline(&csc, &y, &grid, pipe, SolverKind::Cd, &ws_cfg);
        record(
            &pipe.name(),
            "working-set",
            "csc",
            1,
            m_sweep.mean_s,
            t0.elapsed().as_secs_f64(),
            &run,
            &mut rep,
        );
    }

    // sharded backend across thread counts (in-RAM shards isolate the
    // pool-scaling signal from disk behavior)
    for &threads in &thread_counts {
        let sh = ShardSetMatrix::split_csc(&csc, shards)
            .with_pool(Arc::new(WorkerPool::new(threads)));
        let m_sweep = bench.run("xt_w sharded", || {
            DesignMatrix::xt_w(&sh, &w, &mut out);
            black_box(out[0])
        });
        for pipe in &pipelines {
            // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
            let t0 = std::time::Instant::now();
            let run = solve_path_pipeline(&sh, &y, &grid, pipe, SolverKind::Cd, &cfg);
            record(
                &pipe.name(),
                "screen",
                "sharded",
                threads,
                m_sweep.mean_s,
                t0.elapsed().as_secs_f64(),
                &run,
                &mut rep,
            );
        }
        for pipe in &ws_pipelines {
            // audit:allow(determinism:clock, CLI timing report only; never feeds numerics)
            let t0 = std::time::Instant::now();
            let run = solve_path_pipeline(&sh, &y, &grid, pipe, SolverKind::Cd, &ws_cfg);
            record(
                &pipe.name(),
                "working-set",
                "sharded",
                threads,
                m_sweep.mean_s,
                t0.elapsed().as_secs_f64(),
                &run,
                &mut rep,
            );
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"screen\",\n  \"n\": {n},\n  \"p\": {p},\n  \
         \"density\": {density},\n  \"grid\": {grid_k},\n  \"shards\": {shards},\n  \
         \"max_threads\": {max_threads},\n  \"cases\": [\n{}\n  ]\n}}\n",
        cases.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => {
            rep.emit("bench_screen.md");
            println!("wrote {out_path} ({} cases)", cases.len());
        }
        Err(e) => {
            eprintln!("failed to write {out_path}: {e}");
            std::process::exit(2);
        }
    }
}

fn cmd_exp(args: &Args) {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    dpp_screen::experiments::run(which);
}

/// `dpp audit [--json] [--write-wire-lock]` — run the invariant auditor
/// over this crate's own source tree (DESIGN.md §5). Exits 0 iff the tree
/// has zero findings; waivers and the unsafe inventory are reported but
/// never fail the run.
fn cmd_audit(args: &Args) {
    use dpp_screen::analysis::{current_wire_consts, run_audit, wirecheck, AuditConfig};
    let cfg = AuditConfig::for_crate(env!("CARGO_MANIFEST_DIR"));
    if args.flag("write-wire-lock") {
        match current_wire_consts(&cfg.src_root) {
            Ok(consts) => print!("{}", wirecheck::render_lock(&consts)),
            Err(e) => {
                eprintln!("audit: cannot parse wire sources: {e}");
                std::process::exit(2);
            }
        }
        return;
    }
    let report = match run_audit(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("audit: cannot scan {}: {e}", cfg.src_root.display());
            std::process::exit(2);
        }
    };
    if args.flag("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    if !report.clean() {
        std::process::exit(1);
    }
}
