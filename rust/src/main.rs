//! `dpp` — CLI for the dpp-screen library (leader entrypoint).
//!
//! Subcommands:
//!   info                         environment + artifact inventory
//!   path      --dataset … --rule … --solver …      run a screened λ-path
//!   group     --ngroups …        run a group-Lasso screened path
//!   service   --requests …       demo the batching screening service
//!   convert   --file in.svm --out shard.dppcsc     stream to an on-disk shard
//!   exp       <fig1|fig2|fig3|fig4|fig5|fig6|all>  regenerate paper tables/figures
//!
//! `path` and `service` accept `--matrix dense|csc|mmap|auto` (default
//! auto): auto keeps an already-sparse input sparse (a LIBSVM file loads
//! as CSC, a shard directory as the out-of-core mmap backend) and picks
//! CSC for dense data sparse enough that the O(nnz) sweep wins. `mmap`
//! requires a shard produced by `dpp convert`; `--mmap-budget BYTES`
//! bounds its resident window. The chosen backend is reported on stderr.

use std::path::Path;

use dpp_screen::coordinator::service::ScreeningService;
use dpp_screen::data::{convert, synthetic, Dataset, RealDataset};
use dpp_screen::linalg::{CscMatrix, DesignStore, MmapCscMatrix};
use dpp_screen::path::group::{solve_group_path, GroupRuleKind};
use dpp_screen::path::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};
use dpp_screen::runtime::ArtifactRuntime;
use dpp_screen::solver::SolveOptions;
use dpp_screen::util::cli::Args;
use dpp_screen::util::{benchkit, full_scale, grid_size};

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("info") => cmd_info(),
        Some("path") => cmd_path(&args),
        Some("group") => cmd_group(&args),
        Some("service") => cmd_service(&args),
        Some("convert") => cmd_convert(&args),
        Some("exp") => cmd_exp(&args),
        _ => {
            eprintln!(
                "usage: dpp <info|path|group|service|convert|exp> [--options]\n\
                 \n\
                 dpp path --dataset pie --rule edpp --solver cd --grid 100\n\
                 dpp path --dataset mnist --matrix csc      # sparse backend\n\
                 dpp convert --file data.svm --out data.dppcsc\n\
                 dpp path --file data.dppcsc --matrix mmap  # out-of-core backend\n\
                 dpp group --ngroups 100 --rule group-edpp\n\
                 dpp service --requests 20 --rule edpp --matrix auto\n\
                 dpp exp fig1        # regenerate a paper figure/table\n\
                 dpp exp all"
            );
            std::process::exit(2);
        }
    }
}

/// Auto-pick threshold: below this fill fraction the O(nnz) CSC sweep beats
/// the unrolled dense kernel comfortably (see benches/kernels.rs).
const AUTO_CSC_DENSITY: f64 = 0.25;

/// Resolve `--matrix dense|csc|mmap|auto` against whatever backend the
/// loader produced. An already-sparse input is never densified to "measure
/// density" — auto keeps it as-is; only an explicit `--matrix dense`
/// materializes a dense copy.
fn pick_backend(x: DesignStore, choice: &str) -> DesignStore {
    match choice {
        "dense" => DesignStore::Dense(x.into_dense()),
        "csc" => match x {
            c @ DesignStore::Csc(_) => c,
            other => DesignStore::Csc(other.into_csc()),
        },
        "mmap" => match x {
            m @ DesignStore::Mmap(_) => m,
            other => {
                eprintln!(
                    "--matrix mmap needs an on-disk shard, not a {} input: run \
                     `dpp convert --file data.svm --out data.dppcsc` and pass \
                     `--file data.dppcsc`",
                    other.backend_name()
                );
                std::process::exit(2);
            }
        },
        "auto" => match x {
            DesignStore::Dense(d) => {
                // count first, convert after: building the CSC just to
                // measure density would spike peak memory ~2.5x on large
                // dense data — exactly the datasets where memory matters
                let nnz = d.data().iter().filter(|v| **v != 0.0).count();
                let density = nnz as f64 / d.data().len().max(1) as f64;
                if density < AUTO_CSC_DENSITY {
                    DesignStore::Csc(CscMatrix::from_dense(&d))
                } else {
                    DesignStore::Dense(d)
                }
            }
            sparse => sparse,
        },
        other => {
            eprintln!("unknown --matrix `{other}` (dense|csc|mmap|auto)");
            std::process::exit(2);
        }
    }
}

/// One-line backend report, identical for `path` and `service`, on stderr
/// so it never disturbs parseable stdout tables.
fn report_backend(cmd: &str, x: &DesignStore) {
    eprintln!(
        "[dpp {cmd}] matrix backend: {} ({}x{}, nnz={}, density={:.4})",
        x.backend_name(),
        x.n_rows(),
        x.n_cols(),
        x.nnz(),
        x.density()
    );
}

/// Does `--file` point at a dppcsc shard (directory or `.dppcsc` suffix)?
fn is_shard_path(path: &str) -> bool {
    path.ends_with(".dppcsc") || Path::new(path).join("meta.txt").exists()
}

fn load_shard(path: &str, args: &Args) -> anyhow::Result<Dataset> {
    let budget = args.get_parse::<usize>(
        "mmap-budget",
        dpp_screen::linalg::mmap::DEFAULT_WINDOW_BYTES,
    );
    let x = MmapCscMatrix::open_with_budget(path, budget)?;
    let y = convert::read_shard_y(path)?.ok_or_else(|| {
        anyhow::anyhow!("shard {path} has no y.bin (convert from a labeled dataset)")
    })?;
    if y.len() != x.n_rows() {
        anyhow::bail!(
            "shard {path}: y.bin has {} entries, matrix has {} rows",
            y.len(),
            x.n_rows()
        );
    }
    Ok(Dataset { name: path.to_string(), x: x.into(), y, beta_true: None, groups: None })
}

fn load_dataset(args: &Args) -> Dataset {
    // user-supplied data: --file data.csv (y,x1,…,xp), data.svm (LIBSVM,
    // loads as CSC), or a data.dppcsc shard (loads out-of-core)
    if let Some(path) = args.get("file") {
        let res = if is_shard_path(path) {
            load_shard(path, args)
        } else if path.ends_with(".svm") || path.ends_with(".libsvm") {
            dpp_screen::data::io::read_libsvm(path, None)
        } else {
            dpp_screen::data::io::read_csv(path)
        };
        match res {
            Ok(ds) => return ds,
            Err(e) => {
                eprintln!("failed to load {path}: {e:#}");
                std::process::exit(2);
            }
        }
    }
    let name = args.get_or("dataset", "synthetic1");
    let seed = args.get_parse::<u64>("seed", 42);
    let full = full_scale() || args.flag("full");
    match name.as_str() {
        "synthetic1" => {
            let (n, p) = if full { (250, 10000) } else { (100, 1000) };
            synthetic::synthetic1(n, p, args.get_parse("nnz", p / 10), 0.1, seed)
        }
        "synthetic2" => {
            let (n, p) = if full { (250, 10000) } else { (100, 1000) };
            synthetic::synthetic2(n, p, args.get_parse("nnz", p / 10), 0.1, seed)
        }
        other => match RealDataset::from_name(other) {
            Some(d) => d.generate(full, seed),
            None => {
                eprintln!("unknown dataset `{other}`");
                std::process::exit(2);
            }
        },
    }
}

fn cmd_info() {
    println!("dpp-screen — Lasso screening via dual polytope projection (NIPS'13)");
    println!(
        "datasets: synthetic1 synthetic2 {}",
        RealDataset::ALL.map(|d| d.name()).join(" ")
    );
    println!("rules:    {} none", RuleKind::ALL_LASSO.map(|r| r.name()).join(" "));
    println!("solvers:  cd fista lars");
    println!("matrix:   dense csc mmap auto (shards via `dpp convert`)");
    match ArtifactRuntime::load_default() {
        Some(rt) => {
            println!("artifacts ({}):", rt.artifact_dir().display());
            for (name, n, p) in rt.available() {
                println!("  {name}  {n}x{p}");
            }
        }
        None => println!("artifacts: none (run `make artifacts`; native fallback active)"),
    }
}

fn cmd_path(args: &Args) {
    let ds = load_dataset(args);
    let rule = RuleKind::from_name(&args.get_or("rule", "edpp")).expect("bad --rule");
    let solver = SolverKind::from_name(&args.get_or("solver", "cd")).expect("bad --solver");
    let k = args.get_parse("grid", grid_size(100));
    let lo = args.get_parse("lo", 0.05);
    let cfg = PathConfig { sequential: !args.flag("basic"), ..Default::default() };
    let name = ds.name.clone();
    let (n, p) = (ds.n(), ds.p());
    let y = ds.y.clone();
    let backend = pick_backend(ds.x, &args.get_or("matrix", "auto"));
    report_backend("path", &backend);
    let x = backend.as_design();
    let grid = LambdaGrid::relative(x, &y, k, lo, 1.0);
    println!(
        "dataset={} ({}x{}), matrix={}, rule={}, solver={}, grid={}x[{}..1.0]·λmax",
        name,
        n,
        p,
        backend.backend_name(),
        rule.name(),
        solver.name(),
        k,
        lo
    );
    let out = solve_path(x, &y, &grid, rule, solver, &cfg);
    let mut report = benchkit::Report::new(
        &format!(
            "path: {name} / {} / {} [{}]",
            rule.name(),
            solver.name(),
            backend.backend_name()
        ),
        &["λ/λmax", "kept", "discarded", "rejection", "screen(s)", "solve(s)", "iters", "repairs"],
    );
    for r in &out.records {
        report.row(&[
            format!("{:.3}", r.lam / grid.lam_max),
            r.kept.to_string(),
            r.discarded.to_string(),
            format!("{:.3}", r.rejection_ratio()),
            format!("{:.4}", r.screen_secs),
            format!("{:.4}", r.solve_secs),
            r.solver_iters.to_string(),
            r.kkt_repairs.to_string(),
        ]);
    }
    report.emit("path_runs.md");
    println!(
        "mean rejection ratio: {:.4}   total screen {:.3}s   total solve {:.3}s",
        out.mean_rejection_ratio(),
        out.total_screen_secs(),
        out.total_solve_secs()
    );
}

fn cmd_group(args: &Args) {
    let seed = args.get_parse::<u64>("seed", 42);
    let full = full_scale() || args.flag("full");
    let (n, p) = if full { (250, 200_000) } else { (80, 2000) };
    let ngroups = args.get_parse("ngroups", if full { 10_000 } else { 400 });
    let ds = synthetic::group_synthetic(n, p, ngroups, seed);
    let groups = ds.groups.clone().unwrap();
    let (glm, _) = dpp_screen::solver::dual::group_lambda_max(&ds.x, &ds.y, &groups);
    let grid =
        LambdaGrid::relative_to(glm, args.get_parse("grid", grid_size(100)), 0.05, 1.0);
    let rule = match args.get_or("rule", "group-edpp").as_str() {
        "group-edpp" => GroupRuleKind::Edpp,
        "group-strong" => GroupRuleKind::Strong,
        "none" => GroupRuleKind::None,
        other => {
            eprintln!("unknown group rule `{other}`");
            std::process::exit(2);
        }
    };
    let out = solve_group_path(&ds.x, &ds.y, &groups, &grid, rule, &SolveOptions::default());
    println!(
        "group path: {} groups of size {}, rule={} → mean rejection {:.4}, screen {:.3}s, solve {:.3}s",
        ngroups,
        p / ngroups,
        out.rule,
        out.mean_rejection_ratio(),
        out.total_screen_secs(),
        out.total_solve_secs()
    );
}

fn cmd_service(args: &Args) {
    let ds = load_dataset(args);
    let rule = RuleKind::from_name(&args.get_or("rule", "edpp")).expect("bad --rule");
    let n_req = args.get_parse("requests", 20usize);
    let y = ds.y.clone();
    let backend = pick_backend(ds.x, &args.get_or("matrix", "auto"));
    report_backend("service", &backend);
    let lam_max = dpp_screen::solver::dual::lambda_max(backend.as_design(), &y);
    println!("service backend: {}", backend.backend_name());
    let svc = ScreeningService::spawn_boxed(
        backend.into_boxed(),
        y,
        rule,
        SolverKind::Cd,
        PathConfig::default(),
    );
    // fire a burst of requests across the λ range (arrivals out of order)
    let mut rxs = Vec::new();
    for i in 0..n_req {
        let f = 0.05 + 0.9 * ((i * 7919) % n_req) as f64 / n_req as f64;
        rxs.push(svc.request(f * lam_max));
    }
    for rx in rxs {
        let resp = rx.recv().expect("service died");
        println!(
            "λ/λmax={:.3} kept={} discarded={} latency={:.2}ms",
            resp.lam / lam_max,
            resp.kept.len(),
            resp.discarded,
            resp.latency_s * 1e3
        );
    }
    let m = svc.shutdown();
    println!("metrics: {}", m.summary());
}

fn cmd_convert(args: &Args) {
    let Some(input) = args.get("file") else {
        eprintln!("usage: dpp convert --file data.svm|data.csv [--out data.dppcsc] [--p N]");
        std::process::exit(2);
    };
    let out = args
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{input}.dppcsc"));
    let p_hint = args.get("p").map(|v| match v.parse::<usize>() {
        Ok(p) => p,
        Err(_) => {
            // a typo'd --p must not silently fall back to inferring the
            // feature count from the data
            eprintln!("bad --p `{v}` (expected a feature count)");
            std::process::exit(2);
        }
    });
    match convert::convert_to_shard(input, &out, p_hint) {
        Ok(s) => {
            println!(
                "converted {input} -> {out}: {}x{} matrix, nnz={} ({:.1} MB on disk; \
                 one bounded-memory pass per direction)",
                s.n_rows,
                s.n_cols,
                s.nnz,
                s.disk_bytes() as f64 / 1e6
            );
            println!("run it out-of-core:  dpp path --file {out} --matrix mmap");
        }
        Err(e) => {
            eprintln!("convert failed: {e:#}");
            std::process::exit(2);
        }
    }
}

fn cmd_exp(args: &Args) {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    dpp_screen::experiments::run(which);
}
