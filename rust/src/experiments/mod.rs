//! Experiment harness: regenerates every figure and table of the paper's
//! evaluation (§2.3.3, §4). Each `figN` function prints the same
//! rows/series the paper reports (markdown) and appends them to
//! `results/*.md`; the benches in `rust/benches/` and `dpp exp …` both call
//! into here (DESIGN.md §7 experiment index).
//!
//! Scale: `DPP_SCALE=full` uses the paper's exact shapes; the default uses
//! the scaled-down shapes of `RealDataset::small_shape` so the whole suite
//! is minutes-scale on one core. `DPP_TRIALS` / `DPP_GRID` override the
//! trial count and λ-grid size (paper: 100 trials / 100-point grid).
//! `DPP_MATRIX=csc` runs every Lasso path through the sparse CSC backend
//! instead of the dense one, `DPP_MATRIX=mmap` through the out-of-core
//! shard backend (each trial's matrix is written to a temp shard and paged
//! back under the window budget), and `DPP_MATRIX=sharded` through the
//! row-sharded pool-parallel backend (`DPP_SHARDS` row ranges,
//! `DPP_POOL_THREADS` sweep threads) — the rules/solvers are
//! backend-generic, so the numbers must match; only the runtimes differ.

use crate::coordinator::run_trials;
use crate::data::{convert, synthetic, Dataset, RealDataset};
use crate::linalg::{DesignMatrix, DesignStore, MmapCscMatrix};
use crate::path::group::{solve_group_path, GroupRuleKind};
use crate::path::{solve_path, LambdaGrid, PathConfig, PathOutput, RuleKind, SolverKind};
use crate::solver::SolveOptions;
use crate::util::benchkit::Report;
use crate::util::{full_scale, grid_size, n_trials};

/// Which backend the experiment harness runs Lasso paths on
/// (`DPP_MATRIX=dense|csc|mmap|sharded`; default dense — the generators
/// produce dense matrices). `sharded` splits each trial's matrix into
/// `DPP_SHARDS` (default 3) in-RAM row-range shards swept on the worker
/// pool (`DPP_POOL_THREADS`).
#[derive(Clone, Copy, PartialEq, Eq)]
enum MatrixEnv {
    Dense,
    Csc,
    Mmap,
    Sharded,
}

fn matrix_env() -> MatrixEnv {
    match std::env::var("DPP_MATRIX").as_deref() {
        Err(_) | Ok("") | Ok("dense") => MatrixEnv::Dense,
        Ok("csc") => MatrixEnv::Csc,
        Ok("mmap") => MatrixEnv::Mmap,
        Ok("sharded") => MatrixEnv::Sharded,
        Ok(other) => {
            // a typo must not silently mislabel a whole experiment run as
            // another backend's numbers
            eprintln!("unknown DPP_MATRIX `{other}` (dense|csc|mmap|sharded)");
            std::process::exit(2);
        }
    }
}

/// Shard count for `DPP_MATRIX=sharded` trials.
fn shard_env() -> usize {
    std::env::var("DPP_SHARDS").ok().and_then(|v| v.parse().ok()).unwrap_or(3).max(1)
}

/// Write this trial's matrix to a temp shard and reopen it out-of-core.
/// Returns the store plus the shard dir to clean up afterwards.
fn mmap_trial_store(ds: &Dataset, tag: u64) -> (DesignStore, std::path::PathBuf) {
    let dir = std::env::temp_dir()
        .join(format!("dpp-exp-shard-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    convert::shard_from_design(ds.x.as_design(), Some(&ds.y), &dir)
        .expect("writing experiment shard");
    let mm = MmapCscMatrix::open(&dir).expect("opening experiment shard");
    (DesignStore::Mmap(mm), dir)
}

/// Dispatch an experiment by name.
pub fn run(which: &str) {
    match which {
        "fig1" | "table1" => fig1_dpp_family(),
        "fig2" => fig2_basic_rules(),
        "fig3" | "table2" => fig3_synthetic(),
        "fig4" | "table3" => fig4_real(),
        "fig5" | "table4" => fig5_lars(),
        "fig6" | "table5" => fig6_group(),
        "all" => {
            fig1_dpp_family();
            fig2_basic_rules();
            fig3_synthetic();
            fig4_real();
            fig5_lars();
            fig6_group();
        }
        other => {
            eprintln!("unknown experiment `{other}` (fig1..fig6|all)");
            std::process::exit(2);
        }
    }
}

/// Paper's λ-grid: `grid_size` points on λ/λmax ∈ [0.05, 1].
fn paper_grid(ds: &Dataset, k: usize) -> LambdaGrid {
    LambdaGrid::relative(&ds.x, &ds.y, k, 0.05, 1.0)
}

/// Indices at which the rejection-ratio series is printed (≈10 samples).
fn series_samples(k: usize) -> Vec<usize> {
    let step = (k / 10).max(1);
    (0..k).step_by(step).chain(std::iter::once(k - 1)).collect()
}

struct LassoRun {
    rule: RuleKind,
    out: PathOutput,
}

/// Run a set of rules plus the no-screening baseline on one dataset and
/// average over `trials` (dataset regenerated per trial seed, paper
/// protocol for the image datasets).
fn run_rules(
    make_ds: &(dyn Fn(u64) -> Dataset + Sync),
    rules: &[RuleKind],
    solver: SolverKind,
    sequential: bool,
    trials: usize,
    k: usize,
) -> (Vec<LassoRun>, f64, Vec<Vec<f64>>) {
    let cfg = PathConfig { sequential, ..Default::default() };
    let workers = crate::coordinator::default_workers();
    let backend = matrix_env();
    // per-trial: baseline time + per-rule outputs
    let per_trial = run_trials(trials, workers, |t| {
        let ds = make_ds(1000 + t as u64);
        let (store, shard_dir) = match backend {
            MatrixEnv::Dense => (None, None),
            MatrixEnv::Csc => (Some(DesignStore::Csc(ds.x.to_csc())), None),
            MatrixEnv::Mmap => {
                let (s, dir) = mmap_trial_store(&ds, t as u64);
                (Some(s), Some(dir))
            }
            MatrixEnv::Sharded => (
                Some(DesignStore::Sharded(crate::linalg::ShardSetMatrix::split_csc(
                    &ds.x.to_csc(),
                    shard_env(),
                ))),
                None,
            ),
        };
        let x: &dyn DesignMatrix = match &store {
            Some(s) => s.as_design(),
            None => &ds.x,
        };
        let grid = paper_grid(&ds, k);
        let base = solve_path(x, &ds.y, &grid, RuleKind::None, solver, &cfg);
        let outs: Vec<PathOutput> = rules
            .iter()
            .map(|&r| solve_path(x, &ds.y, &grid, r, solver, &cfg))
            .collect();
        drop(store);
        if let Some(dir) = shard_dir {
            let _ = std::fs::remove_dir_all(dir);
        }
        (base.total_secs(), outs)
    });
    // aggregate: mean baseline time; concatenate rule outputs (mean ratios
    // computed per-λ across trials)
    let base_secs: f64 =
        per_trial.iter().map(|(b, _)| *b).sum::<f64>() / trials as f64;
    let mut runs: Vec<LassoRun> = Vec::new();
    let mut ratio_series: Vec<Vec<f64>> = Vec::new();
    for (ri, &rule) in rules.iter().enumerate() {
        // mean rejection ratio per λ-index across trials
        let kk = per_trial[0].1[ri].records.len();
        let mut series = vec![0.0; kk];
        for (_, outs) in &per_trial {
            for (i, rec) in outs[ri].records.iter().enumerate() {
                series[i] += rec.rejection_ratio() / trials as f64;
            }
        }
        ratio_series.push(series);
        // representative output: the first trial's (times averaged below)
        runs.push(LassoRun { rule, out: per_trial[0].1[ri].clone() });
        // overwrite times with the cross-trial means
        let mean_screen: f64 = per_trial
            .iter()
            .map(|(_, outs)| outs[ri].total_screen_secs())
            .sum::<f64>()
            / trials as f64;
        let mean_solve: f64 = per_trial
            .iter()
            .map(|(_, outs)| outs[ri].total_solve_secs())
            .sum::<f64>()
            / trials as f64;
        let nrec = runs[ri].out.records.len() as f64;
        for rec in &mut runs[ri].out.records {
            rec.screen_secs = mean_screen / nrec;
            rec.solve_secs = mean_solve / nrec;
        }
    }
    (runs, base_secs, ratio_series)
}

fn emit_rejection_series(
    title: &str,
    file: &str,
    grid_k: usize,
    lam_fracs: &[f64],
    rule_names: &[&str],
    series: &[Vec<f64>],
) {
    let mut header = vec!["λ/λmax"];
    header.extend(rule_names);
    let mut rep = Report::new(title, &header);
    for &i in &series_samples(grid_k) {
        let mut row = vec![format!("{:.3}", lam_fracs[i])];
        for s in series {
            row.push(format!("{:.3}", s[i]));
        }
        rep.row(&row);
    }
    rep.emit(file);
}

fn emit_speedup_table(
    title: &str,
    file: &str,
    rows: &[(String, f64, Vec<(String, f64, f64)>)],
) {
    // rows: (dataset, baseline_secs, [(rule, total_secs_with_rule, screen_secs)])
    let mut header = vec!["data".to_string(), "solver(s)".to_string()];
    for (rule, _, _) in &rows[0].2 {
        header.push(format!("{rule}+solver(s)"));
    }
    for (rule, _, _) in &rows[0].2 {
        header.push(format!("{rule} screen(s)"));
    }
    for (rule, _, _) in &rows[0].2 {
        header.push(format!("{rule} speedup"));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut rep = Report::new(title, &hdr);
    for (ds, base, rules) in rows {
        let mut row = vec![ds.clone(), format!("{base:.2}")];
        for (_, total, _) in rules {
            row.push(format!("{total:.2}"));
        }
        for (_, _, screen) in rules {
            row.push(format!("{screen:.3}"));
        }
        for (_, total, _) in rules {
            row.push(format!("{:.1}x", base / total.max(1e-12)));
        }
        rep.row(&row);
    }
    rep.emit(file);
}

fn real_ds_maker(d: RealDataset, normalize: bool) -> impl Fn(u64) -> Dataset + Sync {
    let full = full_scale();
    move |seed| {
        let mut ds = d.generate(full, seed);
        if normalize {
            ds.normalize_features().expect("in-RAM backend");
        }
        ds
    }
}

/// Fig. 1 + Table 1 — the DPP family (DPP, Improvement 1/2, EDPP) on
/// sim-Prostate / sim-PIE / sim-MNIST: rejection ratios and speedups.
pub fn fig1_dpp_family() {
    let k = grid_size(100);
    let trials = n_trials(3);
    let rules = [
        RuleKind::Dpp,
        RuleKind::Improvement1,
        RuleKind::Improvement2,
        RuleKind::Edpp,
    ];
    let rule_names: Vec<&str> = rules.iter().map(|r| r.name()).collect();
    let mut table_rows = Vec::new();
    for d in [RealDataset::ProstateCancer, RealDataset::Pie, RealDataset::Mnist] {
        let maker = real_ds_maker(d, false);
        let (runs, base, series) =
            run_rules(&maker, &rules, SolverKind::Cd, true, trials, k);
        let fr: Vec<f64> = runs[0]
            .out
            .records
            .iter()
            .map(|r| r.lam / runs[0].out.records[0].lam)
            .collect();
        emit_rejection_series(
            &format!("Fig.1 rejection ratios — {} (trials={trials})", d.name()),
            "fig1.md",
            k,
            &fr,
            &rule_names,
            &series,
        );
        table_rows.push((
            d.name().to_string(),
            base,
            runs.iter()
                .map(|r| {
                    (
                        r.rule.name().to_string(),
                        r.out.total_secs(),
                        r.out.total_screen_secs(),
                    )
                })
                .collect(),
        ));
    }
    emit_speedup_table("Table 1 — DPP family runtimes", "fig1.md", &table_rows);
}

/// Fig. 2 — basic versions of SAFE, DOME, strong rule and EDPP on six
/// unit-norm datasets.
pub fn fig2_basic_rules() {
    let k = grid_size(100);
    let trials = n_trials(2);
    let rules = [RuleKind::Safe, RuleKind::Dome, RuleKind::Strong, RuleKind::Edpp];
    let rule_names: Vec<&str> = rules.iter().map(|r| r.name()).collect();
    for d in [
        RealDataset::ColonCancer,
        RealDataset::LungCancer,
        RealDataset::ProstateCancer,
        RealDataset::Pie,
        RealDataset::Mnist,
        RealDataset::Coil100,
    ] {
        // DOME requires unit-norm features (§4.1.1)
        let maker = real_ds_maker(d, true);
        let (runs, _base, series) =
            run_rules(&maker, &rules, SolverKind::Cd, /*sequential=*/ false, trials, k);
        let fr: Vec<f64> = runs[0]
            .out
            .records
            .iter()
            .map(|r| r.lam / runs[0].out.records[0].lam)
            .collect();
        emit_rejection_series(
            &format!("Fig.2 basic-rule rejection ratios — {} (trials={trials})", d.name()),
            "fig2.md",
            k,
            &fr,
            &rule_names,
            &series,
        );
    }
}

/// Fig. 3 + Table 2 — sequential SAFE / strong / EDPP on Synthetic 1 & 2
/// with p̄ ∈ {100, 1000, 5000} nonzeros (scaled at small sizes).
pub fn fig3_synthetic() {
    let k = grid_size(100);
    let trials = n_trials(3);
    let full = full_scale();
    let (n, p) = if full { (250, 10_000) } else { (100, 2_000) };
    let nnzs: [usize; 3] = if full { [100, 1000, 5000] } else { [20, 200, 1000] };
    let rules = [RuleKind::Safe, RuleKind::Strong, RuleKind::Edpp];
    let rule_names: Vec<&str> = rules.iter().map(|r| r.name()).collect();
    let mut table_rows = Vec::new();
    for (variant, gen) in [
        ("synthetic1", synthetic::synthetic1 as fn(usize, usize, usize, f64, u64) -> Dataset),
        ("synthetic2", synthetic::synthetic2 as fn(usize, usize, usize, f64, u64) -> Dataset),
    ] {
        for &nnz in &nnzs {
            let maker = move |seed: u64| gen(n, p, nnz, 0.1, seed);
            let (runs, base, series) =
                run_rules(&maker, &rules, SolverKind::Cd, true, trials, k);
            let fr: Vec<f64> = runs[0]
                .out
                .records
                .iter()
                .map(|r| r.lam / runs[0].out.records[0].lam)
                .collect();
            emit_rejection_series(
                &format!("Fig.3 {variant} p̄={nnz} (trials={trials})"),
                "fig3.md",
                k,
                &fr,
                &rule_names,
                &series,
            );
            table_rows.push((
                format!("{variant} p̄={nnz}"),
                base,
                runs.iter()
                    .map(|r| {
                        (
                            r.rule.name().to_string(),
                            r.out.total_secs(),
                            r.out.total_screen_secs(),
                        )
                    })
                    .collect(),
            ));
        }
    }
    emit_speedup_table("Table 2 — synthetic runtimes", "fig3.md", &table_rows);
}

/// Fig. 4 + Table 3 — sequential SAFE / strong / EDPP on six (simulated)
/// real datasets.
pub fn fig4_real() {
    let k = grid_size(100);
    let trials = n_trials(2);
    let rules = [RuleKind::Safe, RuleKind::Strong, RuleKind::Edpp];
    let rule_names: Vec<&str> = rules.iter().map(|r| r.name()).collect();
    let mut table_rows = Vec::new();
    for d in [
        RealDataset::BreastCancer,
        RealDataset::Leukemia,
        RealDataset::ProstateCancer,
        RealDataset::Pie,
        RealDataset::Mnist,
        RealDataset::Svhn,
    ] {
        let maker = real_ds_maker(d, false);
        let (runs, base, series) =
            run_rules(&maker, &rules, SolverKind::Cd, true, trials, k);
        let fr: Vec<f64> = runs[0]
            .out
            .records
            .iter()
            .map(|r| r.lam / runs[0].out.records[0].lam)
            .collect();
        emit_rejection_series(
            &format!("Fig.4 rejection ratios — {} (trials={trials})", d.name()),
            "fig4.md",
            k,
            &fr,
            &rule_names,
            &series,
        );
        table_rows.push((
            d.name().to_string(),
            base,
            runs.iter()
                .map(|r| {
                    (
                        r.rule.name().to_string(),
                        r.out.total_secs(),
                        r.out.total_screen_secs(),
                    )
                })
                .collect(),
        ));
    }
    emit_speedup_table("Table 3 — real-data runtimes (CD solver)", "fig4.md", &table_rows);
}

/// Fig. 5 + Table 4 — strong rule and EDPP with the LARS solver.
pub fn fig5_lars() {
    let k = grid_size(100);
    let trials = n_trials(1);
    let rules = [RuleKind::Strong, RuleKind::Edpp];
    let mut table_rows = Vec::new();
    for d in [
        RealDataset::BreastCancer,
        RealDataset::Leukemia,
        RealDataset::ProstateCancer,
        RealDataset::Pie,
        RealDataset::Mnist,
        RealDataset::Svhn,
    ] {
        let maker = real_ds_maker(d, false);
        let (runs, base, _series) =
            run_rules(&maker, &rules, SolverKind::Lars, true, trials, k);
        table_rows.push((
            d.name().to_string(),
            base,
            runs.iter()
                .map(|r| {
                    (
                        r.rule.name().to_string(),
                        r.out.total_secs(),
                        r.out.total_screen_secs(),
                    )
                })
                .collect(),
        ));
    }
    emit_speedup_table(
        "Fig.5 / Table 4 — LARS solver: runtimes and speedup",
        "fig5.md",
        &table_rows,
    );
}

/// Fig. 6 + Table 5 — group EDPP vs group strong rule with varying group
/// counts on the 250×200000 synthetic problem (scaled by default).
pub fn fig6_group() {
    let k = grid_size(100);
    let trials = n_trials(2);
    let full = full_scale();
    let (n, p) = if full { (250, 200_000) } else { (100, 6_000) };
    let ngroups: [usize; 3] = if full { [10_000, 20_000, 40_000] } else { [300, 600, 1_200] };
    let opts = SolveOptions::default();
    let mut table_rows = Vec::new();
    for &ng in &ngroups {
        let workers = crate::coordinator::default_workers();
        let per_trial = run_trials(trials, workers, |t| {
            let ds = synthetic::group_synthetic(n, p, ng, 3000 + t as u64);
            let groups = ds.groups.clone().unwrap();
            let (glm, _) = crate::solver::dual::group_lambda_max(&ds.x, &ds.y, &groups);
            let grid = LambdaGrid::relative_to(glm, k, 0.05, 1.0);
            let base =
                solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::None, &opts);
            let strong =
                solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Strong, &opts);
            let edpp =
                solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
            (base, strong, edpp)
        });
        // rejection series (mean across trials)
        let kk = per_trial[0].1.records.len();
        let mut s_strong = vec![0.0; kk];
        let mut s_edpp = vec![0.0; kk];
        for (_, st, ed) in &per_trial {
            for i in 0..kk {
                s_strong[i] += st.records[i].rejection_ratio() / trials as f64;
                s_edpp[i] += ed.records[i].rejection_ratio() / trials as f64;
            }
        }
        let fr: Vec<f64> = per_trial[0]
            .1
            .records
            .iter()
            .map(|r| r.lam / per_trial[0].1.records[0].lam)
            .collect();
        emit_rejection_series(
            &format!("Fig.6 group rejection ratios — n_g={ng} (trials={trials})"),
            "fig6.md",
            k,
            &fr,
            &["group-strong", "group-edpp"],
            &[s_strong, s_edpp],
        );
        let base: f64 =
            per_trial.iter().map(|(b, _, _)| b.total_secs()).sum::<f64>() / trials as f64;
        let strong_total: f64 =
            per_trial.iter().map(|(_, s, _)| s.total_secs()).sum::<f64>() / trials as f64;
        let strong_screen: f64 = per_trial
            .iter()
            .map(|(_, s, _)| s.total_screen_secs())
            .sum::<f64>()
            / trials as f64;
        let edpp_total: f64 =
            per_trial.iter().map(|(_, _, e)| e.total_secs()).sum::<f64>() / trials as f64;
        let edpp_screen: f64 = per_trial
            .iter()
            .map(|(_, _, e)| e.total_screen_secs())
            .sum::<f64>()
            / trials as f64;
        table_rows.push((
            format!("n_g={ng}"),
            base,
            vec![
                ("group-strong".to_string(), strong_total, strong_screen),
                ("group-edpp".to_string(), edpp_total, edpp_screen),
            ],
        ));
    }
    emit_speedup_table("Table 5 — group-Lasso runtimes", "fig6.md", &table_rows);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_samples_cover_range() {
        let s = series_samples(100);
        assert_eq!(*s.first().unwrap(), 0);
        assert_eq!(*s.last().unwrap(), 99);
        assert!(s.len() >= 10);
        let s1 = series_samples(3);
        assert!(s1.contains(&0) && s1.contains(&2));
    }

    #[test]
    fn run_rules_smoke() {
        // tiny end-to-end harness run: 1 trial, 2 rules, small grid
        let maker = |seed: u64| synthetic::synthetic1(30, 120, 10, 0.1, seed);
        let (runs, base, series) = run_rules(
            &maker,
            &[RuleKind::Dpp, RuleKind::Edpp],
            SolverKind::Cd,
            true,
            1,
            6,
        );
        assert_eq!(runs.len(), 2);
        assert_eq!(series.len(), 2);
        assert!(base > 0.0);
        // EDPP mean rejection ≥ DPP mean rejection
        let mean = |s: &Vec<f64>| s.iter().sum::<f64>() / s.len() as f64;
        assert!(mean(&series[1]) >= mean(&series[0]) - 1e-9);
    }
}
