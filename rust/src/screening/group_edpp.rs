//! EDPP for group Lasso (paper §3, Theorem 20 / Corollary 21) — to the
//! paper's knowledge the first *exact* (safe) screening rule for group
//! Lasso. The dual feasible set is an intersection of ellipsoids
//! `{θ : ‖X_gᵀθ‖ ≤ √n_g}` (eq. (51)) — closed and convex, so the same
//! projection machinery applies.

use crate::linalg::{nrm2, DesignMatrix};
use crate::solver::dual;

/// Precomputed context for group screening along a path. Matrix-free: the
/// design matrix is seen only through the [`DesignMatrix`] trait, so group
/// screening runs on dense or CSC backends alike.
pub struct GroupScreenContext<'a> {
    pub x: &'a dyn DesignMatrix,
    pub y: &'a [f64],
    /// `(start, len)` per group.
    pub groups: &'a [(usize, usize)],
    /// Spectral norms ‖X_g‖₂ (Theorem 20's Lipschitz factor).
    pub group_op_norms: Vec<f64>,
    pub y_norm: f64,
    /// λ̄max = max_g ‖X_gᵀy‖/√n_g (eq. (55)).
    pub lam_max: f64,
    /// The attaining group X* (eq. (58)).
    pub lam_max_arg: usize,
}

impl<'a> GroupScreenContext<'a> {
    pub fn new(
        x: &'a dyn DesignMatrix,
        y: &'a [f64],
        groups: &'a [(usize, usize)],
    ) -> Self {
        let group_op_norms = groups
            .iter()
            .enumerate()
            .map(|(g, &(start, len))| {
                let cols: Vec<usize> = (start..start + len).collect();
                x.op_norm_sq_subset(&cols, 20, 0x6E0 + g as u64).sqrt()
            })
            .collect();
        let (lam_max, lam_max_arg) = dual::group_lambda_max(x, y, groups);
        GroupScreenContext {
            x,
            y,
            groups,
            group_op_norms,
            y_norm: nrm2(y),
            lam_max,
            lam_max_arg,
        }
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// ‖X_gᵀw‖₂ for one group.
    pub fn group_corr_norm(&self, g: usize, w: &[f64]) -> f64 {
        let (start, len) = self.groups[g];
        let mut ss = 0.0;
        for j in start..start + len {
            let d = self.x.col_dot_w(j, w);
            ss += d * d;
        }
        ss.sqrt()
    }
}

/// Step input: λ₀ → λ with θ*(λ₀) known (= y/λ̄max at λ₀ = λ̄max, eq. (57)).
pub struct GroupStepInput<'a> {
    pub lam_prev: f64,
    pub lam: f64,
    pub theta_prev: &'a [f64],
}

/// A group-screening rule (keep mask is per *group*).
pub trait GroupScreeningRule {
    fn name(&self) -> &'static str;
    fn is_safe(&self) -> bool;
    fn screen(&self, ctx: &GroupScreenContext, step: &GroupStepInput, keep: &mut [bool]);
}

/// v̄₁(λ₀) of eq. (59): `y/λ₀ − θ*(λ₀)` below λ̄max, `X*X*ᵀy` at λ̄max.
pub fn group_v1(ctx: &GroupScreenContext, step: &GroupStepInput) -> Vec<f64> {
    let n = ctx.y.len();
    if step.lam_prev < ctx.lam_max * (1.0 - 1e-12) {
        (0..n).map(|i| ctx.y[i] / step.lam_prev - step.theta_prev[i]).collect()
    } else {
        // X*X*ᵀy
        let (start, len) = ctx.groups[ctx.lam_max_arg];
        let mut out = vec![0.0; n];
        for j in start..start + len {
            let cj = ctx.x.col_dot_w(j, ctx.y);
            ctx.x.col_axpy_into(j, cj, &mut out);
        }
        out
    }
}

/// Group EDPP (Corollary 21): discard group g when
/// `‖X_gᵀ(θ*(λ₀) + ½v̄₂⊥)‖ < √n_g − ½‖v̄₂⊥‖·‖X_g‖₂`.
pub struct GroupEdppRule;

impl GroupScreeningRule for GroupEdppRule {
    fn name(&self) -> &'static str {
        "group-edpp"
    }

    fn is_safe(&self) -> bool {
        true
    }

    fn screen(&self, ctx: &GroupScreenContext, step: &GroupStepInput, keep: &mut [bool]) {
        assert_eq!(keep.len(), ctx.n_groups());
        let a = group_v1(ctx, step);
        let b: Vec<f64> = ctx
            .y
            .iter()
            .zip(step.theta_prev.iter())
            .map(|(yi, t)| yi / step.lam - t)
            .collect();
        let perp = super::v2_perp(&a, &b);
        let r = 0.5 * nrm2(&perp);
        let center: Vec<f64> = step
            .theta_prev
            .iter()
            .zip(perp.iter())
            .map(|(t, w)| t + 0.5 * w)
            .collect();
        for g in 0..ctx.n_groups() {
            let (_, len) = ctx.groups[g];
            let lhs = ctx.group_corr_norm(g, &center);
            let rhs = (len as f64).sqrt() - r * ctx.group_op_norms[g];
            keep[g] = lhs >= rhs;
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::solver::{group::GroupBcdSolver, SolveOptions};

    /// Exact solve at λ_prev, screen λ_prev→λ, exact solve at λ; returns
    /// (discarded groups, false discards, truly-zero groups).
    pub fn check_group_rule(
        rule: &dyn GroupScreeningRule,
        x: &dyn DesignMatrix,
        y: &[f64],
        groups: &[(usize, usize)],
        lam_prev: f64,
        lam: f64,
    ) -> (usize, usize, usize) {
        let ctx = GroupScreenContext::new(x, y, groups);
        let active: Vec<usize> = (0..groups.len()).collect();
        let opts = SolveOptions { tol_gap: 1e-11, ..Default::default() };
        let prev = GroupBcdSolver.solve(x, y, groups, &active, lam_prev, None, &opts);
        let full_prev = prev.scatter(groups, &active, x.n_cols());
        // θ*(λ_prev) = (y − Xβ)/λ_prev
        let mut theta = y.to_vec();
        for (j, b) in full_prev.iter().enumerate() {
            if *b != 0.0 {
                x.col_axpy_into(j, -b, &mut theta);
            }
        }
        for t in theta.iter_mut() {
            *t /= lam_prev;
        }
        let step = GroupStepInput { lam_prev, lam, theta_prev: &theta };
        let mut keep = vec![true; groups.len()];
        rule.screen(&ctx, &step, &mut keep);

        let exact = GroupBcdSolver.solve(x, y, groups, &active, lam, None, &opts);
        let mut discarded = 0;
        let mut false_discards = 0;
        let mut true_zero = 0;
        for g in 0..groups.len() {
            let zero = exact.beta[g].iter().all(|v| v.abs() < 1e-12);
            if zero {
                true_zero += 1;
            }
            if !keep[g] {
                discarded += 1;
                if !zero {
                    false_discards += 1;
                }
            }
        }
        (discarded, false_discards, true_zero)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::check_group_rule;
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::dot;
    use crate::util::prop;

    #[test]
    fn context_lambda_max_matches_eq55() {
        let ds = synthetic::group_synthetic(30, 80, 16, 1);
        let groups = ds.groups.clone().unwrap();
        let ctx = GroupScreenContext::new(&ds.x, &ds.y, &groups);
        let mut manual = 0.0f64;
        for &(start, len) in &groups {
            let mut ss = 0.0;
            for j in start..start + len {
                let d = dot(ds.x.dense().unwrap().col(j), &ds.y);
                ss += d * d;
            }
            manual = manual.max((ss / len as f64).sqrt());
        }
        assert!((ctx.lam_max - manual).abs() < 1e-10);
    }

    #[test]
    fn group_v1_at_lambda_max_is_xstar_xstar_t_y() {
        let ds = synthetic::group_synthetic(20, 40, 8, 2);
        let groups = ds.groups.clone().unwrap();
        let ctx = GroupScreenContext::new(&ds.x, &ds.y, &groups);
        let theta: Vec<f64> = ds.y.iter().map(|v| v / ctx.lam_max).collect();
        let step = GroupStepInput {
            lam_prev: ctx.lam_max,
            lam: 0.5 * ctx.lam_max,
            theta_prev: &theta,
        };
        let v = group_v1(&ctx, &step);
        // manual X* X*ᵀ y
        let (start, len) = groups[ctx.lam_max_arg];
        let mut manual = vec![0.0; 20];
        for j in start..start + len {
            let c = ds.x.dense().unwrap().col(j);
            crate::linalg::axpy(dot(c, &ds.y), c, &mut manual);
        }
        for (a, b) in v.iter().zip(manual.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn group_edpp_is_safe_randomized() {
        prop::check("group EDPP safety", 0x6ED, 8, |rng| {
            let ng = 6 + rng.usize(10);
            let gsize = 2 + rng.usize(4);
            let n = 15 + rng.usize(15);
            let ds = synthetic::group_synthetic(n, ng * gsize, ng, rng.next_u64());
            let groups = ds.groups.clone().unwrap();
            let ctx = GroupScreenContext::new(&ds.x, &ds.y, &groups);
            let f1 = rng.uniform(0.4, 1.0);
            let f2 = rng.uniform(0.15, f1 * 0.95);
            let (_, false_discards, _) = check_group_rule(
                &GroupEdppRule,
                &ds.x,
                &ds.y,
                &groups,
                f1 * ctx.lam_max,
                f2 * ctx.lam_max,
            );
            assert_eq!(false_discards, 0, "unsafe group discard");
        });
    }

    #[test]
    fn rejects_many_near_prev_lambda() {
        let ds = synthetic::group_synthetic(40, 400, 100, 5);
        let groups = ds.groups.clone().unwrap();
        let ctx = GroupScreenContext::new(&ds.x, &ds.y, &groups);
        let (discarded, fd, true_zero) = check_group_rule(
            &GroupEdppRule,
            &ds.x,
            &ds.y,
            &groups,
            0.5 * ctx.lam_max,
            0.45 * ctx.lam_max,
        );
        assert_eq!(fd, 0);
        assert!(
            discarded as f64 >= 0.8 * true_zero as f64,
            "discarded {discarded}/{true_zero}"
        );
    }
}
