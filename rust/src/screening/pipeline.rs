//! Composable screening pipelines (DESIGN.md §3): the stateful [`Screener`]
//! lifecycle and its combinators.
//!
//! The paper's sequential rules (Theorem 3.3 / Corollary 17) are inherently
//! *stateful*: each step λ₀ → λ consumes the exact dual point θ*(λ₀) of the
//! previous solve. A [`Screener`] owns that state — `init` anchors it at
//! λmax, `screen_step` screens the next λ from the internal anchor, and
//! `observe` feeds the exact solution back in (θ-propagation) — so path
//! drivers and the service no longer hand-thread `StepInput`.
//!
//! Composition is screeners all the way down:
//!
//! * [`CascadeScreener`] — `cascade:sis,edpp`: each stage screens only the
//!   previous stage's survivors (masked subset sweeps), so a cheap
//!   heuristic can shrink the working set before an expensive safe rule
//!   pays its sweep.
//! * [`HybridScreener`] — `hybrid:strong+edpp` (Zeng et al. 2017): the safe
//!   certifier screens first, then the heuristic proposes additional
//!   discards among the certified keeps. Discards beyond the certifier's
//!   are *uncertified* and form the only KKT-repair candidates — the
//!   repair loop no longer re-checks provably-safe discards.
//! * [`GapSafeScreener`] — `dynamic:<pipeline>` / `--dynamic` (Fercoq,
//!   Gramfort, Salmon 2015): in-solver dynamic screening. The solver calls
//!   [`GapSafeHook`] at its duality-gap checks; the hook builds a feasible
//!   dual point from the current residual and shrinks the working set with
//!   the gap-sphere `B(θ, √(2G)/λ)` as the gap closes.
//!
//! Single-rule pipelines are **bit-identical** to driving the underlying
//! [`ScreeningRule`] by hand: on a pristine (all-true) mask the adapter
//! calls `ScreeningRule::screen` directly, and θ-propagation performs the
//! same `theta_from_solution_into` update the path driver used to do.

use super::group_edpp::{
    GroupScreenContext, GroupScreeningRule, GroupStepInput,
};
use super::{
    theta_from_solution_into, ScreenContext, ScreeningRule, StepInput,
};
use crate::linalg::{dot, nrm1};
use crate::solver::SolverHook;

/// All rule names the pipeline grammar accepts as components.
pub const RULE_NAMES: [&str; 9] = [
    "none",
    "safe",
    "dome",
    "dpp",
    "improvement1",
    "improvement2",
    "edpp",
    "strong",
    "sis",
];

/// The subset of [`RULE_NAMES`] that are safe rules (valid hybrid
/// certifiers).
pub const SAFE_RULE_NAMES: [&str; 6] =
    ["safe", "dome", "dpp", "improvement1", "improvement2", "edpp"];

/// Build a Lasso screening rule by name (`"none"` → `None`). This is the
/// single rule factory shared by [`crate::path::RuleKind`], the service and
/// the pipeline builder. Panics on unknown names — validate user input with
/// [`ScreenPipeline::parse`] first.
pub fn make_rule(name: &str, n_rows: usize) -> Option<Box<dyn ScreeningRule>> {
    match name {
        "none" => None,
        "safe" => Some(Box::new(super::safe::SafeRule)),
        "dome" => Some(Box::new(super::dome::DomeRule::default())),
        "dpp" => Some(Box::new(super::dpp::DppRule)),
        "improvement1" => Some(Box::new(super::edpp::Improvement1Rule)),
        "improvement2" => Some(Box::new(super::edpp::Improvement2Rule)),
        "edpp" => Some(Box::new(super::edpp::EdppRule)),
        "strong" => Some(Box::new(super::strong::StrongRule)),
        "sis" => Some(Box::new(super::sis::SisRule::with_default_count(n_rows))),
        other => panic!("unknown screening rule `{other}` (parse the pipeline first)"),
    }
}

/// Is `name` a safe rule? (Unknown names are not safe.)
pub fn rule_name_is_safe(name: &str) -> bool {
    SAFE_RULE_NAMES.contains(&name)
}

/// Per-stage discard count for one screening step: how many features this
/// stage removed beyond everything before it in the pipeline.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageCount {
    pub stage: String,
    pub discarded: usize,
}

/// Stateful screening lifecycle. Contract:
///
/// 1. `init(ctx)` once per path/service — resets every stage to the λmax
///    anchor θ*(λmax) = y/λmax;
/// 2. `screen_step(ctx, lam, keep)` per λ — `keep` arrives all-true from
///    drivers (combinators hand later stages a partially-cleared mask;
///    stages must then only *clear* bits);
/// 3. `observe(ctx, lam, beta)` after the exact solve at λ — the screener
///    advances its own θ*(λ₀) state (λ must not exceed the current anchor
///    for the sequential rules to stay safe; drivers guarantee descending
///    order, the service re-`init`s when it must anchor above its state).
///
/// `Send` is a supertrait: a built pipeline is owned, thread-mobile state,
/// which is what lets the multi-tenant coordinator pin each session's
/// screener to whichever pool worker processes that session's batch.
pub trait Screener: Send {
    /// Canonical pipeline name (`"edpp"`, `"cascade:sis,edpp"`, …).
    fn name(&self) -> String;
    /// All discards provably correct ⇒ the driver skips KKT repair.
    fn is_safe(&self) -> bool;
    /// Reset per-path state to the λmax anchor.
    fn init(&mut self, ctx: &ScreenContext);
    /// λ₀ of the current sequential anchor (∞ before `init`).
    fn anchor_lam(&self) -> f64;
    /// Screen for λ from the internal anchor; returns per-stage discard
    /// counts in stage order.
    fn screen_step(
        &mut self,
        ctx: &ScreenContext,
        lam: f64,
        keep: &mut [bool],
    ) -> Vec<StageCount>;
    /// Feed back the exact full-length solution at λ (θ-propagation).
    fn observe(&mut self, ctx: &ScreenContext, lam: f64, beta: &[f64]);
    /// For heuristic pipelines: per-feature mask of discards that still
    /// need KKT verification (valid after `screen_step`). `None` ⇒ verify
    /// every discard (the pre-pipeline behaviour).
    fn uncertified(&self) -> Option<&[bool]> {
        None
    }
    /// Whether the pipeline wants the in-solver gap-safe refine hook.
    fn dynamic(&self) -> bool {
        false
    }
}

/// Adapter: one stateless [`ScreeningRule`] driven through the stateful
/// lifecycle. `sequential = false` reproduces the "basic" §4.1.1 variants
/// (anchor pinned at λmax; `observe` is a no-op).
pub struct RuleScreener {
    rule: Option<Box<dyn ScreeningRule>>,
    label: String,
    sequential: bool,
    lam_prev: f64,
    theta_prev: Vec<f64>,
}

impl RuleScreener {
    pub fn new(rule: Box<dyn ScreeningRule>, sequential: bool) -> Self {
        let label = rule.name().to_string();
        RuleScreener {
            rule: Some(rule),
            label,
            sequential,
            lam_prev: f64::INFINITY,
            theta_prev: Vec::new(),
        }
    }

    /// The `none` pipeline: screens nothing, discards nothing.
    pub fn none() -> Self {
        RuleScreener {
            rule: None,
            label: "none".to_string(),
            sequential: true,
            lam_prev: f64::INFINITY,
            theta_prev: Vec::new(),
        }
    }
}

impl Screener for RuleScreener {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn is_safe(&self) -> bool {
        self.rule.as_ref().map(|r| r.is_safe()).unwrap_or(true)
    }

    fn init(&mut self, ctx: &ScreenContext) {
        self.lam_prev = ctx.lam_max;
        self.theta_prev.clear();
        self.theta_prev.extend(ctx.y.iter().map(|v| v / ctx.lam_max));
    }

    fn anchor_lam(&self) -> f64 {
        self.lam_prev
    }

    fn screen_step(
        &mut self,
        ctx: &ScreenContext,
        lam: f64,
        keep: &mut [bool],
    ) -> Vec<StageCount> {
        let Some(rule) = &self.rule else {
            return vec![StageCount { stage: self.label.clone(), discarded: 0 }];
        };
        assert!(
            !self.theta_prev.is_empty(),
            "Screener::init must run before screen_step"
        );
        let before = keep.iter().filter(|k| **k).count();
        let step = StepInput {
            lam_prev: self.lam_prev,
            lam,
            theta_prev: &self.theta_prev,
        };
        if before == keep.len() {
            // pristine mask: the exact legacy call — single-rule pipelines
            // stay bit-identical to the pre-lifecycle API
            rule.screen(ctx, &step, keep);
        } else {
            rule.screen_masked(ctx, &step, keep);
        }
        let after = keep.iter().filter(|k| **k).count();
        vec![StageCount { stage: self.label.clone(), discarded: before - after }]
    }

    fn observe(&mut self, ctx: &ScreenContext, lam: f64, beta: &[f64]) {
        if !self.sequential || self.rule.is_none() {
            return;
        }
        assert!(!self.theta_prev.is_empty(), "observe before init");
        theta_from_solution_into(ctx.x, ctx.y, beta, lam, &mut self.theta_prev);
        self.lam_prev = lam;
    }
}

/// `cascade:r1,r2[,…]` — each stage screens only the previous stage's
/// survivors; the pipeline's discard set is the union of its stages'.
pub struct CascadeScreener {
    stages: Vec<Box<dyn Screener>>,
}

impl CascadeScreener {
    pub fn new(stages: Vec<Box<dyn Screener>>) -> Self {
        assert!(stages.len() >= 2, "cascade needs at least two stages");
        CascadeScreener { stages }
    }
}

impl Screener for CascadeScreener {
    fn name(&self) -> String {
        format!(
            "cascade:{}",
            self.stages.iter().map(|s| s.name()).collect::<Vec<_>>().join(",")
        )
    }

    fn is_safe(&self) -> bool {
        // any unsafe stage can discard an active feature ⇒ repair needed
        self.stages.iter().all(|s| s.is_safe())
    }

    fn init(&mut self, ctx: &ScreenContext) {
        for s in &mut self.stages {
            s.init(ctx);
        }
    }

    fn anchor_lam(&self) -> f64 {
        self.stages[0].anchor_lam()
    }

    fn screen_step(
        &mut self,
        ctx: &ScreenContext,
        lam: f64,
        keep: &mut [bool],
    ) -> Vec<StageCount> {
        let mut stats = Vec::with_capacity(self.stages.len());
        for s in &mut self.stages {
            stats.extend(s.screen_step(ctx, lam, keep));
        }
        stats
    }

    fn observe(&mut self, ctx: &ScreenContext, lam: f64, beta: &[f64]) {
        for s in &mut self.stages {
            s.observe(ctx, lam, beta);
        }
    }
}

/// `hybrid:heuristic+safe` — the safe certifier screens first (its discards
/// are provably correct), then the heuristic proposes additional discards
/// among the certified keeps. Only those extra discards are *uncertified*
/// and need KKT verification, so the repair loop checks a residual set
/// instead of every discarded feature (Zeng et al. 2017).
pub struct HybridScreener {
    heuristic: Box<dyn Screener>,
    certifier: Box<dyn Screener>,
    uncertified: Vec<bool>,
}

impl HybridScreener {
    pub fn new(heuristic: Box<dyn Screener>, certifier: Box<dyn Screener>) -> Self {
        assert!(certifier.is_safe(), "hybrid certifier must be a safe rule");
        HybridScreener { heuristic, certifier, uncertified: Vec::new() }
    }
}

impl Screener for HybridScreener {
    fn name(&self) -> String {
        format!("hybrid:{}+{}", self.heuristic.name(), self.certifier.name())
    }

    fn is_safe(&self) -> bool {
        // e.g. hybrid:edpp+edpp: every discard certified ⇒ no repair
        self.heuristic.is_safe() && self.certifier.is_safe()
    }

    fn init(&mut self, ctx: &ScreenContext) {
        self.certifier.init(ctx);
        self.heuristic.init(ctx);
        self.uncertified.clear();
    }

    fn anchor_lam(&self) -> f64 {
        self.certifier.anchor_lam()
    }

    fn screen_step(
        &mut self,
        ctx: &ScreenContext,
        lam: f64,
        keep: &mut [bool],
    ) -> Vec<StageCount> {
        // 1) safe certification pass
        let mut stats = self.certifier.screen_step(ctx, lam, keep);
        let cert_keep: Vec<bool> = keep.to_vec();
        // 2) heuristic proposes extra discards among certified keeps
        stats.extend(self.heuristic.screen_step(ctx, lam, keep));
        // discards beyond the certifier's are the KKT-repair candidates
        self.uncertified.clear();
        self.uncertified.extend(
            cert_keep.iter().zip(keep.iter()).map(|(c, k)| *c && !*k),
        );
        stats
    }

    fn observe(&mut self, ctx: &ScreenContext, lam: f64, beta: &[f64]) {
        self.certifier.observe(ctx, lam, beta);
        self.heuristic.observe(ctx, lam, beta);
    }

    fn uncertified(&self) -> Option<&[bool]> {
        if self.is_safe() {
            None
        } else {
            Some(&self.uncertified)
        }
    }
}

/// `dynamic:<pipeline>` — wraps any screener and additionally requests the
/// in-solver gap-safe refine hook from the driver.
pub struct GapSafeScreener {
    inner: Box<dyn Screener>,
}

impl GapSafeScreener {
    pub fn new(inner: Box<dyn Screener>) -> Self {
        GapSafeScreener { inner }
    }
}

impl Screener for GapSafeScreener {
    fn name(&self) -> String {
        format!("dynamic:{}", self.inner.name())
    }

    fn is_safe(&self) -> bool {
        self.inner.is_safe()
    }

    fn init(&mut self, ctx: &ScreenContext) {
        self.inner.init(ctx);
    }

    fn anchor_lam(&self) -> f64 {
        self.inner.anchor_lam()
    }

    fn screen_step(
        &mut self,
        ctx: &ScreenContext,
        lam: f64,
        keep: &mut [bool],
    ) -> Vec<StageCount> {
        self.inner.screen_step(ctx, lam, keep)
    }

    fn observe(&mut self, ctx: &ScreenContext, lam: f64, beta: &[f64]) {
        self.inner.observe(ctx, lam, beta);
    }

    fn uncertified(&self) -> Option<&[bool]> {
        self.inner.uncertified()
    }

    fn dynamic(&self) -> bool {
        true
    }
}

/// In-solver gap-safe refinement (Fercoq, Gramfort, Salmon 2015). The
/// solver calls [`SolverHook::refine`] at its duality-gap checks; the hook
/// builds the feasible dual point θ = s·r (s = min(1/λ, 1/‖X_liveᵀr‖∞)),
/// computes the *absolute* gap G(β, θ) for that exact θ, and applies the
/// sphere test with center θ and radius √(2G)/λ. Certified features are
/// zero in the exact solution, so the solver may drop them mid-iteration
/// (zeroing their coefficient and restoring the residual). Cost: one
/// subset sweep per gap check — the same order as the gap check itself.
pub struct GapSafeHook<'a> {
    ctx: &'a ScreenContext<'a>,
    /// Global column indices dropped since the last [`Self::take_dropped`].
    dropped: Vec<usize>,
    /// Total drops over the hook's lifetime (one path step).
    pub total_dropped: usize,
}

impl<'a> GapSafeHook<'a> {
    pub fn new(ctx: &'a ScreenContext<'a>) -> Self {
        GapSafeHook { ctx, dropped: Vec::new(), total_dropped: 0 }
    }

    /// Drain the global column indices dropped so far — the driver folds
    /// them into the step's keep mask after each solve.
    pub fn take_dropped(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.dropped)
    }

    /// Drain the drops recorded since the last call into the step's keep
    /// mask, returning how many features were newly discarded. When the
    /// surrounding pipeline is *heuristic*, pass `revalidate`: certificates
    /// issued against a possibly-unrepaired reduced problem cannot be
    /// trusted, so the drops must rejoin the KKT-repair candidate set
    /// (DESIGN.md §3) — this is the single shared implementation both the
    /// path driver and the service use.
    pub fn fold_into(
        &mut self,
        keep: &mut [bool],
        revalidate: Option<&mut Vec<bool>>,
    ) -> usize {
        let dropped = self.take_dropped();
        if let Some(rv) = revalidate {
            for &j in &dropped {
                rv[j] = true;
            }
        }
        let mut newly = 0;
        for j in dropped {
            if keep[j] {
                keep[j] = false;
                newly += 1;
            }
        }
        newly
    }
}

/// The full KKT-repair candidate set for a heuristic dynamic pipeline:
/// the certifier's uncertified discards plus any in-solver hook drops.
pub fn merge_kkt_candidates(uncertified: &[bool], hook_dropped: &[bool]) -> Vec<bool> {
    debug_assert_eq!(uncertified.len(), hook_dropped.len());
    uncertified
        .iter()
        .zip(hook_dropped.iter())
        .map(|(c, h)| *c || *h)
        .collect()
}

impl SolverHook for GapSafeHook<'_> {
    fn refine(
        &mut self,
        lam: f64,
        cols: &[usize],
        beta: &[f64],
        r: &[f64],
        _gap: f64,
        keep_pos: &mut [bool],
    ) -> usize {
        debug_assert_eq!(cols.len(), beta.len());
        debug_assert_eq!(cols.len(), keep_pos.len());
        let live: Vec<usize> = (0..cols.len()).filter(|&k| keep_pos[k]).collect();
        if live.is_empty() {
            return 0;
        }
        let live_cols: Vec<usize> = live.iter().map(|&k| cols[k]).collect();
        let mut corr = vec![0.0; live_cols.len()];
        self.ctx.sweep.xt_w_subset(&live_cols, r, &mut corr);
        let inf = corr.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let s = if inf <= lam || inf == 0.0 { 1.0 / lam } else { 1.0 / inf };
        // absolute gap for θ = s·r — same algebra as dual::duality_gap but
        // unscaled, and self-consistent with the θ we screen against
        let rr = dot(r, r);
        let ry = dot(r, self.ctx.y);
        let yy = dot(self.ctx.y, self.ctx.y);
        let primal = 0.5 * rr + lam * nrm1(beta);
        let dist = s * s * rr - 2.0 * s / lam * ry + yy / (lam * lam);
        let dual = 0.5 * yy - 0.5 * lam * lam * dist;
        let gap_abs = (primal - dual).max(0.0);
        if !gap_abs.is_finite() {
            return 0;
        }
        let radius = (2.0 * gap_abs).sqrt() / lam;
        // same slack/boundary discipline as sphere_screen (DESIGN.md §1)
        let slack = self.ctx.safety_slack * (1.0 + s * rr.sqrt());
        let mut dropped_now = 0usize;
        for (i, &k) in live.iter().enumerate() {
            let sup =
                (corr[i] * s).abs() + (radius + slack) * self.ctx.col_norms[cols[k]];
            if sup < 1.0 - 1e-9 * (1.0 + sup.abs()) {
                keep_pos[k] = false;
                self.dropped.push(cols[k]);
                dropped_now += 1;
            }
        }
        self.total_dropped += dropped_now;
        dropped_now
    }
}

/// Parsed pipeline spec: which rules, how composed, dynamic or not.
#[derive(Clone, Debug, PartialEq, Eq)]
enum PipelineSpec {
    Single(String),
    Cascade(Vec<String>),
    Hybrid { heuristic: String, certifier: String },
}

/// A validated, buildable screening pipeline — the thing `--rule` parses
/// into and services/paths carry instead of a bare rule enum.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScreenPipeline {
    spec: PipelineSpec,
    /// In-solver gap-safe refinement on top of the staged screen.
    pub dynamic: bool,
}

impl ScreenPipeline {
    /// Parse the pipeline grammar:
    ///
    /// ```text
    /// <rule>                 one of RULE_NAMES
    /// cascade:<r1>,<r2>[,…]  each rule screens the previous one's survivors
    /// hybrid:<heur>+<safe>   heuristic proposes, safe rule certifies
    /// dynamic:<pipeline>     in-solver gap-safe refinement (= --dynamic)
    /// ```
    pub fn parse(spec: &str) -> Result<ScreenPipeline, String> {
        let spec = spec.trim();
        if let Some(rest) = spec.strip_prefix("dynamic:") {
            let inner = Self::parse(rest)?;
            if inner.dynamic {
                return Err(format!(
                    "duplicate `dynamic:` prefix in `{spec}`\n{}",
                    Self::grammar()
                ));
            }
            return Ok(inner.with_dynamic(true));
        }
        if let Some(rest) = spec.strip_prefix("cascade:") {
            let names: Vec<String> =
                rest.split(',').map(|s| s.trim().to_string()).collect();
            if names.len() < 2 {
                return Err(format!(
                    "cascade needs at least two comma-separated rules, got `{rest}`\n{}",
                    Self::grammar()
                ));
            }
            for n in &names {
                Self::check_component(n)?;
            }
            return Ok(ScreenPipeline {
                spec: PipelineSpec::Cascade(names),
                dynamic: false,
            });
        }
        if let Some(rest) = spec.strip_prefix("hybrid:") {
            let Some((h, c)) = rest.split_once('+') else {
                return Err(format!(
                    "hybrid needs `<heuristic>+<safe>`, got `{rest}`\n{}",
                    Self::grammar()
                ));
            };
            let (h, c) = (h.trim(), c.trim());
            Self::check_component(h)?;
            Self::check_component(c)?;
            if !rule_name_is_safe(c) {
                return Err(format!(
                    "hybrid certifier `{c}` is not a safe rule (pick one of: {})\n{}",
                    SAFE_RULE_NAMES.join(" "),
                    Self::grammar()
                ));
            }
            return Ok(ScreenPipeline {
                spec: PipelineSpec::Hybrid {
                    heuristic: h.to_string(),
                    certifier: c.to_string(),
                },
                dynamic: false,
            });
        }
        if !RULE_NAMES.contains(&spec) {
            return Err(format!("unknown rule `{spec}`\n{}", Self::grammar()));
        }
        Ok(ScreenPipeline {
            spec: PipelineSpec::Single(spec.to_string()),
            dynamic: false,
        })
    }

    fn check_component(name: &str) -> Result<(), String> {
        if name == "none" {
            return Err(format!(
                "`none` cannot appear inside a composed pipeline\n{}",
                Self::grammar()
            ));
        }
        if !RULE_NAMES.contains(&name) {
            return Err(format!(
                "unknown rule `{name}` in pipeline\n{}",
                Self::grammar()
            ));
        }
        Ok(())
    }

    /// The full grammar, for `--rule` error messages and `dpp info`.
    pub fn grammar() -> String {
        format!(
            "screening pipeline grammar:\n  \
             <rule>                 one of: {}\n  \
             cascade:<r1>,<r2>[,…]  each rule screens the previous one's survivors\n  \
             hybrid:<heur>+<safe>   heuristic proposes, safe rule certifies (safe: {})\n  \
             dynamic:<pipeline>     in-solver gap-safe refinement (or pass --dynamic)",
            RULE_NAMES.join(" "),
            SAFE_RULE_NAMES.join(" ")
        )
    }

    /// Single-rule pipeline from a known-good name (panics on bad names —
    /// use [`Self::parse`] for user input).
    pub fn single(name: &str) -> ScreenPipeline {
        Self::parse(name).expect("invalid rule name")
    }

    pub fn with_dynamic(mut self, on: bool) -> ScreenPipeline {
        self.dynamic = on;
        self
    }

    /// `--rule auto`: pick a pipeline from problem shape (n samples, p
    /// features, fill fraction, number of λ-evaluations expected). The
    /// policy encodes the BENCH_screen.json trends pinned since PR 3/4:
    ///
    /// * p ≫ n (the paper's regime): `hybrid:strong+edpp` — the strong rule
    ///   proposes aggressively, EDPP certifies, and KKT repair only sweeps
    ///   the uncertified residual set, so the hybrid's rejection dominates
    ///   plain EDPP at nearly the same cost;
    /// * p ≲ 8n: plain `edpp` — with few inactive features the heuristic
    ///   stage has nothing extra to discard and repair risk isn't worth it;
    /// * a coarse λ-grid (< 10 evaluations) leaves the sequential anchor far
    ///   from each target λ, and very sparse data (density ≤ 5%) makes the
    ///   gap-sphere subset sweep nearly free — both tip the balance toward
    ///   `dynamic:` in-solver refinement, which recovers the discards the
    ///   loose static screen missed.
    ///
    /// Used as the default session pipeline by the serving coordinator and
    /// exposed as `--rule auto` on the CLI (resolved after the dataset
    /// loads, since it needs the shape).
    pub fn auto(n: usize, p: usize, density: f64, grid: usize) -> ScreenPipeline {
        let base = if p >= 8 * n.max(1) {
            ScreenPipeline::parse("hybrid:strong+edpp").expect("auto policy pipeline")
        } else {
            ScreenPipeline::single("edpp")
        };
        let dynamic = grid < 10 || (density > 0.0 && density <= 0.05);
        base.with_dynamic(dynamic)
    }

    /// `--rule auto` strategy companion: pick the pipeline (exactly
    /// [`Self::auto`]'s choice) *and* the path strategy. The working-set
    /// engine (DESIGN.md §3b) wins when the problem is wide enough that
    /// growing a set from a seed beats shrinking from p (p ≥ 8n) **and**
    /// the λ-grid is fine enough (≥ 10 evaluations) for the accumulated
    /// active set to amortise across steps; otherwise screen-first. The
    /// CLI resolves this after the dataset loads and reports the pick on
    /// stderr (an explicit `--strategy` always wins).
    pub fn auto_with_strategy(
        n: usize,
        p: usize,
        density: f64,
        grid: usize,
    ) -> (ScreenPipeline, crate::path::PathStrategy) {
        let strategy = if p >= 8 * n.max(1) && grid >= 10 {
            crate::path::PathStrategy::WorkingSet
        } else {
            crate::path::PathStrategy::Screen
        };
        (Self::auto(n, p, density, grid), strategy)
    }

    /// Canonical name (round-trips through [`Self::parse`]).
    pub fn name(&self) -> String {
        let base = match &self.spec {
            PipelineSpec::Single(n) => n.clone(),
            PipelineSpec::Cascade(ns) => format!("cascade:{}", ns.join(",")),
            PipelineSpec::Hybrid { heuristic, certifier } => {
                format!("hybrid:{heuristic}+{certifier}")
            }
        };
        if self.dynamic {
            format!("dynamic:{base}")
        } else {
            base
        }
    }

    /// Instantiate the screener tree. `sequential = false` pins every
    /// stage's anchor at λmax (the §4.1.1 "basic" variants).
    pub fn build(&self, n_rows: usize, sequential: bool) -> Box<dyn Screener> {
        let leaf = |name: &str| -> Box<dyn Screener> {
            Box::new(match make_rule(name, n_rows) {
                Some(r) => RuleScreener::new(r, sequential),
                None => RuleScreener::none(),
            })
        };
        let base: Box<dyn Screener> = match &self.spec {
            PipelineSpec::Single(n) => leaf(n),
            PipelineSpec::Cascade(ns) => Box::new(CascadeScreener::new(
                ns.iter().map(|n| leaf(n)).collect(),
            )),
            PipelineSpec::Hybrid { heuristic, certifier } => {
                Box::new(HybridScreener::new(leaf(heuristic), leaf(certifier)))
            }
        };
        if self.dynamic {
            Box::new(GapSafeScreener::new(base))
        } else {
            base
        }
    }
}

// ---------------------------------------------------------------------------
// Group-Lasso lifecycle (the group path driver drives the same shape).
// ---------------------------------------------------------------------------

/// Stateful lifecycle for group screening — the group analogue of
/// [`Screener`] (keep mask is per *group*).
pub trait GroupScreener {
    fn name(&self) -> String;
    fn is_safe(&self) -> bool;
    fn init(&mut self, ctx: &GroupScreenContext);
    fn anchor_lam(&self) -> f64;
    fn screen_step(
        &mut self,
        ctx: &GroupScreenContext,
        lam: f64,
        keep: &mut [bool],
    ) -> Vec<StageCount>;
    /// Feed back the exact full-length solution at λ.
    fn observe(&mut self, ctx: &GroupScreenContext, lam: f64, beta: &[f64]);
}

/// Adapter driving one stateless [`GroupScreeningRule`] through the
/// lifecycle, owning the group θ-propagation the driver used to hand-roll.
pub struct GroupRuleScreener {
    rule: Option<Box<dyn GroupScreeningRule>>,
    label: String,
    lam_prev: f64,
    theta_prev: Vec<f64>,
}

impl GroupRuleScreener {
    pub fn new(rule: Box<dyn GroupScreeningRule>) -> Self {
        let label = rule.name().to_string();
        GroupRuleScreener {
            rule: Some(rule),
            label,
            lam_prev: f64::INFINITY,
            theta_prev: Vec::new(),
        }
    }

    pub fn none() -> Self {
        GroupRuleScreener {
            rule: None,
            label: "none".to_string(),
            lam_prev: f64::INFINITY,
            theta_prev: Vec::new(),
        }
    }
}

impl GroupScreener for GroupRuleScreener {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn is_safe(&self) -> bool {
        self.rule.as_ref().map(|r| r.is_safe()).unwrap_or(true)
    }

    fn init(&mut self, ctx: &GroupScreenContext) {
        self.lam_prev = ctx.lam_max;
        self.theta_prev.clear();
        self.theta_prev.extend(ctx.y.iter().map(|v| v / ctx.lam_max));
    }

    fn anchor_lam(&self) -> f64 {
        self.lam_prev
    }

    fn screen_step(
        &mut self,
        ctx: &GroupScreenContext,
        lam: f64,
        keep: &mut [bool],
    ) -> Vec<StageCount> {
        let Some(rule) = &self.rule else {
            return vec![StageCount { stage: self.label.clone(), discarded: 0 }];
        };
        assert!(!self.theta_prev.is_empty(), "init before screen_step");
        let before = keep.iter().filter(|k| **k).count();
        let step = GroupStepInput {
            lam_prev: self.lam_prev,
            lam,
            theta_prev: &self.theta_prev,
        };
        rule.screen(ctx, &step, keep);
        let after = keep.iter().filter(|k| **k).count();
        vec![StageCount {
            stage: self.label.clone(),
            discarded: before.saturating_sub(after),
        }]
    }

    fn observe(&mut self, ctx: &GroupScreenContext, lam: f64, beta: &[f64]) {
        if self.rule.is_none() {
            return;
        }
        assert!(!self.theta_prev.is_empty(), "observe before init");
        // θ*(λ) = (y − Xβ)/λ — same update the Lasso adapter performs
        theta_from_solution_into(ctx.x, ctx.y, beta, lam, &mut self.theta_prev);
        self.lam_prev = lam;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::screening::theta_at_lambda_max;
    use crate::solver::{cd::CdSolver, LassoSolver, SolveOptions};

    #[test]
    fn parser_roundtrips_and_rejects() {
        for s in [
            "edpp",
            "none",
            "strong",
            "cascade:sis,edpp",
            "cascade:strong,dpp,edpp",
            "hybrid:strong+edpp",
            "dynamic:edpp",
            "dynamic:hybrid:strong+edpp",
        ] {
            let p = ScreenPipeline::parse(s).expect(s);
            assert_eq!(p.name(), s, "canonical name mismatch for {s}");
            // canonical names re-parse to the same pipeline
            assert_eq!(ScreenPipeline::parse(&p.name()).unwrap(), p);
        }
        for bad in [
            "edppp",
            "cascade:edpp",
            "cascade:edpp,nope",
            "hybrid:strong",
            "hybrid:strong+sis",   // sis is not a safe certifier
            "hybrid:edpp+strong",  // strong is not a safe certifier
            "cascade:none,edpp",
            "dynamic:dynamic:edpp",
        ] {
            let err = ScreenPipeline::parse(bad).unwrap_err();
            assert!(err.contains("grammar"), "error for `{bad}` lacks grammar: {err}");
        }
    }

    /// The `--rule auto` policy picks shape-appropriate pipelines and only
    /// ever returns parseable canonical names.
    #[test]
    fn auto_policy_tracks_problem_shape() {
        // wide p ≫ n, dense-ish data, fine grid → hybrid without dynamic
        assert_eq!(ScreenPipeline::auto(100, 1000, 0.3, 100).name(), "hybrid:strong+edpp");
        // modest p/n ratio → plain edpp
        assert_eq!(ScreenPipeline::auto(100, 400, 0.3, 100).name(), "edpp");
        // coarse grid → dynamic refinement compensates the loose anchor
        assert_eq!(ScreenPipeline::auto(100, 400, 0.3, 5).name(), "dynamic:edpp");
        // very sparse data → dynamic (subset sweeps are nearly free)
        assert_eq!(
            ScreenPipeline::auto(100, 2000, 0.01, 50).name(),
            "dynamic:hybrid:strong+edpp"
        );
        // every auto pick round-trips through the grammar
        for (n, p, d, g) in
            [(1usize, 10usize, 0.5f64, 1usize), (50, 50, 0.0, 20), (200, 5000, 0.1, 100)]
        {
            let pipe = ScreenPipeline::auto(n, p, d, g);
            assert_eq!(ScreenPipeline::parse(&pipe.name()).unwrap(), pipe);
        }
    }

    /// `auto_with_strategy` decision table: the working-set engine needs
    /// BOTH the wide regime (p ≥ 8n) and a fine grid (≥ 10 λ-evaluations);
    /// the pipeline half is always exactly `auto`'s pick.
    #[test]
    fn auto_strategy_decision_table() {
        use crate::path::PathStrategy;
        let cases = [
            (100usize, 1000usize, 0.3f64, 100usize, PathStrategy::WorkingSet),
            (100, 800, 0.3, 10, PathStrategy::WorkingSet), // boundary: p = 8n, grid = 10
            (100, 799, 0.3, 100, PathStrategy::Screen),    // just under 8n
            (100, 1000, 0.3, 9, PathStrategy::Screen),     // grid too coarse
            (100, 400, 0.3, 100, PathStrategy::Screen),    // modest p/n ratio
            (0, 7, 0.3, 50, PathStrategy::Screen),         // degenerate n → n.max(1)
        ];
        for (n, p, d, g, want) in cases {
            let (pipe, strat) = ScreenPipeline::auto_with_strategy(n, p, d, g);
            assert_eq!(strat, want, "n={n} p={p} grid={g}");
            assert_eq!(pipe, ScreenPipeline::auto(n, p, d, g));
        }
    }

    #[test]
    fn dynamic_flag_and_safety_flags() {
        let p = ScreenPipeline::parse("hybrid:strong+edpp").unwrap();
        let s = p.build(50, true);
        assert!(!s.is_safe());
        assert!(!s.dynamic());
        let d = p.clone().with_dynamic(true).build(50, true);
        assert!(d.dynamic());
        assert_eq!(d.name(), "dynamic:hybrid:strong+edpp");
        let safe_hybrid = ScreenPipeline::parse("hybrid:edpp+edpp").unwrap().build(50, true);
        assert!(safe_hybrid.is_safe(), "hybrid of two safe rules is safe");
        let casc = ScreenPipeline::parse("cascade:sis,edpp").unwrap().build(50, true);
        assert!(!casc.is_safe(), "cascade containing sis is heuristic");
    }

    /// Single-rule screeners reproduce the legacy StepInput-driven calls
    /// bit-for-bit: same keep mask at the λmax anchor and after observing
    /// an exact solution.
    #[test]
    fn rule_screener_matches_legacy_protocol() {
        let ds = synthetic::synthetic1(30, 100, 8, 0.1, 0x5C12);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let mut scr = ScreenPipeline::single("edpp").build(30, true);
        scr.init(&ctx);
        let lam1 = 0.6 * ctx.lam_max;

        let mut keep_new = vec![true; 100];
        scr.screen_step(&ctx, lam1, &mut keep_new);

        let theta_max = theta_at_lambda_max(&ctx);
        let step = StepInput { lam_prev: ctx.lam_max, lam: lam1, theta_prev: &theta_max };
        let mut keep_old = vec![true; 100];
        super::super::edpp::EdppRule.screen(&ctx, &step, &mut keep_old);
        assert_eq!(keep_new, keep_old, "λmax-anchored step diverged");

        // exact solve at lam1, observe, then screen lam2 both ways
        let cols: Vec<usize> = (0..100).collect();
        let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let beta = CdSolver
            .solve(&ds.x, &ds.y, &cols, lam1, None, &opts)
            .scatter(&cols, 100);
        scr.observe(&ctx, lam1, &beta);
        assert_eq!(scr.anchor_lam(), lam1);
        let lam2 = 0.4 * ctx.lam_max;
        let mut keep_new2 = vec![true; 100];
        scr.screen_step(&ctx, lam2, &mut keep_new2);

        let theta = crate::screening::theta_from_solution(&ds.x, &ds.y, &beta, lam1);
        let step2 = StepInput { lam_prev: lam1, lam: lam2, theta_prev: &theta };
        let mut keep_old2 = vec![true; 100];
        super::super::edpp::EdppRule.screen(&ctx, &step2, &mut keep_old2);
        assert_eq!(keep_new2, keep_old2, "sequential step diverged");
    }

    /// Cascade: stage 1 runs on the pristine mask exactly as it would
    /// alone; later stages only clear bits; per-stage counts add up.
    #[test]
    fn cascade_union_of_discards() {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, 0xCA5C);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let lam = 0.5 * ctx.lam_max;

        let mut casc = ScreenPipeline::parse("cascade:dpp,edpp").unwrap().build(30, true);
        casc.init(&ctx);
        let mut keep = vec![true; 120];
        let stats = casc.screen_step(&ctx, lam, &mut keep);
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].stage, "dpp");
        assert_eq!(stats[1].stage, "edpp");
        let total_discards = keep.iter().filter(|k| !**k).count();
        assert_eq!(stats[0].discarded + stats[1].discarded, total_discards);

        // stage 1 alone (pristine mask ⇒ identical call)
        let mut solo = ScreenPipeline::single("dpp").build(30, true);
        solo.init(&ctx);
        let mut keep_solo = vec![true; 120];
        solo.screen_step(&ctx, lam, &mut keep_solo);
        for j in 0..120 {
            if !keep_solo[j] {
                assert!(!keep[j], "cascade resurrected stage-1 discard {j}");
            }
        }
        // edpp dominates dpp ⇒ the cascade should discard strictly more on
        // this well-separated problem
        assert!(total_discards >= keep_solo.iter().filter(|k| !**k).count());
    }

    /// Hybrid: keep ⊆ certifier keep; uncertified = heuristic-only
    /// discards; hybrid of a safe rule with itself has no uncertified
    /// discards and equals the rule's own keep-set.
    #[test]
    fn hybrid_certification_masks() {
        let ds = synthetic::synthetic1(40, 150, 12, 0.1, 0x4B1D);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let lam = 0.55 * ctx.lam_max;

        let mut hyb =
            ScreenPipeline::parse("hybrid:strong+edpp").unwrap().build(40, true);
        hyb.init(&ctx);
        let mut keep = vec![true; 150];
        hyb.screen_step(&ctx, lam, &mut keep);

        let mut cert = ScreenPipeline::single("edpp").build(40, true);
        cert.init(&ctx);
        let mut keep_cert = vec![true; 150];
        cert.screen_step(&ctx, lam, &mut keep_cert);

        let unc = hyb.uncertified().expect("heuristic hybrid has candidates");
        for j in 0..150 {
            if keep[j] {
                assert!(keep_cert[j], "hybrid kept a feature edpp discarded: {j}");
                assert!(!unc[j], "kept feature marked uncertified: {j}");
            }
            if !keep_cert[j] {
                assert!(!unc[j], "certified discard marked uncertified: {j}");
            }
            assert_eq!(unc[j], keep_cert[j] && !keep[j]);
        }

        let mut selfhyb =
            ScreenPipeline::parse("hybrid:edpp+edpp").unwrap().build(40, true);
        selfhyb.init(&ctx);
        let mut keep_self = vec![true; 150];
        selfhyb.screen_step(&ctx, lam, &mut keep_self);
        assert!(selfhyb.uncertified().is_none(), "safe hybrid needs no repair");
        for j in 0..150 {
            if !keep_cert[j] {
                assert!(!keep_self[j], "self-hybrid kept an edpp discard: {j}");
            }
        }
    }

    /// The gap-safe hook only drops features that are exactly zero in the
    /// high-precision reference solution, and CD with the hook reaches the
    /// same solution as without.
    #[test]
    fn gap_safe_hook_drops_only_true_zeros() {
        let ds = synthetic::synthetic1(30, 100, 8, 0.1, 0x6A95);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let lam = 0.3 * ctx.lam_max;
        let cols: Vec<usize> = (0..100).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };

        let reference = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts);
        let ref_full = reference.scatter(&cols, 100);

        let mut hook = GapSafeHook::new(&ctx);
        let hooked = CdSolver.solve_with_hook(
            &ds.x,
            &ds.y,
            &cols,
            lam,
            None,
            &opts,
            Some(&mut hook),
        );
        let hooked_full = hooked.scatter(&cols, 100);
        for j in hook.take_dropped() {
            assert_eq!(ref_full[j], 0.0, "hook dropped active feature {j}");
        }
        for j in 0..100 {
            assert!(
                (hooked_full[j] - ref_full[j]).abs() < 1e-4 * (1.0 + ref_full[j].abs()),
                "dynamic solve diverged at {j}"
            );
        }
        // on a gap-converged solve the sphere should have certified a
        // meaningful share of the inactive features
        assert!(hook.total_dropped > 0, "hook never dropped anything");
    }
}
