//! Screening rules — the paper's contribution plus every baseline it
//! compares against.
//!
//! All *safe* sphere rules share one shape (paper §2.1, rule (R1')): given a
//! ball `B(c, ρ)` known to contain the dual optimum θ*(λ), discard feature i
//! when `sup_{θ∈B} |xᵢᵀθ| = |xᵢᵀc| + ρ‖xᵢ‖ < 1` (eq. (14)). The rules
//! differ only in the ball:
//!
//! | rule | center c | radius ρ |
//! |---|---|---|
//! | SAFE/ST1 (seq.) | y/λ | ‖y/λ − θ*(λ₀)‖ |
//! | DPP (Cor. 5) | θ*(λ₀) | (1/λ − 1/λ₀)·‖y‖ |
//! | Improvement 1 (Thm 11) | θ*(λ₀) | ‖v₂⊥‖ |
//! | Improvement 2 (Thm 14) | θ*(λ₀) + ½(1/λ−1/λ₀)y | ½(1/λ−1/λ₀)‖y‖ |
//! | EDPP (Cor. 17) | θ*(λ₀) + ½v₂⊥ | ½‖v₂⊥‖ |
//!
//! DOME refines the SAFE sphere with a half-space cut; strong rules and SIS
//! are heuristic (not safe) and are paired with the KKT repair loop in
//! [`crate::path`].
//!
//! The O(nnz) part of every rule is one correlation sweep `Xᵀw`; rules are
//! **matrix-free**: they see the feature matrix only through the
//! [`DesignMatrix`] trait (DESIGN.md §2), so the same code runs on the
//! dense backend, the CSC backend, or the AOT-compiled PJRT sweep
//! ([`crate::runtime::ArtifactSweep`]).

pub mod dome;
pub mod dpp;
pub mod edpp;
pub mod group_edpp;
pub mod group_strong;
pub mod pipeline;
pub mod safe;
pub mod sis;
pub mod strong;

use std::cell::RefCell;

pub use pipeline::{
    GapSafeHook, GroupScreener, RuleScreener, ScreenPipeline, Screener, StageCount,
};

use crate::linalg::DesignMatrix;
#[cfg(test)]
use crate::solver::dual;

/// Owned, backend-independent precomputed statistics of one (X, y) problem
/// — exactly what [`ScreenContext::with_sweep`] derives with its two O(nnz)
/// sweeps. Long-lived owners (the serving sessions in
/// [`crate::coordinator::registry`]) keep one per dataset and rebuild a
/// borrowing [`ScreenContext`] per request batch without re-sweeping;
/// [`ContextStats::context`] reproduces `ScreenContext::with_sweep_slack`
/// bit-for-bit.
#[derive(Clone, Debug)]
pub struct ContextStats {
    pub col_norms: Vec<f64>,
    pub xty: Vec<f64>,
    pub y_norm: f64,
    pub lam_max: f64,
    pub lam_max_arg: usize,
    /// Identity stamp of the backend the statistics were computed from:
    /// (n, p, [`DesignMatrix::data_version`]). Long-lived owners check
    /// [`ContextStats::is_valid`] before rebuilding a context — cached
    /// O(nnz) statistics must never silently outlive the data they
    /// summarize (every shipped backend is immutable, so today this only
    /// guards future mutable backends).
    stamp: (usize, usize, u64),
}

impl ContextStats {
    /// The two sweeps (`col_norms`, `Xᵀy`) plus λmax — identical math to
    /// [`ScreenContext::with_sweep`].
    pub fn compute(x: &dyn DesignMatrix, y: &[f64]) -> ContextStats {
        let col_norms = x.col_norms();
        let mut xty = vec![0.0; x.n_cols()];
        x.xt_w(y, &mut xty);
        let mut lam_max = 0.0f64;
        let mut lam_max_arg = 0usize;
        for (j, v) in xty.iter().enumerate() {
            if v.abs() > lam_max {
                lam_max = v.abs();
                lam_max_arg = j;
            }
        }
        ContextStats {
            col_norms,
            xty,
            y_norm: crate::linalg::nrm2(y),
            lam_max,
            lam_max_arg,
            stamp: (x.n_rows(), x.n_cols(), x.data_version()),
        }
    }

    /// True when these statistics still describe `x`: same shape, same
    /// [`DesignMatrix::data_version`]. O(1) — cheap enough to check per
    /// batch.
    pub fn is_valid(&self, x: &dyn DesignMatrix) -> bool {
        self.stamp == (x.n_rows(), x.n_cols(), x.data_version())
    }

    /// Materialize a borrowing context over `x`/`y` from the cached
    /// statistics (two p-length copies, no sweeps). The values are the ones
    /// `compute` produced, so the resulting context is bit-identical to
    /// `ScreenContext::with_sweep_slack(x, y, x, safety_slack)`.
    pub fn context<'a>(
        &self,
        x: &'a dyn DesignMatrix,
        y: &'a [f64],
        safety_slack: f64,
    ) -> ScreenContext<'a> {
        ScreenContext {
            x,
            y,
            col_norms: self.col_norms.clone(),
            xty: self.xty.clone(),
            y_norm: self.y_norm,
            lam_max: self.lam_max,
            lam_max_arg: self.lam_max_arg,
            sweep: x,
            safety_slack,
            scratch: RefCell::new(vec![0.0; x.n_cols()]),
        }
    }
}

/// Precomputed per-problem quantities shared by every rule along a path.
pub struct ScreenContext<'a> {
    /// The design matrix, seen matrix-free.
    pub x: &'a dyn DesignMatrix,
    pub y: &'a [f64],
    /// ‖xᵢ‖₂ for every feature.
    pub col_norms: Vec<f64>,
    /// Xᵀy (used by basic rules and λmax).
    pub xty: Vec<f64>,
    pub y_norm: f64,
    /// λmax = ‖Xᵀy‖∞ (eq. (7)).
    pub lam_max: f64,
    /// argmax feature x* of eq. (17).
    pub lam_max_arg: usize,
    /// Sweep provider for `Xᵀw` (the matrix itself by default; the PJRT
    /// artifact runtime optionally).
    pub sweep: &'a dyn DesignMatrix,
    /// Relative slack widening keep-decisions when the sweep is computed in
    /// reduced precision (0.0 for the native f64 sweep; see
    /// [`crate::runtime::ArtifactSweep::SAFETY_SLACK`]). Keeping *more*
    /// features can never break safety — only discard fewer.
    pub safety_slack: f64,
    /// Reusable p-length sweep buffer: [`sphere_screen`], the strong rule
    /// and the KKT checker run once per λ step, and hoisting their score
    /// vector here removes a p-sized allocation per step (§Perf).
    scratch: RefCell<Vec<f64>>,
}

impl<'a> ScreenContext<'a> {
    /// Build a context over any [`DesignMatrix`] backend using its native
    /// sweep.
    pub fn new(x: &'a dyn DesignMatrix, y: &'a [f64]) -> Self {
        Self::with_sweep(x, y, x)
    }

    /// Build a context with an explicit sweep provider (e.g. the PJRT
    /// artifact runtime) and its required safety slack.
    pub fn with_sweep_slack(
        x: &'a dyn DesignMatrix,
        y: &'a [f64],
        sweep: &'a dyn DesignMatrix,
        safety_slack: f64,
    ) -> Self {
        let mut ctx = Self::with_sweep(x, y, sweep);
        ctx.safety_slack = safety_slack;
        ctx
    }

    /// Build a context with an explicit sweep provider (e.g. the PJRT
    /// artifact runtime). The precomputed statistics (`xty`, λmax, column
    /// norms) always come from `x`'s exact native kernels.
    pub fn with_sweep(
        x: &'a dyn DesignMatrix,
        y: &'a [f64],
        sweep: &'a dyn DesignMatrix,
    ) -> Self {
        let stats = ContextStats::compute(x, y);
        let p = x.n_cols();
        ScreenContext {
            x,
            y,
            col_norms: stats.col_norms,
            xty: stats.xty,
            y_norm: stats.y_norm,
            lam_max: stats.lam_max,
            lam_max_arg: stats.lam_max_arg,
            sweep,
            safety_slack: 0.0,
            scratch: RefCell::new(vec![0.0; p]),
        }
    }

    pub fn p(&self) -> usize {
        self.x.n_cols()
    }

    /// Borrow the reusable sweep buffer (resized to p).
    pub(crate) fn sweep_scratch(&self) -> std::cell::RefMut<'_, Vec<f64>> {
        let mut s = self.scratch.borrow_mut();
        s.resize(self.p(), 0.0);
        s
    }
}

/// Inputs for one sequential screening step λ₀ → λ (λ < λ₀ ≤ λmax).
pub struct StepInput<'a> {
    /// λ₀ — the larger parameter whose exact solution is known.
    pub lam_prev: f64,
    /// λ — the parameter we are about to solve.
    pub lam: f64,
    /// θ*(λ₀) = (y − Xβ*(λ₀))/λ₀ (KKT eq. (3)); equals y/λmax at λ₀ = λmax.
    pub theta_prev: &'a [f64],
}

/// A feature-screening rule. `screen` fills `keep` (true = feature survives,
/// false = discarded). Safe rules guarantee discarded ⇒ [β*(λ)]ᵢ = 0.
///
/// `Send` is a supertrait so pipelines built from rules can move across
/// threads (the multi-tenant coordinator processes session batches on the
/// shared [`crate::runtime::pool`]); every rule is plain owned data.
pub trait ScreeningRule: Send {
    fn name(&self) -> &'static str;
    /// Whether discards are guaranteed correct (drives the KKT repair loop).
    fn is_safe(&self) -> bool;
    fn screen(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]);

    /// Masked form used by later stages of a [`pipeline::CascadeScreener`]:
    /// `keep` may arrive with some features already discarded by an earlier
    /// stage. The rule must only *clear* additional bits — never resurrect a
    /// discard — and should restrict its sweep to the surviving columns
    /// where its math allows (the sphere rules pay O(nnz of survivors)
    /// instead of a full sweep). Default: full evaluation into a scratch
    /// mask, then intersect.
    fn screen_masked(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let mut full = vec![true; keep.len()];
        self.screen(ctx, step, &mut full);
        for (k, f) in keep.iter_mut().zip(full.into_iter()) {
            *k = *k && f;
        }
    }
}

/// Shared sphere test: keep[i] = false when `|xᵢᵀc| + ρ‖xᵢ‖ < 1`.
/// `center` is a dual-space (length-N) vector. One `Xᵀ·center` sweep into
/// the context's reusable scratch buffer (no per-step allocation).
pub fn sphere_screen(ctx: &ScreenContext, center: &[f64], radius: f64, keep: &mut [bool]) {
    let p = ctx.p();
    assert_eq!(keep.len(), p);
    let mut scores = ctx.sweep_scratch();
    ctx.sweep.xt_w(center, &mut scores[..]);
    // widen the keep-condition by the sweep's precision slack (reduced-
    // precision sweeps must never turn a keep into an unsafe discard)
    let slack = ctx.safety_slack * (1.0 + crate::linalg::nrm2(center));
    for j in 0..p {
        let sup = scores[j].abs() + (radius + slack) * ctx.col_norms[j];
        // boundary tolerance: an active feature can satisfy sup == 1 exactly
        // (e.g. radius → 0 with |xᵢᵀθ*| = 1); round-off must not discard it
        keep[j] = sup >= 1.0 - 1e-9 * (1.0 + sup.abs());
    }
}

/// Masked sphere test for cascade stages: evaluate only the features still
/// true in `keep` — one `xt_w_subset` over the survivors, O(nnz of the
/// surviving columns) instead of a full sweep — and only *clear* bits.
/// Same keep-condition (slack, boundary tolerance) as [`sphere_screen`].
pub fn sphere_screen_masked(
    ctx: &ScreenContext,
    center: &[f64],
    radius: f64,
    keep: &mut [bool],
) {
    let p = ctx.p();
    assert_eq!(keep.len(), p);
    let cols: Vec<usize> = (0..p).filter(|&j| keep[j]).collect();
    let mut scores = vec![0.0; cols.len()];
    ctx.sweep.xt_w_subset(&cols, center, &mut scores);
    let slack = ctx.safety_slack * (1.0 + crate::linalg::nrm2(center));
    for (k, &j) in cols.iter().enumerate() {
        let sup = scores[k].abs() + (radius + slack) * ctx.col_norms[j];
        keep[j] = sup >= 1.0 - 1e-9 * (1.0 + sup.abs());
    }
}

/// v₁(λ₀) of eq. (17): the ray direction whose projection stays at θ*(λ₀).
pub fn v1(ctx: &ScreenContext, step: &StepInput) -> Vec<f64> {
    let n = ctx.y.len();
    if step.lam_prev < ctx.lam_max * (1.0 - 1e-12) {
        // y/λ₀ − θ*(λ₀)
        (0..n).map(|i| ctx.y[i] / step.lam_prev - step.theta_prev[i]).collect()
    } else {
        // sign(x*ᵀy)·x*
        let s = ctx.xty[ctx.lam_max_arg].signum();
        let mut v = vec![0.0; n];
        ctx.x.col_into(ctx.lam_max_arg, &mut v);
        for vi in v.iter_mut() {
            *vi *= s;
        }
        v
    }
}

/// v₂(λ, λ₀) = y/λ − θ*(λ₀) (eq. (18)).
pub fn v2(ctx: &ScreenContext, step: &StepInput) -> Vec<f64> {
    ctx.y
        .iter()
        .zip(step.theta_prev.iter())
        .map(|(yi, ti)| yi / step.lam - ti)
        .collect()
}

/// v₂⊥ = v₂ − (⟨v₁,v₂⟩/‖v₁‖²)·v₁ (eq. (19)). Theorem 7 proves ⟨v₁,v₂⟩ ≥ 0;
/// we guard numerically and fall back to v₂ itself when the inner product is
/// (floating-point) negative, which keeps the ball valid (eq. (25)).
pub fn v2_perp(v1: &[f64], v2: &[f64]) -> Vec<f64> {
    let v1v2 = crate::linalg::dot(v1, v2);
    let v1v1 = crate::linalg::dot(v1, v1);
    if v1v1 <= 0.0 || v1v2 < 0.0 {
        return v2.to_vec();
    }
    let c = v1v2 / v1v1;
    v2.iter().zip(v1.iter()).map(|(b, a)| b - c * a).collect()
}

/// Exact dual point from a full-length primal solution (KKT eq. (3)),
/// written into `theta` (length N) — the allocation-free form the path
/// driver uses at every λ step.
pub fn theta_from_solution_into(
    x: &dyn DesignMatrix,
    y: &[f64],
    beta: &[f64],
    lam: f64,
    theta: &mut [f64],
) {
    assert_eq!(theta.len(), y.len());
    theta.copy_from_slice(y);
    for (j, b) in beta.iter().enumerate() {
        if *b != 0.0 {
            x.col_axpy_into(j, -b, theta);
        }
    }
    for t in theta.iter_mut() {
        *t /= lam;
    }
}

/// Exact dual point from a full-length primal solution (KKT eq. (3)).
pub fn theta_from_solution(
    x: &dyn DesignMatrix,
    y: &[f64],
    beta: &[f64],
    lam: f64,
) -> Vec<f64> {
    let mut theta = vec![0.0; y.len()];
    theta_from_solution_into(x, y, beta, lam, &mut theta);
    theta
}

/// Convenience: θ*(λmax) = y/λmax (eq. (9)).
pub fn theta_at_lambda_max(ctx: &ScreenContext) -> Vec<f64> {
    ctx.y.iter().map(|v| v / ctx.lam_max).collect()
}

/// Shared test-support: verify a rule's discards against a high-precision
/// reference solution; returns (discarded, false_discards, true_zeros).
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::solver::{cd::CdSolver, LassoSolver, SolveOptions};

    pub struct RuleCheck {
        pub discarded: usize,
        pub false_discards: usize,
        pub true_zeros: usize,
    }

    /// Screen λ_prev→λ with `rule` (θ from exact solve at λ_prev) and
    /// compare against the exact support at λ.
    pub fn check_rule(
        rule: &dyn ScreeningRule,
        x: &dyn DesignMatrix,
        y: &[f64],
        lam_prev: f64,
        lam: f64,
    ) -> RuleCheck {
        let ctx = ScreenContext::new(x, y);
        let cols: Vec<usize> = (0..x.n_cols()).collect();
        let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let prev = CdSolver.solve(x, y, &cols, lam_prev, None, &opts);
        let theta = theta_from_solution(x, y, &prev.scatter(&cols, x.n_cols()), lam_prev);
        let step = StepInput { lam_prev, lam, theta_prev: &theta };
        let mut keep = vec![true; x.n_cols()];
        rule.screen(&ctx, &step, &mut keep);

        let exact = CdSolver.solve(x, y, &cols, lam, None, &opts);
        let beta = exact.scatter(&cols, x.n_cols());
        let mut discarded = 0;
        let mut false_discards = 0;
        let mut true_zeros = 0;
        for j in 0..x.n_cols() {
            if beta[j] == 0.0 {
                true_zeros += 1;
            }
            if !keep[j] {
                discarded += 1;
                if beta[j] != 0.0 {
                    false_discards += 1;
                }
            }
        }
        RuleCheck { discarded, false_discards, true_zeros }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::prop;

    #[test]
    fn context_precomputations() {
        let ds = synthetic::synthetic1(20, 40, 5, 0.1, 1);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        assert_eq!(ctx.col_norms.len(), 40);
        assert!((ctx.lam_max - dual::lambda_max(&ds.x, &ds.y)).abs() < 1e-12);
        assert!((ctx.xty[ctx.lam_max_arg].abs() - ctx.lam_max).abs() < 1e-12);
    }

    #[test]
    fn v1_matches_cases() {
        let ds = synthetic::synthetic1(15, 30, 4, 0.1, 2);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let theta_max = theta_at_lambda_max(&ctx);
        // at λ₀ = λmax: v1 = sign(x*ᵀy)·x*
        let step =
            StepInput { lam_prev: ctx.lam_max, lam: 0.5 * ctx.lam_max, theta_prev: &theta_max };
        let v = v1(&ctx, &step);
        let s = ctx.xty[ctx.lam_max_arg].signum();
        for (a, b) in v.iter().zip(ds.x.dense().unwrap().col(ctx.lam_max_arg)) {
            assert!((a - s * b).abs() < 1e-14);
        }
        // below λmax: v1 = y/λ₀ − θ
        let theta = vec![0.0; 15];
        let step =
            StepInput { lam_prev: 0.7 * ctx.lam_max, lam: 0.5 * ctx.lam_max, theta_prev: &theta };
        let v = v1(&ctx, &step);
        for (a, yi) in v.iter().zip(ds.y.iter()) {
            assert!((a - yi / (0.7 * ctx.lam_max)).abs() < 1e-12);
        }
    }

    #[test]
    fn v2_perp_orthogonal_and_shorter() {
        prop::check("v2perp ⊥ v1 and ‖v2perp‖ ≤ ‖v2‖", 0x51, 40, |rng| {
            let n = 2 + rng.usize(20);
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            // force nonneg inner product as Theorem 7 guarantees
            if crate::linalg::dot(&a, &b) < 0.0 {
                for v in b.iter_mut() {
                    *v = -*v;
                }
            }
            let perp = v2_perp(&a, &b);
            let ip = crate::linalg::dot(&perp, &a);
            assert!(ip.abs() < 1e-8 * (1.0 + crate::linalg::nrm2(&a)), "ip={ip}");
            assert!(crate::linalg::nrm2(&perp) <= crate::linalg::nrm2(&b) + 1e-12);
        });
    }

    #[test]
    fn sphere_screen_monotone_in_radius() {
        // larger radius ⇒ superset of kept features
        let ds = synthetic::synthetic1(20, 50, 6, 0.1, 3);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let center = theta_at_lambda_max(&ctx);
        let mut keep_small = vec![true; 50];
        let mut keep_big = vec![true; 50];
        sphere_screen(&ctx, &center, 0.01, &mut keep_small);
        sphere_screen(&ctx, &center, 0.5, &mut keep_big);
        for j in 0..50 {
            if keep_small[j] {
                assert!(keep_big[j], "radius monotonicity violated at {j}");
            }
        }
    }

    #[test]
    fn theta_from_solution_kkt_feasible() {
        let ds = synthetic::synthetic1(25, 60, 8, 0.1, 4);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let cols: Vec<usize> = (0..60).collect();
        let lam = 0.3 * ctx.lam_max;
        use crate::solver::{cd::CdSolver, LassoSolver, SolveOptions};
        let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let res = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts);
        let theta = theta_from_solution(&ds.x, &ds.y, &res.scatter(&cols, 60), lam);
        // θ* must be dual feasible: |xᵢᵀθ*| ≤ 1 (+tolerance)
        let mut sc = vec![0.0; 60];
        ds.x.gemv_t(&theta, &mut sc);
        for (j, v) in sc.iter().enumerate() {
            assert!(v.abs() <= 1.0 + 1e-5, "θ infeasible at {j}: {v}");
        }
    }

    #[test]
    fn theta_into_matches_allocating_form() {
        let ds = synthetic::synthetic1(12, 18, 3, 0.1, 6);
        let mut beta = vec![0.0; 18];
        beta[2] = 1.5;
        beta[9] = -0.3;
        let a = theta_from_solution(&ds.x, &ds.y, &beta, 0.7);
        let mut b = vec![9.0; 12];
        theta_from_solution_into(&ds.x, &ds.y, &beta, 0.7, &mut b);
        assert_eq!(a, b);
    }
}
