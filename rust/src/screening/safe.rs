//! SAFE / ST1 (El Ghaoui et al. [16]; Xiang et al. [36]) — the sphere test
//! centered at y/λ.
//!
//! Basic form (paper eq. (15)): discard i when
//! `|xᵢᵀy| < λ − ‖xᵢ‖‖y‖·(λmax−λ)/λmax`. Equivalently (divide by λ): the
//! sphere test with center `y/λ` and radius `‖y‖·(1/λ − 1/λmax)`.
//!
//! Recursive/sequential SAFE: with θ*(λ₀) ∈ F known, projection optimality
//! gives `‖θ*(λ) − y/λ‖ ≤ ‖θ*(λ₀) − y/λ‖`, i.e. the ball
//! `B(y/λ, ‖y/λ − θ*(λ₀)‖)`; at λ₀ = λmax this reduces exactly to ST1.

use super::{sphere_screen, sphere_screen_masked, ScreenContext, ScreeningRule, StepInput};
use crate::linalg::dist_sq_scaled;

/// Recursive SAFE (sequential); reduces to SAFE/ST1 when λ₀ = λmax.
pub struct SafeRule;

impl SafeRule {
    fn ball(ctx: &ScreenContext, step: &StepInput) -> (Vec<f64>, f64) {
        let n = ctx.y.len();
        let center: Vec<f64> = (0..n).map(|i| ctx.y[i] / step.lam).collect();
        // ‖y/λ − θ*(λ₀)‖
        let radius = dist_sq_scaled(ctx.y, 1.0 / step.lam, step.theta_prev).sqrt();
        (center, radius)
    }
}

impl ScreeningRule for SafeRule {
    fn name(&self) -> &'static str {
        "safe"
    }

    fn is_safe(&self) -> bool {
        true
    }

    fn screen(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let (center, radius) = Self::ball(ctx, step);
        sphere_screen(ctx, &center, radius, keep);
    }

    fn screen_masked(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let (center, radius) = Self::ball(ctx, step);
        sphere_screen_masked(ctx, &center, radius, keep);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::screening::testutil::check_rule;
    use crate::screening::{edpp::EdppRule, theta_at_lambda_max};
    use crate::util::prop;

    #[test]
    fn basic_form_matches_eq15() {
        // at λ₀ = λmax the rule must coincide with eq. (15)
        let ds = synthetic::synthetic1(25, 70, 6, 0.1, 1);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let theta = theta_at_lambda_max(&ctx);
        let lam = 0.35 * ctx.lam_max;
        let step = StepInput { lam_prev: ctx.lam_max, lam, theta_prev: &theta };
        let mut keep = vec![true; 70];
        SafeRule.screen(&ctx, &step, &mut keep);
        for j in 0..70 {
            let lhs = ctx.xty[j].abs();
            let rhs = lam
                - ctx.col_norms[j] * ctx.y_norm * (ctx.lam_max - lam) / ctx.lam_max;
            assert_eq!(keep[j], lhs >= rhs, "feature {j}: eq(15) mismatch");
        }
    }

    #[test]
    fn safe_rule_is_safe_randomized() {
        prop::check("SAFE safety", 0x5AFE, 12, |rng| {
            let n = 15 + rng.usize(20);
            let p = 20 + rng.usize(50);
            let ds = synthetic::synthetic1(n, p, p / 5 + 1, 0.1, rng.next_u64());
            let ctx = ScreenContext::new(&ds.x, &ds.y);
            let f1 = rng.uniform(0.3, 1.0);
            let f2 = rng.uniform(0.1, f1);
            let chk =
                check_rule(&SafeRule, &ds.x, &ds.y, f1 * ctx.lam_max, f2 * ctx.lam_max);
            assert_eq!(chk.false_discards, 0);
        });
    }

    #[test]
    fn edpp_dominates_safe() {
        // paper Figs. 2–4: EDPP discards far more than SAFE
        prop::check("EDPP ≥ SAFE rejections", 0x5AF2, 8, |rng| {
            let ds = synthetic::synthetic1(25, 120, 10, 0.1, rng.next_u64());
            let ctx = ScreenContext::new(&ds.x, &ds.y);
            let f1 = rng.uniform(0.5, 1.0);
            let f2 = rng.uniform(0.1, f1 * 0.9);
            let s = check_rule(&SafeRule, &ds.x, &ds.y, f1 * ctx.lam_max, f2 * ctx.lam_max);
            let e = check_rule(&EdppRule, &ds.x, &ds.y, f1 * ctx.lam_max, f2 * ctx.lam_max);
            assert!(e.discarded >= s.discarded, "edpp {} < safe {}", e.discarded, s.discarded);
        });
    }
}
