//! DOME (Xiang, Xu, Ramadge [36]; Xiang, Ramadge [35]) — sphere ∩ half-space
//! ("dome") test. Basic-only: the paper notes it is unclear whether a
//! sequential DOME exists (§1), and it assumes unit-norm features (§4.1.1).
//!
//! Region: the SAFE sphere `B(y/λ, ρ)`, ρ = ‖y‖(1/λ − 1/λmax), intersected
//! with the half-space `{θ : ñᵀθ ≤ 1}` where `ñ = sign(x*ᵀy)·x*` is the
//! λmax-attaining constraint — θ*(λ) lies in both (it is feasible, and the
//! projection of y/λ is no farther from y/λ than the feasible y/λmax).
//!
//! Closed-form sup over the dome for a unit-norm feature x:
//! let `q = y/λ`, `d = 1 − ñᵀq` (signed margin of the plane past the
//! center), `a = xᵀñ`. The unconstrained sphere maximizer `q + ρx` is used
//! when it satisfies the half-space; otherwise the maximum sits on the
//! sphere–plane circle: `xᵀq + d·a + √(ρ²−d²)·√(1−a²)` (derived by
//! parametrizing θ = q + d·ñ + √(ρ²−d²)·u with u ⊥ ñ, ‖u‖ = 1).

use super::{ScreenContext, ScreeningRule, StepInput};

/// Basic DOME test (requires unit-norm features; callers should
/// `Dataset::normalize_features` first — asserted loosely at runtime).
///
/// Perf (DESIGN.md §10): `a = Xᵀñ` is λ-independent (ñ is the
/// λmax-attaining feature), so it is computed once and cached across the
/// whole path instead of re-sweeping at every λ — halving DOME's per-step
/// cost from 2 sweeps to 1.
#[derive(Default)]
pub struct DomeRule {
    xn_cache: std::cell::RefCell<Option<Vec<f64>>>,
}

impl DomeRule {
    /// sup over the dome of `xᵀθ` for a *unit-norm* feature column x,
    /// given precomputed `xᵀq` and `a = xᵀñ`.
    fn sup_dome(xq: f64, a: f64, rho: f64, d: f64) -> f64 {
        // plane entirely outside the sphere ⇒ plain sphere test
        if d >= rho {
            return xq + rho;
        }
        // direction of the sphere maximizer relative to the plane normal:
        // ñᵀ(q + ρx) ≤ 1  ⇔  ρ·a ≤ d
        if rho * a <= d {
            xq + rho
        } else {
            let cap = (rho * rho - d * d).max(0.0).sqrt();
            xq + d * a + cap * (1.0 - a * a).max(0.0).sqrt()
        }
    }

    /// The λ-independent second sweep `Xᵀñ` (cached across the path).
    fn compute_xn(ctx: &ScreenContext) -> Vec<f64> {
        let s = ctx.xty[ctx.lam_max_arg].signum();
        let mut xn = vec![0.0; ctx.p()];
        let mut nstar = vec![0.0; ctx.y.len()];
        ctx.x.col_into(ctx.lam_max_arg, &mut nstar);
        for v in nstar.iter_mut() {
            *v *= s;
        }
        ctx.sweep.xt_w(&nstar, &mut xn);
        xn
    }

    /// The λ-dependent dome parameters: radius ρ of the SAFE sphere and
    /// the signed plane margin d past its center.
    fn dome_params(ctx: &ScreenContext, lam: f64) -> (f64, f64) {
        let rho = ctx.y_norm * (1.0 / lam - 1.0 / ctx.lam_max).max(0.0);
        let s = ctx.xty[ctx.lam_max_arg].signum();
        let nstar_norm = ctx.col_norms[ctx.lam_max_arg];
        debug_assert!(
            (nstar_norm - 1.0).abs() < 1e-6,
            "DOME requires unit-norm features (got ‖x*‖ = {nstar_norm})"
        );
        // ñᵀq = sign(x*ᵀy)·x*ᵀy/λ = λmax/λ (for the attaining feature)
        let nq = s * ctx.xty[ctx.lam_max_arg] / lam; // = λmax/λ ≥ 1
        (rho, 1.0 - nq) // d ≤ 0: the center is beyond the plane
    }

    /// One feature's dome keep-decision given its `xᵀq` and cached `xᵀñ`.
    fn keep_feature(ctx: &ScreenContext, j: usize, xqj: f64, xnj: f64, rho: f64, d: f64) -> bool {
        // account for non-exactly-unit norms defensively
        let nj = ctx.col_norms[j].max(1e-300);
        let sup_pos = Self::sup_dome(xqj / nj, xnj / nj, rho, d) * nj;
        let sup_neg = Self::sup_dome(-xqj / nj, -xnj / nj, rho, d) * nj;
        let sup = sup_pos.max(sup_neg);
        // boundary tolerance: active features can sit exactly on the
        // dual constraint (sup = 1); round-off must not flip them into
        // an unsafe discard
        sup >= 1.0 - 1e-9 * (1.0 + xqj.abs() + rho)
    }
}

impl ScreeningRule for DomeRule {
    fn name(&self) -> &'static str {
        "dome"
    }

    fn is_safe(&self) -> bool {
        true
    }

    fn screen(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        // Basic rule: ignores θ*(λ₀) and always anchors at λmax.
        let p = ctx.p();
        let lam = step.lam;
        let (rho, d) = Self::dome_params(ctx, lam);
        // xᵀq for all features in one sweep into the context scratch buffer;
        // xᵀñ = s·(Xᵀx*) needs a second sweep against the x* column.
        let mut xq = ctx.sweep_scratch();
        let q: Vec<f64> = ctx.y.iter().map(|v| v / lam).collect();
        ctx.sweep.xt_w(&q, &mut xq[..]);
        // λ-independent second sweep, cached across the path (DESIGN.md §10)
        let mut cache = self.xn_cache.borrow_mut();
        let xn: &Vec<f64> = cache.get_or_insert_with(|| Self::compute_xn(ctx));
        for j in 0..p {
            keep[j] = Self::keep_feature(ctx, j, xq[j], xn[j], rho, d);
        }
    }

    fn screen_masked(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        // cascade stage: one `xt_w_subset` over the survivors instead of a
        // full sweep; the cached Xᵀñ is full-length and indexed directly
        let lam = step.lam;
        let (rho, d) = Self::dome_params(ctx, lam);
        let cols: Vec<usize> = (0..ctx.p()).filter(|&j| keep[j]).collect();
        let q: Vec<f64> = ctx.y.iter().map(|v| v / lam).collect();
        let mut xq = vec![0.0; cols.len()];
        ctx.sweep.xt_w_subset(&cols, &q, &mut xq);
        let mut cache = self.xn_cache.borrow_mut();
        let xn: &Vec<f64> = cache.get_or_insert_with(|| Self::compute_xn(ctx));
        for (k, &j) in cols.iter().enumerate() {
            keep[j] = Self::keep_feature(ctx, j, xq[k], xn[j], rho, d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::screening::testutil::check_rule;
    use crate::screening::{safe::SafeRule, theta_at_lambda_max};
    use crate::util::prop;

    fn unit_norm_ds(seed: u64, n: usize, p: usize) -> crate::data::Dataset {
        let mut ds = synthetic::synthetic1(n, p, p / 5 + 1, 0.1, seed);
        ds.normalize_features().expect("in-RAM backend");
        ds
    }

    #[test]
    fn dome_is_safe_randomized() {
        prop::check("DOME safety", 0xD0E, 12, |rng| {
            let ds = unit_norm_ds(rng.next_u64(), 15 + rng.usize(20), 30 + rng.usize(60));
            let ctx = ScreenContext::new(&ds.x, &ds.y);
            let f = rng.uniform(0.1, 0.95);
            // basic rule: λ₀ = λmax
            let chk =
                check_rule(&DomeRule::default(), &ds.x, &ds.y, ctx.lam_max, f * ctx.lam_max);
            assert_eq!(chk.false_discards, 0, "unsafe at f={f}");
        });
    }

    #[test]
    fn dome_dominates_basic_safe() {
        // the dome is a subset of the SAFE sphere ⇒ rejects at least as many
        prop::check("DOME ≥ SAFE(basic) rejections", 0xD0E2, 10, |rng| {
            let ds = unit_norm_ds(rng.next_u64(), 20, 80);
            let ctx = ScreenContext::new(&ds.x, &ds.y);
            let f = rng.uniform(0.1, 0.9);
            let theta = theta_at_lambda_max(&ctx);
            let step = StepInput {
                lam_prev: ctx.lam_max,
                lam: f * ctx.lam_max,
                theta_prev: &theta,
            };
            let mut keep_dome = vec![true; 80];
            let mut keep_safe = vec![true; 80];
            DomeRule::default().screen(&ctx, &step, &mut keep_dome);
            SafeRule.screen(&ctx, &step, &mut keep_safe);
            for j in 0..80 {
                if !keep_safe[j] {
                    assert!(!keep_dome[j], "SAFE rejected {j} but DOME kept it");
                }
            }
        });
    }

    #[test]
    fn sup_dome_reduces_to_sphere_when_plane_far() {
        // d ≥ ρ: the half-space doesn't cut the ball
        let v = DomeRule::sup_dome(0.3, 0.5, 0.2, 0.5);
        assert!((v - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sup_dome_caps_at_plane() {
        // x == ñ (a=1): maximum over the dome is exactly xᵀq + d
        let xq = 0.7;
        let v = DomeRule::sup_dome(xq, 1.0, 0.5, 0.1);
        assert!((v - (xq + 0.1)).abs() < 1e-12);
    }
}
