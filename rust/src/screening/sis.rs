//! SIS — Sure Independence Screening (Fan & Lv [17]).
//!
//! The paper's intro cites SIS as the canonical *heuristic* marginal-
//! correlation screen: keep the d features with the largest |xᵢᵀy|,
//! irrespective of λ. Not safe and not λ-adaptive; included as the ablation
//! baseline (DESIGN.md §7) and paired with KKT repair when used on a path.

use super::{ScreenContext, ScreeningRule, StepInput};

/// Keep the `d` features with the largest marginal correlation |xᵢᵀy|.
/// Fan & Lv suggest d on the order of n/log n or n.
pub struct SisRule {
    pub keep_count: usize,
}

impl SisRule {
    /// The classical d = ⌈n/log n⌉ choice.
    pub fn with_default_count(n: usize) -> Self {
        let d = ((n as f64) / (n as f64).ln().max(1.0)).ceil() as usize;
        SisRule { keep_count: d.max(1) }
    }
}

impl ScreeningRule for SisRule {
    fn name(&self) -> &'static str {
        "sis"
    }

    fn is_safe(&self) -> bool {
        false
    }

    fn screen(&self, ctx: &ScreenContext, _step: &StepInput, keep: &mut [bool]) {
        let p = ctx.p();
        let d = self.keep_count.min(p);
        let mut idx: Vec<usize> = (0..p).collect();
        // total_cmp: identical order to the old partial_cmp().unwrap() for
        // finite |xᵀy|; NaN (impossible for finite inputs) now ranks last
        // instead of panicking mid-screen.
        idx.sort_by(|&a, &b| {
            ctx.xty[b].abs().total_cmp(&ctx.xty[a].abs())
        });
        keep.iter_mut().for_each(|k| *k = false);
        for &j in idx.iter().take(d) {
            keep[j] = true;
        }
    }

    fn screen_masked(&self, ctx: &ScreenContext, _step: &StepInput, keep: &mut [bool]) {
        // among the surviving features, keep the top-d by |xᵢᵀy| — no sweep
        // at all (xty is precomputed), so SIS is the natural cheap first
        // stage of a cascade
        let mut idx: Vec<usize> = (0..ctx.p()).filter(|&j| keep[j]).collect();
        let d = self.keep_count.min(idx.len());
        idx.sort_by(|&a, &b| {
            ctx.xty[b].abs().total_cmp(&ctx.xty[a].abs())
        });
        for &j in idx.iter().skip(d) {
            keep[j] = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn keeps_exactly_d_top_features() {
        let ds = synthetic::synthetic1(30, 100, 10, 0.1, 1);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let rule = SisRule { keep_count: 25 };
        let step = StepInput { lam_prev: ctx.lam_max, lam: 0.5, theta_prev: &ds.y };
        let mut keep = vec![true; 100];
        rule.screen(&ctx, &step, &mut keep);
        assert_eq!(keep.iter().filter(|k| **k).count(), 25);
        // every kept feature has |xᵀy| ≥ every discarded one
        let min_kept = (0..100)
            .filter(|&j| keep[j])
            .map(|j| ctx.xty[j].abs())
            .fold(f64::INFINITY, f64::min);
        let max_drop = (0..100)
            .filter(|&j| !keep[j])
            .map(|j| ctx.xty[j].abs())
            .fold(0.0, f64::max);
        assert!(min_kept >= max_drop);
    }

    #[test]
    fn default_count_formula() {
        let r = SisRule::with_default_count(100);
        assert_eq!(r.keep_count, (100.0f64 / 100.0f64.ln()).ceil() as usize);
        assert!(SisRule::with_default_count(1).keep_count >= 1);
    }

    #[test]
    fn keep_count_capped_at_p() {
        let ds = synthetic::synthetic1(10, 20, 3, 0.1, 2);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let rule = SisRule { keep_count: 500 };
        let step = StepInput { lam_prev: ctx.lam_max, lam: 0.5, theta_prev: &ds.y };
        let mut keep = vec![false; 20];
        rule.screen(&ctx, &step, &mut keep);
        assert!(keep.iter().all(|k| *k));
    }
}
