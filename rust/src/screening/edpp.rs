//! The enhanced DPP family (paper §2.3): Improvement 1 (projections of
//! rays, Theorem 11), Improvement 2 (firm nonexpansiveness, Theorem 14),
//! and EDPP (both combined — Theorem 16 / Corollary 17), which the paper
//! shows discards almost all inactive features along the whole path.

use super::{
    sphere_screen, sphere_screen_masked, v1, v2, v2_perp, ScreenContext, ScreeningRule,
    StepInput,
};
use crate::linalg::nrm2;

/// Improvement 1 (Theorem 11): ball `B(θ*(λ₀), ‖v₂⊥‖)` — the ray-projection
/// refinement shrinks the DPP radius from `(1/λ−1/λ₀)‖y‖` to `‖v₂⊥‖`.
pub struct Improvement1Rule;

impl ScreeningRule for Improvement1Rule {
    fn name(&self) -> &'static str {
        "improvement1"
    }

    fn is_safe(&self) -> bool {
        true
    }

    fn screen(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let a = v1(ctx, step);
        let b = v2(ctx, step);
        let perp = v2_perp(&a, &b);
        sphere_screen(ctx, step.theta_prev, nrm2(&perp), keep);
    }

    fn screen_masked(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let a = v1(ctx, step);
        let b = v2(ctx, step);
        let perp = v2_perp(&a, &b);
        sphere_screen_masked(ctx, step.theta_prev, nrm2(&perp), keep);
    }
}

/// Improvement 2 (Theorem 14): firm nonexpansiveness halves the DPP ball —
/// `B(θ*(λ₀) + ½(1/λ−1/λ₀)y, ½(1/λ−1/λ₀)‖y‖)`.
pub struct Improvement2Rule;

impl ScreeningRule for Improvement2Rule {
    fn name(&self) -> &'static str {
        "improvement2"
    }

    fn is_safe(&self) -> bool {
        true
    }

    fn screen(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let (center, radius) = imp2_ball(ctx, step);
        sphere_screen(ctx, &center, radius, keep);
    }

    fn screen_masked(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let (center, radius) = imp2_ball(ctx, step);
        sphere_screen_masked(ctx, &center, radius, keep);
    }
}

/// Improvement 2's ball `B(θ*(λ₀) + ½(1/λ−1/λ₀)y, ½(1/λ−1/λ₀)‖y‖)`.
fn imp2_ball(ctx: &ScreenContext, step: &StepInput) -> (Vec<f64>, f64) {
    let half_d = 0.5 * (1.0 / step.lam - 1.0 / step.lam_prev).max(0.0);
    let center: Vec<f64> = step
        .theta_prev
        .iter()
        .zip(ctx.y.iter())
        .map(|(t, yi)| t + half_d * yi)
        .collect();
    (center, half_d * ctx.y_norm)
}

/// EDPP (Theorem 16 / Corollary 17): ball
/// `B(θ*(λ₀) + ½v₂⊥, ½‖v₂⊥‖)` — the tightest estimate in the family.
pub struct EdppRule;

impl ScreeningRule for EdppRule {
    fn name(&self) -> &'static str {
        "edpp"
    }

    fn is_safe(&self) -> bool {
        true
    }

    fn screen(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let (center, radius) = edpp_ball(ctx, step);
        sphere_screen(ctx, &center, radius, keep);
    }

    fn screen_masked(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let (center, radius) = edpp_ball(ctx, step);
        sphere_screen_masked(ctx, &center, radius, keep);
    }
}

/// EDPP's ball `B(θ*(λ₀) + ½v₂⊥, ½‖v₂⊥‖)` (Corollary 17).
fn edpp_ball(ctx: &ScreenContext, step: &StepInput) -> (Vec<f64>, f64) {
    let a = v1(ctx, step);
    let b = v2(ctx, step);
    let perp = v2_perp(&a, &b);
    let center: Vec<f64> = step
        .theta_prev
        .iter()
        .zip(perp.iter())
        .map(|(t, w)| t + 0.5 * w)
        .collect();
    (center, 0.5 * nrm2(&perp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::screening::dpp::DppRule;
    use crate::screening::testutil::check_rule;
    use crate::screening::theta_from_solution;
    use crate::solver::{cd::CdSolver, LassoSolver, SolveOptions};
    use crate::util::prop;

    fn rejections(
        rule: &dyn ScreeningRule,
        ds: &crate::data::Dataset,
        f_prev: f64,
        f: f64,
    ) -> usize {
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let cols: Vec<usize> = (0..ds.p()).collect();
        let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let prev = CdSolver
            .solve(&ds.x, &ds.y, &cols, f_prev * ctx.lam_max, None, &opts)
            .scatter(&cols, ds.p());
        let theta = theta_from_solution(&ds.x, &ds.y, &prev, f_prev * ctx.lam_max);
        let step = StepInput {
            lam_prev: f_prev * ctx.lam_max,
            lam: f * ctx.lam_max,
            theta_prev: &theta,
        };
        let mut keep = vec![true; ds.p()];
        rule.screen(&ctx, &step, &mut keep);
        keep.iter().filter(|k| !**k).count()
    }

    #[test]
    fn all_rules_safe_randomized() {
        prop::check("EDPP family safety", 0xED1, 10, |rng| {
            let n = 15 + rng.usize(20);
            let p = 20 + rng.usize(50);
            let ds = synthetic::synthetic2(n, p, p / 5 + 1, 0.1, rng.next_u64());
            let ctx = ScreenContext::new(&ds.x, &ds.y);
            let f1 = rng.uniform(0.3, 1.0);
            let f2 = rng.uniform(0.08, f1);
            for rule in [
                &Improvement1Rule as &dyn ScreeningRule,
                &Improvement2Rule,
                &EdppRule,
            ] {
                let chk =
                    check_rule(rule, &ds.x, &ds.y, f1 * ctx.lam_max, f2 * ctx.lam_max);
                assert_eq!(chk.false_discards, 0, "{} unsafe", rule.name());
            }
        });
    }

    /// The ball-containment hierarchy (Theorems 7/13/15): EDPP discards at
    /// least as many features as Improvement 1/2, which discard at least as
    /// many as DPP — on every instance.
    #[test]
    fn dominance_hierarchy() {
        prop::check("EDPP ⊇ Imp1/Imp2 ⊇ DPP rejections", 0xED2, 10, |rng| {
            let n = 15 + rng.usize(20);
            let p = 30 + rng.usize(50);
            let ds = synthetic::synthetic1(n, p, p / 6 + 1, 0.1, rng.next_u64());
            let f_prev = rng.uniform(0.5, 1.0);
            let f = rng.uniform(0.1, f_prev * 0.95);
            let r_dpp = rejections(&DppRule, &ds, f_prev, f);
            let r_i1 = rejections(&Improvement1Rule, &ds, f_prev, f);
            let r_i2 = rejections(&Improvement2Rule, &ds, f_prev, f);
            let r_edpp = rejections(&EdppRule, &ds, f_prev, f);
            assert!(r_i1 >= r_dpp, "imp1 {r_i1} < dpp {r_dpp}");
            assert!(r_i2 >= r_dpp, "imp2 {r_i2} < dpp {r_dpp}");
            assert!(r_edpp >= r_i1, "edpp {r_edpp} < imp1 {r_i1}");
            assert!(r_edpp >= r_i2, "edpp {r_edpp} < imp2 {r_i2}");
        });
    }

    #[test]
    fn edpp_high_rejection_near_prev_lambda() {
        // with an exact θ*(λ₀) and λ close to λ₀, EDPP should reject nearly
        // all inactive features (paper Fig. 1: rejection ≈ 100%)
        let ds = synthetic::synthetic1(50, 300, 15, 0.1, 7);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let chk = check_rule(&EdppRule, &ds.x, &ds.y, 0.5 * ctx.lam_max, 0.45 * ctx.lam_max);
        assert_eq!(chk.false_discards, 0);
        let ratio = chk.discarded as f64 / chk.true_zeros.max(1) as f64;
        assert!(ratio > 0.9, "rejection ratio {ratio}");
    }

    #[test]
    fn edpp_from_lambda_max_uses_xstar_ray() {
        // λ₀ = λmax path must still be safe and strictly better than DPP
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, 8);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let chk_edpp = check_rule(&EdppRule, &ds.x, &ds.y, ctx.lam_max, 0.6 * ctx.lam_max);
        let chk_dpp = check_rule(&DppRule, &ds.x, &ds.y, ctx.lam_max, 0.6 * ctx.lam_max);
        assert_eq!(chk_edpp.false_discards, 0);
        assert!(chk_edpp.discarded >= chk_dpp.discarded);
    }
}
