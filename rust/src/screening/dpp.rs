//! DPP — the fundamental Dual Polytope Projection rule (paper §2.2).
//!
//! Estimation: by nonexpansiveness of the projection onto the dual polytope
//! F (Theorems 1–2), `θ*(λ) ∈ B(θ*(λ₀), (1/λ − 1/λ₀)·‖y‖)` (eq. (12)).
//! Sequential form (Corollary 5): discard i when
//! `|xᵢᵀθ*(λ₀)| < 1 − (1/λ − 1/λ₀)·‖xᵢ‖·‖y‖`; the basic rule (Corollary 4)
//! is the special case λ₀ = λmax, θ*(λmax) = y/λmax.

use super::{sphere_screen, sphere_screen_masked, ScreenContext, ScreeningRule, StepInput};

/// Sequential DPP (Corollary 5). With `lam_prev = λmax` and
/// `theta_prev = y/λmax` it reduces to basic DPP (Corollary 4, Remark 3).
pub struct DppRule;

impl ScreeningRule for DppRule {
    fn name(&self) -> &'static str {
        "dpp"
    }

    fn is_safe(&self) -> bool {
        true
    }

    fn screen(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        debug_assert!(step.lam <= step.lam_prev);
        let radius = (1.0 / step.lam - 1.0 / step.lam_prev).max(0.0) * ctx.y_norm;
        sphere_screen(ctx, step.theta_prev, radius, keep);
    }

    fn screen_masked(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let radius = (1.0 / step.lam - 1.0 / step.lam_prev).max(0.0) * ctx.y_norm;
        sphere_screen_masked(ctx, step.theta_prev, radius, keep);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::screening::testutil::check_rule;
    use crate::screening::theta_at_lambda_max;
    use crate::util::prop;

    #[test]
    fn basic_dpp_matches_corollary4_formula() {
        // screen at λ₀=λmax must equal the Corollary-4 closed form
        let ds = synthetic::synthetic1(25, 60, 6, 0.1, 1);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let theta = theta_at_lambda_max(&ctx);
        let lam = 0.4 * ctx.lam_max;
        let step = StepInput { lam_prev: ctx.lam_max, lam, theta_prev: &theta };
        let mut keep = vec![true; 60];
        DppRule.screen(&ctx, &step, &mut keep);
        for j in 0..60 {
            let lhs = (ctx.xty[j] / ctx.lam_max).abs();
            let rhs =
                1.0 - (1.0 / lam - 1.0 / ctx.lam_max) * ctx.col_norms[j] * ctx.y_norm;
            assert_eq!(keep[j], lhs >= rhs, "feature {j}");
        }
    }

    #[test]
    fn dpp_is_safe_randomized() {
        // the paper's central claim: no active feature is ever discarded
        prop::check("DPP safety", 0xD99, 12, |rng| {
            let n = 15 + rng.usize(25);
            let p = 20 + rng.usize(60);
            let ds = synthetic::synthetic2(n, p, p / 5 + 1, 0.1, rng.next_u64());
            let ctx = ScreenContext::new(&ds.x, &ds.y);
            let f1 = rng.uniform(0.3, 1.0);
            let f2 = rng.uniform(0.1, f1);
            let chk =
                check_rule(&DppRule, &ds.x, &ds.y, f1 * ctx.lam_max, f2 * ctx.lam_max);
            assert_eq!(chk.false_discards, 0, "unsafe discard");
        });
    }

    #[test]
    fn rejects_everything_just_below_lambda_max() {
        // for λ→λmax⁻ the radius →0 and all strictly-inactive features with
        // |xᵢᵀy|/λmax < 1 are discarded
        let ds = synthetic::synthetic1(20, 50, 5, 0.1, 2);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let theta = theta_at_lambda_max(&ctx);
        let lam = 0.999999 * ctx.lam_max;
        let step = StepInput { lam_prev: ctx.lam_max, lam, theta_prev: &theta };
        let mut keep = vec![true; 50];
        DppRule.screen(&ctx, &step, &mut keep);
        let kept = keep.iter().filter(|k| **k).count();
        assert!(kept <= 3, "kept {kept} features at λ≈λmax");
    }

    #[test]
    fn smaller_lambda_discards_fewer() {
        // radius grows as λ decreases ⇒ rejection count shrinks
        let ds = synthetic::synthetic1(20, 80, 8, 0.1, 3);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let theta = theta_at_lambda_max(&ctx);
        let count = |frac: f64| {
            let step = StepInput {
                lam_prev: ctx.lam_max,
                lam: frac * ctx.lam_max,
                theta_prev: &theta,
            };
            let mut keep = vec![true; 80];
            DppRule.screen(&ctx, &step, &mut keep);
            keep.iter().filter(|k| !**k).count()
        };
        assert!(count(0.9) >= count(0.5));
        assert!(count(0.5) >= count(0.1));
    }
}
