//! Strong rule for group Lasso (Tibshirani et al. [32], §4.2 baseline):
//! discard group g when `‖X_gᵀ(y − Xβ*(λ₀))‖₂ < √n_g·(2λ − λ₀)`. Heuristic —
//! requires KKT verification (eq. (53)): a discarded group violates when
//! `‖X_gᵀr‖ > λ√n_g`.

use super::group_edpp::{GroupScreenContext, GroupScreeningRule, GroupStepInput};

/// Sequential group strong rule (heuristic).
pub struct GroupStrongRule;

impl GroupScreeningRule for GroupStrongRule {
    fn name(&self) -> &'static str {
        "group-strong"
    }

    fn is_safe(&self) -> bool {
        false
    }

    fn screen(&self, ctx: &GroupScreenContext, step: &GroupStepInput, keep: &mut [bool]) {
        assert_eq!(keep.len(), ctx.n_groups());
        let thr = 2.0 * step.lam - step.lam_prev;
        if thr <= 0.0 {
            keep.iter_mut().for_each(|k| *k = true);
            return;
        }
        // r(λ₀) = λ₀·θ*(λ₀)
        let r: Vec<f64> = step.theta_prev.iter().map(|t| t * step.lam_prev).collect();
        for g in 0..ctx.n_groups() {
            let (_, len) = ctx.groups[g];
            keep[g] = ctx.group_corr_norm(g, &r) >= (len as f64).sqrt() * thr;
        }
    }
}

/// Group KKT check: violated discarded groups given the reduced-solve
/// residual `r = y − Xβ` at λ.
pub fn group_kkt_violations(
    ctx: &GroupScreenContext,
    r: &[f64],
    lam: f64,
    keep: &[bool],
) -> Vec<usize> {
    (0..ctx.n_groups())
        .filter(|&g| {
            if keep[g] {
                return false;
            }
            let (_, len) = ctx.groups[g];
            ctx.group_corr_norm(g, r) > lam * (len as f64).sqrt() * (1.0 + 1e-7)
        })
        .collect()
}

/// The group working-set loop's shared sweep: one pass over every group
/// computing the ellipsoid ratio `‖X_gᵀr‖/√n_g` — complement violators
/// (ratio > λ, the *certification* threshold, no repair slack) sorted
/// worst-first for the doubling expansion batches, plus the global max
/// ratio that prices the full-problem group dual scale
/// ([`crate::solver::dual::duality_gap_from_parts`]). One O(nnz) sweep per
/// outer round instead of separate violation/scale/gap passes.
pub fn group_kkt_sweep_scored(
    ctx: &GroupScreenContext,
    r: &[f64],
    lam: f64,
    in_set: &[bool],
) -> (Vec<(usize, f64)>, f64) {
    debug_assert_eq!(in_set.len(), ctx.n_groups());
    let mut viol: Vec<(usize, f64)> = Vec::new();
    let mut max_ratio = 0.0f64;
    for g in 0..ctx.n_groups() {
        let (_, len) = ctx.groups[g];
        let ratio = ctx.group_corr_norm(g, r) / (len as f64).sqrt();
        max_ratio = max_ratio.max(ratio);
        if !in_set[g] && ratio > lam {
            viol.push((g, ratio));
        }
    }
    // worst first; stable sort keeps ties deterministic (ascending group id)
    viol.sort_by(|a, b| b.1.total_cmp(&a.1));
    (viol, max_ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::screening::group_edpp::testutil::check_group_rule;
    use crate::solver::{group::GroupBcdSolver, SolveOptions};

    #[test]
    fn screen_matches_closed_form_at_lambda_max() {
        let ds = synthetic::group_synthetic(25, 60, 12, 1);
        let groups = ds.groups.clone().unwrap();
        let ctx = GroupScreenContext::new(&ds.x, &ds.y, &groups);
        let theta: Vec<f64> = ds.y.iter().map(|v| v / ctx.lam_max).collect();
        let lam = 0.8 * ctx.lam_max;
        let step =
            GroupStepInput { lam_prev: ctx.lam_max, lam, theta_prev: &theta };
        let mut keep = vec![true; 12];
        GroupStrongRule.screen(&ctx, &step, &mut keep);
        for (g, &(_, len)) in groups.iter().enumerate() {
            let lhs = ctx.group_corr_norm(g, &ds.y);
            let rhs = (len as f64).sqrt() * (2.0 * lam - ctx.lam_max);
            assert_eq!(keep[g], lhs >= rhs, "group {g}");
        }
    }

    #[test]
    fn usually_correct_on_gaussian_data() {
        // heuristic, but on iid gaussian data with exact prev solutions it
        // should rarely violate; verify the checker catches any violations
        let ds = synthetic::group_synthetic(30, 200, 50, 2);
        let groups = ds.groups.clone().unwrap();
        let ctx = GroupScreenContext::new(&ds.x, &ds.y, &groups);
        let (discarded, false_discards, _) = check_group_rule(
            &GroupStrongRule,
            &ds.x,
            &ds.y,
            &groups,
            0.6 * ctx.lam_max,
            0.5 * ctx.lam_max,
        );
        assert!(discarded > 0);
        // false discards possible in principle; must be *detectable*
        if false_discards > 0 {
            // reproduce the screen and ensure group_kkt_violations flags them
            let active: Vec<usize> = (0..groups.len()).collect();
            let opts = SolveOptions { tol_gap: 1e-11, ..Default::default() };
            let exact = GroupBcdSolver.solve(
                &ds.x,
                &ds.y,
                &groups,
                &active,
                0.5 * ctx.lam_max,
                None,
                &opts,
            );
            let full = exact.scatter(&groups, &active, ds.p());
            let mut r = ds.y.clone();
            for (j, b) in full.iter().enumerate() {
                if *b != 0.0 {
                    crate::linalg::axpy(-b, ds.x.dense().unwrap().col(j), &mut r);
                }
            }
            // with keep = all-false on truly-active groups, violations appear
            let keep = vec![false; groups.len()];
            let viol = group_kkt_violations(&ctx, &r, 0.5 * ctx.lam_max, &keep);
            assert!(!viol.is_empty());
        }
    }

    #[test]
    fn vacuous_below_half_lambda() {
        let ds = synthetic::group_synthetic(20, 40, 8, 3);
        let groups = ds.groups.clone().unwrap();
        let ctx = GroupScreenContext::new(&ds.x, &ds.y, &groups);
        let theta: Vec<f64> = ds.y.iter().map(|v| v / ctx.lam_max).collect();
        let step = GroupStepInput {
            lam_prev: ctx.lam_max,
            lam: 0.3 * ctx.lam_max,
            theta_prev: &theta,
        };
        let mut keep = vec![false; 8];
        GroupStrongRule.screen(&ctx, &step, &mut keep);
        assert!(keep.iter().all(|k| *k));
    }
}
