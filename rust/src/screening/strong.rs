//! Strong rules (Tibshirani et al. [32]) — the heuristic state of the art
//! the paper benchmarks against.
//!
//! Sequential form: discard i when `|xᵢᵀ(y − Xβ*(λ₀))| < 2λ − λ₀`, i.e.
//! `|xᵢᵀθ*(λ₀)|·λ₀ < 2λ − λ₀`. Rests on a unit-slope nonexpansiveness
//! assumption on λ ↦ xᵢᵀ(y−Xβ*(λ)) that can fail, so discards must be
//! verified against the KKT conditions and repaired
//! ([`crate::path`] implements the violation loop, as [32] prescribes).
//! Basic form: λ₀ = λmax, test `|xᵢᵀy| < 2λ − λmax`.

use super::{ScreenContext, ScreeningRule, StepInput};

/// Sequential strong rule (heuristic).
pub struct StrongRule;

impl ScreeningRule for StrongRule {
    fn name(&self) -> &'static str {
        "strong"
    }

    fn is_safe(&self) -> bool {
        false
    }

    fn screen(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let p = ctx.p();
        let thr = 2.0 * step.lam - step.lam_prev;
        if thr <= 0.0 {
            // rule is vacuous (keeps everything) when λ < λ₀/2
            keep.iter_mut().for_each(|k| *k = true);
            return;
        }
        // c(λ₀) = Xᵀ(y − Xβ*(λ₀)) = λ₀·Xᵀθ*(λ₀)
        let mut corr = ctx.sweep_scratch();
        ctx.sweep.xt_w(step.theta_prev, &mut corr[..]);
        for j in 0..p {
            keep[j] = (corr[j] * step.lam_prev).abs() >= thr;
        }
    }

    fn screen_masked(&self, ctx: &ScreenContext, step: &StepInput, keep: &mut [bool]) {
        let thr = 2.0 * step.lam - step.lam_prev;
        if thr <= 0.0 {
            // vacuous: clears nothing (masked contract — never set bits)
            return;
        }
        let cols: Vec<usize> = (0..ctx.p()).filter(|&j| keep[j]).collect();
        let mut corr = vec![0.0; cols.len()];
        ctx.sweep.xt_w_subset(&cols, step.theta_prev, &mut corr);
        for (k, &j) in cols.iter().enumerate() {
            keep[j] = (corr[k] * step.lam_prev).abs() >= thr;
        }
    }
}

/// KKT verification for heuristic rules: given the residual `r = y − Xβ` of
/// the *reduced* solve at λ, any discarded feature with `|xⱼᵀr| > λ` is a
/// violation and must be added back. Returns the violating indices.
pub fn kkt_violations(
    ctx: &ScreenContext,
    r: &[f64],
    lam: f64,
    keep: &[bool],
) -> Vec<usize> {
    let p = ctx.p();
    let mut corr = ctx.sweep_scratch();
    ctx.sweep.xt_w(r, &mut corr[..]);
    // small relative slack so solver tolerance doesn't trigger spurious adds
    let tol = lam * (1.0 + 1e-7);
    (0..p).filter(|&j| !keep[j] && corr[j].abs() > tol).collect()
}

/// Like [`kkt_violations`] but restricted to `candidates` — the hybrid
/// pipeline's *uncertified* discards. Sweeps only the candidate columns
/// (one `xt_w_subset` over the residual set) instead of all p, which is the
/// point of safe certification: the repair check shrinks with the
/// certifier's coverage. The X_jᵀr products land in the context's reusable
/// sweep scratch — repair rounds pay no per-call allocation.
pub fn kkt_violations_in(
    ctx: &ScreenContext,
    r: &[f64],
    lam: f64,
    keep: &[bool],
    candidates: &[bool],
) -> Vec<usize> {
    let p = ctx.p();
    debug_assert_eq!(candidates.len(), p);
    let cand: Vec<usize> = (0..p).filter(|&j| !keep[j] && candidates[j]).collect();
    if cand.is_empty() {
        return Vec::new();
    }
    let mut corr = ctx.sweep_scratch();
    ctx.sweep.xt_w_subset(&cand, r, &mut corr[..cand.len()]);
    let tol = lam * (1.0 + 1e-7);
    let mut viol = Vec::new();
    for (k, &j) in cand.iter().enumerate() {
        if corr[k].abs() > tol {
            viol.push(j);
        }
    }
    viol
}

/// The working-set outer loop's shared sweep: **one** full `Xᵀr` pass (into
/// the context's scratch buffer) that yields everything the loop needs per
/// round — the complement KKT violators with their scores (worst-first, for
/// the doubling expansion batches), and the global ‖Xᵀr‖∞ that prices the
/// full-problem dual scale. Violation here is the *certification* threshold
/// `|xⱼᵀr| > λ` (no repair slack): a clean complement plus a tight
/// restricted solve makes β full-problem optimal, so near-boundary
/// coordinates are admitted rather than left to stall the gap.
pub fn kkt_sweep_scored(
    ctx: &ScreenContext,
    r: &[f64],
    lam: f64,
    in_set: &[bool],
) -> (Vec<(usize, f64)>, f64) {
    let p = ctx.p();
    debug_assert_eq!(in_set.len(), p);
    let mut viol: Vec<(usize, f64)> = Vec::new();
    let mut xtr_inf = 0.0f64;
    {
        let mut corr = ctx.sweep_scratch();
        ctx.sweep.xt_w(r, &mut corr[..]);
        for (j, c) in corr.iter().enumerate().take(p) {
            let a = c.abs();
            xtr_inf = xtr_inf.max(a);
            if !in_set[j] && a > lam {
                viol.push((j, a));
            }
        }
    }
    // worst violators first; stable sort keeps ties in ascending-index
    // order, so expansion batches are deterministic
    viol.sort_by(|a, b| b.1.total_cmp(&a.1));
    (viol, xtr_inf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::screening::testutil::check_rule;
    use crate::screening::{theta_at_lambda_max, theta_from_solution};
    use crate::solver::{cd::CdSolver, LassoSolver, SolveOptions};
    use crate::util::prop;

    #[test]
    fn basic_strong_matches_closed_form() {
        let ds = synthetic::synthetic1(20, 60, 6, 0.1, 1);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let theta = theta_at_lambda_max(&ctx);
        let lam = 0.7 * ctx.lam_max;
        let step = StepInput { lam_prev: ctx.lam_max, lam, theta_prev: &theta };
        let mut keep = vec![true; 60];
        StrongRule.screen(&ctx, &step, &mut keep);
        for j in 0..60 {
            assert_eq!(keep[j], ctx.xty[j].abs() >= 2.0 * lam - ctx.lam_max, "feature {j}");
        }
    }

    #[test]
    fn vacuous_when_lambda_below_half() {
        let ds = synthetic::synthetic1(20, 40, 4, 0.1, 2);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let theta = theta_at_lambda_max(&ctx);
        let step = StepInput {
            lam_prev: ctx.lam_max,
            lam: 0.4 * ctx.lam_max,
            theta_prev: &theta,
        };
        let mut keep = vec![false; 40];
        StrongRule.screen(&ctx, &step, &mut keep);
        assert!(keep.iter().all(|k| *k));
    }

    #[test]
    fn strong_rule_discards_aggressively() {
        // strong typically rejects ≥ as many as safe rules — that is its
        // selling point; verify it is competitive with EDPP on a random case
        let ds = synthetic::synthetic1(40, 200, 12, 0.1, 3);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let chk = check_rule(&StrongRule, &ds.x, &ds.y, 0.5 * ctx.lam_max, 0.45 * ctx.lam_max);
        let ratio = chk.discarded as f64 / chk.true_zeros.max(1) as f64;
        assert!(ratio > 0.8, "strong rejection ratio {ratio}");
    }

    #[test]
    fn kkt_violation_detection_and_injection() {
        // inject a fake violation: discard the strongest feature, solve the
        // reduced problem, and verify the checker flags it
        let ds = synthetic::synthetic1(30, 80, 8, 0.1, 4);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let lam = 0.2 * ctx.lam_max;
        let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let cols: Vec<usize> = (0..80).collect();
        let full = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts).scatter(&cols, 80);
        // the feature with the largest |β| is certainly active
        let strongest = (0..80)
            .max_by(|&a, &b| full[a].abs().total_cmp(&full[b].abs()))
            .unwrap();
        assert!(full[strongest] != 0.0);
        let mut keep = vec![true; 80];
        keep[strongest] = false;
        let reduced: Vec<usize> = (0..80).filter(|&j| keep[j]).collect();
        let res = CdSolver.solve(&ds.x, &ds.y, &reduced, lam, None, &opts);
        let beta_red = res.scatter(&reduced, 80);
        let mut r = ds.y.clone();
        for j in 0..80 {
            if beta_red[j] != 0.0 {
                crate::linalg::axpy(-beta_red[j], ds.x.dense().unwrap().col(j), &mut r);
            }
        }
        let viol = kkt_violations(&ctx, &r, lam, &keep);
        assert!(viol.contains(&strongest), "violation not detected: {viol:?}");
    }

    #[test]
    fn no_violations_when_nothing_discarded() {
        prop::check("KKT checker silent on exact solves", 0x57A, 8, |rng| {
            let ds = synthetic::synthetic1(20, 50, 5, 0.1, rng.next_u64());
            let ctx = ScreenContext::new(&ds.x, &ds.y);
            let lam = rng.uniform(0.2, 0.8) * ctx.lam_max;
            let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
            let cols: Vec<usize> = (0..50).collect();
            let res = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts);
            let beta = res.scatter(&cols, 50);
            let theta = theta_from_solution(&ds.x, &ds.y, &beta, lam);
            let r: Vec<f64> = theta.iter().map(|t| t * lam).collect();
            let keep = vec![true; 50];
            assert!(kkt_violations(&ctx, &r, lam, &keep).is_empty());
        });
    }
}
