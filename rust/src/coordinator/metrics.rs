//! Service metrics: request latency, batch sizes, screening effectiveness.

use crate::util::stats::OnlineStats;

/// Aggregated metrics for the screening service.
#[derive(Debug, Default, Clone)]
pub struct ServiceMetrics {
    pub requests: u64,
    pub batches: u64,
    pub latency: OnlineStats,
    pub batch_size: OnlineStats,
    pub rejection_ratio: OnlineStats,
    pub kept_features: OnlineStats,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency_s: f64) {
        self.requests += 1;
        self.latency.push(latency_s);
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_size.push(size as f64);
    }

    pub fn record_screen(&mut self, kept: usize, discarded: usize, true_zeros: usize) {
        self.kept_features.push(kept as f64);
        let ratio = if true_zeros == 0 {
            1.0
        } else {
            discarded as f64 / true_zeros as f64
        };
        self.rejection_ratio.push(ratio);
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.1} p50_latency≈{:.2}ms mean_rejection={:.3} mean_kept={:.0}",
            self.requests,
            self.batches,
            self.batch_size.mean(),
            self.latency.mean() * 1e3,
            self.rejection_ratio.mean(),
            self.kept_features.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = ServiceMetrics::new();
        m.record_request(0.010);
        m.record_request(0.020);
        m.record_batch(2);
        m.record_screen(10, 90, 95);
        assert_eq!(m.requests, 2);
        assert_eq!(m.batches, 1);
        assert!((m.latency.mean() - 0.015).abs() < 1e-12);
        assert!((m.rejection_ratio.mean() - 90.0 / 95.0).abs() < 1e-12);
        assert!(m.summary().contains("requests=2"));
    }

    #[test]
    fn zero_true_zeros_counts_as_full_rejection() {
        let mut m = ServiceMetrics::new();
        m.record_screen(5, 0, 0);
        assert_eq!(m.rejection_ratio.mean(), 1.0);
    }
}
