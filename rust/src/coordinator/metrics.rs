//! Service metrics: request latency, batch sizes, screening effectiveness,
//! deadline outcomes.

use crate::util::stats::{quantile, OnlineStats};

/// Latency samples kept for percentile reporting (`dpp bench-serve`,
/// [`ServiceMetrics::latency_quantile`]). Beyond the cap only the streaming
/// moments keep updating — serving benchmarks stay allocation-bounded.
const LATENCY_SAMPLE_CAP: usize = 4096;

/// Coordinator-wide admission counters (one per [`Coordinator`], not per
/// session): how much load arrived, how much the admission policy shed with
/// [`crate::coordinator::RequestError::Overloaded`], and how many sessions
/// the TTL sweep evicted.
///
/// [`Coordinator`]: crate::coordinator::Coordinator
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Requests that reached the admission gate (admitted + shed).
    pub submitted: u64,
    /// Requests (or registrations) refused with `Overloaded`.
    pub shed: u64,
    /// Sessions closed by the idle-TTL sweep.
    pub evicted: u64,
}

impl AdmissionStats {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} shed={} evicted_sessions={}",
            self.submitted, self.shed, self.evicted
        )
    }
}

/// Aggregated metrics for one screening session.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct ServiceMetrics {
    pub requests: u64,
    pub batches: u64,
    pub latency: OnlineStats,
    pub batch_size: OnlineStats,
    pub rejection_ratio: OnlineStats,
    pub kept_features: OnlineStats,
    /// Deadline-bounded requests answered with a partial (gap-tagged)
    /// result instead of an exact solution.
    pub partials: u64,
    /// First [`LATENCY_SAMPLE_CAP`] request latencies, for percentiles.
    latency_samples: Vec<f64>,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency_s: f64) {
        self.requests += 1;
        self.latency.push(latency_s);
        if self.latency_samples.len() < LATENCY_SAMPLE_CAP {
            self.latency_samples.push(latency_s);
        }
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_size.push(size as f64);
    }

    pub fn record_screen(&mut self, kept: usize, discarded: usize, true_zeros: usize) {
        self.kept_features.push(kept as f64);
        let ratio = if true_zeros == 0 {
            1.0
        } else {
            discarded as f64 / true_zeros as f64
        };
        self.rejection_ratio.push(ratio);
    }

    /// A deadline stopped a solve early (the response was gap-tagged).
    pub fn record_partial(&mut self) {
        self.partials += 1;
    }

    /// q-th latency quantile (seconds) over the retained samples, q ∈ [0,1].
    pub fn latency_quantile(&self, q: f64) -> f64 {
        quantile(&self.latency_samples, q)
    }

    /// Retained latency samples (first [`LATENCY_SAMPLE_CAP`] requests) —
    /// exposed so the wire codec can carry metrics across a socket intact.
    pub fn latency_samples(&self) -> &[f64] {
        &self.latency_samples
    }

    /// Rebuild metrics from transported parts (inverse of field access +
    /// [`ServiceMetrics::latency_samples`]). Samples beyond the cap are
    /// dropped, matching what a local recorder would have kept.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        requests: u64,
        batches: u64,
        latency: OnlineStats,
        batch_size: OnlineStats,
        rejection_ratio: OnlineStats,
        kept_features: OnlineStats,
        partials: u64,
        mut latency_samples: Vec<f64>,
    ) -> Self {
        latency_samples.truncate(LATENCY_SAMPLE_CAP);
        ServiceMetrics {
            requests,
            batches,
            latency,
            batch_size,
            rejection_ratio,
            kept_features,
            partials,
            latency_samples,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.1} p50_latency≈{:.2}ms p95≈{:.2}ms \
             partials={} mean_rejection={:.3} mean_kept={:.0}",
            self.requests,
            self.batches,
            self.batch_size.mean(),
            self.latency_quantile(0.5) * 1e3,
            self.latency_quantile(0.95) * 1e3,
            self.partials,
            self.rejection_ratio.mean(),
            self.kept_features.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = ServiceMetrics::new();
        m.record_request(0.010);
        m.record_request(0.020);
        m.record_batch(2);
        m.record_screen(10, 90, 95);
        assert_eq!(m.requests, 2);
        assert_eq!(m.batches, 1);
        assert!((m.latency.mean() - 0.015).abs() < 1e-12);
        assert!((m.rejection_ratio.mean() - 90.0 / 95.0).abs() < 1e-12);
        assert!(m.summary().contains("requests=2"));
    }

    #[test]
    fn zero_true_zeros_counts_as_full_rejection() {
        let mut m = ServiceMetrics::new();
        m.record_screen(5, 0, 0);
        assert_eq!(m.rejection_ratio.mean(), 1.0);
    }

    #[test]
    fn latency_quantiles_and_partials() {
        let mut m = ServiceMetrics::new();
        for i in 1..=100 {
            m.record_request(i as f64 * 1e-3);
        }
        m.record_partial();
        assert_eq!(m.partials, 1);
        let p50 = m.latency_quantile(0.5);
        assert!((p50 - 0.0505).abs() < 1e-9, "p50 = {p50}");
        assert!(m.latency_quantile(0.99) > p50);
        assert!(m.summary().contains("partials=1"));
    }
}
