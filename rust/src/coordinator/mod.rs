//! L3 coordinator: the system layer that turns the path driver into a
//! deployable multi-tenant serving system (DESIGN.md §4).
//!
//! Layer map:
//!
//! * [`protocol`] — the typed [`Request`]/[`Response`] grammar (Screen,
//!   FitPath, Predict, Warm, SessionStats) with per-request options
//!   (deadline, pipeline override, solver tolerance) and typed
//!   [`RequestError`]s;
//! * [`registry`] — [`SessionRegistry`]: one coordinator owns many named
//!   sessions, each with its own backend, screening pipeline, sequential
//!   anchor and warm-start cache;
//! * [`service`] — the [`Coordinator`] router (per-session batches executed
//!   concurrently on the shared [`crate::runtime::pool`], single-owner
//!   state per session) and the legacy single-session
//!   [`service::ScreeningService`] facade;
//! * [`admission`] — the load-shedding and session-TTL policy
//!   ([`AdmissionConfig`]/[`AdmissionController`]): queue-depth caps answer
//!   with typed [`RequestError::Overloaded`] instead of queueing
//!   unboundedly, idle sessions are evicted;
//! * [`metrics`] — per-session latency/batching/rejection/partial metrics,
//!   plus coordinator-wide [`AdmissionStats`].
//!
//! The paper's protocol also averages 100 trials per dataset and sweeps
//! many (rule × dataset × λ-grid) combinations; [`run_trials`] fans trials
//! out over worker threads (std::thread + mpsc — tokio is not available in
//! the offline image, DESIGN.md §6).

pub mod admission;
pub mod metrics;
pub mod protocol;
pub mod registry;
pub mod service;

pub use admission::{AdmissionConfig, AdmissionController};
pub use metrics::{AdmissionStats, ServiceMetrics};
pub use protocol::{
    PathSummary, Prediction, Request, RequestError, RequestOptions, Response,
    ScreenResponse, SessionStats, WarmResponse,
};
pub use registry::{SessionRegistry, SessionSpec};
pub use service::{Coordinator, PendingResponse, ScreeningService, SERVICE_SESSION};

use std::sync::mpsc;
use std::thread;

/// Fan `n_trials` evaluations of `job` over `workers` threads and collect
/// results in trial order. `job` receives the trial index and must be
/// deterministic per index (seeding discipline lives with the caller).
pub fn run_trials<T, F>(n_trials: usize, workers: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers >= 1);
    if n_trials == 0 {
        return Vec::new();
    }
    let workers = workers.min(n_trials);
    let (task_tx, task_rx) = mpsc::channel::<usize>();
    let task_rx = std::sync::Mutex::new(task_rx);
    let (res_tx, res_rx) = mpsc::channel::<(usize, T)>();
    for t in 0..n_trials {
        // audit:allow(panic, receiver is alive in this scope; send cannot fail)
        task_tx.send(t).unwrap();
    }
    drop(task_tx);

    let mut out: Vec<Option<T>> = (0..n_trials).map(|_| None).collect();
    thread::scope(|scope| {
        for _ in 0..workers {
            let res_tx = res_tx.clone();
            let task_rx = &task_rx;
            let job = &job;
            scope.spawn(move || {
                loop {
                    let next =
                        { task_rx.lock().unwrap_or_else(|e| e.into_inner()).recv() };
                    match next {
                        Ok(idx) => {
                            let r = job(idx);
                            if res_tx.send((idx, r)).is_err() {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
            });
        }
        drop(res_tx);
        while let Ok((idx, r)) = res_rx.recv() {
            out[idx] = Some(r);
        }
    });
    // audit:allow(panic, a missing trial is a harness bug, not a request error)
    out.into_iter().map(|o| o.expect("worker dropped a trial")).collect()
}

/// Number of worker threads to use (`DPP_WORKERS`, default = available
/// parallelism).
pub fn default_workers() -> usize {
    std::env::var("DPP_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn trials_in_order_and_complete() {
        let out = run_trials(17, 4, |i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_trials_run_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = run_trials(25, 3, |i| {
            counter.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(counter.load(Ordering::SeqCst), 25);
        assert_eq!(out.len(), 25);
    }

    #[test]
    fn zero_trials() {
        let out: Vec<usize> = run_trials(0, 2, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_matches_multi() {
        let a = run_trials(10, 1, |i| i + 1);
        let b = run_trials(10, 4, |i| i + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }
}
