//! Session registry: one [`super::Coordinator`] owns many named sessions,
//! each a self-contained serving unit — its own boxed
//! [`DesignMatrix`] backend, screening pipeline, sequential anchor and
//! warm-start cache (DESIGN.md §4).
//!
//! Single-owner discipline: a session's state is only ever touched by the
//! one pool job processing that session's batch (the router creates at most
//! one job per session per tick), so the sequential θ*(λ₀) propagation and
//! warm starts evolve exactly as in the old single-session worker thread —
//! per-session responses are **bit-identical** to an isolated
//! [`super::service::ScreeningService`] replaying the same requests
//! (pinned in `tests/serve_protocol.rs`).
//!
//! Failure discipline: a panic while processing one request marks the
//! session dead with the panic payload as the reason; the remaining batch
//! and every later request get a typed
//! [`RequestError::SessionClosed`] instead of a hung channel.

// audit:allow(determinism:hash-iter, lookup-only; iteration uses the registration-order Vec)
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::metrics::ServiceMetrics;
use super::protocol::{
    PathSummary, PendingRequest, Prediction, Request, RequestError, RequestOptions,
    Response, ScreenResponse, SessionStats, WarmResponse,
};
use crate::linalg::DesignMatrix;
use crate::path::{
    solve_path_pipeline, solve_path_with_screener_warm, LambdaGrid, PathConfig,
    PathStrategy, SolverKind,
};
use crate::runtime::pool::panic_message;
use crate::screening::{
    pipeline::merge_kkt_candidates, strong::kkt_violations, strong::kkt_violations_in,
    ContextStats, GapSafeHook, ScreenContext, ScreenPipeline, Screener,
};
use crate::solver::{
    working_set::{solve_working_set, WorkingSetState},
    LassoSolver, SolverHook, SolverState,
};

/// Everything needed to open a session: the dataset, how to screen it, how
/// to solve it.
pub struct SessionSpec {
    pub name: String,
    pub x: Box<dyn DesignMatrix + Send>,
    pub y: Vec<f64>,
    /// Human-readable backend label for stats/logs (`csc`, `sharded`, …).
    pub backend: String,
    pub pipeline: ScreenPipeline,
    pub solver: SolverKind,
    pub cfg: PathConfig,
}

impl SessionSpec {
    /// Spec over any owned backend. The pipeline accepts whatever
    /// [`crate::coordinator::service::ScreeningService::spawn`] accepts —
    /// a bare [`crate::path::RuleKind`] converts implicitly.
    pub fn new<M: DesignMatrix + Send + 'static>(
        name: impl Into<String>,
        x: M,
        y: Vec<f64>,
        pipeline: impl Into<ScreenPipeline>,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> SessionSpec {
        Self::boxed(name, Box::new(x), y, pipeline, solver, cfg)
    }

    /// Spec from an already-boxed backend (the CLI picks the backend at
    /// runtime and hands the box over).
    pub fn boxed(
        name: impl Into<String>,
        x: Box<dyn DesignMatrix + Send>,
        y: Vec<f64>,
        pipeline: impl Into<ScreenPipeline>,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> SessionSpec {
        SessionSpec {
            name: name.into(),
            x,
            y,
            backend: "unspecified".to_string(),
            pipeline: pipeline.into(),
            solver,
            cfg,
        }
    }

    /// Attach a backend label (shows up in [`SessionStats`]).
    pub fn with_backend_label(mut self, label: impl Into<String>) -> SessionSpec {
        self.backend = label.into();
        self
    }
}

/// Live state of one session. Field layout mirrors the old single-session
/// worker's stack frame; `ContextStats` replaces the worker's one-shot
/// `ScreenContext` so a borrowing context can be rebuilt per batch without
/// re-paying the O(nnz) sweeps.
pub(crate) struct SessionState {
    name: String,
    backend: String,
    x: Box<dyn DesignMatrix + Send>,
    y: Vec<f64>,
    pipeline: ScreenPipeline,
    solver: SolverKind,
    cfg: PathConfig,
    stats: ContextStats,
    /// The session's long-lived pipeline; its anchor is the exact solution
    /// at the smallest λ solved so far.
    screener: Box<dyn Screener>,
    /// Deepest λ with an exact solution (warm-start tracker; stays monotone
    /// even for pipelines whose anchor never advances).
    lam_state: f64,
    /// Full-length solution at `lam_state`.
    beta_state: Vec<f64>,
    /// Solver resume state recorded by the most recent solve that ran the
    /// *session's* solver (FISTA momentum etc.). Solver-tagged: a
    /// per-request solver override threads a throwaway state instead, so it
    /// can neither replay nor clobber another solver's momentum.
    solver_state: SolverState,
    /// The session's working-set warm start ([`PathStrategy::WorkingSet`]
    /// only): the union of every active set solved so far plus the last
    /// certified β. Repeat Screen/FitPath requests seed from it and certify
    /// in one complement sweep per λ — O(active set), not O(p).
    ws_state: WorkingSetState,
    pub(crate) metrics: ServiceMetrics,
    /// Panic reason once a request poisoned the session.
    dead: Option<String>,
}

impl SessionState {
    fn new(spec: SessionSpec) -> Result<SessionState, RequestError> {
        let SessionSpec { name, x, y, backend, pipeline, solver, cfg } = spec;
        if y.len() != x.n_rows() {
            return Err(RequestError::InvalidRequest(format!(
                "session `{name}`: y has {} entries, matrix has {} rows",
                y.len(),
                x.n_rows()
            )));
        }
        if y.iter().any(|v| !v.is_finite()) {
            return Err(RequestError::InvalidRequest(format!(
                "session `{name}`: y contains a non-finite entry"
            )));
        }
        let x_dyn: &dyn DesignMatrix = &*x;
        let stats = ContextStats::compute(x_dyn, &y);
        let mut screener = pipeline.build(x.n_rows(), cfg.sequential);
        {
            let ctx = stats.context(x_dyn, &y, cfg.safety_slack);
            screener.init(&ctx);
        }
        let p = x.n_cols();
        let lam_state = stats.lam_max;
        Ok(SessionState {
            name,
            backend,
            x,
            y,
            pipeline,
            solver,
            cfg,
            stats,
            screener,
            lam_state,
            beta_state: vec![0.0; p],
            solver_state: SolverState::None,
            ws_state: WorkingSetState::default(),
            metrics: ServiceMetrics::new(),
            dead: None,
        })
    }

    /// Process one tick's batch for this session: λ-descending order for
    /// the λ-carrying requests (the old service's batching trick — larger λ
    /// solved first tightens θ for the rest), stats/paths after, in arrival
    /// order. The borrowing [`ScreenContext`] is rebuilt once per *batch*
    /// (its two O(p) statistic copies amortize over the batch), and a panic
    /// in one request poisons the session, not the process.
    pub(crate) fn process_batch(&mut self, mut batch: Vec<PendingRequest>) {
        if batch.is_empty() {
            return;
        }
        self.metrics.record_batch(batch.len());
        // the cached O(nnz) statistics must still describe the live backend
        // (shape + data_version stamp): serving sweeps of data that no
        // longer exists would be silently wrong, so a stale session dies
        // with a typed reason instead
        if self.dead.is_none() && !self.stats.is_valid(&*self.x) {
            self.dead = Some(
                "stale context statistics: backend data_version changed after \
                 ContextStats::compute"
                    .to_string(),
            );
        }
        // total_cmp never panics; NaN λ is rejected at the API boundary and
        // cannot reach this sort (the old loop's partial_cmp().unwrap() bug)
        batch.sort_by(|a, b| b.request.sort_lam().total_cmp(&a.request.sort_lam()));
        // split-borrow the session: the context borrows x/y, everything
        // mutable travels in the core
        let SessionState {
            name,
            backend,
            x,
            y,
            pipeline,
            solver,
            cfg,
            stats,
            screener,
            lam_state,
            beta_state,
            solver_state,
            ws_state,
            metrics,
            dead,
        } = self;
        let x: &dyn DesignMatrix = &**x;
        let ctx = stats.context(x, y, cfg.safety_slack);
        let mut core = SessionCore {
            name: name.as_str(),
            backend: backend.as_str(),
            ctx,
            pipeline,
            solver: *solver,
            cfg,
            screener,
            lam_state,
            beta_state,
            solver_state,
            ws_state,
            metrics,
        };
        for PendingRequest { request, reply, t0 } in batch {
            let resp = if let Some(reason) = dead.clone() {
                Response::Error(RequestError::SessionClosed {
                    session: name.clone(),
                    reason,
                })
            } else {
                match catch_unwind(AssertUnwindSafe(|| core.process_one(request, t0))) {
                    Ok(resp) => resp,
                    Err(payload) => {
                        let reason = panic_message(payload);
                        *dead = Some(reason.clone());
                        Response::Error(RequestError::SessionClosed {
                            session: name.clone(),
                            reason,
                        })
                    }
                }
            };
            let _ = reply.send(resp);
        }
    }
}

/// Split-borrowed view of one session while a batch is being processed:
/// the per-batch context plus the mutable serving state. Exists so the
/// context's O(p) statistic copies are paid once per batch, not once per
/// request, while the borrow checker still sees disjoint fields.
struct SessionCore<'s> {
    name: &'s str,
    backend: &'s str,
    ctx: ScreenContext<'s>,
    pipeline: &'s ScreenPipeline,
    solver: SolverKind,
    cfg: &'s PathConfig,
    screener: &'s mut Box<dyn Screener>,
    lam_state: &'s mut f64,
    beta_state: &'s mut Vec<f64>,
    solver_state: &'s mut SolverState,
    ws_state: &'s mut WorkingSetState,
    metrics: &'s mut ServiceMetrics,
}

impl SessionCore<'_> {
    fn process_one(&mut self, request: Request, t0: Instant) -> Response {
        match request {
            Request::Screen { lam, opts } => match self.solve_at(lam, &opts, t0) {
                Ok(resp) => Response::Screen(resp),
                Err(e) => Response::Error(e),
            },
            Request::Warm { lam } => {
                match self.solve_at(lam, &RequestOptions::default(), t0) {
                    Ok(resp) => Response::Warmed(WarmResponse {
                        lam: resp.lam,
                        gap: resp.gap,
                        latency_s: resp.latency_s,
                    }),
                    Err(e) => Response::Error(e),
                }
            }
            Request::Predict { features, lam, opts } => {
                let p = self.ctx.x.n_cols();
                if features.len() != p {
                    return Response::Error(RequestError::InvalidRequest(format!(
                        "predict features have length {}, matrix has {p} columns",
                        features.len()
                    )));
                }
                if features.iter().any(|v| !v.is_finite()) {
                    return Response::Error(RequestError::InvalidRequest(
                        "predict features contain a non-finite entry".to_string(),
                    ));
                }
                match self.solve_at(lam, &opts, t0) {
                    Ok(resp) => {
                        let yhat = features
                            .iter()
                            .zip(resp.beta.iter())
                            .map(|(f, b)| f * b)
                            .sum();
                        Response::Predict(Prediction {
                            lam: resp.lam,
                            yhat,
                            gap: resp.gap,
                            partial: resp.partial,
                            latency_s: t0.elapsed().as_secs_f64(),
                        })
                    }
                    Err(e) => Response::Error(e),
                }
            }
            Request::FitPath { grid, lo, opts } => self.fit_path(grid, lo, &opts, t0),
            Request::SessionStats => Response::Stats(self.stats_snapshot()),
        }
    }

    /// Screen + solve at one λ — the old worker loop's per-request body,
    /// extended with per-request tolerance/pipeline overrides and deadline
    /// semantics. Requests without options follow the exact pre-protocol
    /// code path (bit-identity contract).
    fn solve_at(
        &mut self,
        lam: f64,
        opts: &RequestOptions,
        t0: Instant,
    ) -> Result<ScreenResponse, RequestError> {
        // belt and braces: the coordinator validates at the boundary, but
        // the registry can also be driven directly
        if !lam.is_finite() || lam < 0.0 {
            return Err(RequestError::InvalidLambda(lam));
        }
        let SessionCore {
            ctx,
            pipeline,
            solver,
            cfg,
            screener,
            lam_state,
            beta_state,
            solver_state,
            ws_state,
            metrics,
            ..
        } = self;
        let ctx: &ScreenContext = ctx;
        let pipeline: &ScreenPipeline = pipeline;
        let cfg: &PathConfig = cfg;
        let solver: SolverKind = *solver;
        let screener: &mut Box<dyn Screener> = screener;
        let lam_state: &mut f64 = lam_state;
        let beta_state: &mut Vec<f64> = beta_state;
        let solver_state: &mut SolverState = solver_state;
        let ws_state: &mut WorkingSetState = ws_state;
        let metrics: &mut ServiceMetrics = metrics;
        let x = ctx.x;
        let y = ctx.y;
        let p = x.n_cols();
        let lam = lam.min(ctx.lam_max);

        // per-request overrides
        let mut solve_opts = cfg.solve_opts.clone();
        if let Some(tol) = opts.tol_gap {
            solve_opts.tol_gap = tol;
        }
        let deadline_expired = |t0: Instant| opts.deadline.is_some_and(|d| t0.elapsed() >= d);

        let mut keep = vec![true; p];
        // screen from the best available anchor: the session pipeline if its
        // λ₀ ≥ lam and no override, else a throwaway λmax-anchored pipeline
        // (a sequential rule must never anchor below its target λ)
        let mut fresh;
        let scr: &mut dyn Screener = match &opts.pipeline {
            Some(over) => {
                fresh = over.build(x.n_rows(), cfg.sequential);
                fresh.init(ctx);
                fresh.as_mut()
            }
            None if screener.anchor_lam() >= lam => screener.as_mut(),
            None => {
                fresh = pipeline.build(x.n_rows(), cfg.sequential);
                fresh.init(ctx);
                fresh.as_mut()
            }
        };
        let stage_discards = scr.screen_step(ctx, lam, &mut keep);

        if cfg.strategy == PathStrategy::WorkingSet {
            // working-set solve: the survivors are only a *seed* — the
            // engine certifies against the full-problem gap, so heuristic
            // pipelines need no KKT-repair loop here. The session's
            // accumulated working set and β make a repeat request certify
            // in one complement sweep (O(active set), not O(p)).
            if let Some(d) = opts.deadline {
                solve_opts.time_budget = Some(d.saturating_sub(t0.elapsed()));
            }
            let req_solver = opts.solver.unwrap_or(solver);
            let lasso = req_solver.make();
            // a per-request solver override must not replay or clobber the
            // session solver's momentum: run on a throwaway copy of the
            // cached set and leave the session state untouched
            let mut throwaway;
            let ws: &mut WorkingSetState = if req_solver == solver {
                ws_state
            } else {
                throwaway = WorkingSetState {
                    cols: ws_state.cols.clone(),
                    beta: ws_state.beta.clone(),
                    solver_state: SolverState::None,
                };
                &mut throwaway
            };
            let wres = solve_working_set(ctx, lam, &keep, lasso.as_ref(), &solve_opts, ws);
            let gap = wres.gap;
            let partial = gap > solve_opts.tol_gap && deadline_expired(t0);
            let beta = wres.beta;
            let true_zeros = beta.iter().filter(|b| **b == 0.0).count();
            let kept_cols = ws.cols.clone();
            let discarded = p - kept_cols.len();
            // the answer is full-problem certified, so the anchor-advance
            // guard only needs the session tolerance (no repair bookkeeping)
            if lam < *lam_state && !partial && gap <= cfg.solve_opts.tol_gap {
                screener.observe(ctx, lam, &beta);
                beta_state.copy_from_slice(&beta);
                *lam_state = lam;
            }
            let latency = t0.elapsed().as_secs_f64();
            metrics.record_request(latency);
            metrics.record_screen(kept_cols.len(), discarded, true_zeros);
            if partial {
                metrics.record_partial();
            }
            return Ok(ScreenResponse {
                lam,
                kept: kept_cols,
                beta,
                discarded,
                true_zeros,
                latency_s: latency,
                stage_discards,
                dynamic_discards: 0,
                gap,
                partial,
            });
        }

        let mut cols: Vec<usize> = (0..p).filter(|&j| keep[j]).collect();
        let is_safe = scr.is_safe();
        // per-request solver override; the session's recorded resume state
        // is threaded only when the request runs the session's own solver —
        // an override gets a throwaway state, so switching solvers
        // mid-session never replays (or clobbers) another solver's momentum
        let req_solver = opts.solver.unwrap_or(solver);
        let mut override_state = SolverState::None;
        let resume_state: &mut SolverState =
            if req_solver == solver { solver_state } else { &mut override_state };
        let lasso = req_solver.make();
        let mut hook = if scr.dynamic() { Some(GapSafeHook::new(ctx)) } else { None };
        let mut dynamic_discards = 0usize;
        // heuristic pipeline: hook drops certified against a possibly-
        // unrepaired reduced problem must be re-validated by the KKT check
        let mut hook_dropped: Vec<bool> =
            if hook.is_some() && !is_safe { vec![false; p] } else { Vec::new() };
        // set when the deadline cuts the KKT repair loop short: some
        // heuristic discards may be unverified, so the answer is partial
        // even if the last reduced solve converged
        let mut repair_truncated = false;
        let res = loop {
            // re-derive the remaining budget each round: KKT-repair
            // re-solves share the request's one deadline instead of each
            // restarting a fresh full budget
            if let Some(d) = opts.deadline {
                solve_opts.time_budget = Some(d.saturating_sub(t0.elapsed()));
            }
            let warm: Vec<f64> = cols.iter().map(|&j| beta_state[j]).collect();
            let r = lasso.solve_warm(
                x,
                y,
                &cols,
                lam,
                Some(&warm),
                &solve_opts,
                hook.as_mut().map(|h| h as &mut dyn SolverHook),
                resume_state,
            );
            if let Some(h) = hook.as_mut() {
                let revalidate = if is_safe { None } else { Some(&mut hook_dropped) };
                dynamic_discards += h.fold_into(&mut keep, revalidate);
            }
            if is_safe || !cfg.kkt_repair {
                break r;
            }
            if deadline_expired(t0) {
                // no budget left to verify/repair the heuristic discards —
                // hand back the gap-tagged iterate instead of blocking
                repair_truncated = true;
                break r;
            }
            let full = r.scatter(&cols, p);
            let mut resid = y.to_vec();
            for (j, b) in full.iter().enumerate() {
                if *b != 0.0 {
                    x.col_axpy_into(j, -b, &mut resid);
                }
            }
            // only the pipeline's *uncertified* discards (plus any in-solver
            // hook drops) need the KKT check (hybrid certification,
            // DESIGN.md §3)
            let viol = match scr.uncertified() {
                Some(cand) if !hook_dropped.is_empty() => {
                    let merged = merge_kkt_candidates(cand, &hook_dropped);
                    kkt_violations_in(ctx, &resid, lam, &keep, &merged)
                }
                Some(cand) => kkt_violations_in(ctx, &resid, lam, &keep, cand),
                None => kkt_violations(ctx, &resid, lam, &keep),
            };
            if viol.is_empty() {
                break r;
            }
            for j in viol {
                keep[j] = true;
            }
            cols = (0..p).filter(|&j| keep[j]).collect();
        };
        let beta = res.scatter(&cols, p);
        let gap = res.gap;
        // partial means the *deadline* cut the work short — a solver that
        // merely hit max_iters without converging (clock never tripped) is
        // not the deadline's doing and stays untagged, deadline or not
        let partial = (repair_truncated || gap > solve_opts.tol_gap) && deadline_expired(t0);
        let true_zeros = beta.iter().filter(|b| **b == 0.0).count();
        let kept_cols: Vec<usize> = (0..p).filter(|&j| keep[j]).collect();
        let discarded = p - kept_cols.len();
        // advance the sequential pipeline only with a solution we can trust
        // as exact: deepest λ so far, never a deadline-partial iterate,
        // heuristic discards repaired to fixpoint, and the gap certified at
        // the *session's* tolerance — a per-request loosened tol_gap or an
        // unrepaired pipeline override must not poison the anchor every
        // later request screens from
        let repaired = is_safe || (cfg.kkt_repair && !repair_truncated);
        if lam < *lam_state && !partial && repaired && gap <= cfg.solve_opts.tol_gap {
            screener.observe(ctx, lam, &beta);
            beta_state.copy_from_slice(&beta);
            *lam_state = lam;
        }
        let latency = t0.elapsed().as_secs_f64();
        metrics.record_request(latency);
        metrics.record_screen(kept_cols.len(), discarded, true_zeros);
        if partial {
            metrics.record_partial();
        }
        Ok(ScreenResponse {
            lam,
            kept: kept_cols,
            beta,
            discarded,
            true_zeros,
            latency_s: latency,
            stage_discards,
            dynamic_discards,
            gap,
            partial,
        })
    }

    /// Run a λ-grid path on the session's dataset. Independent of the
    /// session's sequential state (its own fresh pipeline). A deadline is
    /// honored at the *request* level: the path driver re-splits the
    /// remaining budget across the remaining solves before every step
    /// ([`crate::path::replan_step_budget`] — early finishers donate their
    /// slack downstream), and the summary comes back tagged partial when
    /// the deadline expired with some step above tolerance.
    fn fit_path(
        &mut self,
        grid: usize,
        lo: f64,
        opts: &RequestOptions,
        t0: Instant,
    ) -> Response {
        if grid == 0 || !(lo > 0.0 && lo <= 1.0) {
            return Response::Error(RequestError::InvalidRequest(format!(
                "fit-path needs grid ≥ 1 and lo ∈ (0, 1], got grid={grid} lo={lo}"
            )));
        }
        let pipe = opts.pipeline.clone().unwrap_or_else(|| self.pipeline.clone());
        let mut path_cfg = self.cfg.clone();
        if let Some(tol) = opts.tol_gap {
            path_cfg.solve_opts.tol_gap = tol;
        }
        if let Some(d) = opts.deadline {
            // hand the driver what's left of the request deadline; it
            // re-plans per-step slices as the path progresses, so the whole
            // fit stays bounded by the deadline (not grid × deadline)
            path_cfg.path_budget = Some(d.saturating_sub(t0.elapsed()));
        }
        let lam_grid = LambdaGrid::relative_to(self.ctx.lam_max, grid, lo, 1.0);
        let out = if path_cfg.strategy == PathStrategy::WorkingSet {
            // thread the session's persistent working-set warm start: a
            // repeat FitPath seeds every λ from the union of all active
            // sets solved so far and certifies in one sweep per λ
            let mut screener = pipe.build(self.ctx.x.n_rows(), path_cfg.sequential);
            solve_path_with_screener_warm(
                &self.ctx,
                &lam_grid,
                screener.as_mut(),
                self.solver,
                &path_cfg,
                self.ws_state,
            )
        } else {
            solve_path_pipeline(self.ctx.x, self.ctx.y, &lam_grid, &pipe, self.solver, &path_cfg)
        };
        let max_gap = out.records.iter().map(|r| r.gap).fold(0.0f64, f64::max);
        // with a deadline set, any step left above tolerance was cut by its
        // budget slice — the slices are the deadline, so a step can be
        // truncated long before the total wall clock reaches it
        let partial = opts.deadline.is_some() && max_gap > path_cfg.solve_opts.tol_gap;
        let latency = t0.elapsed().as_secs_f64();
        self.metrics.record_request(latency);
        if partial {
            self.metrics.record_partial();
        }
        Response::Path(PathSummary {
            rule: out.rule.clone(),
            solver: out.solver,
            steps: out.records.len(),
            mean_rejection: out.mean_rejection_ratio(),
            screen_secs: out.total_screen_secs(),
            solve_secs: out.total_solve_secs(),
            max_gap,
            mean_working_set: out.mean_working_set(),
            kkt_passes: out.total_kkt_passes(),
            partial,
            latency_s: latency,
        })
    }

    fn stats_snapshot(&self) -> SessionStats {
        SessionStats {
            session: self.name.to_string(),
            backend: self.backend.to_string(),
            pipeline: self.pipeline.name(),
            n: self.ctx.x.n_rows(),
            p: self.ctx.x.n_cols(),
            lam_max: self.ctx.lam_max,
            anchor_lam: self.screener.anchor_lam(),
            metrics: self.metrics.clone(),
        }
    }
}

/// Named sessions owned by one coordinator. Lookup is by name; iteration
/// (shutdown reporting) follows registration order.
#[derive(Default)]
pub struct SessionRegistry {
    // audit:allow(determinism:hash-iter, lookup-only; iteration uses the registration-order Vec)
    sessions: HashMap<String, Arc<Mutex<SessionState>>>,
    order: Vec<String>,
    /// Why an evicted session is gone. A request naming an evicted session
    /// gets [`RequestError::SessionClosed`] with the eviction reason instead
    /// of a bare `UnknownSession` — the client learns its session was
    /// reclaimed, not that it never existed. Cleared if the name is
    /// re-registered.
    // audit:allow(determinism:hash-iter, lookup-only; never iterated)
    tombstones: HashMap<String, String>,
}

impl SessionRegistry {
    pub fn new() -> SessionRegistry {
        SessionRegistry::default()
    }

    /// Validate and open a session. A panicking backend (bad mmap shard,
    /// hostile `DesignMatrix` impl) is caught and reported as a typed
    /// error rather than killing the router.
    pub fn register(&mut self, spec: SessionSpec) -> Result<(), RequestError> {
        if self.sessions.contains_key(&spec.name) {
            return Err(RequestError::DuplicateSession(spec.name));
        }
        let name = spec.name.clone();
        let state = catch_unwind(AssertUnwindSafe(|| SessionState::new(spec)))
            .map_err(|payload| {
                RequestError::InvalidRequest(format!(
                    "session `{name}` registration panicked: {}",
                    panic_message(payload)
                ))
            })??;
        self.tombstones.remove(&name);
        self.order.push(name.clone());
        self.sessions.insert(name, Arc::new(Mutex::new(state)));
        Ok(())
    }

    /// Close a session because the admission policy reclaimed it (TTL
    /// expiry), leaving a tombstone so late requests get the reason.
    pub fn evict(&mut self, name: &str, reason: impl Into<String>) -> Option<ServiceMetrics> {
        let metrics = self.close(name)?;
        self.tombstones.insert(name.to_string(), reason.into());
        Some(metrics)
    }

    /// The reason a session was evicted, if it was (explicitly closed or
    /// never-registered names return `None`).
    pub fn eviction_reason(&self, name: &str) -> Option<&str> {
        self.tombstones.get(name).map(String::as_str)
    }

    pub(crate) fn get(&self, name: &str) -> Option<Arc<Mutex<SessionState>>> {
        self.sessions.get(name).cloned()
    }

    /// Close one session, returning its metrics.
    pub fn close(&mut self, name: &str) -> Option<ServiceMetrics> {
        let state = self.sessions.remove(name)?;
        self.order.retain(|n| n != name);
        let metrics = state.lock().unwrap_or_else(|e| e.into_inner()).metrics.clone();
        Some(metrics)
    }

    /// Tear everything down, returning (name, metrics) in registration
    /// order.
    pub fn drain_metrics(&mut self) -> Vec<(String, ServiceMetrics)> {
        let order = std::mem::take(&mut self.order);
        order
            .into_iter()
            .filter_map(|name| {
                let state = self.sessions.remove(&name)?;
                let metrics =
                    state.lock().unwrap_or_else(|e| e.into_inner()).metrics.clone();
                Some((name, metrics))
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Session names in registration order.
    pub fn names(&self) -> &[String] {
        &self.order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::path::RuleKind;

    fn spec(name: &str, seed: u64) -> SessionSpec {
        let ds = synthetic::synthetic1(30, 100, 8, 0.1, seed);
        SessionSpec::new(
            name,
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        )
        .with_backend_label("dense")
    }

    #[test]
    fn register_close_and_duplicates() {
        let mut reg = SessionRegistry::new();
        assert!(reg.is_empty());
        reg.register(spec("a", 1)).unwrap();
        reg.register(spec("b", 2)).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), ["a".to_string(), "b".to_string()]);
        assert_eq!(
            reg.register(spec("a", 3)).unwrap_err(),
            RequestError::DuplicateSession("a".to_string())
        );
        assert!(reg.close("a").is_some());
        assert!(reg.close("a").is_none());
        assert_eq!(reg.len(), 1);
        let drained = reg.drain_metrics();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].0, "b");
        assert!(reg.is_empty());
    }

    #[test]
    fn eviction_leaves_a_tombstone_until_reregistration() {
        let mut reg = SessionRegistry::new();
        reg.register(spec("a", 1)).unwrap();
        reg.register(spec("b", 2)).unwrap();
        assert!(reg.evict("a", "evicted: idle past session-ttl (100ms)").is_some());
        assert!(reg.get("a").is_none());
        assert_eq!(reg.eviction_reason("a"), Some("evicted: idle past session-ttl (100ms)"));
        // explicit close is not an eviction
        reg.close("b");
        assert_eq!(reg.eviction_reason("b"), None);
        // evicting an unknown name is a no-op
        assert!(reg.evict("ghost", "x").is_none());
        assert_eq!(reg.eviction_reason("ghost"), None);
        // re-registering the name clears the tombstone
        reg.register(spec("a", 3)).unwrap();
        assert_eq!(reg.eviction_reason("a"), None);
    }

    /// Immutable-backend wrapper whose `data_version` is test-controlled —
    /// stands in for a future mutable backend (streaming appends, refreshed
    /// shards) to exercise the ContextStats staleness guard.
    struct VersionedMatrix {
        inner: crate::linalg::DenseMatrix,
        version: Arc<std::sync::atomic::AtomicU64>,
    }

    impl DesignMatrix for VersionedMatrix {
        fn n_rows(&self) -> usize {
            self.inner.n_rows()
        }
        fn n_cols(&self) -> usize {
            self.inner.n_cols()
        }
        fn xt_w(&self, w: &[f64], out: &mut [f64]) {
            self.inner.xt_w(w, out)
        }
        fn col_dot_w(&self, j: usize, w: &[f64]) -> f64 {
            self.inner.col_dot_w(j, w)
        }
        fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]) {
            self.inner.col_axpy_into(j, a, out)
        }
        fn col_sq_norm(&self, j: usize) -> f64 {
            self.inner.col_sq_norm(j)
        }
        fn col_dot_col(&self, i: usize, j: usize) -> f64 {
            self.inner.col_dot_col(i, j)
        }
        fn col_into(&self, j: usize, out: &mut [f64]) {
            self.inner.col_into(j, out)
        }
        fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]) {
            self.inner.col_gather(j, rows, out)
        }
        fn nnz(&self) -> usize {
            self.inner.nnz()
        }
        fn data_version(&self) -> u64 {
            self.version.load(std::sync::atomic::Ordering::SeqCst)
        }
    }

    fn one_shot(state: &Arc<Mutex<SessionState>>, request: Request) -> Response {
        let (tx, rx) = std::sync::mpsc::channel();
        state.lock().unwrap().process_batch(vec![PendingRequest {
            request,
            reply: tx,
            t0: Instant::now(),
        }]);
        rx.recv().unwrap()
    }

    #[test]
    fn stale_backend_stats_close_the_session_with_a_typed_reason() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let ds = synthetic::synthetic1(25, 60, 5, 0.1, 11);
        let version = Arc::new(AtomicU64::new(0));
        let x = VersionedMatrix { inner: ds.x.into_dense(), version: Arc::clone(&version) };
        let mut reg = SessionRegistry::new();
        reg.register(SessionSpec::new(
            "v",
            x,
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        ))
        .unwrap();
        let state = reg.get("v").unwrap();

        // unchanged backend: served normally
        assert!(matches!(one_shot(&state, Request::SessionStats), Response::Stats(_)));

        // backend mutates under the session: the cached O(nnz) statistics
        // are stale — the session dies with the typed reason instead of
        // silently serving sweeps of data that no longer exists
        version.fetch_add(1, Ordering::SeqCst);
        match one_shot(&state, Request::SessionStats) {
            Response::Error(RequestError::SessionClosed { session, reason }) => {
                assert_eq!(session, "v");
                assert!(reason.contains("stale context statistics"), "{reason}");
            }
            other => panic!("expected SessionClosed, got {other:?}"),
        }
    }

    #[test]
    fn fista_session_records_momentum_state_and_overrides_use_a_throwaway() {
        let ds = synthetic::synthetic1(30, 80, 6, 0.1, 21);
        let mut reg = SessionRegistry::new();
        reg.register(SessionSpec::new(
            "f",
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Fista,
            PathConfig::default(),
        ))
        .unwrap();
        let state = reg.get("f").unwrap();
        let lam = state.lock().unwrap().stats.lam_max * 0.5;

        match one_shot(&state, Request::Screen { lam, opts: RequestOptions::default() }) {
            Response::Screen(r) => assert!(r.gap.is_finite()),
            other => panic!("unexpected {other:?}"),
        }
        match &state.lock().unwrap().solver_state {
            SolverState::Fista(fs) => assert_eq!(fs.lam, lam),
            other => panic!("expected recorded FISTA state, got {other:?}"),
        }

        // a per-request CD override runs with a throwaway state: the
        // session's recorded FISTA momentum survives untouched
        let opts = RequestOptions { solver: Some(SolverKind::Cd), ..Default::default() };
        match one_shot(&state, Request::Screen { lam: lam * 0.9, opts }) {
            Response::Screen(r) => assert!(r.gap.is_finite()),
            other => panic!("unexpected {other:?}"),
        }
        match &state.lock().unwrap().solver_state {
            SolverState::Fista(fs) => assert_eq!(fs.lam, lam),
            other => panic!("expected FISTA state to survive the override, got {other:?}"),
        }
    }

    #[test]
    fn register_rejects_shape_mismatch() {
        let mut reg = SessionRegistry::new();
        let ds = synthetic::synthetic1(20, 50, 4, 0.1, 9);
        let bad = SessionSpec::new(
            "bad",
            ds.x.clone(),
            vec![0.0; 7],
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        );
        match reg.register(bad) {
            Err(RequestError::InvalidRequest(msg)) => {
                assert!(msg.contains("rows"), "{msg}")
            }
            other => panic!("expected InvalidRequest, got {other:?}"),
        }
    }
}
