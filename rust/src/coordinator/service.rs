//! Screening-as-a-service: a request/response loop around the sequential
//! screening state machine.
//!
//! Model-selection workloads (cross-validation, stability selection) issue
//! many λ-evaluations against one dataset. The service owns the dataset and
//! a stateful screening **pipeline** (DESIGN.md §3) whose sequential anchor
//! is the exact solution at the smallest λ solved so far, **batches**
//! concurrently-arriving requests, and processes each batch in descending-λ
//! order so every request benefits from the tightest available θ*(λ₀) — the
//! same trick that makes sequential rules dominate basic ones (§4.1.1).
//! Requests above the anchor screen through a throwaway λmax-anchored
//! pipeline (a sequential rule must never anchor below its target λ).
//!
//! Threading: one worker thread owns all state; clients talk over mpsc
//! channels (the offline image has no tokio — DESIGN.md §4).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use super::metrics::ServiceMetrics;
use crate::linalg::DesignMatrix;
use crate::path::{PathConfig, SolverKind};
use crate::screening::{
    pipeline::merge_kkt_candidates, strong::kkt_violations, strong::kkt_violations_in,
    GapSafeHook, ScreenContext, ScreenPipeline, Screener, StageCount,
};
use crate::solver::LassoSolver;

/// A screening/solve request at one λ.
pub struct ScreenRequest {
    pub lam: f64,
    pub reply: Sender<ScreenResponse>,
}

/// Response: the surviving features and the exact solution at λ.
#[derive(Clone, Debug)]
pub struct ScreenResponse {
    pub lam: f64,
    pub kept: Vec<usize>,
    pub beta: Vec<f64>,
    pub discarded: usize,
    pub true_zeros: usize,
    pub latency_s: f64,
    /// Per-pipeline-stage discard counts in stage order.
    pub stage_discards: Vec<StageCount>,
    /// Features additionally discarded in-solver by the gap-safe hook.
    pub dynamic_discards: usize,
}

enum Msg {
    Request(ScreenRequest, Instant),
    Shutdown(Sender<ServiceMetrics>),
}

/// Handle to a running screening service.
pub struct ScreeningService {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl ScreeningService {
    /// Spawn the service worker owning `x`, `y`. Accepts any matrix backend
    /// (dense, CSC, …) and any screening pipeline — a bare
    /// [`crate::path::RuleKind`] converts implicitly, composed pipelines
    /// come from [`ScreenPipeline::parse`].
    pub fn spawn<M: DesignMatrix + Send + 'static>(
        x: M,
        y: Vec<f64>,
        pipeline: impl Into<ScreenPipeline>,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> ScreeningService {
        Self::spawn_boxed(Box::new(x), y, pipeline, solver, cfg)
    }

    /// Spawn from an already-boxed backend (the CLI picks dense/CSC at
    /// runtime and hands the box over directly).
    pub fn spawn_boxed(
        x: Box<dyn DesignMatrix + Send>,
        y: Vec<f64>,
        pipeline: impl Into<ScreenPipeline>,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> ScreeningService {
        let pipeline = pipeline.into();
        let (tx, rx) = channel::<Msg>();
        let worker =
            std::thread::spawn(move || worker_loop(x, y, pipeline, solver, cfg, rx));
        ScreeningService { tx, worker: Some(worker) }
    }

    /// Fire a request; the response arrives on the returned receiver.
    pub fn request(&self, lam: f64) -> Receiver<ScreenResponse> {
        let (reply, rx) = channel();
        let _ = self
            .tx
            .send(Msg::Request(ScreenRequest { lam, reply }, Instant::now()));
        rx
    }

    /// Convenience: blocking request.
    pub fn screen(&self, lam: f64) -> ScreenResponse {
        self.request(lam).recv().expect("service dropped")
    }

    /// Stop the worker and collect metrics.
    pub fn shutdown(mut self) -> ServiceMetrics {
        let (mtx, mrx) = channel();
        let _ = self.tx.send(Msg::Shutdown(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        metrics
    }
}

impl Drop for ScreeningService {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let (mtx, _mrx) = channel();
            let _ = self.tx.send(Msg::Shutdown(mtx));
            let _ = w.join();
        }
    }
}

fn worker_loop(
    x: Box<dyn DesignMatrix + Send>,
    y: Vec<f64>,
    pipeline: ScreenPipeline,
    solver_kind: SolverKind,
    cfg: PathConfig,
    rx: Receiver<Msg>,
) {
    let x: &dyn DesignMatrix = &*x;
    // slack > 0 widens keep-decisions for reduced-precision backends
    // (f32 shards) — same discipline as the PJRT sweep, DESIGN.md §1
    let ctx = ScreenContext::with_sweep_slack(x, &y, x, cfg.safety_slack);
    // the service's long-lived pipeline: its anchor is the exact solution
    // at the smallest λ solved so far
    let mut screener = pipeline.build(x.n_rows(), cfg.sequential);
    screener.init(&ctx);
    let solver: Box<dyn LassoSolver> = match solver_kind {
        SolverKind::Cd => Box::new(crate::solver::cd::CdSolver),
        SolverKind::Fista => Box::new(crate::solver::fista::FistaSolver),
        SolverKind::Lars => Box::new(crate::solver::lars::LarsSolver),
    };
    let p = x.n_cols();
    let mut metrics = ServiceMetrics::new();

    // warm-start state: the solution at the deepest λ solved so far. The
    // explicit tracker (rather than the screener's anchor) keeps warm
    // starts monotone even for pipelines whose anchor never advances
    // (`none`, basic mode).
    let mut lam_state = ctx.lam_max;
    let mut beta_state: Vec<f64> = vec![0.0; p];

    loop {
        // block for one message, then drain whatever else arrived → a batch
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return,
        };
        let mut batch: Vec<(ScreenRequest, Instant)> = Vec::new();
        let mut shutdown: Option<Sender<ServiceMetrics>> = None;
        match first {
            Msg::Request(r, t) => batch.push((r, t)),
            Msg::Shutdown(s) => shutdown = Some(s),
        }
        while let Ok(m) = rx.try_recv() {
            match m {
                Msg::Request(r, t) => batch.push((r, t)),
                Msg::Shutdown(s) => shutdown = Some(s),
            }
        }
        if !batch.is_empty() {
            metrics.record_batch(batch.len());
            // λ-descending order: larger λ solved first tightens θ for the rest
            batch.sort_by(|a, b| b.0.lam.partial_cmp(&a.0.lam).unwrap());
            for (req, t0) in batch {
                let lam = req.lam.min(ctx.lam_max);
                let mut keep = vec![true; p];
                // screen from the best available anchor: the sequential
                // pipeline if its λ₀ ≥ lam, else a throwaway λmax-anchored
                // pipeline (a sequential rule must never anchor below λ)
                let mut fresh;
                let scr: &mut dyn Screener = if screener.anchor_lam() >= lam {
                    screener.as_mut()
                } else {
                    fresh = pipeline.build(x.n_rows(), cfg.sequential);
                    fresh.init(&ctx);
                    fresh.as_mut()
                };
                let stage_discards = scr.screen_step(&ctx, lam, &mut keep);
                let mut cols: Vec<usize> = (0..p).filter(|&j| keep[j]).collect();
                let is_safe = scr.is_safe();
                let mut hook =
                    if scr.dynamic() { Some(GapSafeHook::new(&ctx)) } else { None };
                let mut dynamic_discards = 0usize;
                // heuristic pipeline: hook drops certified against a
                // possibly-unrepaired reduced problem must be re-validated
                // by the KKT check (see path::solve_path_with_screener)
                let mut hook_dropped: Vec<bool> =
                    if hook.is_some() && !is_safe { vec![false; p] } else { Vec::new() };
                let res = loop {
                    let warm: Vec<f64> = cols.iter().map(|&j| beta_state[j]).collect();
                    let r = match hook.as_mut() {
                        Some(h) => solver.solve_with_hook(
                            x,
                            &y,
                            &cols,
                            lam,
                            Some(&warm),
                            &cfg.solve_opts,
                            Some(h),
                        ),
                        None => solver.solve(x, &y, &cols, lam, Some(&warm), &cfg.solve_opts),
                    };
                    if let Some(h) = hook.as_mut() {
                        let revalidate =
                            if is_safe { None } else { Some(&mut hook_dropped) };
                        dynamic_discards += h.fold_into(&mut keep, revalidate);
                    }
                    if is_safe || !cfg.kkt_repair {
                        break r;
                    }
                    let full = r.scatter(&cols, p);
                    let mut resid = y.to_vec();
                    for (j, b) in full.iter().enumerate() {
                        if *b != 0.0 {
                            x.col_axpy_into(j, -b, &mut resid);
                        }
                    }
                    // only the pipeline's *uncertified* discards (plus any
                    // in-solver hook drops) need the KKT check (hybrid
                    // certification, DESIGN.md §3)
                    let viol = match scr.uncertified() {
                        Some(cand) if !hook_dropped.is_empty() => {
                            let merged = merge_kkt_candidates(cand, &hook_dropped);
                            kkt_violations_in(&ctx, &resid, lam, &keep, &merged)
                        }
                        Some(cand) => kkt_violations_in(&ctx, &resid, lam, &keep, cand),
                        None => kkt_violations(&ctx, &resid, lam, &keep),
                    };
                    if viol.is_empty() {
                        break r;
                    }
                    for j in viol {
                        keep[j] = true;
                    }
                    cols = (0..p).filter(|&j| keep[j]).collect();
                };
                let beta = res.scatter(&cols, p);
                let true_zeros = beta.iter().filter(|b| **b == 0.0).count();
                let kept_cols: Vec<usize> = (0..p).filter(|&j| keep[j]).collect();
                let discarded = p - kept_cols.len();
                // advance the sequential pipeline if this is the deepest λ
                if lam < lam_state {
                    screener.observe(&ctx, lam, &beta);
                    beta_state.copy_from_slice(&beta);
                    lam_state = lam;
                }
                let latency = t0.elapsed().as_secs_f64();
                metrics.record_request(latency);
                metrics.record_screen(kept_cols.len(), discarded, true_zeros);
                let _ = req.reply.send(ScreenResponse {
                    lam,
                    kept: kept_cols,
                    beta,
                    discarded,
                    true_zeros,
                    latency_s: latency,
                    stage_discards,
                    dynamic_discards,
                });
            }
        }
        if let Some(s) = shutdown {
            let _ = s.send(metrics.clone());
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::path::RuleKind;
    use crate::solver::{cd::CdSolver, SolveOptions};

    fn service(seed: u64) -> (ScreeningService, crate::data::Dataset, f64) {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, seed);
        let lam_max = crate::solver::dual::lambda_max(&ds.x, &ds.y);
        let svc = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        );
        (svc, ds, lam_max)
    }

    #[test]
    fn serves_exact_solutions() {
        let (svc, ds, lam_max) = service(1);
        let resp = svc.screen(0.5 * lam_max);
        // compare against direct solve
        let cols: Vec<usize> = (0..ds.p()).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let direct = CdSolver
            .solve(&ds.x, &ds.y, &cols, 0.5 * lam_max, None, &opts)
            .scatter(&cols, ds.p());
        for j in 0..ds.p() {
            assert!(
                (resp.beta[j] - direct[j]).abs() < 1e-4 * (1.0 + direct[j].abs()),
                "feature {j}"
            );
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 1);
    }

    #[test]
    fn sequential_state_reused_descending() {
        let (svc, _ds, lam_max) = service(2);
        // descending λ sequence: each response exact, screening effective
        let mut last_kept = usize::MAX;
        for f in [0.8, 0.6, 0.4, 0.2] {
            let resp = svc.screen(f * lam_max);
            assert!(resp.kept.len() <= resp.beta.len());
            last_kept = resp.kept.len();
        }
        assert!(last_kept > 0);
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 4);
        assert!(metrics.rejection_ratio.mean() > 0.5);
    }

    #[test]
    fn concurrent_requests_batched() {
        let (svc, _ds, lam_max) = service(3);
        // fire several requests before reading replies → they arrive as a batch
        let rxs: Vec<_> =
            [0.7, 0.5, 0.3].iter().map(|f| svc.request(f * lam_max)).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(!resp.beta.is_empty());
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 3);
        // at least one multi-request batch must have formed OR requests were
        // processed in ≤3 batches
        assert!(metrics.batches <= 3);
    }

    #[test]
    fn pipeline_service_reports_stages_and_exact_solutions() {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, 9);
        let lam_max = crate::solver::dual::lambda_max(&ds.x, &ds.y);
        let pipe = crate::screening::ScreenPipeline::parse("hybrid:strong+edpp")
            .unwrap()
            .with_dynamic(true);
        let svc = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            pipe,
            SolverKind::Cd,
            PathConfig::default(),
        );
        let resp = svc.screen(0.4 * lam_max);
        assert_eq!(resp.stage_discards.len(), 2);
        assert_eq!(resp.stage_discards[0].stage, "edpp");
        assert_eq!(resp.stage_discards[1].stage, "strong");
        // the hybrid mask dominates the plain-EDPP service's at the same λ
        let svc_edpp = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        );
        let resp_edpp = svc_edpp.screen(0.4 * lam_max);
        assert!(resp.discarded >= resp_edpp.discarded);
        svc_edpp.shutdown();
        // exactness: compare against a direct full solve
        let cols: Vec<usize> = (0..ds.p()).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let direct = CdSolver
            .solve(&ds.x, &ds.y, &cols, 0.4 * lam_max, None, &opts)
            .scatter(&cols, ds.p());
        for j in 0..ds.p() {
            assert!(
                (resp.beta[j] - direct[j]).abs() < 1e-4 * (1.0 + direct[j].abs()),
                "feature {j}"
            );
        }
        svc.shutdown();
    }

    #[test]
    fn lam_above_lambda_max_clamped() {
        let (svc, ds, lam_max) = service(4);
        let resp = svc.screen(lam_max * 2.0);
        assert!(resp.beta.iter().all(|b| *b == 0.0));
        assert_eq!(resp.true_zeros, ds.p());
        svc.shutdown();
    }
}
