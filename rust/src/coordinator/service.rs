//! The multi-tenant serving coordinator and its single-session facade
//! (DESIGN.md §4).
//!
//! [`Coordinator`] is the serving front door: a router thread accepts typed
//! [`Request`]s addressed to named sessions (see
//! [`super::registry::SessionRegistry`]), groups concurrently-arriving
//! requests into per-session batches, and executes the batches concurrently
//! on the shared [`crate::runtime::pool`] worker pool — one job per session
//! per tick, so each session's sequential state stays single-owner and its
//! responses stay bit-identical to a dedicated single-session worker.
//! Within a batch, λ-carrying requests run in descending-λ order so every
//! request benefits from the tightest available θ*(λ₀) — the same trick
//! that makes sequential rules dominate basic ones (§4.1.1).
//!
//! [`ScreeningService`] is the legacy single-session surface, now a thin
//! facade over one coordinator session: `spawn`/`screen`/`shutdown` keep
//! working for existing callers, plus a `Result`-based
//! [`ScreeningService::try_screen`] that surfaces typed errors (a dead
//! worker's panic reason included) instead of panicking with "service
//! dropped".
//!
//! Threading: std::thread + mpsc for routing, the [`crate::runtime::pool`]
//! for execution (the offline image has no tokio — DESIGN.md §6).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::metrics::ServiceMetrics;
use super::protocol::{
    PendingRequest, Request, RequestError, RequestOptions, Response, ScreenResponse,
};
use super::registry::{SessionRegistry, SessionSpec};
use crate::linalg::DesignMatrix;
use crate::path::{PathConfig, SolverKind};
use crate::runtime::pool::{self, WorkerPool};
use crate::screening::ScreenPipeline;

enum CoordMsg {
    Submit { session: String, pending: PendingRequest },
    Register { spec: SessionSpec, reply: Sender<Result<(), RequestError>> },
    Close { session: String, reply: Sender<Option<ServiceMetrics>> },
    Sessions { reply: Sender<Vec<String>> },
    Shutdown { reply: Sender<Vec<(String, ServiceMetrics)>> },
}

/// A submitted request's reply slot. `recv_response` blocks for the typed
/// [`Response`]; `recv` is the screen-shaped convenience used by the
/// facade and most clients.
pub struct PendingResponse {
    rx: Receiver<Response>,
}

impl PendingResponse {
    /// Block for the typed response.
    pub fn recv_response(&self) -> Result<Response, RequestError> {
        self.rx.recv().map_err(|_| {
            RequestError::Disconnected("coordinator shut down before replying".to_string())
        })
    }

    /// Block for a screen response; protocol errors come back as `Err`.
    pub fn recv(&self) -> Result<ScreenResponse, RequestError> {
        match self.recv_response()? {
            Response::Screen(resp) => Ok(resp),
            Response::Error(e) => Err(e),
            other => Err(RequestError::InvalidRequest(format!(
                "expected a screen response, got {other:?}"
            ))),
        }
    }
}

/// Multi-tenant serving front door: owns the router thread and, through it,
/// the session registry. Dropping the coordinator shuts the router down.
pub struct Coordinator {
    tx: Sender<CoordMsg>,
    router: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Coordinator executing session batches on the process-wide pool
    /// ([`pool::global`], sized by `DPP_POOL_THREADS`).
    pub fn new() -> Coordinator {
        Self::with_pool(None)
    }

    /// Coordinator with an explicit pool (benches and tests sweep thread
    /// counts without touching the global pool).
    pub fn with_pool(pool: Option<Arc<WorkerPool>>) -> Coordinator {
        let (tx, rx) = channel::<CoordMsg>();
        let router = std::thread::Builder::new()
            .name("dpp-coordinator".to_string())
            .spawn(move || router_loop(rx, pool))
            // audit:allow(panic, startup-fatal: no coordinator thread means no service)
            .expect("spawning coordinator router");
        Coordinator { tx, router: Some(router) }
    }

    /// Open a named session; blocks until the registry accepted (or
    /// rejected) the spec, so a following [`Coordinator::submit`] always
    /// finds it.
    pub fn register(&self, spec: SessionSpec) -> Result<(), RequestError> {
        let (rtx, rrx) = channel();
        self.tx
            .send(CoordMsg::Register { spec, reply: rtx })
            .map_err(|_| disconnected())?;
        rrx.recv().map_err(|_| disconnected())?
    }

    /// Fire a request at a session. Never blocks: validation failures and
    /// routing failures are delivered through the returned slot as typed
    /// errors. λ is validated here, at the API boundary — a NaN λ used to
    /// reach the worker's batch sort and panic it.
    pub fn submit(&self, session: &str, request: Request) -> PendingResponse {
        let (rtx, rrx) = channel();
        if let Some(lam) = request.lam() {
            if !lam.is_finite() || lam < 0.0 {
                let _ = rtx.send(Response::Error(RequestError::InvalidLambda(lam)));
                return PendingResponse { rx: rrx };
            }
        }
        let msg = CoordMsg::Submit {
            session: session.to_string(),
            // audit:allow(determinism:clock, latency metric only; never feeds numerics)
            pending: PendingRequest { request, reply: rtx.clone(), t0: Instant::now() },
        };
        if self.tx.send(msg).is_err() {
            let _ = rtx.send(Response::Error(disconnected()));
        }
        PendingResponse { rx: rrx }
    }

    /// Names of the currently-open sessions, in registration order. The
    /// network server advertises these in its hello so clients can address
    /// sessions without out-of-band configuration.
    pub fn sessions(&self) -> Vec<String> {
        let (rtx, rrx) = channel();
        if self.tx.send(CoordMsg::Sessions { reply: rtx }).is_err() {
            return Vec::new();
        }
        rrx.recv().unwrap_or_default()
    }

    /// Close one session, returning its metrics (None if unknown).
    pub fn close_session(&self, session: &str) -> Option<ServiceMetrics> {
        let (rtx, rrx) = channel();
        self.tx
            .send(CoordMsg::Close { session: session.to_string(), reply: rtx })
            .ok()?;
        rrx.recv().ok().flatten()
    }

    /// Stop the router and collect per-session metrics in registration
    /// order.
    pub fn shutdown(mut self) -> Vec<(String, ServiceMetrics)> {
        let (rtx, rrx) = channel();
        let _ = self.tx.send(CoordMsg::Shutdown { reply: rtx });
        let metrics = rrx.recv().unwrap_or_default();
        if let Some(router) = self.router.take() {
            let _ = router.join();
        }
        metrics
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(router) = self.router.take() {
            let (rtx, _rrx) = channel();
            let _ = self.tx.send(CoordMsg::Shutdown { reply: rtx });
            let _ = router.join();
        }
    }
}

fn disconnected() -> RequestError {
    RequestError::Disconnected("coordinator router is gone".to_string())
}

/// The router: drain whatever arrived into per-session batches, run one
/// pool job per session (per-session affinity — single owner of the
/// session's sequential state), repeat. Register/close/shutdown interleave
/// with submits in arrival order, so a submit that follows a successful
/// register (same client thread) always finds its session.
///
/// The tick is a barrier: messages arriving mid-tick wait for the slowest
/// session's batch before dispatch, and that queue wait counts against
/// their deadline (DESIGN.md §4 records the tradeoff; per-session dispatch
/// queues are the ROADMAP follow-on). Every solve is budget-bounded, so a
/// tick's length is bounded by its slowest deadline-free request.
///
/// Nested parallelism: when ≥2 session batches share a tick, each job runs
/// on a pool worker, so a sharded backend's own `pool.run` sweeps execute
/// inline (the pool's nested-dispatch guard) — results stay bit-identical
/// (the pool's determinism contract), but a sharded session's sweeps are
/// sequential until the tick has a worker to spare. A single-session tick
/// runs inline on the router, keeping full shard parallelism.
fn router_loop(rx: Receiver<CoordMsg>, pool: Option<Arc<WorkerPool>>) {
    let pool_ref: &WorkerPool = match &pool {
        Some(p) => p.as_ref(),
        None => pool::global(),
    };
    let mut registry = SessionRegistry::new();
    loop {
        // block for one message, then drain whatever else arrived → a tick
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return,
        };
        let mut msgs = vec![first];
        while let Ok(m) = rx.try_recv() {
            msgs.push(m);
        }
        let mut shutdown: Option<Sender<Vec<(String, ServiceMetrics)>>> = None;
        // per-session batches for this tick, in first-seen order
        let mut batches: Vec<(String, Vec<PendingRequest>)> = Vec::new();
        for msg in msgs {
            match msg {
                CoordMsg::Register { spec, reply } => {
                    let _ = reply.send(registry.register(spec));
                }
                CoordMsg::Close { session, reply } => {
                    let _ = reply.send(registry.close(&session));
                }
                CoordMsg::Sessions { reply } => {
                    let _ = reply.send(registry.names().to_vec());
                }
                CoordMsg::Shutdown { reply } => shutdown = Some(reply),
                CoordMsg::Submit { session, pending } => {
                    if registry.get(&session).is_none() {
                        let _ = pending.reply.send(Response::Error(
                            RequestError::UnknownSession(session),
                        ));
                        continue;
                    }
                    match batches.iter_mut().find(|(name, _)| *name == session) {
                        Some((_, batch)) => batch.push(pending),
                        None => batches.push((session, vec![pending])),
                    }
                }
            }
        }
        if !batches.is_empty() {
            // one job per session: the pool provides the concurrency, the
            // per-session batch keeps the state single-owner. Jobs only
            // move Arcs and owned batches, and process_batch catches
            // per-request panics, so a poisoned session cannot take the
            // router (or the pool) down with it.
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (name, batch) in batches {
                let Some(state) = registry.get(&name) else {
                    // a Close later in the same tick removed the session
                    for pending in batch {
                        let _ = pending.reply.send(Response::Error(
                            RequestError::UnknownSession(name.clone()),
                        ));
                    }
                    continue;
                };
                jobs.push(Box::new(move || {
                    state.lock().unwrap_or_else(|e| e.into_inner()).process_batch(batch);
                }));
            }
            pool_ref.run(jobs);
        }
        if let Some(reply) = shutdown {
            let _ = reply.send(registry.drain_metrics());
            return;
        }
    }
}

/// Name of the facade's only session.
pub const SERVICE_SESSION: &str = "service";

/// Single-session facade over the serving protocol — the pre-protocol
/// `ScreeningService` surface, unchanged for existing callers. Spawning
/// registers one session named [`SERVICE_SESSION`] on a private
/// [`Coordinator`]; `screen`/`request` submit [`Request::Screen`]s to it.
pub struct ScreeningService {
    coord: Coordinator,
}

impl ScreeningService {
    /// Spawn the service owning `x`, `y`. Accepts any matrix backend
    /// (dense, CSC, …) and any screening pipeline — a bare
    /// [`crate::path::RuleKind`] converts implicitly, composed pipelines
    /// come from [`ScreenPipeline::parse`].
    pub fn spawn<M: DesignMatrix + Send + 'static>(
        x: M,
        y: Vec<f64>,
        pipeline: impl Into<ScreenPipeline>,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> ScreeningService {
        Self::spawn_boxed(Box::new(x), y, pipeline, solver, cfg)
    }

    /// Spawn from an already-boxed backend (the CLI picks dense/CSC at
    /// runtime and hands the box over directly).
    pub fn spawn_boxed(
        x: Box<dyn DesignMatrix + Send>,
        y: Vec<f64>,
        pipeline: impl Into<ScreenPipeline>,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> ScreeningService {
        let coord = Coordinator::new();
        coord
            .register(SessionSpec::boxed(SERVICE_SESSION, x, y, pipeline, solver, cfg))
            // audit:allow(panic, documented panicking constructor; typed path is Coordinator::register)
            .unwrap_or_else(|e| panic!("spawning screening service: {e}"));
        ScreeningService { coord }
    }

    /// Fire a screen request; the response arrives on the returned slot.
    pub fn request(&self, lam: f64) -> PendingResponse {
        self.request_with(lam, RequestOptions::default())
    }

    /// Screen request with per-request options (deadline, tolerance,
    /// pipeline override).
    pub fn request_with(&self, lam: f64, opts: RequestOptions) -> PendingResponse {
        self.coord.submit(SERVICE_SESSION, Request::Screen { lam, opts })
    }

    /// Blocking request with typed errors: an invalid λ, a worker panic
    /// (with its reason), and coordinator shutdown all come back as
    /// [`RequestError`] instead of a panic.
    pub fn try_screen(&self, lam: f64) -> Result<ScreenResponse, RequestError> {
        self.request(lam).recv()
    }

    /// Convenience: blocking request. Panics on request failure — prefer
    /// [`ScreeningService::try_screen`] when the caller can handle errors;
    /// the panic message carries the typed reason (e.g. the worker's own
    /// panic payload), not a bare "service dropped".
    pub fn screen(&self, lam: f64) -> ScreenResponse {
        self.try_screen(lam)
            // audit:allow(panic, documented panicking facade; typed path is try_screen)
            .unwrap_or_else(|e| panic!("screening service request failed: {e}"))
    }

    /// The underlying coordinator, for callers that want to grow the
    /// single-session facade into a multi-tenant deployment (register more
    /// sessions, submit typed requests to [`SERVICE_SESSION`]).
    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Stop the worker and collect metrics.
    pub fn shutdown(self) -> ServiceMetrics {
        self.coord
            .shutdown()
            .into_iter()
            .find(|(name, _)| name == SERVICE_SESSION)
            .map(|(_, metrics)| metrics)
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::path::RuleKind;
    use crate::solver::{cd::CdSolver, LassoSolver, SolveOptions};

    fn service(seed: u64) -> (ScreeningService, crate::data::Dataset, f64) {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, seed);
        let lam_max = crate::solver::dual::lambda_max(&ds.x, &ds.y);
        let svc = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        );
        (svc, ds, lam_max)
    }

    #[test]
    fn serves_exact_solutions() {
        let (svc, ds, lam_max) = service(1);
        let resp = svc.screen(0.5 * lam_max);
        assert!(!resp.partial);
        // compare against direct solve
        let cols: Vec<usize> = (0..ds.p()).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let direct = CdSolver
            .solve(&ds.x, &ds.y, &cols, 0.5 * lam_max, None, &opts)
            .scatter(&cols, ds.p());
        for j in 0..ds.p() {
            assert!(
                (resp.beta[j] - direct[j]).abs() < 1e-4 * (1.0 + direct[j].abs()),
                "feature {j}"
            );
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 1);
    }

    #[test]
    fn sequential_state_reused_descending() {
        let (svc, _ds, lam_max) = service(2);
        // descending λ sequence: each response exact, screening effective
        let mut last_kept = usize::MAX;
        for f in [0.8, 0.6, 0.4, 0.2] {
            let resp = svc.screen(f * lam_max);
            assert!(resp.kept.len() <= resp.beta.len());
            last_kept = resp.kept.len();
        }
        assert!(last_kept > 0);
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 4);
        assert!(metrics.rejection_ratio.mean() > 0.5);
    }

    #[test]
    fn concurrent_requests_batched() {
        let (svc, _ds, lam_max) = service(3);
        // fire several requests before reading replies → they arrive as a batch
        let rxs: Vec<_> =
            [0.7, 0.5, 0.3].iter().map(|f| svc.request(f * lam_max)).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(!resp.beta.is_empty());
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 3);
        // at least one multi-request batch must have formed OR requests were
        // processed in ≤3 batches
        assert!(metrics.batches <= 3);
    }

    #[test]
    fn pipeline_service_reports_stages_and_exact_solutions() {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, 9);
        let lam_max = crate::solver::dual::lambda_max(&ds.x, &ds.y);
        let pipe = crate::screening::ScreenPipeline::parse("hybrid:strong+edpp")
            .unwrap()
            .with_dynamic(true);
        let svc = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            pipe,
            SolverKind::Cd,
            PathConfig::default(),
        );
        let resp = svc.screen(0.4 * lam_max);
        assert_eq!(resp.stage_discards.len(), 2);
        assert_eq!(resp.stage_discards[0].stage, "edpp");
        assert_eq!(resp.stage_discards[1].stage, "strong");
        // the hybrid mask dominates the plain-EDPP service's at the same λ
        let svc_edpp = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        );
        let resp_edpp = svc_edpp.screen(0.4 * lam_max);
        assert!(resp.discarded >= resp_edpp.discarded);
        svc_edpp.shutdown();
        // exactness: compare against a direct full solve
        let cols: Vec<usize> = (0..ds.p()).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let direct = CdSolver
            .solve(&ds.x, &ds.y, &cols, 0.4 * lam_max, None, &opts)
            .scatter(&cols, ds.p());
        for j in 0..ds.p() {
            assert!(
                (resp.beta[j] - direct[j]).abs() < 1e-4 * (1.0 + direct[j].abs()),
                "feature {j}"
            );
        }
        svc.shutdown();
    }

    #[test]
    fn lam_above_lambda_max_clamped() {
        let (svc, ds, lam_max) = service(4);
        let resp = svc.screen(lam_max * 2.0);
        assert!(resp.beta.iter().all(|b| *b == 0.0));
        assert_eq!(resp.true_zeros, ds.p());
        svc.shutdown();
    }

    #[test]
    fn invalid_lambda_is_a_typed_error_not_a_poisoned_worker() {
        let (svc, _ds, lam_max) = service(5);
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.5] {
            match svc.try_screen(bad) {
                Err(RequestError::InvalidLambda(_)) => {}
                other => panic!("λ={bad}: expected InvalidLambda, got {other:?}"),
            }
        }
        // the worker survived and still answers
        let resp = svc.try_screen(0.5 * lam_max).unwrap();
        assert!(!resp.beta.is_empty());
        let metrics = svc.shutdown();
        // rejected requests never reached the session
        assert_eq!(metrics.requests, 1);
    }

    #[test]
    fn unknown_session_and_shutdown_are_typed() {
        let (svc, _ds, lam_max) = service(6);
        let err = svc
            .coordinator()
            .submit("nope", Request::Screen { lam: 0.5 * lam_max, opts: Default::default() })
            .recv()
            .unwrap_err();
        assert_eq!(err, RequestError::UnknownSession("nope".to_string()));
        svc.shutdown();
    }

    #[test]
    fn coordinator_serves_multiple_sessions() {
        let coord = Coordinator::new();
        let mut lam_maxes = Vec::new();
        for (i, seed) in [11u64, 12, 13].iter().enumerate() {
            let ds = synthetic::synthetic1(25 + 5 * i, 80 + 20 * i, 8, 0.1, *seed);
            lam_maxes.push(crate::solver::dual::lambda_max(&ds.x, &ds.y));
            coord
                .register(SessionSpec::new(
                    format!("s{i}"),
                    ds.x.clone(),
                    ds.y.clone(),
                    RuleKind::Edpp,
                    SolverKind::Cd,
                    PathConfig::default(),
                ))
                .unwrap();
        }
        // interleaved submissions across all three sessions
        let mut slots = Vec::new();
        for f in [0.7, 0.4] {
            for (i, lm) in lam_maxes.iter().enumerate() {
                slots.push(coord.submit(
                    &format!("s{i}"),
                    Request::Screen { lam: f * lm, opts: Default::default() },
                ));
            }
        }
        for slot in slots {
            let resp = slot.recv().unwrap();
            assert!(!resp.beta.is_empty());
            assert!(!resp.partial);
        }
        let metrics = coord.shutdown();
        assert_eq!(metrics.len(), 3);
        assert_eq!(metrics[0].0, "s0");
        for (_, m) in &metrics {
            assert_eq!(m.requests, 2);
        }
    }
}
