//! The multi-tenant serving coordinator and its single-session facade
//! (DESIGN.md §4).
//!
//! [`Coordinator`] is the serving front door: a router thread accepts typed
//! [`Request`]s addressed to named sessions (see
//! [`super::registry::SessionRegistry`]) and enqueues each one on the
//! session's dispatch queue in the serving scheduler
//! ([`crate::runtime::scheduler`]). Each session drains through its own
//! detached dispatcher job on the shared [`crate::runtime::pool`] worker
//! pool — at most one live dispatcher per session, so the sequential state
//! stays single-owner and responses stay bit-identical to a dedicated
//! single-session worker — while distinct sessions never wait on each
//! other (the old tick barrier is gone). Batches form from backlog: within
//! one, λ-carrying requests run in descending-λ order so every request
//! benefits from the tightest available θ*(λ₀) — the same trick that makes
//! sequential rules dominate basic ones (§4.1.1). An
//! [`super::admission::AdmissionController`] in front of the queues sheds
//! load with typed [`RequestError::Overloaded`] replies instead of queueing
//! unboundedly, and retires sessions idle past a TTL.
//!
//! [`ScreeningService`] is the legacy single-session surface, now a thin
//! facade over one coordinator session: `spawn`/`screen`/`shutdown` keep
//! working for existing callers, plus a `Result`-based
//! [`ScreeningService::try_screen`] that surfaces typed errors (a dead
//! worker's panic reason included) instead of panicking with "service
//! dropped".
//!
//! Threading: std::thread + mpsc for routing, the [`crate::runtime::pool`]
//! for execution (the offline image has no tokio — DESIGN.md §6).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{AdmissionConfig, AdmissionController};
use super::metrics::{AdmissionStats, ServiceMetrics};
use super::protocol::{
    PendingRequest, Request, RequestError, RequestOptions, Response, ScreenResponse,
};
use super::registry::{SessionRegistry, SessionSpec, SessionState};
use crate::linalg::DesignMatrix;
use crate::path::{PathConfig, SolverKind};
use crate::runtime::pool::WorkerPool;
use crate::runtime::scheduler::{PoolHandle, Scheduler};
use crate::screening::ScreenPipeline;

enum CoordMsg {
    Submit { session: String, pending: PendingRequest },
    Register { spec: SessionSpec, reply: Sender<Result<(), RequestError>> },
    Close { session: String, reply: Sender<Option<ServiceMetrics>> },
    Sessions { reply: Sender<Vec<String>> },
    AdmissionStats { reply: Sender<AdmissionStats> },
    Shutdown { reply: Sender<Vec<(String, ServiceMetrics)>> },
}

/// A submitted request's reply slot. `recv_response` blocks for the typed
/// [`Response`]; `recv` is the screen-shaped convenience used by the
/// facade and most clients.
pub struct PendingResponse {
    rx: Receiver<Response>,
}

impl PendingResponse {
    /// Block for the typed response.
    pub fn recv_response(&self) -> Result<Response, RequestError> {
        self.rx.recv().map_err(|_| {
            RequestError::Disconnected("coordinator shut down before replying".to_string())
        })
    }

    /// Block for a screen response; protocol errors come back as `Err`.
    pub fn recv(&self) -> Result<ScreenResponse, RequestError> {
        match self.recv_response()? {
            Response::Screen(resp) => Ok(resp),
            Response::Error(e) => Err(e),
            other => Err(RequestError::InvalidRequest(format!(
                "expected a screen response, got {other:?}"
            ))),
        }
    }
}

/// Multi-tenant serving front door: owns the router thread and, through it,
/// the session registry. Dropping the coordinator shuts the router down.
pub struct Coordinator {
    tx: Sender<CoordMsg>,
    router: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Coordinator executing session batches on the process-wide pool
    /// ([`pool::global`], sized by `DPP_POOL_THREADS`).
    pub fn new() -> Coordinator {
        Self::with_pool(None)
    }

    /// Coordinator with an explicit pool (benches and tests sweep thread
    /// counts without touching the global pool) and a fully open admission
    /// policy.
    pub fn with_pool(pool: Option<Arc<WorkerPool>>) -> Coordinator {
        Self::with_config(pool, AdmissionConfig::default())
    }

    /// Coordinator with an explicit pool and admission policy (the CLI's
    /// `--admission`/`--max-sessions` knobs build one; the default config
    /// admits everything and never evicts).
    pub fn with_config(pool: Option<Arc<WorkerPool>>, admission: AdmissionConfig) -> Coordinator {
        let (tx, rx) = channel::<CoordMsg>();
        let router = std::thread::Builder::new()
            .name("dpp-coordinator".to_string())
            .spawn(move || router_loop(rx, pool, admission))
            // audit:allow(panic, startup-fatal: no coordinator thread means no service)
            .expect("spawning coordinator router");
        Coordinator { tx, router: Some(router) }
    }

    /// Open a named session; blocks until the registry accepted (or
    /// rejected) the spec, so a following [`Coordinator::submit`] always
    /// finds it.
    pub fn register(&self, spec: SessionSpec) -> Result<(), RequestError> {
        let (rtx, rrx) = channel();
        self.tx
            .send(CoordMsg::Register { spec, reply: rtx })
            .map_err(|_| disconnected())?;
        rrx.recv().map_err(|_| disconnected())?
    }

    /// Fire a request at a session. Never blocks: validation failures and
    /// routing failures are delivered through the returned slot as typed
    /// errors. λ is validated here, at the API boundary — a NaN λ used to
    /// reach the worker's batch sort and panic it.
    pub fn submit(&self, session: &str, request: Request) -> PendingResponse {
        let (rtx, rrx) = channel();
        if let Some(lam) = request.lam() {
            if !lam.is_finite() || lam < 0.0 {
                let _ = rtx.send(Response::Error(RequestError::InvalidLambda(lam)));
                return PendingResponse { rx: rrx };
            }
        }
        let msg = CoordMsg::Submit {
            session: session.to_string(),
            // audit:allow(determinism:clock, latency metric only; never feeds numerics)
            pending: PendingRequest { request, reply: rtx.clone(), t0: Instant::now() },
        };
        if self.tx.send(msg).is_err() {
            let _ = rtx.send(Response::Error(disconnected()));
        }
        PendingResponse { rx: rrx }
    }

    /// Names of the currently-open sessions, in registration order. The
    /// network server advertises these in its hello so clients can address
    /// sessions without out-of-band configuration.
    pub fn sessions(&self) -> Vec<String> {
        let (rtx, rrx) = channel();
        if self.tx.send(CoordMsg::Sessions { reply: rtx }).is_err() {
            return Vec::new();
        }
        rrx.recv().unwrap_or_default()
    }

    /// Admission counters since startup: requests submitted, requests and
    /// registrations shed, sessions evicted.
    pub fn admission_stats(&self) -> AdmissionStats {
        let (rtx, rrx) = channel();
        if self.tx.send(CoordMsg::AdmissionStats { reply: rtx }).is_err() {
            return AdmissionStats::default();
        }
        rrx.recv().unwrap_or_default()
    }

    /// Close one session, returning its metrics (None if unknown).
    pub fn close_session(&self, session: &str) -> Option<ServiceMetrics> {
        let (rtx, rrx) = channel();
        self.tx
            .send(CoordMsg::Close { session: session.to_string(), reply: rtx })
            .ok()?;
        rrx.recv().ok().flatten()
    }

    /// Stop the router and collect per-session metrics in registration
    /// order.
    pub fn shutdown(mut self) -> Vec<(String, ServiceMetrics)> {
        let (rtx, rrx) = channel();
        let _ = self.tx.send(CoordMsg::Shutdown { reply: rtx });
        let metrics = rrx.recv().unwrap_or_default();
        if let Some(router) = self.router.take() {
            let _ = router.join();
        }
        metrics
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(router) = self.router.take() {
            let (rtx, _rrx) = channel();
            let _ = self.tx.send(CoordMsg::Shutdown { reply: rtx });
            let _ = router.join();
        }
    }
}

fn disconnected() -> RequestError {
    RequestError::Disconnected("coordinator router is gone".to_string())
}

/// One scheduled unit of work: the session's state travels with the
/// request, so the executor needs no registry access — and a session closed
/// *after* a request was admitted still answers it (the `Arc` keeps the
/// state alive until the queue drains).
type Unit = (Arc<Mutex<SessionState>>, PendingRequest);

/// The router: admit each message as it arrives and enqueue admitted
/// requests on the session's dispatch queue — per-session FIFO order, one
/// live dispatcher per session ([`Scheduler`]), so the session's sequential
/// state stays single-owner and distinct sessions never wait on each other.
/// Register/close/shutdown interleave with submits in arrival order, so a
/// submit that follows a successful register (same client thread) always
/// finds its session.
///
/// Batches form from backlog: whatever queues up behind a busy dispatcher
/// becomes its next batch, and a session's responses are invariant to how
/// its request stream is split into batches (λ-descending processing within
/// each batch — the bit-identity contract). The admission controller gates
/// every enqueue on the scheduler's queue depths, shedding with typed
/// [`RequestError::Overloaded`] instead of queueing unboundedly, and the
/// TTL sweep evicts sessions that have been idle past the configured TTL
/// (only when their queue is quiescent — in-flight work is activity).
///
/// Nested parallelism: every batch runs on a pool worker, and a sharded
/// backend's own `pool.run` sweeps *help* from inside the worker
/// (work-stealing join) — idle workers execute the shard jobs instead of
/// the whole sweep running inline, with results bit-identical by the pool's
/// determinism contract.
fn router_loop(rx: Receiver<CoordMsg>, pool: Option<Arc<WorkerPool>>, admission: AdmissionConfig) {
    let handle = match pool {
        Some(p) => PoolHandle::Owned(p),
        None => PoolHandle::Global,
    };
    let mut registry = SessionRegistry::new();
    let mut admission = AdmissionController::new(admission);
    // Wake up at a fraction of the TTL even when no messages arrive, so
    // idle sessions are actually evicted on time.
    let ttl_tick = admission
        .config()
        .session_ttl
        .map(|ttl| ttl.clamp(Duration::from_millis(5), Duration::from_millis(100)));
    let sched: Scheduler<Unit> = Scheduler::new(handle, |_key, batch: Vec<Unit>| {
        // every unit of one key carries the same session Arc (a Close
        // removes the key's queue before the name can be re-registered)
        let Some((state, _)) = batch.first() else { return };
        let state = Arc::clone(state);
        let batch: Vec<PendingRequest> = batch.into_iter().map(|(_, p)| p).collect();
        // process_batch catches per-request panics, so a poisoned session
        // cannot take its dispatcher (or the pool) down with it
        state.lock().unwrap_or_else(|e| e.into_inner()).process_batch(batch);
    });
    loop {
        let msg = match ttl_tick {
            Some(tick) => match rx.recv_timeout(tick) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => return,
            },
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => return,
            },
        };
        match msg {
            None => {}
            Some(CoordMsg::Register { spec, reply }) => {
                let name = spec.name.clone();
                let res = admission
                    .admit_register(registry.len())
                    .and_then(|()| registry.register(spec));
                if res.is_ok() {
                    admission.touch(&name);
                }
                let _ = reply.send(res);
            }
            Some(CoordMsg::Close { session, reply }) => {
                // drain the queue first (waits out an in-flight batch):
                // every undelivered request gets a typed reply, then the
                // registry drops the session
                for (_, pending) in sched.remove(&session) {
                    let _ = pending.reply.send(Response::Error(
                        RequestError::SessionClosed {
                            session: session.clone(),
                            reason: "session closed with the request still queued"
                                .to_string(),
                        },
                    ));
                }
                admission.forget(&session);
                let _ = reply.send(registry.close(&session));
            }
            Some(CoordMsg::Sessions { reply }) => {
                let _ = reply.send(registry.names().to_vec());
            }
            Some(CoordMsg::AdmissionStats { reply }) => {
                let _ = reply.send(admission.stats());
            }
            Some(CoordMsg::Shutdown { reply }) => {
                // every admitted request is answered before teardown
                sched.quiesce();
                let _ = reply.send(registry.drain_metrics());
                return;
            }
            Some(CoordMsg::Submit { session, pending }) => match registry.get(&session) {
                None => {
                    let err = match registry.eviction_reason(&session) {
                        Some(reason) => RequestError::SessionClosed {
                            session: session.clone(),
                            reason: reason.to_string(),
                        },
                        None => RequestError::UnknownSession(session),
                    };
                    let _ = pending.reply.send(Response::Error(err));
                }
                Some(state) => {
                    match admission.admit(sched.depth(&session), sched.total_pending()) {
                        Err(e) => {
                            let _ = pending.reply.send(Response::Error(e));
                        }
                        Ok(()) => {
                            admission.touch(&session);
                            sched.enqueue(&session, (state, pending));
                        }
                    }
                }
            },
        }
        // TTL sweep: evict sessions idle past the TTL. Only quiescent
        // queues are evicted — queued or in-flight work counts as activity
        // the TTL book just hasn't seen yet.
        for name in admission.expired() {
            if !sched.is_idle(&name) {
                admission.touch(&name);
                continue;
            }
            if registry.evict(&name, admission.eviction_reason()).is_some() {
                admission.record_eviction();
            }
            admission.forget(&name);
        }
    }
}

/// Name of the facade's only session.
pub const SERVICE_SESSION: &str = "service";

/// Single-session facade over the serving protocol — the pre-protocol
/// `ScreeningService` surface, unchanged for existing callers. Spawning
/// registers one session named [`SERVICE_SESSION`] on a private
/// [`Coordinator`]; `screen`/`request` submit [`Request::Screen`]s to it.
pub struct ScreeningService {
    coord: Coordinator,
}

impl ScreeningService {
    /// Spawn the service owning `x`, `y`. Accepts any matrix backend
    /// (dense, CSC, …) and any screening pipeline — a bare
    /// [`crate::path::RuleKind`] converts implicitly, composed pipelines
    /// come from [`ScreenPipeline::parse`].
    pub fn spawn<M: DesignMatrix + Send + 'static>(
        x: M,
        y: Vec<f64>,
        pipeline: impl Into<ScreenPipeline>,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> ScreeningService {
        Self::spawn_boxed(Box::new(x), y, pipeline, solver, cfg)
    }

    /// Spawn from an already-boxed backend (the CLI picks dense/CSC at
    /// runtime and hands the box over directly).
    pub fn spawn_boxed(
        x: Box<dyn DesignMatrix + Send>,
        y: Vec<f64>,
        pipeline: impl Into<ScreenPipeline>,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> ScreeningService {
        let coord = Coordinator::new();
        coord
            .register(SessionSpec::boxed(SERVICE_SESSION, x, y, pipeline, solver, cfg))
            // audit:allow(panic, documented panicking constructor; typed path is Coordinator::register)
            .unwrap_or_else(|e| panic!("spawning screening service: {e}"));
        ScreeningService { coord }
    }

    /// Fire a screen request; the response arrives on the returned slot.
    pub fn request(&self, lam: f64) -> PendingResponse {
        self.request_with(lam, RequestOptions::default())
    }

    /// Screen request with per-request options (deadline, tolerance,
    /// pipeline override).
    pub fn request_with(&self, lam: f64, opts: RequestOptions) -> PendingResponse {
        self.coord.submit(SERVICE_SESSION, Request::Screen { lam, opts })
    }

    /// Blocking request with typed errors: an invalid λ, a worker panic
    /// (with its reason), and coordinator shutdown all come back as
    /// [`RequestError`] instead of a panic.
    pub fn try_screen(&self, lam: f64) -> Result<ScreenResponse, RequestError> {
        self.request(lam).recv()
    }

    /// Convenience: blocking request. Panics on request failure — prefer
    /// [`ScreeningService::try_screen`] when the caller can handle errors;
    /// the panic message carries the typed reason (e.g. the worker's own
    /// panic payload), not a bare "service dropped".
    pub fn screen(&self, lam: f64) -> ScreenResponse {
        self.try_screen(lam)
            // audit:allow(panic, documented panicking facade; typed path is try_screen)
            .unwrap_or_else(|e| panic!("screening service request failed: {e}"))
    }

    /// The underlying coordinator, for callers that want to grow the
    /// single-session facade into a multi-tenant deployment (register more
    /// sessions, submit typed requests to [`SERVICE_SESSION`]).
    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Stop the worker and collect metrics.
    pub fn shutdown(self) -> ServiceMetrics {
        self.coord
            .shutdown()
            .into_iter()
            .find(|(name, _)| name == SERVICE_SESSION)
            .map(|(_, metrics)| metrics)
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::path::RuleKind;
    use crate::solver::{cd::CdSolver, LassoSolver, SolveOptions};

    fn service(seed: u64) -> (ScreeningService, crate::data::Dataset, f64) {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, seed);
        let lam_max = crate::solver::dual::lambda_max(&ds.x, &ds.y);
        let svc = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        );
        (svc, ds, lam_max)
    }

    #[test]
    fn serves_exact_solutions() {
        let (svc, ds, lam_max) = service(1);
        let resp = svc.screen(0.5 * lam_max);
        assert!(!resp.partial);
        // compare against direct solve
        let cols: Vec<usize> = (0..ds.p()).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let direct = CdSolver
            .solve(&ds.x, &ds.y, &cols, 0.5 * lam_max, None, &opts)
            .scatter(&cols, ds.p());
        for j in 0..ds.p() {
            assert!(
                (resp.beta[j] - direct[j]).abs() < 1e-4 * (1.0 + direct[j].abs()),
                "feature {j}"
            );
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 1);
    }

    #[test]
    fn sequential_state_reused_descending() {
        let (svc, _ds, lam_max) = service(2);
        // descending λ sequence: each response exact, screening effective
        let mut last_kept = usize::MAX;
        for f in [0.8, 0.6, 0.4, 0.2] {
            let resp = svc.screen(f * lam_max);
            assert!(resp.kept.len() <= resp.beta.len());
            last_kept = resp.kept.len();
        }
        assert!(last_kept > 0);
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 4);
        assert!(metrics.rejection_ratio.mean() > 0.5);
    }

    #[test]
    fn concurrent_requests_batched() {
        let (svc, _ds, lam_max) = service(3);
        // fire several requests before reading replies → they arrive as a batch
        let rxs: Vec<_> =
            [0.7, 0.5, 0.3].iter().map(|f| svc.request(f * lam_max)).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(!resp.beta.is_empty());
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 3);
        // at least one multi-request batch must have formed OR requests were
        // processed in ≤3 batches
        assert!(metrics.batches <= 3);
    }

    #[test]
    fn pipeline_service_reports_stages_and_exact_solutions() {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, 9);
        let lam_max = crate::solver::dual::lambda_max(&ds.x, &ds.y);
        let pipe = crate::screening::ScreenPipeline::parse("hybrid:strong+edpp")
            .unwrap()
            .with_dynamic(true);
        let svc = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            pipe,
            SolverKind::Cd,
            PathConfig::default(),
        );
        let resp = svc.screen(0.4 * lam_max);
        assert_eq!(resp.stage_discards.len(), 2);
        assert_eq!(resp.stage_discards[0].stage, "edpp");
        assert_eq!(resp.stage_discards[1].stage, "strong");
        // the hybrid mask dominates the plain-EDPP service's at the same λ
        let svc_edpp = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        );
        let resp_edpp = svc_edpp.screen(0.4 * lam_max);
        assert!(resp.discarded >= resp_edpp.discarded);
        svc_edpp.shutdown();
        // exactness: compare against a direct full solve
        let cols: Vec<usize> = (0..ds.p()).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let direct = CdSolver
            .solve(&ds.x, &ds.y, &cols, 0.4 * lam_max, None, &opts)
            .scatter(&cols, ds.p());
        for j in 0..ds.p() {
            assert!(
                (resp.beta[j] - direct[j]).abs() < 1e-4 * (1.0 + direct[j].abs()),
                "feature {j}"
            );
        }
        svc.shutdown();
    }

    #[test]
    fn lam_above_lambda_max_clamped() {
        let (svc, ds, lam_max) = service(4);
        let resp = svc.screen(lam_max * 2.0);
        assert!(resp.beta.iter().all(|b| *b == 0.0));
        assert_eq!(resp.true_zeros, ds.p());
        svc.shutdown();
    }

    #[test]
    fn invalid_lambda_is_a_typed_error_not_a_poisoned_worker() {
        let (svc, _ds, lam_max) = service(5);
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.5] {
            match svc.try_screen(bad) {
                Err(RequestError::InvalidLambda(_)) => {}
                other => panic!("λ={bad}: expected InvalidLambda, got {other:?}"),
            }
        }
        // the worker survived and still answers
        let resp = svc.try_screen(0.5 * lam_max).unwrap();
        assert!(!resp.beta.is_empty());
        let metrics = svc.shutdown();
        // rejected requests never reached the session
        assert_eq!(metrics.requests, 1);
    }

    #[test]
    fn unknown_session_and_shutdown_are_typed() {
        let (svc, _ds, lam_max) = service(6);
        let err = svc
            .coordinator()
            .submit("nope", Request::Screen { lam: 0.5 * lam_max, opts: Default::default() })
            .recv()
            .unwrap_err();
        assert_eq!(err, RequestError::UnknownSession("nope".to_string()));
        svc.shutdown();
    }

    fn session_spec(name: &str, seed: u64) -> SessionSpec {
        let ds = synthetic::synthetic1(25, 60, 5, 0.1, seed);
        SessionSpec::new(
            name,
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        )
    }

    #[test]
    fn admission_depth_cap_sheds_with_typed_overloaded() {
        // depth cap 0: every request sheds — deterministic, no racing the
        // solver
        let cfg = AdmissionConfig { max_session_pending: Some(0), ..Default::default() };
        let coord = Coordinator::with_config(None, cfg);
        coord.register(session_spec("s", 31)).unwrap();
        let err = coord
            .submit("s", Request::Screen { lam: 1.0, opts: Default::default() })
            .recv()
            .unwrap_err();
        match err {
            RequestError::Overloaded { retry_after_ms } => assert!(retry_after_ms >= 25),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        let stats = coord.admission_stats();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.shed, 1);
        coord.shutdown();
    }

    #[test]
    fn max_sessions_cap_sheds_registrations() {
        let cfg = AdmissionConfig { max_sessions: Some(1), ..Default::default() };
        let coord = Coordinator::with_config(None, cfg);
        coord.register(session_spec("a", 41)).unwrap();
        match coord.register(session_spec("b", 42)) {
            Err(RequestError::Overloaded { .. }) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(coord.sessions(), vec!["a".to_string()]);
        assert_eq!(coord.admission_stats().shed, 1);
        coord.shutdown();
    }

    #[test]
    fn ttl_eviction_is_a_typed_session_closed() {
        let cfg = AdmissionConfig {
            session_ttl: Some(std::time::Duration::from_millis(0)),
            ..Default::default()
        };
        let coord = Coordinator::with_config(None, cfg);
        coord.register(session_spec("s", 33)).unwrap();
        // zero TTL: the next router sweep evicts the idle session
        let t0 = std::time::Instant::now();
        while !coord.sessions().is_empty() {
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(10),
                "session was never evicted"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        match coord.submit("s", Request::SessionStats).recv_response().unwrap() {
            Response::Error(RequestError::SessionClosed { session, reason }) => {
                assert_eq!(session, "s");
                assert!(reason.contains("evicted"), "{reason}");
            }
            other => panic!("expected SessionClosed, got {other:?}"),
        }
        assert_eq!(coord.admission_stats().evicted, 1);
        coord.shutdown();
    }

    #[test]
    fn coordinator_serves_multiple_sessions() {
        let coord = Coordinator::new();
        let mut lam_maxes = Vec::new();
        for (i, seed) in [11u64, 12, 13].iter().enumerate() {
            let ds = synthetic::synthetic1(25 + 5 * i, 80 + 20 * i, 8, 0.1, *seed);
            lam_maxes.push(crate::solver::dual::lambda_max(&ds.x, &ds.y));
            coord
                .register(SessionSpec::new(
                    format!("s{i}"),
                    ds.x.clone(),
                    ds.y.clone(),
                    RuleKind::Edpp,
                    SolverKind::Cd,
                    PathConfig::default(),
                ))
                .unwrap();
        }
        // interleaved submissions across all three sessions
        let mut slots = Vec::new();
        for f in [0.7, 0.4] {
            for (i, lm) in lam_maxes.iter().enumerate() {
                slots.push(coord.submit(
                    &format!("s{i}"),
                    Request::Screen { lam: f * lm, opts: Default::default() },
                ));
            }
        }
        for slot in slots {
            let resp = slot.recv().unwrap();
            assert!(!resp.beta.is_empty());
            assert!(!resp.partial);
        }
        let metrics = coord.shutdown();
        assert_eq!(metrics.len(), 3);
        assert_eq!(metrics[0].0, "s0");
        for (_, m) in &metrics {
            assert_eq!(m.requests, 2);
        }
    }
}
