//! Screening-as-a-service: a request/response loop around the sequential
//! screening state machine.
//!
//! Model-selection workloads (cross-validation, stability selection) issue
//! many λ-evaluations against one dataset. The service owns the dataset and
//! the sequential state (exact solution at the last solved λ), **batches**
//! concurrently-arriving requests, and processes each batch in descending-λ
//! order so every request benefits from the tightest available θ*(λ₀) — the
//! same trick that makes sequential rules dominate basic ones (§4.1.1).
//!
//! Threading: one worker thread owns all state; clients talk over mpsc
//! channels (the offline image has no tokio — DESIGN.md §3).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use super::metrics::ServiceMetrics;
use crate::linalg::DesignMatrix;
use crate::path::{PathConfig, RuleKind, SolverKind};
use crate::screening::{theta_from_solution, ScreenContext, ScreeningRule, StepInput};
use crate::solver::LassoSolver;

/// A screening/solve request at one λ.
pub struct ScreenRequest {
    pub lam: f64,
    pub reply: Sender<ScreenResponse>,
}

/// Response: the surviving features and the exact solution at λ.
#[derive(Clone, Debug)]
pub struct ScreenResponse {
    pub lam: f64,
    pub kept: Vec<usize>,
    pub beta: Vec<f64>,
    pub discarded: usize,
    pub true_zeros: usize,
    pub latency_s: f64,
}

enum Msg {
    Request(ScreenRequest, Instant),
    Shutdown(Sender<ServiceMetrics>),
}

/// Handle to a running screening service.
pub struct ScreeningService {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl ScreeningService {
    /// Spawn the service worker owning `x`, `y`. Accepts any matrix backend
    /// (dense, CSC, …) — one service binary handles them all.
    pub fn spawn<M: DesignMatrix + Send + 'static>(
        x: M,
        y: Vec<f64>,
        rule: RuleKind,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> ScreeningService {
        Self::spawn_boxed(Box::new(x), y, rule, solver, cfg)
    }

    /// Spawn from an already-boxed backend (the CLI picks dense/CSC at
    /// runtime and hands the box over directly).
    pub fn spawn_boxed(
        x: Box<dyn DesignMatrix + Send>,
        y: Vec<f64>,
        rule: RuleKind,
        solver: SolverKind,
        cfg: PathConfig,
    ) -> ScreeningService {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || worker_loop(x, y, rule, solver, cfg, rx));
        ScreeningService { tx, worker: Some(worker) }
    }

    /// Fire a request; the response arrives on the returned receiver.
    pub fn request(&self, lam: f64) -> Receiver<ScreenResponse> {
        let (reply, rx) = channel();
        let _ = self
            .tx
            .send(Msg::Request(ScreenRequest { lam, reply }, Instant::now()));
        rx
    }

    /// Convenience: blocking request.
    pub fn screen(&self, lam: f64) -> ScreenResponse {
        self.request(lam).recv().expect("service dropped")
    }

    /// Stop the worker and collect metrics.
    pub fn shutdown(mut self) -> ServiceMetrics {
        let (mtx, mrx) = channel();
        let _ = self.tx.send(Msg::Shutdown(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        metrics
    }
}

impl Drop for ScreeningService {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let (mtx, _mrx) = channel();
            let _ = self.tx.send(Msg::Shutdown(mtx));
            let _ = w.join();
        }
    }
}

fn worker_loop(
    x: Box<dyn DesignMatrix + Send>,
    y: Vec<f64>,
    rule_kind: RuleKind,
    solver_kind: SolverKind,
    cfg: PathConfig,
    rx: Receiver<Msg>,
) {
    let x: &dyn DesignMatrix = &*x;
    // slack > 0 widens keep-decisions for reduced-precision backends
    // (f32 shards) — same discipline as the PJRT sweep, DESIGN.md §1
    let ctx = ScreenContext::with_sweep_slack(x, &y, x, cfg.safety_slack);
    let rule: Option<Box<dyn ScreeningRule>> = match rule_kind {
        RuleKind::None => None,
        RuleKind::Edpp => Some(Box::new(crate::screening::edpp::EdppRule)),
        RuleKind::Dpp => Some(Box::new(crate::screening::dpp::DppRule)),
        RuleKind::Safe => Some(Box::new(crate::screening::safe::SafeRule)),
        RuleKind::Strong => Some(Box::new(crate::screening::strong::StrongRule)),
        _ => Some(Box::new(crate::screening::edpp::EdppRule)),
    };
    let solver: Box<dyn LassoSolver> = match solver_kind {
        SolverKind::Cd => Box::new(crate::solver::cd::CdSolver),
        SolverKind::Fista => Box::new(crate::solver::fista::FistaSolver),
        SolverKind::Lars => Box::new(crate::solver::lars::LarsSolver),
    };
    let p = x.n_cols();
    let mut metrics = ServiceMetrics::new();

    // sequential screening state: the *smallest* λ solved so far with its
    // exact solution; requests at smaller λ chain from it
    let mut lam_state = ctx.lam_max;
    let mut theta_state: Vec<f64> = y.iter().map(|v| v / ctx.lam_max).collect();
    let mut beta_state: Vec<f64> = vec![0.0; p];

    loop {
        // block for one message, then drain whatever else arrived → a batch
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return,
        };
        let mut batch: Vec<(ScreenRequest, Instant)> = Vec::new();
        let mut shutdown: Option<Sender<ServiceMetrics>> = None;
        match first {
            Msg::Request(r, t) => batch.push((r, t)),
            Msg::Shutdown(s) => shutdown = Some(s),
        }
        while let Ok(m) = rx.try_recv() {
            match m {
                Msg::Request(r, t) => batch.push((r, t)),
                Msg::Shutdown(s) => shutdown = Some(s),
            }
        }
        if !batch.is_empty() {
            metrics.record_batch(batch.len());
            // λ-descending order: larger λ solved first tightens θ for the rest
            batch.sort_by(|a, b| b.0.lam.partial_cmp(&a.0.lam).unwrap());
            for (req, t0) in batch {
                let lam = req.lam.min(ctx.lam_max);
                // screen from the best available anchor: state if its λ is
                // ≥ lam (sequential), else fall back to λmax anchor
                let (anchor_lam, anchor_theta) = if lam_state >= lam {
                    (lam_state, theta_state.clone())
                } else {
                    (ctx.lam_max, y.iter().map(|v| v / ctx.lam_max).collect())
                };
                let mut keep = vec![true; p];
                if let Some(rule) = &rule {
                    let step = StepInput {
                        lam_prev: anchor_lam,
                        lam,
                        theta_prev: &anchor_theta,
                    };
                    rule.screen(&ctx, &step, &mut keep);
                }
                let mut cols: Vec<usize> = (0..p).filter(|&j| keep[j]).collect();
                let is_safe = rule.as_ref().map(|r| r.is_safe()).unwrap_or(true);
                let res = loop {
                    let warm: Vec<f64> = cols.iter().map(|&j| beta_state[j]).collect();
                    let r = solver.solve(x, &y, &cols, lam, Some(&warm), &cfg.solve_opts);
                    if is_safe || !cfg.kkt_repair {
                        break r;
                    }
                    let full = r.scatter(&cols, p);
                    let mut resid = y.to_vec();
                    for (j, b) in full.iter().enumerate() {
                        if *b != 0.0 {
                            x.col_axpy_into(j, -b, &mut resid);
                        }
                    }
                    let viol =
                        crate::screening::strong::kkt_violations(&ctx, &resid, lam, &keep);
                    if viol.is_empty() {
                        break r;
                    }
                    for j in viol {
                        keep[j] = true;
                    }
                    cols = (0..p).filter(|&j| keep[j]).collect();
                };
                let beta = res.scatter(&cols, p);
                let true_zeros = beta.iter().filter(|b| **b == 0.0).count();
                let discarded = p - keep.iter().filter(|k| **k).count();
                // advance state if this is the deepest λ seen
                if lam < lam_state {
                    theta_state = theta_from_solution(x, &y, &beta, lam);
                    lam_state = lam;
                    beta_state = beta.clone();
                }
                let latency = t0.elapsed().as_secs_f64();
                metrics.record_request(latency);
                metrics.record_screen(cols.len(), discarded, true_zeros);
                let _ = req.reply.send(ScreenResponse {
                    lam,
                    kept: cols,
                    beta,
                    discarded,
                    true_zeros,
                    latency_s: latency,
                });
            }
        }
        if let Some(s) = shutdown {
            let _ = s.send(metrics.clone());
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::{cd::CdSolver, SolveOptions};

    fn service(seed: u64) -> (ScreeningService, crate::data::Dataset, f64) {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, seed);
        let lam_max = crate::solver::dual::lambda_max(&ds.x, &ds.y);
        let svc = ScreeningService::spawn(
            ds.x.clone(),
            ds.y.clone(),
            RuleKind::Edpp,
            SolverKind::Cd,
            PathConfig::default(),
        );
        (svc, ds, lam_max)
    }

    #[test]
    fn serves_exact_solutions() {
        let (svc, ds, lam_max) = service(1);
        let resp = svc.screen(0.5 * lam_max);
        // compare against direct solve
        let cols: Vec<usize> = (0..ds.p()).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let direct = CdSolver
            .solve(&ds.x, &ds.y, &cols, 0.5 * lam_max, None, &opts)
            .scatter(&cols, ds.p());
        for j in 0..ds.p() {
            assert!(
                (resp.beta[j] - direct[j]).abs() < 1e-4 * (1.0 + direct[j].abs()),
                "feature {j}"
            );
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 1);
    }

    #[test]
    fn sequential_state_reused_descending() {
        let (svc, _ds, lam_max) = service(2);
        // descending λ sequence: each response exact, screening effective
        let mut last_kept = usize::MAX;
        for f in [0.8, 0.6, 0.4, 0.2] {
            let resp = svc.screen(f * lam_max);
            assert!(resp.kept.len() <= resp.beta.len());
            last_kept = resp.kept.len();
        }
        assert!(last_kept > 0);
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 4);
        assert!(metrics.rejection_ratio.mean() > 0.5);
    }

    #[test]
    fn concurrent_requests_batched() {
        let (svc, _ds, lam_max) = service(3);
        // fire several requests before reading replies → they arrive as a batch
        let rxs: Vec<_> =
            [0.7, 0.5, 0.3].iter().map(|f| svc.request(f * lam_max)).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(!resp.beta.is_empty());
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.requests, 3);
        // at least one multi-request batch must have formed OR requests were
        // processed in ≤3 batches
        assert!(metrics.batches <= 3);
    }

    #[test]
    fn lam_above_lambda_max_clamped() {
        let (svc, ds, lam_max) = service(4);
        let resp = svc.screen(lam_max * 2.0);
        assert!(resp.beta.iter().all(|b| *b == 0.0));
        assert_eq!(resp.true_zeros, ds.p());
        svc.shutdown();
    }
}
