//! Admission control and session lifecycle policy (DESIGN.md §4): the
//! coordinator's defense against unbounded queueing.
//!
//! The scheduler ([`crate::runtime::scheduler`]) makes enqueueing free —
//! which is exactly why it needs a policy on top: without one, a tenant
//! outrunning the pool piles work into its dispatch queue forever and every
//! deadline inside drowns. [`AdmissionController`] instead *sheds* load with
//! a typed [`RequestError::Overloaded`] carrying a deterministic
//! `retry_after_ms` hint, bounds the session count, and retires sessions
//! idle past a TTL so their memory (the backend can be an entire dataset)
//! comes back.
//!
//! Policy knobs ([`AdmissionConfig`], CLI `--admission`/`--max-sessions`):
//!
//! * `depth` — per-session pending cap: a session with this many requests
//!   enqueued-but-unfinished sheds new ones;
//! * `total` — coordinator-wide pending cap across all sessions (pool
//!   saturation backstop);
//! * `ttl-ms` — idle eviction: a session untouched this long is closed with
//!   an eviction reason once its queue is idle;
//! * `max_sessions` — registration cap.
//!
//! Everything here is bookkeeping over queue depths — admission decisions
//! never read the matrices, so shedding cannot perturb what admitted
//! requests compute (the bit-identity contract is untouched).

use std::time::{Duration, Instant};

use super::metrics::AdmissionStats;
use super::protocol::RequestError;

/// Retry-hint quantum: one queued-but-unfinished request is assumed to be
/// worth this many milliseconds of backoff. Deterministic in the queue
/// state, so identical load patterns shed with identical hints.
const RETRY_QUANTUM_MS: u64 = 25;

/// Longest retry hint ever issued (the hint is advice, not a lease).
const RETRY_CAP_MS: u64 = 5_000;

/// Admission policy knobs. `Default` is fully open — no caps, no TTL —
/// which is the pre-admission behavior of the coordinator.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct AdmissionConfig {
    /// Maximum registered sessions; registrations beyond it are shed.
    pub max_sessions: Option<usize>,
    /// Per-session pending-request cap (scheduler queue depth).
    pub max_session_pending: Option<usize>,
    /// Coordinator-wide pending-request cap across all sessions.
    pub max_total_pending: Option<usize>,
    /// Idle eviction: sessions untouched this long are closed.
    pub session_ttl: Option<Duration>,
}

impl AdmissionConfig {
    /// Parse the CLI `--admission` spec: comma-separated `key=value` pairs
    /// with keys `depth`, `total`, `ttl-ms` (e.g. `depth=8,total=64,
    /// ttl-ms=30000`). The session cap rides the separate `--max-sessions`
    /// flag and is left untouched here.
    pub fn parse(spec: &str) -> Result<AdmissionConfig, String> {
        let mut cfg = AdmissionConfig::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("bad --admission part `{part}`: expected key=value"))?;
            let parsed: u64 = value
                .trim()
                .parse()
                .map_err(|_| format!("bad --admission value in `{part}`: expected an integer"))?;
            match key.trim() {
                "depth" => cfg.max_session_pending = Some(parsed as usize),
                "total" => cfg.max_total_pending = Some(parsed as usize),
                "ttl-ms" => cfg.session_ttl = Some(Duration::from_millis(parsed)),
                other => {
                    return Err(format!(
                        "unknown --admission key `{other}` (expected depth, total, or ttl-ms)"
                    ))
                }
            }
        }
        Ok(cfg)
    }

    /// True when at least one knob is set (the router skips admission
    /// bookkeeping entirely otherwise).
    pub fn is_active(&self) -> bool {
        self.max_sessions.is_some()
            || self.max_session_pending.is_some()
            || self.max_total_pending.is_some()
            || self.session_ttl.is_some()
    }
}

/// Deterministic backoff hint for a shed request: scale with how deep the
/// offending queue already is, clamped to `[RETRY_QUANTUM_MS, RETRY_CAP_MS]`.
fn retry_hint_ms(pending: usize) -> u64 {
    (pending as u64).saturating_mul(RETRY_QUANTUM_MS).clamp(RETRY_QUANTUM_MS, RETRY_CAP_MS)
}

/// The coordinator-side policy state: per-session last-activity stamps plus
/// shed/eviction counters. Owned by the router thread — no locking here.
pub struct AdmissionController {
    cfg: AdmissionConfig,
    /// Last-activity stamp per session, in registration order (a `Vec`
    /// keeps eviction scans deterministic; session counts are small).
    touched: Vec<(String, Instant)>,
    stats: AdmissionStats,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> AdmissionController {
        AdmissionController { cfg, touched: Vec::new(), stats: AdmissionStats::default() }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Gate a registration against the session cap.
    pub fn admit_register(&mut self, current_sessions: usize) -> Result<(), RequestError> {
        if let Some(cap) = self.cfg.max_sessions {
            if current_sessions >= cap {
                self.stats.shed += 1;
                return Err(RequestError::Overloaded {
                    retry_after_ms: retry_hint_ms(current_sessions),
                });
            }
        }
        Ok(())
    }

    /// Gate a request against the queue-depth caps. `session_pending` and
    /// `total_pending` are the scheduler's depths *before* this request.
    pub fn admit(
        &mut self,
        session_pending: usize,
        total_pending: usize,
    ) -> Result<(), RequestError> {
        self.stats.submitted += 1;
        if let Some(cap) = self.cfg.max_session_pending {
            if session_pending >= cap {
                self.stats.shed += 1;
                return Err(RequestError::Overloaded {
                    retry_after_ms: retry_hint_ms(session_pending),
                });
            }
        }
        if let Some(cap) = self.cfg.max_total_pending {
            if total_pending >= cap {
                self.stats.shed += 1;
                return Err(RequestError::Overloaded {
                    retry_after_ms: retry_hint_ms(total_pending),
                });
            }
        }
        Ok(())
    }

    /// Record session activity (registration or an admitted request) for
    /// the TTL clock. No-op unless a TTL is configured.
    pub fn touch(&mut self, session: &str) {
        if self.cfg.session_ttl.is_none() {
            return;
        }
        // audit:allow(determinism:clock, TTL bookkeeping only; never feeds numerics)
        let now = Instant::now();
        match self.touched.iter_mut().find(|(name, _)| name == session) {
            Some((_, at)) => *at = now,
            None => self.touched.push((session.to_string(), now)),
        }
    }

    /// Drop a session from the TTL book (closed or evicted).
    pub fn forget(&mut self, session: &str) {
        self.touched.retain(|(name, _)| name != session);
    }

    /// Sessions idle past the TTL, in registration order. The caller must
    /// still confirm the session's queue is idle before evicting — a
    /// request in flight counts as activity it just hasn't seen yet.
    pub fn expired(&self) -> Vec<String> {
        let Some(ttl) = self.cfg.session_ttl else {
            return Vec::new();
        };
        self.touched
            .iter()
            // audit:allow(determinism:clock, TTL bookkeeping only; never feeds numerics)
            .filter(|(_, at)| at.elapsed() >= ttl)
            .map(|(name, _)| name.clone())
            .collect()
    }

    /// Human-readable reason attached to a TTL eviction's tombstone.
    pub fn eviction_reason(&self) -> String {
        let ttl_ms =
            self.cfg.session_ttl.map(|d| d.as_millis() as u64).unwrap_or_default();
        format!("evicted: idle past session-ttl ({ttl_ms}ms)")
    }

    /// Count one completed eviction.
    pub fn record_eviction(&mut self) {
        self.stats.evicted += 1;
    }

    pub fn stats(&self) -> AdmissionStats {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_admits_everything() {
        let mut ctl = AdmissionController::new(AdmissionConfig::default());
        assert!(!ctl.config().is_active());
        for depth in [0usize, 10, 10_000] {
            assert!(ctl.admit(depth, depth * 4).is_ok());
        }
        assert!(ctl.admit_register(1_000).is_ok());
        assert!(ctl.expired().is_empty());
        assert_eq!(ctl.stats().shed, 0);
    }

    #[test]
    fn depth_and_total_caps_shed_with_retry_hint() {
        let cfg = AdmissionConfig {
            max_session_pending: Some(2),
            max_total_pending: Some(3),
            ..Default::default()
        };
        let mut ctl = AdmissionController::new(cfg);
        assert!(ctl.admit(0, 0).is_ok());
        assert!(ctl.admit(1, 1).is_ok());
        match ctl.admit(2, 2) {
            Err(RequestError::Overloaded { retry_after_ms }) => {
                assert_eq!(retry_after_ms, 2 * RETRY_QUANTUM_MS);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // under the per-session cap but over the total cap
        match ctl.admit(1, 3) {
            Err(RequestError::Overloaded { retry_after_ms }) => {
                assert_eq!(retry_after_ms, 3 * RETRY_QUANTUM_MS);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        let stats = ctl.stats();
        assert_eq!(stats.submitted, 4);
        assert_eq!(stats.shed, 2);
    }

    #[test]
    fn session_cap_sheds_registrations() {
        let cfg = AdmissionConfig { max_sessions: Some(2), ..Default::default() };
        let mut ctl = AdmissionController::new(cfg);
        assert!(ctl.admit_register(0).is_ok());
        assert!(ctl.admit_register(1).is_ok());
        assert!(matches!(
            ctl.admit_register(2),
            Err(RequestError::Overloaded { .. })
        ));
    }

    #[test]
    fn zero_ttl_expires_touched_sessions() {
        let cfg = AdmissionConfig {
            session_ttl: Some(Duration::from_millis(0)),
            ..Default::default()
        };
        let mut ctl = AdmissionController::new(cfg);
        ctl.touch("a");
        ctl.touch("b");
        ctl.touch("a"); // re-touch keeps registration order
        assert_eq!(ctl.expired(), vec!["a".to_string(), "b".to_string()]);
        ctl.forget("a");
        ctl.record_eviction();
        assert_eq!(ctl.expired(), vec!["b".to_string()]);
        assert_eq!(ctl.stats().evicted, 1);
        assert!(ctl.eviction_reason().contains("session-ttl"));
    }

    #[test]
    fn no_ttl_never_expires() {
        let mut ctl = AdmissionController::new(AdmissionConfig::default());
        ctl.touch("a"); // no-op without a TTL
        assert!(ctl.expired().is_empty());
    }

    #[test]
    fn parse_round_trips_and_rejects() {
        let cfg = AdmissionConfig::parse("depth=8, total=64, ttl-ms=30000").unwrap();
        assert_eq!(cfg.max_session_pending, Some(8));
        assert_eq!(cfg.max_total_pending, Some(64));
        assert_eq!(cfg.session_ttl, Some(Duration::from_millis(30_000)));
        assert!(cfg.is_active());
        assert_eq!(AdmissionConfig::parse("").unwrap(), AdmissionConfig::default());
        assert!(AdmissionConfig::parse("depth").is_err());
        assert!(AdmissionConfig::parse("depth=abc").is_err());
        assert!(AdmissionConfig::parse("bogus=1").is_err());
    }

    #[test]
    fn retry_hint_is_clamped() {
        assert_eq!(retry_hint_ms(0), RETRY_QUANTUM_MS);
        assert_eq!(retry_hint_ms(1), RETRY_QUANTUM_MS);
        assert_eq!(retry_hint_ms(4), 4 * RETRY_QUANTUM_MS);
        assert_eq!(retry_hint_ms(1_000_000), RETRY_CAP_MS);
    }
}
