//! The typed multi-tenant serving protocol (DESIGN.md §4): every question a
//! client can ask a session, every answer a session can give, and the typed
//! errors that replace the old loop's panics.
//!
//! The request grammar mirrors what large-scale model selection actually
//! needs from DPP/EDPP screening (many λ-evaluations against many
//! datasets): [`Request::Screen`] is the paper's workload, [`Request::Warm`]
//! pre-tightens a session's sequential anchor, [`Request::Predict`] serves
//! ŷ = xᵀβ*(λ) for a fresh sample, [`Request::FitPath`] runs a whole λ-grid,
//! and [`Request::SessionStats`] snapshots the session. Per-request
//! [`RequestOptions`] carry a deadline (gap-safe partial answers instead of
//! blocking — Fercoq et al. 2015 give solves an *anytime* character), a
//! pipeline override, and a solver-tolerance override.
//!
//! Validation discipline: anything that used to poison the worker thread —
//! a NaN λ in the batch sort, a mismatched predict vector — is rejected at
//! the API boundary (or inside the session) with a typed
//! [`RequestError`], never a panic.

use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use super::metrics::ServiceMetrics;
use crate::path::SolverKind;
use crate::screening::{ScreenPipeline, StageCount};

/// Per-request knobs. `Default` is "no deadline, session defaults" — the
/// exact behavior of the pre-protocol service.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestOptions {
    /// Wall-clock deadline measured from submission. The queue wait counts:
    /// the remaining budget when the solve starts is
    /// `deadline − time_in_queue`. A solve that exhausts it returns a
    /// *partial* response tagged with the achieved duality gap.
    pub deadline: Option<Duration>,
    /// Override the session's duality-gap tolerance for this request.
    pub tol_gap: Option<f64>,
    /// Screen through this pipeline instead of the session's. Overrides
    /// anchor at λmax (a throwaway pipeline has no sequential history);
    /// the session's own anchor still advances on the exact solution.
    pub pipeline: Option<ScreenPipeline>,
    /// Solve with this solver instead of the session's. The session's
    /// warm-start cache stays solver-tagged ([`crate::solver::SolverState`]),
    /// so switching solvers mid-session never replays another solver's
    /// momentum state.
    pub solver: Option<SolverKind>,
}

impl RequestOptions {
    /// Convenience: only a deadline.
    pub fn with_deadline(deadline: Duration) -> RequestOptions {
        RequestOptions { deadline: Some(deadline), ..Default::default() }
    }
}

/// One question for one session.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Screen + solve at one λ — the paper's workload.
    Screen { lam: f64, opts: RequestOptions },
    /// Solve a full λ-grid path (`grid` points on λ/λmax ∈ [lo, 1]) on the
    /// session's dataset. Independent of the session's sequential state; a
    /// deadline's remaining budget is re-split across the *remaining* grid
    /// points after every solve (early finishers donate their slack
    /// downstream), so the whole fit stays request-deadline-bounded.
    FitPath { grid: usize, lo: f64, opts: RequestOptions },
    /// ŷ = featuresᵀ·β*(λ) for one fresh sample (features has length p).
    Predict { features: Vec<f64>, lam: f64, opts: RequestOptions },
    /// Pre-solve at λ to tighten the session's sequential anchor and warm
    /// cache without shipping β back.
    Warm { lam: f64 },
    /// Snapshot the session: shape, pipeline, anchor, metrics.
    SessionStats,
}

impl Request {
    /// The λ this request targets, if any — validated at the API boundary
    /// (a NaN λ used to panic the worker's batch sort).
    pub fn lam(&self) -> Option<f64> {
        match self {
            Request::Screen { lam, .. }
            | Request::Predict { lam, .. }
            | Request::Warm { lam } => Some(*lam),
            Request::FitPath { .. } | Request::SessionStats => None,
        }
    }

    /// Batch-ordering key: λ-carrying requests sort descending (larger λ
    /// solved first tightens θ for the rest — §4.1.1); path fits and stats
    /// run after, in arrival order (the sort is stable).
    pub(crate) fn sort_lam(&self) -> f64 {
        self.lam().unwrap_or(f64::NEG_INFINITY)
    }
}

/// Response to a [`Request::Screen`]: the surviving features and the
/// solution at λ. `gap`/`partial` tag deadline-bounded answers.
#[derive(Clone, Debug, PartialEq)]
pub struct ScreenResponse {
    pub lam: f64,
    pub kept: Vec<usize>,
    pub beta: Vec<f64>,
    pub discarded: usize,
    pub true_zeros: usize,
    pub latency_s: f64,
    /// Per-pipeline-stage discard counts in stage order.
    pub stage_discards: Vec<StageCount>,
    /// Features additionally discarded in-solver by the gap-safe hook.
    pub dynamic_discards: usize,
    /// Final duality gap of the solve backing this response.
    pub gap: f64,
    /// True when a deadline stopped the solve before gap ≤ tol: `beta` is
    /// the best gap-certified iterate, not the exact solution, and the
    /// session's sequential anchor was *not* advanced with it.
    pub partial: bool,
}

/// Summary of a [`Request::FitPath`] run.
#[derive(Clone, Debug, PartialEq)]
pub struct PathSummary {
    pub rule: String,
    pub solver: &'static str,
    pub steps: usize,
    pub mean_rejection: f64,
    pub screen_secs: f64,
    pub solve_secs: f64,
    /// Worst per-step duality gap along the path.
    pub max_gap: f64,
    /// Mean working-set size across steps (under the screen-first strategy
    /// this is the mean post-repair kept-set size). Local diagnostics only —
    /// not carried on the wire.
    pub mean_working_set: f64,
    /// Total complement KKT sweeps across the path. Under the working-set
    /// strategy a warm session certifies in one pass per λ, so repeat
    /// FitPath requests show this shrinking. Not carried on the wire.
    pub kkt_passes: usize,
    /// True when the request carried a deadline and at least one step
    /// finished above tolerance (its per-step budget slice cut it short) —
    /// the path's solutions are not all exact, mirroring
    /// [`ScreenResponse::partial`].
    pub partial: bool,
    pub latency_s: f64,
}

/// Answer to a [`Request::Predict`].
#[derive(Clone, Debug, PartialEq)]
pub struct Prediction {
    pub lam: f64,
    pub yhat: f64,
    pub gap: f64,
    pub partial: bool,
    pub latency_s: f64,
}

/// Answer to a [`Request::Warm`].
#[derive(Clone, Debug, PartialEq)]
pub struct WarmResponse {
    pub lam: f64,
    pub gap: f64,
    pub latency_s: f64,
}

/// Answer to a [`Request::SessionStats`].
#[derive(Clone, Debug, PartialEq)]
pub struct SessionStats {
    pub session: String,
    /// Backend label supplied at registration (`csc`, `sharded`, …).
    pub backend: String,
    pub pipeline: String,
    pub n: usize,
    pub p: usize,
    pub lam_max: f64,
    /// λ₀ of the session's current sequential anchor.
    pub anchor_lam: f64,
    pub metrics: ServiceMetrics,
}

/// One answer. Every variant corresponds to exactly one [`Request`] form,
/// plus [`Response::Error`] for typed failures.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Screen(ScreenResponse),
    Path(PathSummary),
    Predict(Prediction),
    Warmed(WarmResponse),
    Stats(SessionStats),
    Error(RequestError),
}

/// Typed request failures — the protocol replaces the old loop's panics
/// (`partial_cmp(..).unwrap()` on NaN λ, `expect("service dropped")` on a
/// dead worker) with these.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestError {
    /// λ must be finite and ≥ 0 (a NaN λ used to poison the batch sort).
    InvalidLambda(f64),
    /// No session registered under this name.
    UnknownSession(String),
    /// A session with this name already exists.
    DuplicateSession(String),
    /// The session's worker panicked; `reason` is the panic payload. All
    /// later requests to the session get the same answer.
    SessionClosed { session: String, reason: String },
    /// Malformed request (mismatched predict vector, empty grid, …) or a
    /// session spec the registry rejected.
    InvalidRequest(String),
    /// The coordinator router is gone (shutdown or crashed).
    Disconnected(String),
    /// The admission policy shed this request (or registration) instead of
    /// queueing it unboundedly: a queue-depth or session cap tripped.
    /// `retry_after_ms` is a deterministic backoff hint scaled to the
    /// offending queue's depth — advice, not a reservation.
    Overloaded { retry_after_ms: u64 },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::InvalidLambda(lam) => {
                write!(f, "invalid λ = {lam} (must be finite and ≥ 0)")
            }
            RequestError::UnknownSession(s) => write!(f, "unknown session `{s}`"),
            RequestError::DuplicateSession(s) => {
                write!(f, "session `{s}` already registered")
            }
            RequestError::SessionClosed { session, reason } => {
                write!(f, "session `{session}` closed: {reason}")
            }
            RequestError::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
            RequestError::Disconnected(msg) => {
                write!(f, "coordinator disconnected: {msg}")
            }
            RequestError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded: shed by admission control, retry after {retry_after_ms}ms")
            }
        }
    }
}

impl std::error::Error for RequestError {}

/// A submitted request waiting in a session's queue: what was asked, where
/// the answer goes, and when it entered the system (deadlines and latency
/// are measured from `t0`).
pub(crate) struct PendingRequest {
    pub request: Request,
    pub reply: Sender<Response>,
    pub t0: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_keys_put_stats_and_paths_last() {
        let screen = Request::Screen { lam: 0.5, opts: RequestOptions::default() };
        let warm = Request::Warm { lam: 0.9 };
        let stats = Request::SessionStats;
        let path =
            Request::FitPath { grid: 5, lo: 0.1, opts: RequestOptions::default() };
        assert!(warm.sort_lam() > screen.sort_lam());
        assert_eq!(stats.sort_lam(), f64::NEG_INFINITY);
        assert_eq!(path.sort_lam(), f64::NEG_INFINITY);
        assert_eq!(screen.lam(), Some(0.5));
        assert_eq!(stats.lam(), None);
    }

    #[test]
    fn errors_display_their_context() {
        let e = RequestError::SessionClosed {
            session: "s1".to_string(),
            reason: "boom".to_string(),
        };
        let text = e.to_string();
        assert!(text.contains("s1") && text.contains("boom"));
        assert!(RequestError::InvalidLambda(f64::NAN).to_string().contains("NaN"));
    }
}
