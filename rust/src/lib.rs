//! # dpp-screen
//!
//! A production-shaped reproduction of **"Lasso Screening Rules via Dual
//! Polytope Projection"** (Wang, Wonka, Ye — NIPS 2013).
//!
//! The library implements the paper's entire system as a three-layer
//! rust + JAX + Pallas stack (see `DESIGN.md`):
//!
//! * **Screening rules** ([`screening`]): the DPP family (DPP, Improvement 1,
//!   Improvement 2, EDPP — Corollaries 4/5/17, Theorems 11/14/16), the safe
//!   baselines SAFE/ST1 and DOME, the heuristic baselines (sequential strong
//!   rules with KKT repair, SIS), and the group-Lasso extensions
//!   (Corollary 21, group strong rules) — composable into stateful
//!   **pipelines** ([`screening::pipeline`], DESIGN.md §3): `cascade:`
//!   staged survivors-only screens, `hybrid:` safe certification of
//!   heuristic discards, and `dynamic:` in-solver gap-safe refinement.
//! * **Solver substrates** ([`solver`]): coordinate descent (the role of the
//!   paper's SLEP solver), FISTA, LARS, and block coordinate descent for
//!   group Lasso, with duality-gap stopping ([`solver::dual`]).
//! * **Pathwise driver** ([`path`]): solves a Lasso problem along a λ-grid
//!   with sequential screening and warm starts, collecting the paper's two
//!   metrics — rejection ratio and speedup.
//! * **L3 coordinator** ([`coordinator`]): the multi-tenant serving
//!   protocol (DESIGN.md §4) — a typed Request/Response grammar (Screen,
//!   FitPath, Predict, Warm, SessionStats) with per-request deadlines and
//!   overrides, a [`coordinator::SessionRegistry`] of named sessions (each
//!   with its own backend, pipeline, sequential anchor and warm cache)
//!   served concurrently by one [`coordinator::Coordinator`] on the shared
//!   worker pool, deadline-aware gap-tagged partial responses, a
//!   single-session [`coordinator::ScreeningService`] facade for the
//!   classic batching-service shape, plus the multi-trial scheduler and
//!   per-session metrics.
//! * **L4 network layer** ([`net`]): the same serving protocol over TCP
//!   with zero new dependencies (DESIGN.md §4b) — length-prefixed
//!   checksummed framing ([`net::frame`]), a versioned binary wire grammar
//!   covering every request/response/error shape ([`net::wire`]),
//!   `dpp serve --listen` / [`net::NetClient`] for socket serving, and
//!   `dpp shard-node` + [`net::RemoteShard`] for distributed
//!   [`linalg::ShardSetMatrix`] shards whose fold results stay
//!   bit-identical to local execution.
//! * **Front tier** ([`front`]): `dpp front` — session-affine routing
//!   across `dpp serve` processes (DESIGN.md §4c): deterministic
//!   rendezvous placement biased by a probe-refreshed load view
//!   (`AdmissionStats` over the v3 control-plane `Stats` message),
//!   per-session FIFO forwarding over persistent backend connections
//!   (responses stay bit-identical to direct backends), bounded
//!   `Overloaded`-honoring retries, and typed backend-down semantics.
//! * **PJRT runtime** ([`runtime`]): loads AOT artifacts (`artifacts/*.hlo.txt`,
//!   lowered from the JAX/Pallas layers at build time) and executes the
//!   fixed-shape screening sweep through XLA, with a native fallback.
//! * **Substrates**: the matrix-free [`linalg::DesignMatrix`] trait with its
//!   dense, CSC, out-of-core mmap-shard and row-sharded pool-parallel
//!   backends ([`linalg`]; the sharded backend's sweeps run on the
//!   persistent [`runtime::pool`] worker pool), dataset
//!   generators matching the
//!   paper's synthetic and (simulated) real datasets ([`data`]), and
//!   utilities ([`util`]) — RNG, stats, CLI, bench harness, property
//!   testing — hand-rolled because the build image is offline (DESIGN.md §6).
//! * **Invariant auditor** ([`analysis`]): `dpp audit` — a token-level
//!   static analyzer over this crate's own source enforcing the
//!   determinism, unsafe-hygiene, wire-compatibility (`rust/wire.lock`)
//!   and panic-surface policies (DESIGN.md §5).
//!
//! Every rule, solver, path driver and the service is generic over
//! [`linalg::DesignMatrix`] (`&dyn DesignMatrix` / `Box<dyn DesignMatrix +
//! Send>`), so the same code runs the paper's protocol on a dense matrix or
//! a [`linalg::CscMatrix`] without densifying — the paper's own motivation
//! (§1: at MNIST/SVHN scale "we may not even be able to load the data
//! matrix into main memory"). See DESIGN.md §2 for the trait contract.
//!
//! ## Quickstart
//!
//! ```
//! use dpp_screen::prelude::*;
//!
//! // A small synthetic Lasso problem (Synthetic-1 family, eq. (74)).
//! let ds = dpp_screen::data::synthetic::synthetic1(64, 256, 16, 0.1, 7);
//! let grid = LambdaGrid::relative(&ds.x, &ds.y, 20, 0.05, 1.0);
//! let cfg = PathConfig::default();
//! // `solve_path` takes `&dyn DesignMatrix`: pass `&ds.x` (dense) or a
//! // `&CscMatrix` interchangeably.
//! let out = solve_path(&ds.x, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
//! // EDPP is safe: every rejection is a true zero of the reference solution.
//! assert!(out.mean_rejection_ratio() <= 1.0 + 1e-12);
//!
//! // The identical protocol on the sparse backend, no densify round-trip
//! // (datasets loaded from LIBSVM via `data::io::read_libsvm` arrive in
//! // CSC form already, and on-disk shards open as the out-of-core
//! // `MmapCscMatrix` backend — see `data::convert`):
//! let csc = ds.x.to_csc();
//! let sparse_out = solve_path(&csc, &ds.y, &grid, RuleKind::Edpp, SolverKind::Cd, &cfg);
//! assert_eq!(out.records.len(), sparse_out.records.len());
//! ```

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod front;
pub mod linalg;
pub mod net;
pub mod path;
pub mod runtime;
pub mod screening;
pub mod solver;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::coordinator::{
        Coordinator, Request, RequestError, RequestOptions, Response,
        ScreeningService, SessionSpec,
    };
    pub use crate::data::Dataset;
    pub use crate::linalg::{
        CscMatrix, DenseMatrix, DesignMatrix, DesignStore, MmapCscMatrix, ShardSetMatrix,
    };
    pub use crate::front::{Front, FrontConfig};
    pub use crate::net::{NetClient, NetServer, RemoteShard};
    pub use crate::path::{
        solve_path, solve_path_pipeline, LambdaGrid, PathConfig, PathOutput,
        PathStrategy, RuleKind, SolverKind,
    };
    pub use crate::screening::{ScreenContext, ScreenPipeline, Screener, ScreeningRule};
    pub use crate::solver::{cd::CdSolver, LassoSolver, SolveOptions};
}
