//! Repeating background timer — the front tier's probe clock.
//!
//! [`Ticker::spawn`] runs a callback every `interval` on a named thread
//! until the ticker is dropped (or [`Ticker::stop`] is called). The wait is
//! a `recv_timeout` on the stop channel, so shutdown is immediate — a stop
//! never waits out the remainder of an interval — and the module never
//! reads a wall clock itself (the interval is the only time input), so it
//! stays out of the determinism lint's way.

use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

/// A background thread invoking a callback at a fixed period.
pub struct Ticker {
    stop: Option<Sender<()>>,
    handle: Option<JoinHandle<()>>,
}

impl Ticker {
    /// Start a ticker thread named `name` calling `tick` every `interval`.
    /// The first call happens one full interval after spawn.
    pub fn spawn(
        name: &str,
        interval: Duration,
        mut tick: impl FnMut() + Send + 'static,
    ) -> Ticker {
        let (stop, rx) = channel::<()>();
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || loop {
                match rx.recv_timeout(interval) {
                    Err(RecvTimeoutError::Timeout) => tick(),
                    // explicit stop or the Ticker was dropped
                    Ok(()) | Err(RecvTimeoutError::Disconnected) => return,
                }
            })
            .ok();
        Ticker { stop: Some(stop), handle }
    }

    /// Stop the ticker and join its thread.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        if let Some(stop) = self.stop.take() {
            let _ = stop.send(());
        }
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Ticker {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc::channel as mk_channel;
    use std::sync::Arc;

    #[test]
    fn ticks_repeatedly_until_stopped() {
        let (tx, rx) = mk_channel();
        let ticker = Ticker::spawn("test-ticker", Duration::from_millis(5), move || {
            let _ = tx.send(());
        });
        // at least three ticks arrive
        for _ in 0..3 {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        ticker.stop();
        // after stop + drain, no further ticks
        while rx.try_recv().is_ok() {}
        std::thread::sleep(Duration::from_millis(30));
        assert!(rx.try_recv().is_err(), "ticker kept firing after stop");
    }

    #[test]
    fn drop_stops_the_thread() {
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let ticker = Ticker::spawn("test-drop", Duration::from_millis(5), move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        while count.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }
        drop(ticker); // joins: no tick can be in flight afterwards
        let after = count.load(Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(count.load(Ordering::SeqCst), after);
    }
}
