//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — the image's xla_extension 0.5.1 rejects jax≥0.5 protos,
//! see DESIGN.md §6) and serves the fixed-shape screening sweep `Xᵀw`
//! through XLA.
//!
//! Screening always runs on the *full* N×p matrix, so one executable per
//! dataset shape is compiled at load and the matrix is uploaded to the
//! device once ([`ArtifactSweep`] keeps the `PjRtBuffer` resident); each
//! sweep transfers only the length-N vector `w`.
//!
//! Everything here is optional: when `artifacts/` is absent or no entry
//! matches the problem shape, callers fall back to the native f64 sweep.
//!
//! The XLA bindings are gated behind the **`pjrt` cargo feature** so the
//! default build is hermetic (the offline image bakes the bindings in, a
//! fresh environment does not). Without the feature, [`ArtifactRuntime`]
//! and [`ArtifactSweep`] compile as inert stubs: `load_default()` is
//! `None`, every caller takes its native-fallback path, and the
//! [`ArtifactSweep::SAFETY_SLACK`] contract stays available to the f32
//! backends that reuse it. Enabling `pjrt` requires adding the `xla`
//! bindings crate to `[dependencies]` by hand (see `rust/Cargo.toml`).

pub mod pool;
pub mod scheduler;
pub mod timer;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::linalg::{DenseMatrix, DesignMatrix};

/// One artifact from `artifacts/manifest.tsv`:
/// `name <TAB> n <TAB> p <TAB> file`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub n: usize,
    pub p: usize,
    pub file: String,
}

/// Parse a manifest file (TSV; `#` comments and blank lines ignored).
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut entries = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split('\t').collect();
        if parts.len() != 4 {
            bail!("manifest line {}: expected 4 tab-separated fields", lineno + 1);
        }
        entries.push(ManifestEntry {
            name: parts[0].to_string(),
            n: parts[1].parse().context("bad n")?,
            p: parts[2].parse().context("bad p")?,
            file: parts[3].to_string(),
        });
    }
    Ok(entries)
}

/// Loaded artifact store: a PJRT CPU client plus compiled executables keyed
/// by `(name, n, p)`.
#[cfg(feature = "pjrt")]
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    // audit:allow(determinism:hash-iter, executable cache is lookup-only; the artifact listing is sorted)
    exes: std::collections::HashMap<(String, usize, usize), xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl ArtifactRuntime {
    /// Load and compile every artifact listed in `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}"))?;
        let entries = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        // audit:allow(determinism:hash-iter, executable cache is lookup-only; the artifact listing is sorted)
        let mut exes = std::collections::HashMap::new();
        for e in entries {
            let path = dir.join(&e.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
            exes.insert((e.name.clone(), e.n, e.p), exe);
        }
        Ok(ArtifactRuntime { client, exes, dir })
    }

    /// Load from the conventional `artifacts/` directory next to the CWD;
    /// `None` (not an error) when the directory or manifest is missing.
    pub fn load_default() -> Option<ArtifactRuntime> {
        let dir = std::env::var("DPP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        ArtifactRuntime::load(dir).ok()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Names/shapes available.
    pub fn available(&self) -> Vec<(String, usize, usize)> {
        let mut v: Vec<_> = self.exes.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn has(&self, name: &str, n: usize, p: usize) -> bool {
        self.exes.contains_key(&(name.to_string(), n, p))
    }

    /// Execute an artifact with f32 literal inputs, returning the flattened
    /// f32 outputs of the 1-tuple result (jax lowers with return_tuple).
    pub fn execute_f32(
        &self,
        name: &str,
        n: usize,
        p: usize,
        inputs: &[(&[f32], Vec<usize>)],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .exes
            .get(&(name.to_string(), n, p))
            .with_context(|| format!("no artifact {name} for shape {n}x{p}"))?;
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|(data, dims)| {
                self.client
                    .buffer_from_host_buffer::<f32>(data, dims, None)
                    .context("uploading input")
            })
            .collect::<Result<_>>()?;
        let out = exe.execute_b(&bufs).context("executing artifact")?;
        let lit = out[0][0].to_literal_sync().context("fetching result")?;
        let parts = lit.to_tuple().context("untupling result")?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }

    /// Build a resident-matrix sweep for `x` when an `xt_w` artifact with
    /// the matching shape exists. The returned sweep is a full
    /// [`DesignMatrix`]: `xt_w` dispatches to XLA while every column-local
    /// op delegates to the host matrix, so it can serve as a
    /// [`crate::screening::ScreenContext`] sweep provider directly.
    pub fn sweep_for<'a>(&'a self, x: &'a DenseMatrix) -> Option<ArtifactSweep<'a>> {
        let (n, p) = (x.n_rows(), x.n_cols());
        let exe = self.exes.get(&("xt_w".to_string(), n, p))?;
        // jax expects row-major (C-order) f32
        let mut host = vec![0f32; n * p];
        for j in 0..p {
            let col = x.col(j);
            for i in 0..n {
                host[i * p + j] = col[i] as f32;
            }
        }
        let x_buf = self.client.buffer_from_host_buffer::<f32>(&host, &[n, p], None).ok()?;
        Some(ArtifactSweep { client: &self.client, exe, x_buf, host: x, n, p })
    }
}

/// Inert stand-in when the crate is built without the `pjrt` feature: the
/// same API surface, but loading always reports "no artifacts" and the
/// native f64 fallback carries every sweep. The private field keeps it
/// unconstructible outside [`ArtifactRuntime::load`], which always errors.
#[cfg(not(feature = "pjrt"))]
pub struct ArtifactRuntime {
    dir: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl ArtifactRuntime {
    /// Always an error: this build carries no XLA bindings.
    pub fn load(_dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        bail!("built without the `pjrt` feature: no PJRT runtime available")
    }

    /// Always `None` — callers take their native-fallback path.
    pub fn load_default() -> Option<ArtifactRuntime> {
        None
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn available(&self) -> Vec<(String, usize, usize)> {
        Vec::new()
    }

    pub fn has(&self, _name: &str, _n: usize, _p: usize) -> bool {
        false
    }

    pub fn execute_f32(
        &self,
        _name: &str,
        _n: usize,
        _p: usize,
        _inputs: &[(&[f32], Vec<usize>)],
    ) -> Result<Vec<Vec<f32>>> {
        bail!("built without the `pjrt` feature: no PJRT runtime available")
    }

    pub fn sweep_for<'a>(&'a self, _x: &'a DenseMatrix) -> Option<ArtifactSweep<'a>> {
        None
    }
}

/// [`DesignMatrix`] backed by the AOT `xt_w` executable with the feature
/// matrix resident on the device: the `Xᵀw` sweep dispatches to XLA, every
/// other (column-local) operation delegates to the host matrix.
///
/// **Safety discipline** (DESIGN.md §1): the artifact computes in f32;
/// screening decisions must stay *safe*, so consumers must widen the keep
/// condition by [`ArtifactSweep::SAFETY_SLACK`] (ScreenContext applies it
/// automatically via `with_sweep_slack`).
pub struct ArtifactSweep<'a> {
    #[cfg(feature = "pjrt")]
    client: &'a xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    exe: &'a xla::PjRtLoadedExecutable,
    #[cfg(feature = "pjrt")]
    x_buf: xla::PjRtBuffer,
    host: &'a DenseMatrix,
    n: usize,
    p: usize,
}

impl ArtifactSweep<'_> {
    /// Conservative relative slack covering f32 accumulation error of the
    /// sweep (ULP ≈ 1.2e-7; a length-N dot accumulates ≲ N·ulp relative —
    /// 1e-4 covers N up to ~10⁵ with two orders of margin). Shared by the
    /// f32 storage backends even in non-`pjrt` builds.
    pub const SAFETY_SLACK: f64 = 1e-4;

    pub fn shape(&self) -> (usize, usize) {
        (self.n, self.p)
    }
}

impl DesignMatrix for ArtifactSweep<'_> {
    fn n_rows(&self) -> usize {
        self.n
    }

    fn n_cols(&self) -> usize {
        self.p
    }

    #[cfg(feature = "pjrt")]
    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.n);
        assert_eq!(out.len(), self.p);
        let w32: Vec<f32> = w.iter().map(|v| *v as f32).collect();
        let mut run = || -> Result<()> {
            let w_buf = self.client.buffer_from_host_buffer::<f32>(&w32, &[self.n], None)?;
            let res = self.exe.execute_b(&[&self.x_buf, &w_buf])?;
            let lit = res[0][0].to_literal_sync()?;
            let scores = lit.to_tuple1()?.to_vec::<f32>()?;
            // `out` may be a reused scratch buffer holding the previous
            // step's scores — a short result must never leave a stale tail
            assert_eq!(scores.len(), self.p, "artifact returned wrong score count");
            for (o, s) in out.iter_mut().zip(scores.iter()) {
                *o = *s as f64;
            }
            Ok(())
        };
        // The artifact path is an accelerator; on any PJRT failure we must
        // not corrupt screening — panic loudly rather than return garbage.
        run().expect("PJRT sweep execution failed");
    }

    #[cfg(not(feature = "pjrt"))]
    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        // no device in this build: the host matrix carries the sweep
        self.host.xt_w(w, out);
    }

    fn col_dot_w(&self, j: usize, w: &[f64]) -> f64 {
        self.host.col_dot_w(j, w)
    }

    fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]) {
        self.host.col_axpy_into(j, a, out);
    }

    fn col_sq_norm(&self, j: usize) -> f64 {
        self.host.col_sq_norm(j)
    }

    fn col_dot_col(&self, i: usize, j: usize) -> f64 {
        self.host.col_dot_col(i, j)
    }

    fn col_into(&self, j: usize, out: &mut [f64]) {
        self.host.col_into(j, out);
    }

    fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]) {
        self.host.col_gather(j, rows, out);
    }

    fn nnz(&self) -> usize {
        DesignMatrix::nnz(self.host)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_rejects() {
        let text = "# comment\nxt_w\t96\t1600\txt_w.hlo.txt\n\nfista\t64\t256\tf.hlo.txt\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(
            m[0],
            ManifestEntry {
                name: "xt_w".into(),
                n: 96,
                p: 1600,
                file: "xt_w.hlo.txt".into()
            }
        );
        assert!(parse_manifest("too\tfew\tfields").is_err());
        assert!(parse_manifest("xt_w\tNaN\t2\tf").is_err());
    }

    #[test]
    fn load_missing_dir_is_none() {
        std::env::set_var("DPP_ARTIFACTS", "/nonexistent-dpp-artifacts");
        assert!(ArtifactRuntime::load_default().is_none());
        std::env::remove_var("DPP_ARTIFACTS");
    }

    // PJRT round-trip tests live in rust/tests/runtime_integration.rs —
    // they need the `pjrt` feature and `make artifacts` to have run first.
}
