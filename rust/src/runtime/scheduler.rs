//! Per-key dispatch queues over the worker pool — the serving scheduler
//! (DESIGN.md §4).
//!
//! The coordinator's router used to execute session batches itself: drain
//! the inbox into per-session batches, run one pool job per session, and
//! *block* until the slowest finished — a tick barrier where one heavy
//! tenant's batch delayed everyone's next dispatch. The scheduler replaces
//! the barrier with one FIFO [`DispatchQueue`] per key (session): enqueuing
//! work never blocks the caller, and each queue drains through its own
//! detached dispatcher job ([`super::pool::WorkerPool::spawn`]) that runs
//! batches back-to-back until the queue is empty.
//!
//! Ordering contract: at most one dispatcher is ever live per key, and a
//! dispatcher drains its queue in arrival order — so per-key work keeps the
//! exact sequencing a dedicated single-session worker would give it (the
//! bit-identity contract leans on this), while distinct keys never wait on
//! each other. Batches form naturally from backlog: whatever arrives while
//! a dispatcher is busy becomes its next batch.
//!
//! Backpressure surface: the scheduler counts pending items per key and in
//! total (enqueued but not yet executed), which is exactly what the
//! admission policy ([`crate::coordinator::admission`]) needs to shed load
//! instead of queueing unboundedly.

// audit:allow(determinism:hash-iter, lookup-only; the scheduler never iterates the map)
use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use super::pool::{self, WorkerPool};

/// Which pool the dispatchers run on: the process-wide pool or an
/// explicitly owned one (tests and benches sweep thread counts).
#[derive(Clone, Debug)]
pub enum PoolHandle {
    /// The lazily-spawned process-wide pool ([`pool::global`]).
    Global,
    /// An explicitly owned pool.
    Owned(Arc<WorkerPool>),
}

impl PoolHandle {
    /// Resolve to the underlying pool.
    pub fn get(&self) -> &WorkerPool {
        match self {
            PoolHandle::Global => pool::global(),
            PoolHandle::Owned(p) => p,
        }
    }

    /// Worker count of the resolved pool.
    pub fn threads(&self) -> usize {
        self.get().threads()
    }
}

/// One key's FIFO queue plus its dispatcher state.
struct DispatchQueue<T> {
    items: VecDeque<T>,
    /// True while a dispatcher job for this key is live (queued on the
    /// pool or draining) — the single-dispatcher-per-key invariant.
    running: bool,
    /// Items enqueued but not yet *executed* (queued + in the dispatcher's
    /// current batch). This is the admission-control depth: it only drops
    /// once work actually completed.
    pending: usize,
}

impl<T> Default for DispatchQueue<T> {
    fn default() -> Self {
        DispatchQueue { items: VecDeque::new(), running: false, pending: 0 }
    }
}

struct SchedState<T> {
    // audit:allow(determinism:hash-iter, lookup-only; the scheduler never iterates the map)
    queues: HashMap<String, DispatchQueue<T>>,
    /// Live dispatcher jobs across all keys.
    active: usize,
    /// Pending items across all keys.
    pending_total: usize,
}

struct Shared<T> {
    pool: PoolHandle,
    exec: Box<dyn Fn(&str, Vec<T>) + Send + Sync>,
    state: Mutex<SchedState<T>>,
    /// Signalled on every dispatcher/queue transition; `quiesce` and
    /// `remove` wait on it.
    quiet: Condvar,
}

/// The scheduler: per-key dispatch queues executing on a worker pool.
///
/// `T` is one unit of work; the executor closure receives each drained
/// batch together with its key. Cloning is shallow (shared state).
pub struct Scheduler<T: Send + 'static> {
    shared: Arc<Shared<T>>,
}

impl<T: Send + 'static> Scheduler<T> {
    /// Build a scheduler whose dispatchers run `exec` on `pool`.
    pub fn new(
        pool: PoolHandle,
        exec: impl Fn(&str, Vec<T>) + Send + Sync + 'static,
    ) -> Scheduler<T> {
        Scheduler {
            shared: Arc::new(Shared {
                pool,
                exec: Box::new(exec),
                state: Mutex::new(SchedState {
                    // audit:allow(determinism:hash-iter, lookup-only; the scheduler never iterates the map)
                    queues: HashMap::new(),
                    active: 0,
                    pending_total: 0,
                }),
                quiet: Condvar::new(),
            }),
        }
    }

    /// Append one item to `key`'s queue, starting a dispatcher for the key
    /// if none is live. Never blocks on work: the enqueue itself is a map
    /// push under a short lock.
    pub fn enqueue(&self, key: &str, item: T) {
        let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        let q = st.queues.entry(key.to_string()).or_default();
        q.items.push_back(item);
        q.pending += 1;
        st.pending_total += 1;
        let start = !q.running;
        if start {
            q.running = true;
            st.active += 1;
        }
        drop(st);
        if start {
            let shared = Arc::clone(&self.shared);
            let key = key.to_string();
            self.shared.pool.get().spawn(Box::new(move || dispatch(shared, key)));
        }
    }

    /// Pending items for one key (enqueued but not yet executed). Zero for
    /// unknown keys.
    pub fn depth(&self, key: &str) -> usize {
        let st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        st.queues.get(key).map_or(0, |q| q.pending)
    }

    /// Pending items across every key.
    pub fn total_pending(&self) -> usize {
        let st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        st.pending_total
    }

    /// True when `key` has no pending items and no live dispatcher — i.e.
    /// evicting it now cannot drop in-flight work.
    pub fn is_idle(&self, key: &str) -> bool {
        let st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        match st.queues.get(key) {
            None => true,
            Some(q) => !q.running && q.pending == 0,
        }
    }

    /// Remove `key`'s queue, returning any undelivered items. Waits for the
    /// key's live dispatcher (if any) to finish its current batch first, so
    /// the caller can safely tear down whatever the executor touches.
    pub fn remove(&self, key: &str) -> Vec<T> {
        let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            match st.queues.get(key) {
                None => return Vec::new(),
                Some(q) if !q.running => {
                    let q = st.queues.remove(key).unwrap_or_default();
                    st.pending_total -= q.pending;
                    return q.items.into();
                }
                Some(_) => {
                    st = self.shared.quiet.wait(st).unwrap_or_else(|e| e.into_inner());
                }
            }
        }
    }

    /// Block until every queue is drained and every dispatcher has exited.
    pub fn quiesce(&self) {
        let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        while st.active > 0 || st.pending_total > 0 {
            st = self.shared.quiet.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// A key's dispatcher: drain the queue batch-by-batch until it is empty,
/// then retire. Exactly one dispatcher is live per key at any instant
/// (enforced by `running`), which is what keeps per-key execution ordered.
fn dispatch<T: Send + 'static>(shared: Arc<Shared<T>>, key: String) {
    loop {
        let batch: Vec<T> = {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            match st.queues.get_mut(&key) {
                Some(q) if !q.items.is_empty() => q.items.drain(..).collect(),
                // empty (or removed mid-batch): retire this dispatcher
                other => {
                    if let Some(q) = other {
                        q.running = false;
                    }
                    st.active -= 1;
                    shared.quiet.notify_all();
                    return;
                }
            }
        };
        let n = batch.len();
        (shared.exec)(&key, batch);
        let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(q) = st.queues.get_mut(&key) {
            q.pending -= n;
        }
        st.pending_total -= n;
        shared.quiet.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex as StdMutex;

    fn sched_with(
        threads: usize,
        exec: impl Fn(&str, Vec<u32>) + Send + Sync + 'static,
    ) -> Scheduler<u32> {
        Scheduler::new(PoolHandle::Owned(Arc::new(WorkerPool::new(threads))), exec)
    }

    #[test]
    fn per_key_order_is_fifo_at_any_thread_count() {
        for threads in [1usize, 2, 4] {
            let log: Arc<StdMutex<Vec<(String, u32)>>> = Arc::default();
            let l = Arc::clone(&log);
            let sched = sched_with(threads, move |key, batch| {
                let mut g = l.lock().unwrap();
                for v in batch {
                    g.push((key.to_string(), v));
                }
            });
            for v in 0..50u32 {
                sched.enqueue("a", v);
                sched.enqueue("b", 100 + v);
            }
            sched.quiesce();
            let g = log.lock().unwrap();
            let a: Vec<u32> =
                g.iter().filter(|(k, _)| k == "a").map(|(_, v)| *v).collect();
            let b: Vec<u32> =
                g.iter().filter(|(k, _)| k == "b").map(|(_, v)| *v).collect();
            assert_eq!(a, (0..50).collect::<Vec<_>>(), "threads={threads}");
            assert_eq!(b, (100..150).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn slow_key_does_not_block_fast_key() {
        // With ≥2 workers, a long-running batch on `slow` must not delay
        // `fast`'s dispatch: fast's 10 items complete while slow's first
        // batch is still sleeping.
        let done_fast = Arc::new(AtomicUsize::new(0));
        let df = Arc::clone(&done_fast);
        let sched = sched_with(2, move |key, batch| {
            if key == "slow" {
                std::thread::sleep(std::time::Duration::from_millis(150));
            } else {
                df.fetch_add(batch.len(), Ordering::SeqCst);
            }
        });
        sched.enqueue("slow", 0);
        for v in 0..10u32 {
            sched.enqueue("fast", v);
        }
        // fast should finish well inside slow's first 150ms batch
        let t0 = std::time::Instant::now();
        while done_fast.load(Ordering::SeqCst) < 10 {
            assert!(
                t0.elapsed() < std::time::Duration::from_millis(120),
                "fast key starved behind slow key"
            );
            std::thread::yield_now();
        }
        sched.quiesce();
    }

    #[test]
    fn depth_tracks_pending_and_remove_returns_leftovers() {
        let gate = Arc::new(StdMutex::new(()));
        let hold = gate.lock().unwrap();
        let g = Arc::clone(&gate);
        let sched = sched_with(2, move |_, _| {
            let _g = g.lock().unwrap();
        });
        sched.enqueue("a", 1);
        // dispatcher is now blocked on the gate with item 1 in its batch;
        // two more items back up in the queue
        while sched.depth("a") != 1 || !sched.is_idle("missing") {
            std::thread::yield_now();
        }
        sched.enqueue("a", 2);
        sched.enqueue("a", 3);
        assert_eq!(sched.depth("a"), 3);
        assert_eq!(sched.total_pending(), 3);
        assert!(!sched.is_idle("a"));
        drop(hold);
        sched.quiesce();
        assert_eq!(sched.depth("a"), 0);
        assert!(sched.is_idle("a"));
        // leftovers: queue items behind a gate, remove while they wait
        let leftovers = sched.remove("a");
        assert!(leftovers.is_empty());
    }

    #[test]
    fn quiesce_on_empty_scheduler_returns() {
        let sched = sched_with(2, |_, _| {});
        sched.quiesce();
        assert_eq!(sched.total_pending(), 0);
    }
}
