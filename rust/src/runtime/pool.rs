//! Persistent two-level compute worker pool (std threads + mpsc — the
//! offline image has no tokio or rayon, DESIGN.md §6).
//!
//! The pool owns threads for *compute*: the sharded backend
//! ([`crate::linalg::ShardSetMatrix`]) dispatches its `Xᵀw` sweeps, subset
//! sweeps and `gemv` partial sweeps here, one job per column block or per
//! row shard, and the serving scheduler
//! ([`crate::runtime::scheduler`]) runs its per-session dispatchers here as
//! detached level-0 jobs ([`WorkerPool::spawn`]). Fixed thread count, one
//! shared injector queue — every compute caller follows the same fork/join
//! shape: split a sweep into disjoint jobs, run them, continue
//! single-threaded.
//!
//! Two-level dispatch: a [`WorkerPool::run`] issued *from a pool worker*
//! (a session dispatcher forking a sharded sweep) no longer runs its jobs
//! inline. It enqueues them on the shared injector like any other caller
//! and then **helps**: the calling worker drains tasks from the queue while
//! waiting for its own completions, so idle workers pick up the nested jobs
//! and a sharded session keeps its sweep parallelism even while other
//! sessions occupy workers. The help loop makes nested fork/join
//! deadlock-free by construction — the caller itself executes queued tasks
//! whenever its own jobs are not all in flight.
//!
//! Determinism contract: the pool never changes *what* is computed, only
//! *where*. Callers must partition work so that each output element is
//! produced entirely by one job (the sharded backend computes each `out[j]`
//! with a single sequential accumulator); under that discipline results are
//! bit-identical for every thread count, including 1 — pinned by
//! `backend_parity.rs`.
//!
//! Sizing: [`WorkerPool::new`] takes an explicit count (benches sweep it);
//! [`global`] reads `DPP_POOL_THREADS` once, defaulting to the machine's
//! available parallelism.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Env var fixing the global pool's thread count (read once, at first use).
pub const THREADS_ENV: &str = "DPP_POOL_THREADS";

/// A unit of work. Jobs are type-erased `'static` closures internally;
/// [`WorkerPool::run`] is the only constructor and it blocks until every
/// job has finished, which is what makes the borrowed-closure API sound.
type Job = Box<dyn FnOnce() + Send + 'static>;

struct Task {
    job: Job,
    /// Completion signal back to the submitting `run` call: `None` on
    /// success, `Some(panic message)` when the job panicked — the payload
    /// is preserved so a worker-side failure stays diagnosable.
    done: Sender<Option<String>>,
}

thread_local! {
    /// Set inside pool workers so a nested `run` call takes the helping
    /// join path (submit + drain the shared queue) instead of blocking on
    /// a queue it may itself be starving.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Fixed-size persistent worker pool.
///
/// Threads are spawned once in `new` and live until the pool is dropped;
/// submitting work allocates one box per job and nothing else. The pool is
/// `Sync`: concurrent `run` calls interleave their jobs on the shared
/// queue, each joining only its own completions.
pub struct WorkerPool {
    /// `None` only during shutdown (Drop takes it to close the channel).
    tx: Mutex<Option<Sender<Task>>>,
    /// The shared injector's receiving end — workers block on it, and a
    /// nested `run`'s help loop steals from it while joining.
    rx: Arc<Mutex<Receiver<Task>>>,
    threads: usize,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|k| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("dpp-pool-{k}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { tx: Mutex::new(Some(tx)), rx, threads, workers }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enqueue one detached (level-0) job and return immediately. No
    /// completion signal: the caller observes progress through the job's
    /// own side effects (the serving scheduler's dispatchers track their
    /// queues themselves). A panic inside the job is caught by the worker
    /// and dropped — detached callers that care must catch their own.
    ///
    /// Falls back to running the job inline if the pool is shutting down,
    /// so a detached job is never silently lost.
    pub fn spawn(&self, job: Box<dyn FnOnce() + Send + 'static>) {
        let (done_tx, _done_rx) = channel::<Option<String>>();
        let tx = {
            let guard = self.tx.lock().unwrap_or_else(|e| e.into_inner());
            guard.as_ref().cloned()
        };
        match tx {
            Some(tx) => {
                if let Err(std::sync::mpsc::SendError(t)) = tx.send(Task { job, done: done_tx }) {
                    (t.job)();
                }
            }
            None => job(),
        }
    }

    /// Execute every job, blocking until all have completed. Jobs may
    /// borrow from the caller's stack (`'scope`), because this function
    /// does not return until the last job has run.
    ///
    /// Runs inline (no dispatch) when the pool has one thread or there is a
    /// single job. A call from a pool worker (nested fork/join) enqueues on
    /// the shared injector like any other caller and then *helps*: the
    /// calling worker executes queued tasks while waiting, so idle workers
    /// borrow into the nested work and the caller can never deadlock on a
    /// queue it is blocking.
    ///
    /// Panics if any job panicked (after all jobs have settled, so borrowed
    /// data is never observed mid-write by an unwinding caller).
    pub fn run<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if jobs.is_empty() {
            return;
        }
        if self.threads <= 1 || jobs.len() == 1 {
            for job in jobs {
                job();
            }
            return;
        }
        let nested = IN_POOL_WORKER.with(|f| f.get());
        let n = jobs.len();
        let (done_tx, done_rx) = channel::<Option<String>>();
        let tx = {
            let guard = self.tx.lock().unwrap_or_else(|e| e.into_inner());
            guard.as_ref().expect("worker pool already shut down").clone()
        };
        for job in jobs {
            // SAFETY: the only lifetime-erasing cast in the crate. The job
            // borrows data that outlives `'scope`; we block below (in the
            // plain join or the helping join) until every job has signalled
            // completion (panics are caught and still signal — by workers
            // and by helping joiners alike), so no job can run — or be
            // dropped unrun later — after `run` returns and the borrows
            // expire. We hold a live sender, so the queue cannot close with
            // jobs stranded in it; if a worker thread dies anyway,
            // `done_rx.recv()` errors and we panic here rather than return
            // borrows to live jobs.
            let job: Job =
                unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job) };
            let task = Task { job, done: done_tx.clone() };
            if let Err(std::sync::mpsc::SendError(t)) = tx.send(task) {
                // unreachable while we hold `tx`, but never lose a job
                (t.job)();
                let _ = t.done.send(None);
            }
        }
        drop(tx);
        drop(done_tx);
        let first_panic = if nested {
            self.join_helping(n, &done_rx)
        } else {
            join_blocking(n, &done_rx)
        };
        if let Some(msg) = first_panic {
            panic!("worker pool job panicked: {msg}");
        }
    }

    /// Join path for a nested `run` (caller is a pool worker): instead of
    /// blocking — which would idle a worker the queued jobs may need — keep
    /// executing tasks from the shared injector until all `n` of our jobs
    /// have signalled. Stolen tasks may belong to any caller; executing
    /// them is always global progress, and their `done` channels keep their
    /// own `run` calls sound. Only blocks on `done_rx` when the queue is
    /// momentarily empty, i.e. every remaining job of ours is already in
    /// flight on some worker and is guaranteed to signal.
    fn join_helping(&self, n: usize, done_rx: &Receiver<Option<String>>) -> Option<String> {
        let mut pending = n;
        let mut first_panic: Option<String> = None;
        let mut record = |sig: Option<String>, pending: &mut usize| {
            *pending -= 1;
            if let Some(msg) = sig {
                first_panic.get_or_insert(msg);
            }
        };
        while pending > 0 {
            while let Ok(sig) = done_rx.try_recv() {
                record(sig, &mut pending);
            }
            if pending == 0 {
                break;
            }
            let stolen = {
                let guard = self.rx.lock().unwrap_or_else(|e| e.into_inner());
                guard.try_recv()
            };
            match stolen {
                Ok(task) => {
                    let payload =
                        catch_unwind(AssertUnwindSafe(task.job)).err().map(panic_message);
                    let _ = task.done.send(payload);
                }
                Err(_) => match done_rx.recv() {
                    Ok(sig) => record(sig, &mut pending),
                    Err(_) => {
                        first_panic
                            .get_or_insert_with(|| "worker thread died mid-batch".to_string());
                        break;
                    }
                },
            }
        }
        first_panic
    }
}

/// Join path for a top-level `run`: block for all `n` completion signals.
fn join_blocking(n: usize, done_rx: &Receiver<Option<String>>) -> Option<String> {
    let mut first_panic: Option<String> = None;
    for _ in 0..n {
        match done_rx.recv() {
            Ok(None) => {}
            Ok(Some(msg)) => {
                first_panic.get_or_insert(msg);
            }
            Err(_) => {
                first_panic.get_or_insert_with(|| "worker thread died mid-batch".to_string());
                break;
            }
        }
    }
    first_panic
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing the injector ends every worker's recv loop
        let tx = self.tx.lock().unwrap_or_else(|e| e.into_inner()).take();
        drop(tx);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("threads", &self.threads).finish()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Task>>>) {
    IN_POOL_WORKER.with(|f| f.set(true));
    loop {
        let task = {
            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
            guard.recv()
        };
        let Ok(task) = task else { return };
        let payload = catch_unwind(AssertUnwindSafe(task.job)).err().map(panic_message);
        let _ = task.done.send(payload);
    }
}

/// Best-effort text of a caught panic payload (panics carry `&str` or
/// `String` in practice). Shared with the coordinator, which catches
/// per-request panics to keep a poisoned session diagnosable.
pub(crate) fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Thread count the global pool uses: `DPP_POOL_THREADS` if set (≥ 1), else
/// the machine's available parallelism.
pub fn configured_threads() -> usize {
    std::env::var(THREADS_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|t| t.max(1))
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// The process-wide compute pool (lazily spawned on first use). Backends
/// that don't carry their own pool ([`crate::linalg::ShardSetMatrix`]
/// without `with_pool`) dispatch here.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(configured_threads()))
}

/// Split `len` work items into at most `threads` contiguous chunks of
/// near-equal size (≥ 1). Deterministic — independent of scheduling.
pub fn chunk_len(len: usize, threads: usize) -> usize {
    let t = threads.max(1);
    len.div_ceil(t).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_borrowed_jobs_to_completion() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0usize; 97];
        {
            let chunk = chunk_len(out.len(), pool.threads());
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut base = 0usize;
            for part in out.chunks_mut(chunk) {
                let start = base;
                base += part.len();
                jobs.push(Box::new(move || {
                    for (k, v) in part.iter_mut().enumerate() {
                        *v = start + k;
                    }
                }));
            }
            pool.run(jobs);
        }
        for (k, v) in out.iter().enumerate() {
            assert_eq!(*v, k);
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..5)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn nested_run_from_a_worker_does_not_deadlock() {
        // two outer jobs so they really dispatch to workers (a single job
        // would be inlined); each fans out again from inside its worker,
        // which must execute inline rather than wait on the busy queue
        let pool = Arc::new(WorkerPool::new(2));
        let total = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Box<dyn FnOnce() + Send + 'static>> = (0..2)
            .map(|_| {
                let p = Arc::clone(&pool);
                let t = Arc::clone(&total);
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            let t = Arc::clone(&t);
                            Box::new(move || {
                                t.fetch_add(1, Ordering::Relaxed);
                            })
                                as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    p.run(inner);
                }) as Box<dyn FnOnce() + Send + 'static>
            })
            .collect();
        pool.run(jobs);
        assert_eq!(total.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn spawn_runs_detached_jobs() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        for v in 0..6usize {
            let tx = tx.clone();
            pool.spawn(Box::new(move || {
                let _ = tx.send(v);
            }));
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn spawned_job_can_run_nested_fork_join() {
        // a detached dispatcher job forking back into its own pool must
        // help/borrow idle workers rather than deadlock — the serving
        // scheduler's exact shape
        let pool = Arc::new(WorkerPool::new(2));
        let (tx, rx) = std::sync::mpsc::channel();
        let p = Arc::clone(&pool);
        pool.spawn(Box::new(move || {
            let total = AtomicUsize::new(0);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..5)
                .map(|_| {
                    Box::new(|| {
                        total.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            p.run(jobs);
            let _ = tx.send(total.load(Ordering::Relaxed));
        }));
        assert_eq!(rx.recv().unwrap(), 5);
    }

    #[test]
    fn panicking_job_propagates_after_all_jobs_settle() {
        let pool = WorkerPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        let jobs: Vec<Box<dyn FnOnce() + Send + 'static>> = vec![
            Box::new(|| panic!("boom")),
            Box::new(move || {
                d.fetch_add(1, Ordering::Relaxed);
            }),
        ];
        let r = catch_unwind(AssertUnwindSafe(|| pool.run(jobs)));
        let msg = panic_message(r.unwrap_err());
        assert!(msg.contains("boom"), "original payload preserved: {msg}");
        assert_eq!(done.load(Ordering::Relaxed), 1, "healthy job still ran");
        // the pool survives a panicked job
        let ok = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
            .map(|_| {
                Box::new(|| {
                    ok.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run(jobs);
        assert_eq!(ok.load(Ordering::Relaxed), 3);
    }

    /// Miri target (CI runs `cargo +nightly miri test runtime::pool`): the
    /// soundness argument for the `'scope → 'static` transmute in `run` is
    /// that no erased job can run — or be dropped unrun — after `run`
    /// returns. Exercise exactly that window: stack buffers that die right
    /// after each `run` call, workers writing through the erased borrows,
    /// several rounds so queue reuse is covered too. Under Miri a job
    /// outliving its scope is a reported use-after-free, not a flake.
    #[test]
    fn job_lifetime_stays_within_run_scope() {
        let pool = WorkerPool::new(2);
        for round in 0..4usize {
            let mut buf = vec![0usize; 8 + round];
            {
                let chunk = chunk_len(buf.len(), pool.threads());
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                let mut base = 0usize;
                for part in buf.chunks_mut(chunk) {
                    let start = base;
                    base += part.len();
                    jobs.push(Box::new(move || {
                        for (k, v) in part.iter_mut().enumerate() {
                            *v = round + start + k;
                        }
                    }));
                }
                pool.run(jobs);
            }
            for (k, v) in buf.iter().enumerate() {
                assert_eq!(*v, round + k);
            }
            // `buf` drops here: any straggler job still holding the erased
            // borrow would be a use-after-free Miri flags deterministically.
        }
    }

    #[test]
    fn chunking_covers_everything() {
        for len in [1usize, 7, 16, 97] {
            for t in [1usize, 2, 3, 8, 100] {
                let c = chunk_len(len, t);
                assert!(c >= 1);
                assert!(c * t >= len, "len {len} threads {t} chunk {c}");
            }
        }
    }

    #[test]
    fn configured_threads_is_positive() {
        assert!(configured_threads() >= 1);
    }
}
