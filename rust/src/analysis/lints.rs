//! The lint families behind `dpp audit` (DESIGN.md §5).
//!
//! Every scan works on the blanked code from [`super::lexer`], skips
//! `#[cfg(test)]` regions, and honours `// audit:allow(<lint>, reason)`
//! waivers on the flagged line or the line above. A waiver with an empty
//! reason is itself a finding (family `waiver`): the policy must be
//! legible in-tree, not just silenced.

use super::lexer::{line_of, strip_code, test_lines, word_hits, Lexed};
use super::{Finding, UnsafeSite, Waiver};

/// Files where wall-clock reads are the point (timers and the bench kit).
const CLOCK_SANCTIONED: [&str; 2] = ["util/timer.rs", "util/benchkit.rs"];

/// Directories whose float folds *define* the sanctioned FP sequences.
const SUM_SANCTIONED_DIRS: [&str; 2] = ["linalg/", "experiments/"];

/// Request-handling directories where panics are forbidden outside tests.
const PANIC_DIRS: [&str; 3] = ["coordinator/", "net/", "front/"];

const PANIC_TOKENS: [&str; 6] = [
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];

/// Result of scanning one file.
pub struct FileScan {
    pub findings: Vec<Finding>,
    pub waivers: Vec<Waiver>,
    pub unsafe_sites: Vec<UnsafeSite>,
}

enum WaiverState {
    None,
    Empty,
    Reason(String),
}

/// Look for `audit:allow(code-or-family, reason)` on `line` or `line - 1`.
fn find_waiver(lx: &Lexed, line: usize, code_id: &str) -> WaiverState {
    let lines = [Some(line), line.checked_sub(1)];
    for ln in lines.into_iter().flatten() {
        let Some(text) = lx.comments.get(&ln) else { continue };
        let Some(at) = text.find("audit:allow(") else { continue };
        let inner = &text[at + "audit:allow(".len()..];
        let Some(close) = inner.find(')') else { continue };
        let inner = &inner[..close];
        let (lint, reason) = match inner.find(',') {
            Some(c) => (inner[..c].trim(), inner[c + 1..].trim()),
            None => (inner.trim(), ""),
        };
        let family = code_id.split(':').next().unwrap_or(code_id);
        if lint == code_id || lint == family {
            if reason.is_empty() {
                return WaiverState::Empty;
            }
            return WaiverState::Reason(reason.to_string());
        }
    }
    WaiverState::None
}

struct Emitter<'a> {
    rel: &'a str,
    lx: &'a Lexed,
    findings: Vec<Finding>,
    waivers: Vec<Waiver>,
}

impl Emitter<'_> {
    fn emit(&mut self, line: usize, code_id: &'static str, msg: &str) {
        match find_waiver(self.lx, line, code_id) {
            WaiverState::Empty => self.findings.push(Finding {
                code: "waiver",
                file: self.rel.to_string(),
                line: line + 1,
                message: format!("waiver for `{code_id}` has no reason"),
            }),
            WaiverState::Reason(reason) => self.waivers.push(Waiver {
                code: code_id,
                file: self.rel.to_string(),
                line: line + 1,
                reason,
            }),
            WaiverState::None => self.findings.push(Finding {
                code: code_id,
                file: self.rel.to_string(),
                line: line + 1,
                message: msg.to_string(),
            }),
        }
    }
}

fn is_test_line(tests: &[bool], ln: usize) -> bool {
    tests.get(ln).copied().unwrap_or(false)
}

/// Run every lint family over one file. `rel` is the path relative to the
/// crate's `src/` root with `/` separators — the path policies key off it.
pub fn scan_file(rel: &str, src: &str) -> FileScan {
    let lx = strip_code(src);
    let code = lx.code.clone();
    let tests = test_lines(&code);
    let mut em = Emitter { rel, lx: &lx, findings: Vec::new(), waivers: Vec::new() };
    let mut unsafe_sites = Vec::new();

    // determinism:float-sort — `partial_cmp(..).unwrap()` / `.expect(`
    for off in word_hits(&code, "partial_cmp") {
        let ln = line_of(&code, off);
        if is_test_line(&tests, ln) {
            continue;
        }
        let bytes = code.as_bytes();
        let mut j = off + "partial_cmp".len();
        while j < bytes.len() && bytes[j].is_ascii_whitespace() {
            j += 1;
        }
        if j >= bytes.len() || bytes[j] != b'(' {
            continue;
        }
        let mut depth = 0usize;
        while j < bytes.len() {
            if bytes[j] == b'(' {
                depth += 1;
            } else if bytes[j] == b')' {
                depth -= 1;
                if depth == 0 {
                    j += 1;
                    break;
                }
            }
            j += 1;
        }
        while j < bytes.len() && bytes[j].is_ascii_whitespace() {
            j += 1;
        }
        let tail = &code[j.min(code.len())..];
        if tail.starts_with(".unwrap(") || tail.starts_with(".expect(") {
            em.emit(
                ln,
                "determinism:float-sort",
                "float ordering via `partial_cmp(..).unwrap()` — use \
                 `total_cmp` for a total, panic-free order",
            );
        }
    }

    // determinism:clock — wall-clock reads outside the sanctioned homes
    if !CLOCK_SANCTIONED.contains(&rel) {
        for tok in ["Instant::now", "SystemTime::now"] {
            for off in word_hits(&code, tok) {
                let ln = line_of(&code, off);
                if is_test_line(&tests, ln) {
                    continue;
                }
                em.emit(
                    ln,
                    "determinism:clock",
                    "clock read outside util::timer — results must not \
                     depend on wall time",
                );
            }
        }
    }

    // determinism:float-sum — raw reductions outside the sanctioned folds
    if !SUM_SANCTIONED_DIRS.iter().any(|d| rel.starts_with(d)) {
        for tok in [".sum::<f64>()", ".sum::<f32>()"] {
            let mut at = 0;
            while let Some(pos) = code[at..].find(tok) {
                let pos = at + pos;
                let ln = line_of(&code, pos);
                if !is_test_line(&tests, ln) {
                    em.emit(
                        ln,
                        "determinism:float-sum",
                        "raw float reduction — use the sanctioned \
                         `linalg::ops::seq_sum` fold (exact FP sequence)",
                    );
                }
                at = pos + tok.len();
            }
        }
    }

    // determinism:hash-iter — HashMap/HashSet near numeric state
    for tok in ["HashMap", "HashSet"] {
        for off in word_hits(&code, tok) {
            let ln = line_of(&code, off);
            if is_test_line(&tests, ln) {
                continue;
            }
            em.emit(
                ln,
                "determinism:hash-iter",
                "hashed collection in numeric code — iteration order is \
                 nondeterministic; use BTreeMap/Vec or waive with the \
                 reason iteration order cannot reach results",
            );
        }
    }

    // unsafe inventory — every non-test `unsafe` needs a SAFETY: comment
    for off in word_hits(&code, "unsafe") {
        let ln = line_of(&code, off);
        if is_test_line(&tests, ln) {
            continue;
        }
        unsafe_sites.push(UnsafeSite { file: rel.to_string(), line: ln + 1 });
        let lo = ln.saturating_sub(10);
        let documented = (lo..=ln)
            .any(|k| em.lx.comments.get(&k).is_some_and(|c| c.contains("SAFETY:")));
        if !documented {
            em.emit(
                ln,
                "unsafe",
                "`unsafe` without a `// SAFETY:` comment in the 10 lines above",
            );
        }
    }

    // panic surface — no panicking calls on request paths
    if PANIC_DIRS.iter().any(|d| rel.starts_with(d)) {
        for tok in PANIC_TOKENS {
            let mut at = 0;
            while let Some(pos) = code[at..].find(tok) {
                let pos = at + pos;
                let ln = line_of(&code, pos);
                if !is_test_line(&tests, ln) {
                    em.emit(
                        ln,
                        "panic",
                        "panicking call on a request-handling path — \
                         return a typed `RequestError` instead",
                    );
                }
                at = pos + tok.len();
            }
        }
    }

    FileScan { findings: em.findings, waivers: em.waivers, unsafe_sites }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_file_yields_nothing() {
        let s = scan_file("solver/x.rs", "fn f(v: &mut [f64]) { v.sort_by(f64::total_cmp); }\n");
        assert!(s.findings.is_empty());
        assert!(s.waivers.is_empty());
    }

    #[test]
    fn float_sort_flagged_and_waivable() {
        let bad = "fn f(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        let s = scan_file("solver/x.rs", bad);
        assert_eq!(s.findings.len(), 1);
        assert_eq!(s.findings[0].code, "determinism:float-sort");

        let waived = format!("// audit:allow(determinism:float-sort, test fixture)\n{bad}");
        let s = scan_file("solver/x.rs", &waived);
        assert!(s.findings.is_empty());
        assert_eq!(s.waivers.len(), 1);
    }

    #[test]
    fn empty_waiver_reason_is_a_finding() {
        let src = "// audit:allow(determinism:clock)\nfn f() { let t = std::time::Instant::now(); }\n";
        let s = scan_file("solver/x.rs", src);
        assert_eq!(s.findings.len(), 1);
        assert_eq!(s.findings[0].code, "waiver");
    }

    #[test]
    fn clock_sanctioned_in_timer() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert!(scan_file("util/timer.rs", src).findings.is_empty());
        assert_eq!(scan_file("util/other.rs", src).findings.len(), 1);
    }

    #[test]
    fn float_sum_sanctioned_in_linalg() {
        let src = "fn f(v: &[f64]) -> f64 { v.iter().sum::<f64>() }\n";
        assert!(scan_file("linalg/ops.rs", src).findings.is_empty());
        assert_eq!(scan_file("path/mod.rs", src).findings.len(), 1);
    }

    #[test]
    fn panic_scoped_to_request_dirs() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
        assert_eq!(scan_file("net/server.rs", src).findings.len(), 1);
        assert_eq!(scan_file("front/server.rs", src).findings.len(), 1);
        assert!(scan_file("solver/cd.rs", src).findings.is_empty());
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        let s = scan_file("runtime/x.rs", bad);
        assert_eq!(s.findings.len(), 1);
        assert_eq!(s.unsafe_sites.len(), 1);
        let good = "// SAFETY: caller guarantees p is valid\nfn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        let s = scan_file("runtime/x.rs", good);
        assert!(s.findings.is_empty());
        assert_eq!(s.unsafe_sites.len(), 1);
    }

    #[test]
    fn test_regions_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let x: Option<u8> = None; x.unwrap(); }\n}\n";
        assert!(scan_file("net/server.rs", src).findings.is_empty());
    }

    #[test]
    fn tokens_in_strings_and_comments_ignored() {
        let src = "// the old partial_cmp().unwrap() bug\nfn f() -> &'static str { \"Instant::now\" }\n";
        assert!(scan_file("path/mod.rs", src).findings.is_empty());
    }
}
