//! `dpp audit` — a zero-dependency, token-level static analyzer over this
//! crate's own source tree (DESIGN.md §5).
//!
//! The bit-identity contract (identical results across dense/CSC/mmap/
//! sharded/remote backends) and the serving protocol are defended
//! dynamically by `backend_parity` and `serve_protocol`; this module
//! defends them *statically*, before tests run. Four lint families:
//!
//! * **determinism** — float sorts via `partial_cmp(..).unwrap()`
//!   (`total_cmp` required), wall-clock reads outside `util::timer`,
//!   raw float reductions outside the sanctioned `linalg` folds, and
//!   `HashMap`/`HashSet` in numeric code;
//! * **unsafe** — every non-test `unsafe` needs a `// SAFETY:` comment,
//!   and the full inventory is reported so new unsafe is visible in review;
//! * **wire** — the tag/version constants in `net/wire.rs` and
//!   `net/frame.rs` must match the committed `rust/wire.lock` golden
//!   table ([`wirecheck`]);
//! * **panic** — no panicking calls on request-handling paths in
//!   `coordinator/` and `net/` outside tests.
//!
//! Policy exceptions are in-tree and searchable:
//! `// audit:allow(<lint>, reason)` on the flagged line or the line above.
//! An empty reason is itself a finding. The CLI entry point is
//! `dpp audit [--json] [--write-wire-lock]`; the tier-1 test
//! `tests/audit.rs` keeps the shipped tree at zero findings.

pub mod lexer;
pub mod lints;
pub mod wirecheck;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One rule violation. `line` is 1-based (0 = whole-file/lock-level).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Lint code, e.g. `determinism:float-sort`, `unsafe`, `wire`, `panic`.
    pub code: &'static str,
    /// Path relative to the scanned source root (`/`-separated).
    pub file: String,
    pub line: usize,
    pub message: String,
}

/// An accepted, reasoned policy exception found in-tree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Waiver {
    pub code: &'static str,
    pub file: String,
    pub line: usize,
    pub reason: String,
}

/// One `unsafe` occurrence (documented or not) — the review inventory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnsafeSite {
    pub file: String,
    pub line: usize,
}

/// Everything one audit run produced.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub findings: Vec<Finding>,
    pub waivers: Vec<Waiver>,
    pub unsafe_sites: Vec<UnsafeSite>,
}

impl AuditReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Render as a JSON object (hand-rolled — the audit must not pull in
    /// dependencies it would then have to audit).
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len() + 2);
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32));
                    }
                    c => out.push(c),
                }
            }
            out
        }
        let findings: Vec<String> = self
            .findings
            .iter()
            .map(|f| {
                format!(
                    "{{\"code\":\"{}\",\"file\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
                    esc(f.code),
                    esc(&f.file),
                    f.line,
                    esc(&f.message),
                )
            })
            .collect();
        let waivers: Vec<String> = self
            .waivers
            .iter()
            .map(|w| {
                format!(
                    "{{\"code\":\"{}\",\"file\":\"{}\",\"line\":{},\"reason\":\"{}\"}}",
                    esc(w.code),
                    esc(&w.file),
                    w.line,
                    esc(&w.reason),
                )
            })
            .collect();
        let sites: Vec<String> = self
            .unsafe_sites
            .iter()
            .map(|u| format!("{{\"file\":\"{}\",\"line\":{}}}", esc(&u.file), u.line))
            .collect();
        format!(
            "{{\"findings\":[{}],\"waivers\":[{}],\"unsafe\":[{}]}}",
            findings.join(","),
            waivers.join(","),
            sites.join(","),
        )
    }

    /// Human-readable report lines (one per finding/waiver/unsafe site).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "audit[{}] {}:{}: {}\n",
                f.code, f.file, f.line, f.message
            ));
        }
        out.push_str(&format!(
            "audit: {} finding(s), {} waiver(s), {} unsafe site(s)\n",
            self.findings.len(),
            self.waivers.len(),
            self.unsafe_sites.len(),
        ));
        for w in &self.waivers {
            out.push_str(&format!(
                "  waived[{}] {}:{}: {}\n",
                w.code, w.file, w.line, w.reason
            ));
        }
        for u in &self.unsafe_sites {
            out.push_str(&format!("  unsafe {}:{}\n", u.file, u.line));
        }
        out
    }
}

/// Where to audit. `lock_path: None` skips the wire check (fixture trees).
pub struct AuditConfig {
    /// Root of the source tree to scan (the crate's `src/`).
    pub src_root: PathBuf,
    /// Path to the `wire.lock` golden table.
    pub lock_path: Option<PathBuf>,
}

impl AuditConfig {
    /// Audit this crate itself: `src/` and `wire.lock` next to the
    /// manifest directory the binary was built from.
    pub fn for_crate(manifest_dir: &str) -> AuditConfig {
        let root = Path::new(manifest_dir);
        AuditConfig {
            src_root: root.join("src"),
            lock_path: Some(root.join("wire.lock")),
        }
    }
}

/// Collect every `.rs` file under `root`, sorted, as (relative, absolute).
fn rust_files(root: &Path) -> io::Result<Vec<(String, PathBuf)>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
        let mut entries: Vec<PathBuf> =
            fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<Result<_, _>>()?;
        entries.sort();
        for path in entries {
            if path.is_dir() {
                walk(&path, out)?;
            } else if path.extension().is_some_and(|x| x == "rs") {
                out.push(path);
            }
        }
        Ok(())
    }
    let mut paths = Vec::new();
    walk(root, &mut paths)?;
    let mut out = Vec::new();
    for p in paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        out.push((rel, p));
    }
    // lexicographic on the *relative* path so nesting differences between
    // platforms cannot reorder the report
    out.sort();
    Ok(out)
}

/// Run the full audit over `cfg.src_root` (+ the wire check if configured).
pub fn run_audit(cfg: &AuditConfig) -> io::Result<AuditReport> {
    let mut report = AuditReport::default();
    for (rel, abs) in rust_files(&cfg.src_root)? {
        let src = fs::read_to_string(&abs)?;
        let scan = lints::scan_file(&rel, &src);
        report.findings.extend(scan.findings);
        report.waivers.extend(scan.waivers);
        report.unsafe_sites.extend(scan.unsafe_sites);
    }
    if let Some(lock_path) = &cfg.lock_path {
        report.findings.extend(run_wire_check(&cfg.src_root, lock_path));
    }
    report.findings.sort_by(|a, b| {
        (&a.file, a.line, a.code).cmp(&(&b.file, b.line, b.code))
    });
    Ok(report)
}

/// Parse the current wire/frame constants from `src_root`.
pub fn current_wire_consts(src_root: &Path) -> io::Result<Vec<wirecheck::ConstEntry>> {
    let wire = fs::read_to_string(src_root.join("net/wire.rs"))?;
    let frame = fs::read_to_string(src_root.join("net/frame.rs"))?;
    let mut consts = wirecheck::parse_consts("wire", &wire);
    consts.extend(wirecheck::parse_consts("frame", &frame));
    Ok(consts)
}

fn run_wire_check(src_root: &Path, lock_path: &Path) -> Vec<Finding> {
    let consts = match current_wire_consts(src_root) {
        Ok(c) => c,
        Err(e) => {
            return vec![Finding {
                code: "wire",
                file: "net/wire.rs".to_string(),
                line: 0,
                message: format!("cannot read wire sources: {e}"),
            }];
        }
    };
    let lock_text = match fs::read_to_string(lock_path) {
        Ok(t) => t,
        Err(e) => {
            return vec![Finding {
                code: "wire",
                file: lock_path.display().to_string(),
                line: 0,
                message: format!(
                    "cannot read wire.lock ({e}) — regenerate with \
                     `dpp audit --write-wire-lock > rust/wire.lock`"
                ),
            }];
        }
    };
    let lock = match wirecheck::parse_lock(&lock_text) {
        Ok(l) => l,
        Err(e) => {
            return vec![Finding {
                code: "wire",
                file: lock_path.display().to_string(),
                line: 0,
                message: e,
            }];
        }
    };
    wirecheck::check(&consts, &lock)
}
