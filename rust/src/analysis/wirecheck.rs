//! Wire-compatibility audit: `net/wire.rs` + `net/frame.rs` against the
//! committed `rust/wire.lock` golden table (DESIGN.md §5).
//!
//! The lock pins every tag/version constant of the wire grammar. The audit
//! fails on (a) tag reuse inside a namespace (`REQ_*`, `RESP_*`, …— two
//! constants with one byte value would silently re-mean frames), and
//! (b) any drift between source and lock: a drifted entry with an
//! *unchanged* `WIRE_VERSION` means the grammar changed silently; a
//! drifted entry with a *changed* version means the lock needs
//! regenerating (`dpp audit --write-wire-lock > rust/wire.lock`).
//! Wire findings are not waivable — the lock update *is* the waiver.

use std::collections::BTreeMap;

use super::Finding;

/// One `pub const NAME: TYPE = VALUE;` declaration (single-line form).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConstEntry {
    /// Lock namespace: `wire` or `frame`.
    pub table: &'static str,
    pub name: String,
    /// Type text, whitespace-stripped (`[u8;4]`).
    pub ty: String,
    /// Value text, whitespace-stripped (`64<<20`).
    pub val: String,
    /// 1-based source line (0 for lock-only entries).
    pub line: usize,
}

fn squeeze(s: &str) -> String {
    s.chars().filter(|c| !c.is_whitespace()).collect()
}

/// Parse every single-line `pub const` in `src`. Comment lines never match
/// (they don't start with `pub const` after trimming), which is all the
/// lexing this needs.
pub fn parse_consts(table: &'static str, src: &str) -> Vec<ConstEntry> {
    let mut out = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let line = raw.trim_start();
        let Some(rest) = line.strip_prefix("pub const ") else { continue };
        let Some((name, rest)) = rest.split_once(':') else { continue };
        let Some((ty, rest)) = rest.split_once('=') else { continue };
        let Some((val, _)) = rest.split_once(';') else { continue };
        out.push(ConstEntry {
            table,
            name: name.trim().to_string(),
            ty: squeeze(ty),
            val: squeeze(val),
            line: idx + 1,
        });
    }
    out
}

/// Parse a `wire.lock` body: `<table> <NAME> <type> <value>` per line,
/// `#` comments and blanks skipped.
pub fn parse_lock(text: &str) -> Result<Vec<ConstEntry>, String> {
    let mut out = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(table), Some(name), Some(ty), Some(val)) =
            (it.next(), it.next(), it.next(), it.next())
        else {
            return Err(format!("wire.lock:{}: malformed line `{line}`", idx + 1));
        };
        let table = match table {
            "wire" => "wire",
            "frame" => "frame",
            other => {
                return Err(format!("wire.lock:{}: unknown table `{other}`", idx + 1));
            }
        };
        out.push(ConstEntry {
            table,
            name: name.to_string(),
            ty: ty.to_string(),
            val: val.to_string(),
            line: 0,
        });
    }
    Ok(out)
}

/// Render the canonical lock text for the given parsed constants — the
/// exact bytes `dpp audit --write-wire-lock` prints and the round-trip
/// test pins against the committed file.
pub fn render_lock(consts: &[ConstEntry]) -> String {
    let mut out = String::from(
        "# rust/wire.lock — golden copy of the committed wire-grammar surface.\n\
         #\n\
         # One line per constant: <file> <NAME> <type> <value> (whitespace-stripped).\n\
         # `dpp audit` re-parses net/wire.rs and net/frame.rs and fails on any drift:\n\
         # a changed or reused tag, or a grammar change without a WIRE_VERSION bump.\n\
         # After a deliberate change, bump WIRE_VERSION and regenerate:\n\
         #\n\
         #     dpp audit --write-wire-lock > rust/wire.lock\n\
         \n",
    );
    for c in consts {
        out.push_str(&format!("{} {} {} {}\n", c.table, c.name, c.ty, c.val));
    }
    out
}

fn src_file(table: &str) -> &'static str {
    if table == "wire" { "net/wire.rs" } else { "net/frame.rs" }
}

/// Namespace of a tag constant: the prefix before the first `_`
/// (`REQ_SCREEN` → `REQ`). Constants without `_` form their own namespace.
fn namespace(name: &str) -> &str {
    name.split('_').next().unwrap_or(name)
}

/// Check parsed source constants against the lock. Returns findings.
pub fn check(consts: &[ConstEntry], lock: &[ConstEntry]) -> Vec<Finding> {
    let mut findings = Vec::new();

    // (a) tag reuse: two u8 constants sharing a namespace and a value
    let mut seen: BTreeMap<(&str, &str, &str), &ConstEntry> = BTreeMap::new();
    for c in consts {
        if c.ty != "u8" || !c.name.contains('_') {
            continue;
        }
        let key = (c.table, namespace(&c.name), c.val.as_str());
        if let Some(prev) = seen.get(&key) {
            findings.push(Finding {
                code: "wire",
                file: src_file(c.table).to_string(),
                line: c.line,
                message: format!(
                    "tag reuse: `{}` and `{}` both encode as {} in the `{}` \
                     namespace — frames become ambiguous",
                    prev.name,
                    c.name,
                    c.val,
                    namespace(&c.name),
                ),
            });
        } else {
            seen.insert(key, c);
        }
    }

    // (b) drift vs the lock
    let key = |c: &ConstEntry| (c.table, c.name.clone());
    let src_map: BTreeMap<_, _> = consts.iter().map(|c| (key(c), c)).collect();
    let lock_map: BTreeMap<_, _> = lock.iter().map(|c| (key(c), c)).collect();
    let version_key = ("wire", "WIRE_VERSION".to_string());
    let version_bumped = match (src_map.get(&version_key), lock_map.get(&version_key)) {
        (Some(s), Some(l)) => s.val != l.val,
        _ => false,
    };
    let remedy = if version_bumped {
        "WIRE_VERSION was bumped — regenerate the lock: \
         `dpp audit --write-wire-lock > rust/wire.lock`"
    } else {
        "changing the grammar requires a WIRE_VERSION bump *and* a lock \
         regeneration (`dpp audit --write-wire-lock > rust/wire.lock`)"
    };

    for (k, s) in &src_map {
        match lock_map.get(k) {
            None => findings.push(Finding {
                code: "wire",
                file: src_file(s.table).to_string(),
                line: s.line,
                message: format!("`{}` is not in wire.lock — {remedy}", s.name),
            }),
            Some(l) if l.ty != s.ty || l.val != s.val => findings.push(Finding {
                code: "wire",
                file: src_file(s.table).to_string(),
                line: s.line,
                message: format!(
                    "`{}` drifted from wire.lock ({} {} ≠ locked {} {}) — {remedy}",
                    s.name, s.ty, s.val, l.ty, l.val,
                ),
            }),
            Some(_) => {}
        }
    }
    for (k, l) in &lock_map {
        if !src_map.contains_key(k) {
            findings.push(Finding {
                code: "wire",
                file: src_file(l.table).to_string(),
                line: 0,
                message: format!(
                    "`{}` is in wire.lock but gone from the source — {remedy}",
                    l.name,
                ),
            });
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    const WIRE_SRC: &str = "\
pub const WIRE_VERSION: u32 = 1;
pub mod tag {
    pub const REQ_SCREEN: u8 = 0;
    pub const REQ_WARM: u8 = 1;
    pub const RESP_SCREEN: u8 = 0;
}
";

    fn lock_for(src: &str) -> Vec<ConstEntry> {
        parse_consts("wire", src)
            .into_iter()
            .map(|mut c| {
                c.line = 0;
                c
            })
            .collect()
    }

    #[test]
    fn parse_skips_comments_and_strips_whitespace() {
        let src = "// pub const FAKE: u8 = 9;\npub const MAGIC: [u8; 4] = *b\"DPPN\";\n";
        let got = parse_consts("frame", src);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].name, "MAGIC");
        assert_eq!(got[0].ty, "[u8;4]");
        assert_eq!(got[0].val, "*b\"DPPN\"");
        assert_eq!(got[0].line, 2);
    }

    #[test]
    fn matching_lock_is_clean() {
        let consts = parse_consts("wire", WIRE_SRC);
        assert!(check(&consts, &lock_for(WIRE_SRC)).is_empty());
    }

    #[test]
    fn tag_reuse_within_namespace_flagged() {
        let src = WIRE_SRC.replace("REQ_WARM: u8 = 1", "REQ_WARM: u8 = 0");
        let consts = parse_consts("wire", &src);
        let f = check(&consts, &lock_for(&src));
        assert_eq!(f.iter().filter(|f| f.message.contains("tag reuse")).count(), 1);
    }

    #[test]
    fn cross_namespace_same_value_is_fine() {
        // REQ_SCREEN and RESP_SCREEN both 0 — different namespaces
        let consts = parse_consts("wire", WIRE_SRC);
        assert!(check(&consts, &lock_for(WIRE_SRC)).is_empty());
    }

    #[test]
    fn silent_change_demands_version_bump() {
        let drifted = WIRE_SRC.replace("REQ_WARM: u8 = 1", "REQ_WARM: u8 = 7");
        let f = check(&parse_consts("wire", &drifted), &lock_for(WIRE_SRC));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("requires a WIRE_VERSION bump"));
    }

    #[test]
    fn bumped_version_points_at_lock_regeneration() {
        let bumped = WIRE_SRC
            .replace("WIRE_VERSION: u32 = 1", "WIRE_VERSION: u32 = 2")
            .replace("REQ_WARM: u8 = 1", "REQ_WARM: u8 = 7");
        let f = check(&parse_consts("wire", &bumped), &lock_for(WIRE_SRC));
        // both the version const and the tag drifted
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|f| f.message.contains("regenerate the lock")));
    }

    #[test]
    fn new_and_removed_tags_flagged() {
        let grown = WIRE_SRC.replace(
            "pub const RESP_SCREEN: u8 = 0;",
            "pub const RESP_SCREEN: u8 = 0;\n    pub const RESP_EXTRA: u8 = 1;",
        );
        let f = check(&parse_consts("wire", &grown), &lock_for(WIRE_SRC));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("not in wire.lock"));

        let f = check(&parse_consts("wire", WIRE_SRC), &lock_for(&grown));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("gone from the source"));
    }

    #[test]
    fn lock_round_trips_through_render() {
        let consts = lock_for(WIRE_SRC);
        let parsed = parse_lock(&render_lock(&consts)).expect("well-formed lock");
        assert_eq!(parsed, consts);
    }
}
