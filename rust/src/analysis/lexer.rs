//! Token-level source lexer for `dpp audit` (DESIGN.md §5).
//!
//! Deliberately not a parser: it only separates *code* from *non-code*
//! (comments, string/char literals) so the lint scans can match raw tokens
//! without tripping on their own names inside doc text, and it keeps the
//! comment text per line so waivers (`// audit:allow(..)`) and `// SAFETY:`
//! anchors stay findable. Blanking preserves byte offsets and line
//! structure, so every token offset maps straight back to a source line.

use std::collections::BTreeMap;

/// Lexed view of one source file.
pub struct Lexed {
    /// The source with comment bodies and string/char-literal contents
    /// blanked to spaces (newlines kept): same length, same line starts.
    pub code: String,
    /// Comment text concatenated per 0-based line.
    pub comments: BTreeMap<usize, String>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn blank(code: &mut [u8], a: usize, b: usize) {
    for c in code[a..b.min(code.len())].iter_mut() {
        if *c != b'\n' {
            *c = b' ';
        }
    }
}

fn note(comments: &mut BTreeMap<usize, String>, line: usize, text: &[u8]) {
    comments
        .entry(line)
        .or_default()
        .push_str(&String::from_utf8_lossy(text));
}

fn count_newlines(b: &[u8], a: usize, z: usize) -> usize {
    b[a..z.min(b.len())].iter().filter(|&&c| c == b'\n').count()
}

/// Blank comments and literal contents out of `src`; collect comment text.
pub fn strip_code(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut code = b.to_vec();
    let mut comments: BTreeMap<usize, String> = BTreeMap::new();
    let mut i = 0;
    let mut line = 0;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            note(&mut comments, line, &b[i..j]);
            blank(&mut code, i, j);
            i = j;
            continue;
        }
        // block comment (nesting, per-line comment text)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1;
            let mut j = i + 2;
            let mut cur = line;
            let mut seg = i;
            while j < n && depth > 0 {
                if b[j] == b'\n' {
                    note(&mut comments, cur, &b[seg..j]);
                    cur += 1;
                    seg = j + 1;
                    j += 1;
                } else if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            note(&mut comments, cur, &b[seg..j.min(n)]);
            blank(&mut code, i, j);
            line = cur;
            i = j;
            continue;
        }
        // raw string r"…" / r#"…"# / br"…"
        if c == b'r' || (c == b'b' && i + 1 < n && b[i + 1] == b'r') {
            let mut j = i + if c == b'b' { 2 } else { 1 };
            let mut hashes = 0;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                j += 1;
                let mut end = n;
                'outer: while j < n {
                    if b[j] == b'"' {
                        let mut k = 0;
                        while k < hashes {
                            if j + 1 + k >= n || b[j + 1 + k] != b'#' {
                                j += 1;
                                continue 'outer;
                            }
                            k += 1;
                        }
                        end = j + 1 + hashes;
                        break;
                    }
                    j += 1;
                }
                line += count_newlines(b, i, end);
                blank(&mut code, i, end);
                i = end;
                continue;
            }
        }
        // byte string b"…" / byte char b'…': strip the prefix, re-dispatch
        let (c, i0) = if c == b'b' && i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'\'') {
            (b[i + 1], i + 1)
        } else {
            (c, i)
        };
        if c == b'"' {
            let mut j = i0 + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            line += count_newlines(b, i, j);
            blank(&mut code, i, j);
            i = j;
            continue;
        }
        if c == b'\'' {
            // lifetime, or a char literal
            if i0 + 1 < n && (b[i0 + 1].is_ascii_alphabetic() || b[i0 + 1] == b'_') {
                let mut j = i0 + 2;
                while j < n && is_ident(b[j]) {
                    j += 1;
                }
                if j < n && b[j] == b'\'' {
                    blank(&mut code, i, j + 1); // 'x' char literal
                    i = j + 1;
                } else {
                    i = i0 + 1; // lifetime: keep the identifier as code
                }
                continue;
            }
            let mut j = i0 + 1;
            if j < n && b[j] == b'\\' {
                j += 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                j += 1;
            } else if j < n {
                j += 2;
            }
            blank(&mut code, i, j);
            i = j;
            continue;
        }
        i += 1;
    }
    Lexed { code: String::from_utf8_lossy(&code).into_owned(), comments }
}

/// 0-based lines covered by `#[cfg(test)]`-gated items (brace-balanced
/// from the attribute to the matching close of the item it gates).
pub fn test_lines(code: &str) -> Vec<bool> {
    let n_lines = code.split('\n').count();
    let mut out = vec![false; n_lines];
    let b = code.as_bytes();
    let marker = "#[cfg(test)]";
    let mut idx = 0;
    while let Some(at) = code[idx..].find(marker) {
        let at = idx + at;
        let start_line = count_newlines(b, 0, at);
        let Some(open) = code[at..].find('{') else { break };
        let open = at + open;
        let mut depth = 0usize;
        let mut k = open;
        while k < b.len() {
            if b[k] == b'{' {
                depth += 1;
            } else if b[k] == b'}' {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            k += 1;
        }
        let end_line = count_newlines(b, 0, k.min(b.len()));
        for flag in out
            .iter_mut()
            .skip(start_line)
            .take(end_line - start_line + 1)
        {
            *flag = true;
        }
        idx = k.min(b.len() - 1).max(idx + marker.len());
    }
    out
}

/// Byte offsets of word-boundary occurrences of `needle` in `hay`.
pub fn word_hits(hay: &str, needle: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    let hb = hay.as_bytes();
    let mut at = 0;
    while let Some(pos) = hay[at..].find(needle) {
        let pos = at + pos;
        let before_ok = pos == 0 || !is_ident(hb[pos - 1]);
        let end = pos + needle.len();
        let after_ok = end >= hb.len() || !is_ident(hb[end]);
        if before_ok && after_ok {
            hits.push(pos);
        }
        at = pos + needle.len();
    }
    hits
}

/// 0-based line of byte offset `off` in `code`.
pub fn line_of(code: &str, off: usize) -> usize {
    count_newlines(code.as_bytes(), 0, off)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_blanked_and_collected() {
        let lx = strip_code("let a = 1; // unwrap() here\nlet b = 2;\n");
        assert!(!lx.code.contains("unwrap"));
        assert!(lx.comments[&0].contains("unwrap() here"));
        assert!(lx.code.starts_with("let a = 1; "));
    }

    #[test]
    fn strings_and_chars_are_blanked() {
        let lx = strip_code(r#"let s = "partial_cmp"; let c = '"'; let t = s;"#);
        assert!(!lx.code.contains("partial_cmp"));
        assert!(lx.code.contains("let t = s;"));
    }

    #[test]
    fn raw_and_byte_literals() {
        let lx = strip_code("let m = *b\"DPPN\"; let r = r#\"HashMap\"#; let x = b'/';");
        assert!(!lx.code.contains("DPPN"));
        assert!(!lx.code.contains("HashMap"));
        assert!(!lx.code.contains('/'));
    }

    #[test]
    fn lifetimes_survive_blanking() {
        let lx = strip_code("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(lx.code.contains("fn f<"));
        assert!(lx.code.contains("a str"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let lx = strip_code("a /* one /* two */ still */ b\n/* l1\nl2 SAFETY: x */ c\n");
        assert!(lx.code.contains('a'));
        assert!(lx.code.contains('b'));
        assert!(lx.code.contains('c'));
        assert!(!lx.code.contains("still"));
        assert!(lx.comments[&2].contains("SAFETY:"));
    }

    #[test]
    fn test_region_detection() {
        let src = "fn prod() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\nfn late() {}\n";
        let lx = strip_code(src);
        let tl = test_lines(&lx.code);
        assert!(!tl[0]);
        assert!(tl[1] && tl[2] && tl[3] && tl[4]);
        assert!(!tl[5]);
    }

    #[test]
    fn word_boundaries() {
        assert_eq!(word_hits("HashMap and MyHashMap and HashMap2", "HashMap"), vec![0]);
        assert_eq!(word_hits("unsafe_sites unsafe", "unsafe"), vec![13]);
    }
}
