//! Small statistics helpers used by the bench harness and metrics.

use crate::linalg::ops::seq_sum;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    seq_sum(xs) / xs.len() as f64
}

/// Sample standard deviation (0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Median (sorts a copy; 0.0 for empty input).
///
/// Sorts with `total_cmp`: unlike the old `partial_cmp().unwrap()`, a NaN
/// sample no longer panics — it sorts above +∞ (IEEE total order) and
/// poisons the result visibly instead of aborting a metrics flush.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// q-th quantile with linear interpolation, q ∈ [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Streaming mean/variance (Welford) — used by the coordinator's metrics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std_dev(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Raw accumulator state `(n, mean, m2, min, max)` — used by the wire
    /// codec so stats survive a socket hop bit-exactly.
    pub fn to_raw(&self) -> (u64, f64, f64, f64, f64) {
        (self.n, self.mean, self.m2, self.min, self.max)
    }

    /// Rebuild from raw accumulator state (inverse of [`OnlineStats::to_raw`]).
    pub fn from_raw(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> Self {
        OnlineStats { n, mean, m2, min, max }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_median() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert!((std_dev(&xs) - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.0);
        assert!((quantile(&xs, 0.25) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut os = OnlineStats::new();
        for &x in &xs {
            os.push(x);
        }
        assert!((os.mean() - mean(&xs)).abs() < 1e-12);
        assert!((os.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(os.min(), 2.0);
        assert_eq!(os.max(), 9.0);
        assert_eq!(os.count(), 8);
    }
}
