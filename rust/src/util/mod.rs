//! Utility substrates: RNG, statistics, timing, CLI parsing, the bench
//! harness, and the property-test driver.
//!
//! The build image has no network registry access and only the `xla` crate's
//! dependency closure vendored, so `rand`, `clap`, `criterion`, and
//! `proptest` are unavailable; these modules are the in-repo replacements
//! (DESIGN.md §6 "Environment deviations").

pub mod benchkit;
pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

/// Read a scale knob from the environment.
///
/// `DPP_SCALE=full` makes dataset generators use the paper's exact shapes;
/// anything else (default) uses scaled-down shapes that keep every bench
/// minutes-scale on the 1-core image (DESIGN.md §7).
pub fn full_scale() -> bool {
    std::env::var("DPP_SCALE").map(|v| v == "full").unwrap_or(false)
}

/// Number of trials for averaged experiments (paper uses 100; default here
/// is small for CI-speed; override with `DPP_TRIALS`).
pub fn n_trials(default: usize) -> usize {
    std::env::var("DPP_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// λ-grid size (paper uses 100 points on λ/λmax ∈ [0.05, 1]).
pub fn grid_size(default: usize) -> usize {
    std::env::var("DPP_GRID").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}
