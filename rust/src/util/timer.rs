//! Wall-clock timing helpers.

use std::time::Instant;

/// A simple stopwatch accumulating named phases — the path driver uses one
/// to split screening time from solver time (the paper reports both).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, f64)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and accumulate under `name`. Returns the closure value.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Add `secs` to the phase `name`.
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(p) = self.phases.iter_mut().find(|(n, _)| n == name) {
            p.1 += secs;
        } else {
            self.phases.push((name.to_string(), secs));
        }
    }

    /// Accumulated seconds for `name` (0.0 if never recorded).
    pub fn get(&self, name: &str) -> f64 {
        self.phases.iter().find(|(n, _)| n == name).map(|(_, s)| *s).unwrap_or(0.0)
    }

    /// Total across all phases.
    pub fn total(&self) -> f64 {
        self.phases.iter().map(|(_, s)| s).sum()
    }

    /// Merge another timer's phases into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (n, s) in &other.phases {
            self.add(n, *s);
        }
    }

    pub fn phases(&self) -> &[(String, f64)] {
        &self.phases
    }
}

/// Time a single closure, returning (value, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let mut t = PhaseTimer::new();
        t.add("screen", 0.5);
        t.add("solve", 1.0);
        t.add("screen", 0.25);
        assert!((t.get("screen") - 0.75).abs() < 1e-12);
        assert!((t.total() - 1.75).abs() < 1e-12);
        assert_eq!(t.get("missing"), 0.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = PhaseTimer::new();
        a.add("x", 1.0);
        let mut b = PhaseTimer::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert_eq!(a.get("x"), 3.0);
        assert_eq!(a.get("y"), 3.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
