//! Criterion-style bench harness (criterion is not available offline).
//!
//! Benches in `rust/benches/` are `harness = false` binaries that use
//! [`Bench`] for warmup + timed iterations and [`Report`] to print
//! paper-style markdown tables; `cargo bench` runs them all.

use std::time::Instant;

use super::stats;

/// Timed micro-benchmark: warms up, then runs `iters` measured iterations.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 2, iters: 5 }
    }
}

/// One measurement result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub samples: usize,
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Bench { warmup_iters, iters }
    }

    /// Run `f` with warmup and return timing statistics. `f` must not be
    /// optimized away — return something and let the caller black-box it.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        Measurement {
            name: name.to_string(),
            mean_s: stats::mean(&times),
            std_s: stats::std_dev(&times),
            min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
            samples: times.len(),
        }
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects rows and renders a markdown table — used to print the same rows
/// the paper's tables report, plus to append results to `results/*.md`.
#[derive(Debug, Default)]
pub struct Report {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Report {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    /// Render the table as markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n### {}\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Print to stdout and append to `results/<file>` (creating the dir).
    pub fn emit(&self, file: &str) {
        let md = self.to_markdown();
        println!("{md}");
        let _ = std::fs::create_dir_all("results");
        let path = format!("results/{file}");
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            let _ = f.write_all(md.as_bytes());
        }
    }
}

/// Format seconds with sensible precision.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let b = Bench::new(1, 3);
        let m = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(m.mean_s > 0.0);
        assert!(m.min_s <= m.mean_s);
        assert_eq!(m.samples, 3);
    }

    #[test]
    fn report_markdown_shape() {
        let mut r = Report::new("T", &["a", "b"]);
        r.row_strs(&["1", "2"]);
        let md = r.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic]
    fn report_arity_checked() {
        let mut r = Report::new("T", &["a", "b"]);
        r.row_strs(&["only-one"]);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(123.4), "123");
        assert_eq!(fmt_secs(1.5), "1.50");
        assert_eq!(fmt_secs(0.0015), "1.50ms");
        assert_eq!(fmt_secs(2e-5), "20.0us");
    }
}
