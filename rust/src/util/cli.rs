//! Minimal CLI argument parser (clap is not available offline — DESIGN.md §6).
//!
//! Grammar: `dpp <subcommand> [--key value]... [--flag]... [positional]...`

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    /// `--key value` options and bare `--flag`s (flags map to "true").
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(key) = t.strip_prefix("--") {
                // --key=value, --key value, or bare --flag
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.options.insert(key.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.options.insert(key.to_string(), "true".to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("path --dataset pie --grid 100 --full");
        assert_eq!(a.command.as_deref(), Some("path"));
        assert_eq!(a.get("dataset"), Some("pie"));
        assert_eq!(a.get_parse::<usize>("grid", 0), 100);
        assert!(a.flag("full"));
        assert!(!a.flag("absent"));
    }

    #[test]
    fn equals_form_and_positionals() {
        let a = parse("exp fig1 --trials=5 extra");
        assert_eq!(a.command.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["fig1", "extra"]);
        assert_eq!(a.get_parse::<usize>("trials", 0), 5);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("run --verbose --seed 9");
        assert!(a.flag("verbose"));
        assert_eq!(a.get_parse::<u64>("seed", 0), 9);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_parse::<f64>("missing", 1.5), 1.5);
    }
}
