//! Property-test driver (proptest is unavailable offline — DESIGN.md §6).
//!
//! A property is a closure over a seeded [`Rng`]; the driver runs it for many
//! derived seeds and, on failure, reports the exact failing seed so the case
//! is replayable with `check_one`.

use super::rng::Rng;

/// Run `cases` instances of `property`, each with an independent RNG derived
/// from `base_seed`. Panics (with the failing seed) on the first failure.
pub fn check(name: &str, base_seed: u64, cases: usize, mut property: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = derive_seed(base_seed, case as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property `{name}` failed at case {case}/{cases} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (used to debug a reported failure).
pub fn check_one(seed: u64, mut property: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(seed);
    property(&mut rng);
}

fn derive_seed(base: u64, case: u64) -> u64 {
    // splitmix-style mix so adjacent cases are decorrelated
    let mut z = base ^ case.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 1, 25, |_rng| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check("always-fails", 2, 3, |_rng| panic!("boom"));
        }));
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn derived_seeds_distinct() {
        let a = derive_seed(1, 0);
        let b = derive_seed(1, 1);
        let c = derive_seed(2, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }
}
