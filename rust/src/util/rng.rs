//! Deterministic pseudo-random number generation.
//!
//! xoshiro256++ seeded through splitmix64 — the standard, well-tested
//! construction (Blackman & Vigna). Every experiment in the repo is
//! reproducible from a single `u64` seed.

/// xoshiro256++ generator with Box–Muller normal sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second output of the last Box–Muller pair
    gauss_cache: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Derive an independent child stream (for per-trial / per-thread use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal N(0, 1) via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
            self.gauss_cache = Some(r * s);
            return r * c;
        }
    }

    /// Fill a slice with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Lognormal with the given log-mean / log-std.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: first k slots
        for i in 0..k {
            let j = i + self.usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut s, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
            s3 += x * x * x;
        }
        assert!((s / n as f64).abs() < 0.02);
        assert!((s2 / n as f64 - 1.0).abs() < 0.03);
        assert!((s3 / n as f64).abs() < 0.1); // symmetry
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(3);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut back = xs.clone();
        back.sort_unstable();
        assert_eq!(back, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
