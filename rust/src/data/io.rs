//! Dataset I/O: CSV and (sparse) LIBSVM formats, so downstream users can run
//! the screening framework on their own data (`dpp path --file …`).
//!
//! CSV layout: one sample per line, `y,x1,x2,…,xp` (optional `#` comments).
//! LIBSVM layout: `y idx:val idx:val …` with 1-based indices.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;
use crate::linalg::DenseMatrix;

/// Parse a CSV dataset (`y,x1,…,xp` per line).
pub fn read_csv(path: impl AsRef<Path>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    parse_csv(BufReader::new(f), path.as_ref().display().to_string())
}

fn parse_csv(reader: impl BufRead, name: String) -> Result<Dataset> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut y = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("reading line")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut vals = line.split(',').map(|t| t.trim().parse::<f64>());
        let yi = vals
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?
            .with_context(|| format!("line {}: bad y", lineno + 1))?;
        let feat: Result<Vec<f64>, _> = vals.collect();
        let feat = feat.with_context(|| format!("line {}: bad feature", lineno + 1))?;
        if let Some(first) = rows.first() {
            if feat.len() != first.len() {
                bail!(
                    "line {}: {} features, expected {}",
                    lineno + 1,
                    feat.len(),
                    first.len()
                );
            }
        }
        y.push(yi);
        rows.push(feat);
    }
    if rows.is_empty() {
        bail!("no data rows");
    }
    Ok(Dataset {
        name,
        x: DenseMatrix::from_rows(&rows),
        y,
        beta_true: None,
        groups: None,
    })
}

/// Write a dataset as CSV.
pub fn write_csv(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    for i in 0..ds.n() {
        let mut line = format!("{}", ds.y[i]);
        for j in 0..ds.p() {
            line.push(',');
            line.push_str(&format!("{}", ds.x.get(i, j)));
        }
        line.push('\n');
        f.write_all(line.as_bytes())?;
    }
    Ok(())
}

/// Parse LIBSVM format (`y idx:val …`, 1-based indices). `p_hint` can force
/// the feature count (otherwise the max index seen is used).
pub fn read_libsvm(path: impl AsRef<Path>, p_hint: Option<usize>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    parse_libsvm(BufReader::new(f), path.as_ref().display().to_string(), p_hint)
}

fn parse_libsvm(reader: impl BufRead, name: String, p_hint: Option<usize>) -> Result<Dataset> {
    let mut entries: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut y = Vec::new();
    let mut p_max = p_hint.unwrap_or(0);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("reading line")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut toks = line.split_whitespace();
        let yi: f64 = toks
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let mut row = Vec::new();
        for t in toks {
            let (idx, val) = t
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("line {}: bad pair `{t}`", lineno + 1))?;
            let idx: usize =
                idx.parse().with_context(|| format!("line {}: bad index", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: LIBSVM indices are 1-based", lineno + 1);
            }
            let val: f64 =
                val.parse().with_context(|| format!("line {}: bad value", lineno + 1))?;
            p_max = p_max.max(idx);
            row.push((idx - 1, val));
        }
        y.push(yi);
        entries.push(row);
    }
    if entries.is_empty() {
        bail!("no data rows");
    }
    if let Some(p) = p_hint {
        if p_max > p {
            bail!("index {} exceeds p_hint {}", p_max, p);
        }
        p_max = p;
    }
    let n = entries.len();
    let mut x = DenseMatrix::zeros(n, p_max);
    for (i, row) in entries.iter().enumerate() {
        for &(j, v) in row {
            x.set(i, j, v);
        }
    }
    Ok(Dataset { name, x, y, beta_true: None, groups: None })
}

/// Write a dataset in LIBSVM format (zeros skipped).
pub fn write_libsvm(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    for i in 0..ds.n() {
        let mut line = format!("{}", ds.y[i]);
        for j in 0..ds.p() {
            let v = ds.x.get(i, j);
            if v != 0.0 {
                line.push_str(&format!(" {}:{}", j + 1, v));
            }
        }
        line.push('\n');
        f.write_all(line.as_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use std::io::Cursor;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dpp-io-tests");
        let _ = std::fs::create_dir_all(&dir);
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip() {
        let ds = synthetic::synthetic1(10, 7, 3, 0.1, 1);
        let path = tmp("round.csv");
        write_csv(&ds, &path).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!((back.n(), back.p()), (10, 7));
        for i in 0..10 {
            assert!((back.y[i] - ds.y[i]).abs() < 1e-12);
            for j in 0..7 {
                assert!((back.x.get(i, j) - ds.x.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn csv_rejects_ragged_and_garbage() {
        assert!(parse_csv(Cursor::new("1,2,3\n4,5\n"), "t".into()).is_err());
        assert!(parse_csv(Cursor::new("1,abc\n"), "t".into()).is_err());
        assert!(parse_csv(Cursor::new("# only comments\n"), "t".into()).is_err());
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let ds =
            parse_csv(Cursor::new("# header\n1,2,3\n\n-1,0,4\n"), "t".into()).unwrap();
        assert_eq!((ds.n(), ds.p()), (2, 2));
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.get(1, 1), 4.0);
    }

    #[test]
    fn libsvm_roundtrip_sparse() {
        let mut ds = synthetic::synthetic1(8, 6, 2, 0.1, 2);
        // sparsify
        for j in 0..6 {
            for v in ds.x.col_mut(j).iter_mut() {
                if v.abs() < 0.8 {
                    *v = 0.0;
                }
            }
        }
        let path = tmp("round.svm");
        write_libsvm(&ds, &path).unwrap();
        let back = read_libsvm(&path, Some(6)).unwrap();
        assert_eq!((back.n(), back.p()), (8, 6));
        for i in 0..8 {
            for j in 0..6 {
                assert!((back.x.get(i, j) - ds.x.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn libsvm_rejects_bad_input() {
        assert!(parse_libsvm(Cursor::new("1 0:3\n"), "t".into(), None).is_err()); // 0-based
        assert!(parse_libsvm(Cursor::new("1 a:3\n"), "t".into(), None).is_err());
        assert!(parse_libsvm(Cursor::new("1 5:1\n"), "t".into(), Some(3)).is_err()); // exceeds hint
        assert!(parse_libsvm(Cursor::new(""), "t".into(), None).is_err());
    }

    #[test]
    fn loaded_dataset_solves() {
        // end to end: write → read → screened path
        let ds = synthetic::synthetic1(20, 30, 4, 0.1, 3);
        let path = tmp("solve.csv");
        write_csv(&ds, &path).unwrap();
        let back = read_csv(&path).unwrap();
        let grid = crate::path::LambdaGrid::relative(&back.x, &back.y, 5, 0.1, 1.0);
        let out = crate::path::solve_path(
            &back.x,
            &back.y,
            &grid,
            crate::path::RuleKind::Edpp,
            crate::path::SolverKind::Cd,
            &crate::path::PathConfig::default(),
        );
        assert_eq!(out.records.len(), 5);
    }
}
