//! Dataset I/O: CSV and (sparse) LIBSVM formats, so downstream users can run
//! the screening framework on their own data (`dpp path --file …`).
//!
//! CSV layout: one sample per line, `y,x1,x2,…,xp` (optional `#` comments);
//! it is a dense format and loads into the dense backend. LIBSVM layout:
//! `y idx:val idx:val …` with 1-based indices; it is a sparse format and
//! loads **straight into the CSC backend** — the entries stream through a
//! counting sort into `CscMatrix::from_parts` and no dense N×p buffer is
//! ever allocated, so `Dataset` carries the sparse matrix end-to-end to
//! `Backend` selection, screening and the solvers. (Before this fix the
//! reader densified every sparse dataset, which silently forced the whole
//! EDPP-on-sparse pipeline onto the dense backend.)
//!
//! Per-line `idx:val` pairs are sorted by index (LIBSVM in the wild is not
//! always ordered) and duplicate indices are rejected as parse errors with
//! line numbers — they used to fall through to `from_parts` asserts and
//! panic. For datasets larger than RAM, `data::convert` turns the same
//! formats into an on-disk shard for the `mmap` backend in one
//! bounded-memory pass.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;
use crate::linalg::{CscMatrix, DenseMatrix};

/// Parse a CSV dataset (`y,x1,…,xp` per line).
pub fn read_csv(path: impl AsRef<Path>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    parse_csv(BufReader::new(f), path.as_ref().display().to_string())
}

/// Parse one CSV line into its label and feature fields (reusing `out`).
/// Returns `Ok(None)` for blank/comment lines, else `Ok(Some(label))`.
/// Shared by the in-RAM reader and the shard converter so the two paths
/// accept exactly the same inputs (the LIBSVM twin is
/// [`parse_libsvm_pairs`]).
pub(crate) fn parse_csv_fields(
    line: &str,
    lineno: usize,
    out: &mut Vec<f64>,
) -> Result<Option<f64>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    out.clear();
    let mut vals = line.split(',').map(|t| t.trim().parse::<f64>());
    let yi = vals
        .next()
        .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?
        .with_context(|| format!("line {}: bad y", lineno + 1))?;
    for v in vals {
        out.push(v.with_context(|| format!("line {}: bad feature", lineno + 1))?);
    }
    Ok(Some(yi))
}

fn parse_csv(reader: impl BufRead, name: String) -> Result<Dataset> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut y = Vec::new();
    let mut feat = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("reading line")?;
        let Some(yi) = parse_csv_fields(&line, lineno, &mut feat)? else {
            continue;
        };
        if let Some(first) = rows.first() {
            if feat.len() != first.len() {
                bail!(
                    "line {}: {} features, expected {}",
                    lineno + 1,
                    feat.len(),
                    first.len()
                );
            }
        }
        y.push(yi);
        rows.push(feat.clone());
    }
    if rows.is_empty() {
        bail!("no data rows");
    }
    Ok(Dataset {
        name,
        x: DenseMatrix::from_rows(&rows).into(),
        y,
        beta_true: None,
        groups: None,
    })
}

/// Write a dataset as CSV.
pub fn write_csv(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    for i in 0..ds.n() {
        let mut line = format!("{}", ds.y[i]);
        for j in 0..ds.p() {
            line.push(',');
            line.push_str(&format!("{}", ds.x.get(i, j)));
        }
        line.push('\n');
        f.write_all(line.as_bytes())?;
    }
    Ok(())
}

/// Parse one LIBSVM line into sorted, validated 0-based `(index, value)`
/// pairs (reusing `out`). Returns `Ok(None)` for blank/comment lines, else
/// `Ok(Some(label))`. Out-of-order pairs are sorted; duplicate indices,
/// 0-based indices and malformed tokens are errors carrying the 1-based
/// line number. Shared by the in-RAM reader and the shard converter so the
/// two paths accept exactly the same inputs.
pub(crate) fn parse_libsvm_pairs(
    line: &str,
    lineno: usize,
    out: &mut Vec<(u32, f64)>,
) -> Result<Option<f64>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    out.clear();
    let mut toks = line.split_whitespace();
    let yi: f64 = toks
        .next()
        .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?
        .parse()
        .with_context(|| format!("line {}: bad label", lineno + 1))?;
    for t in toks {
        let (idx, val) = t
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("line {}: bad pair `{t}`", lineno + 1))?;
        let idx: usize =
            idx.parse().with_context(|| format!("line {}: bad index", lineno + 1))?;
        if idx == 0 {
            bail!("line {}: LIBSVM indices are 1-based", lineno + 1);
        }
        if idx - 1 > u32::MAX as usize {
            bail!("line {}: index {} exceeds u32 range", lineno + 1, idx);
        }
        let val: f64 =
            val.parse().with_context(|| format!("line {}: bad value", lineno + 1))?;
        out.push(((idx - 1) as u32, val));
    }
    out.sort_unstable_by_key(|(j, _)| *j);
    for w in out.windows(2) {
        if w[0].0 == w[1].0 {
            bail!("line {}: duplicate feature index {}", lineno + 1, w[0].0 + 1);
        }
    }
    Ok(Some(yi))
}

/// Parse LIBSVM format (`y idx:val …`, 1-based indices) into a **CSC**
/// dataset. `p_hint` can force the feature count (otherwise the max index
/// seen is used).
pub fn read_libsvm(path: impl AsRef<Path>, p_hint: Option<usize>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    parse_libsvm(BufReader::new(f), path.as_ref().display().to_string(), p_hint)
}

fn parse_libsvm(reader: impl BufRead, name: String, p_hint: Option<usize>) -> Result<Dataset> {
    let mut rows: Vec<Vec<(u32, f64)>> = Vec::new();
    let mut y = Vec::new();
    let mut p_max = 0usize;
    let mut pairs = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("reading line")?;
        let Some(yi) = parse_libsvm_pairs(&line, lineno, &mut pairs)? else {
            continue;
        };
        if let Some(&(j, _)) = pairs.last() {
            p_max = p_max.max(j as usize + 1);
        }
        y.push(yi);
        rows.push(pairs.clone());
    }
    if rows.is_empty() {
        bail!("no data rows");
    }
    let p = match p_hint {
        Some(p) => {
            if p_max > p {
                bail!("index {} exceeds p_hint {}", p_max, p);
            }
            p
        }
        None => p_max,
    };
    let n = rows.len();
    if n > u32::MAX as usize {
        bail!("{} rows exceed u32 row-index range", n);
    }

    // counting sort into CSC — O(nnz) memory, no dense buffer: rows are
    // visited in order, so each column's row indices come out strictly
    // increasing (the `from_parts` invariant) by construction
    let mut counts = vec![0usize; p];
    for row in &rows {
        for &(j, _) in row {
            counts[j as usize] += 1;
        }
    }
    let mut col_ptr = vec![0usize; p + 1];
    for j in 0..p {
        col_ptr[j + 1] = col_ptr[j] + counts[j];
    }
    let nnz = col_ptr[p];
    let mut row_idx = vec![0u32; nnz];
    let mut values = vec![0.0; nnz];
    let mut cursor = col_ptr.clone();
    for (i, row) in rows.iter().enumerate() {
        for &(j, v) in row {
            let k = cursor[j as usize];
            row_idx[k] = i as u32;
            values[k] = v;
            cursor[j as usize] += 1;
        }
    }
    let x = CscMatrix::from_parts(n, p, col_ptr, row_idx, values);
    Ok(Dataset { name, x: x.into(), y, beta_true: None, groups: None })
}

/// Write a dataset in LIBSVM format (zeros skipped; any backend).
///
/// Element access is `DesignStore::get`, which on the out-of-core `mmap`
/// backend streams the column per element — fine for the in-RAM backends
/// and small shards these writers serve, O(N·nnz) disk traffic on a big
/// shard (a text export of a larger-than-RAM dataset wants a dedicated
/// column-streaming transpose, which `dpp convert` is the inverse of).
pub fn write_libsvm(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    for i in 0..ds.n() {
        let mut line = format!("{}", ds.y[i]);
        for j in 0..ds.p() {
            let v = ds.x.get(i, j);
            if v != 0.0 {
                line.push_str(&format!(" {}:{}", j + 1, v));
            }
        }
        line.push('\n');
        f.write_all(line.as_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use std::io::Cursor;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dpp-io-tests");
        let _ = std::fs::create_dir_all(&dir);
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip() {
        let ds = synthetic::synthetic1(10, 7, 3, 0.1, 1);
        let path = tmp("round.csv");
        write_csv(&ds, &path).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!((back.n(), back.p()), (10, 7));
        assert!(back.x.is_dense());
        for i in 0..10 {
            assert!((back.y[i] - ds.y[i]).abs() < 1e-12);
            for j in 0..7 {
                assert!((back.x.get(i, j) - ds.x.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn csv_rejects_ragged_and_garbage() {
        assert!(parse_csv(Cursor::new("1,2,3\n4,5\n"), "t".into()).is_err());
        assert!(parse_csv(Cursor::new("1,abc\n"), "t".into()).is_err());
        assert!(parse_csv(Cursor::new("# only comments\n"), "t".into()).is_err());
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let ds =
            parse_csv(Cursor::new("# header\n1,2,3\n\n-1,0,4\n"), "t".into()).unwrap();
        assert_eq!((ds.n(), ds.p()), (2, 2));
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.get(1, 1), 4.0);
    }

    /// The CSC mirror of `csv_roundtrip`: write → read must land on the
    /// sparse backend and reproduce every entry (the satellite fix — the
    /// reader used to densify here).
    #[test]
    fn libsvm_roundtrip_stays_csc() {
        let mut ds = synthetic::synthetic1(8, 6, 2, 0.1, 2);
        // sparsify
        for j in 0..6 {
            for v in ds.x.dense_mut().unwrap().col_mut(j).iter_mut() {
                if v.abs() < 0.8 {
                    *v = 0.0;
                }
            }
        }
        let path = tmp("round.svm");
        write_libsvm(&ds, &path).unwrap();
        let back = read_libsvm(&path, Some(6)).unwrap();
        assert_eq!((back.n(), back.p()), (8, 6));
        assert_eq!(back.x.backend_name(), "csc", "sparse input must stay sparse");
        // stored entries are exactly the dense matrix's non-zeros
        assert_eq!(back.x.to_csc(), ds.x.to_csc());
        for i in 0..8 {
            for j in 0..6 {
                assert!((back.x.get(i, j) - ds.x.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn libsvm_unordered_pairs_are_sorted_not_panicked() {
        let ds = parse_libsvm(
            Cursor::new("1 3:3.0 1:1.0 2:2.0\n-1 2:5.0\n"),
            "t".into(),
            None,
        )
        .unwrap();
        assert_eq!((ds.n(), ds.p()), (2, 3));
        assert_eq!(ds.x.get(0, 0), 1.0);
        assert_eq!(ds.x.get(0, 1), 2.0);
        assert_eq!(ds.x.get(0, 2), 3.0);
        assert_eq!(ds.x.get(1, 1), 5.0);
        assert_eq!(ds.x.nnz(), 4);
    }

    #[test]
    fn libsvm_duplicate_index_is_an_error_with_line_number() {
        let err =
            parse_libsvm(Cursor::new("1 1:1.0\n1 2:1.0 2:9.0\n"), "t".into(), None)
                .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("duplicate"), "{msg}");
        assert!(msg.contains('2'), "{msg}");
    }

    #[test]
    fn libsvm_rejects_bad_input() {
        assert!(parse_libsvm(Cursor::new("1 0:3\n"), "t".into(), None).is_err()); // 0-based
        assert!(parse_libsvm(Cursor::new("1 a:3\n"), "t".into(), None).is_err());
        assert!(parse_libsvm(Cursor::new("1 5:1\n"), "t".into(), Some(3)).is_err()); // exceeds hint
        assert!(parse_libsvm(Cursor::new(""), "t".into(), None).is_err());
    }

    #[test]
    fn loaded_dataset_solves() {
        // end to end: write → read → screened path
        let ds = synthetic::synthetic1(20, 30, 4, 0.1, 3);
        let path = tmp("solve.csv");
        write_csv(&ds, &path).unwrap();
        let back = read_csv(&path).unwrap();
        let grid = crate::path::LambdaGrid::relative(&back.x, &back.y, 5, 0.1, 1.0);
        let out = crate::path::solve_path(
            &back.x,
            &back.y,
            &grid,
            crate::path::RuleKind::Edpp,
            crate::path::SolverKind::Cd,
            &crate::path::PathConfig::default(),
        );
        assert_eq!(out.records.len(), 5);
    }

    #[test]
    fn loaded_sparse_dataset_solves_on_csc() {
        // the same end-to-end guarantee for the sparse reader: the path
        // runs on the CSC backend the reader produced, no densify
        let mut ds = synthetic::synthetic1(20, 30, 4, 0.1, 4);
        for j in 0..30 {
            for v in ds.x.dense_mut().unwrap().col_mut(j).iter_mut() {
                if v.abs() < 0.9 {
                    *v = 0.0;
                }
            }
        }
        let path = tmp("solve.svm");
        write_libsvm(&ds, &path).unwrap();
        let back = read_libsvm(&path, Some(30)).unwrap();
        assert_eq!(back.x.backend_name(), "csc");
        let grid = crate::path::LambdaGrid::relative(&back.x, &back.y, 5, 0.1, 1.0);
        let out = crate::path::solve_path(
            &back.x,
            &back.y,
            &grid,
            crate::path::RuleKind::Edpp,
            crate::path::SolverKind::Cd,
            &crate::path::PathConfig::default(),
        );
        assert_eq!(out.records.len(), 5);
    }
}
