//! Datasets: the paper's synthetic problems and simulated stand-ins for its
//! nine real datasets (substitution rationale in DESIGN.md §8).

pub mod convert;
pub mod io;
pub mod realsim;
pub mod synthetic;

use crate::linalg::DesignStore;

/// A regression problem instance: response `y` (length N) and feature matrix
/// `x` (N×p). Group-Lasso problems additionally carry `groups`.
///
/// `x` is a [`DesignStore`]: generators produce the dense backend, the
/// LIBSVM reader produces CSC, and shard directories open as the
/// out-of-core `mmap` backend — whatever the source, `&ds.x` coerces to
/// `&dyn DesignMatrix` at every screening/solver/path call site.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: DesignStore,
    pub y: Vec<f64>,
    /// Ground-truth coefficients when generated from a linear model
    /// (used to verify support recovery in tests; `None` for label-style y).
    pub beta_true: Option<Vec<f64>>,
    /// Group boundaries for group-Lasso problems: `groups[g] = (start, len)`.
    pub groups: Option<Vec<(usize, usize)>>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.n_rows()
    }
    pub fn p(&self) -> usize {
        self.x.n_cols()
    }

    /// Scale every feature column to unit ℓ2 norm (required by DOME; the
    /// DPP family works either way — the paper explicitly does *not* assume
    /// unit length, §2.1). In-RAM backends only — errors (with the fix) on
    /// a read-only out-of-core backend; normalize before converting to an
    /// on-disk shard.
    pub fn normalize_features(&mut self) -> anyhow::Result<()> {
        self.x.normalize_columns()?;
        Ok(())
    }
}

/// Identifier for the nine real datasets the paper evaluates on, simulated
/// here (DESIGN.md §8). Shapes follow the paper; `full=false` scales them to
/// 1-core-friendly sizes while keeping N:p character.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RealDataset {
    ProstateCancer,
    Pie,
    Mnist,
    ColonCancer,
    LungCancer,
    Coil100,
    BreastCancer,
    Leukemia,
    Svhn,
}

impl RealDataset {
    pub const ALL: [RealDataset; 9] = [
        RealDataset::ProstateCancer,
        RealDataset::Pie,
        RealDataset::Mnist,
        RealDataset::ColonCancer,
        RealDataset::LungCancer,
        RealDataset::Coil100,
        RealDataset::BreastCancer,
        RealDataset::Leukemia,
        RealDataset::Svhn,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RealDataset::ProstateCancer => "prostate",
            RealDataset::Pie => "pie",
            RealDataset::Mnist => "mnist",
            RealDataset::ColonCancer => "colon",
            RealDataset::LungCancer => "lung",
            RealDataset::Coil100 => "coil100",
            RealDataset::BreastCancer => "breast",
            RealDataset::Leukemia => "leukemia",
            RealDataset::Svhn => "svhn",
        }
    }

    pub fn from_name(s: &str) -> Option<RealDataset> {
        RealDataset::ALL.iter().copied().find(|d| d.name() == s)
    }

    /// (N, p) as reported in the paper.
    pub fn paper_shape(&self) -> (usize, usize) {
        match self {
            RealDataset::ProstateCancer => (132, 15154),
            RealDataset::Pie => (1024, 11553),
            RealDataset::Mnist => (784, 50000),
            RealDataset::ColonCancer => (62, 2000),
            RealDataset::LungCancer => (203, 12600),
            RealDataset::Coil100 => (1024, 7199),
            RealDataset::BreastCancer => (44, 7129),
            RealDataset::Leukemia => (52, 11225),
            RealDataset::Svhn => (3072, 99288),
        }
    }

    /// Scaled-down shape used by default (`DPP_SCALE != full`).
    pub fn small_shape(&self) -> (usize, usize) {
        match self {
            RealDataset::ProstateCancer => (96, 1600),
            RealDataset::Pie => (196, 1200),
            RealDataset::Mnist => (196, 2400),
            RealDataset::ColonCancer => (62, 800),
            RealDataset::LungCancer => (128, 1400),
            RealDataset::Coil100 => (196, 1008),
            RealDataset::BreastCancer => (44, 1000),
            RealDataset::Leukemia => (52, 1200),
            RealDataset::Svhn => (300, 3000),
        }
    }

    /// Shape honoring the global scale knob.
    pub fn shape(&self, full: bool) -> (usize, usize) {
        if full {
            self.paper_shape()
        } else {
            self.small_shape()
        }
    }

    /// Generate the simulated stand-in for this dataset.
    pub fn generate(&self, full: bool, seed: u64) -> Dataset {
        realsim::generate(*self, full, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_roundtrip() {
        for d in RealDataset::ALL {
            assert_eq!(RealDataset::from_name(d.name()), Some(d));
        }
        assert_eq!(RealDataset::from_name("nope"), None);
    }

    #[test]
    fn paper_shapes_match_text() {
        assert_eq!(RealDataset::ProstateCancer.paper_shape(), (132, 15154));
        assert_eq!(RealDataset::Svhn.paper_shape(), (3072, 99288));
        assert_eq!(RealDataset::Mnist.paper_shape(), (784, 50000));
    }

    #[test]
    fn small_shapes_are_smaller() {
        for d in RealDataset::ALL {
            let (n, p) = d.paper_shape();
            let (sn, sp) = d.small_shape();
            assert!(sn <= n && sp <= p, "{}", d.name());
        }
    }

    #[test]
    fn normalize_features_unit_norm() {
        let mut ds = RealDataset::ColonCancer.generate(false, 3);
        ds.normalize_features().unwrap();
        for n in ds.x.col_norms() {
            assert!((n - 1.0).abs() < 1e-9);
        }
    }
}
