//! The paper's synthetic benchmarks (§4.1.2, eq. (74)):
//! `y = Xβ* + σ·ε`, ε ~ N(0,1), σ = 0.1; β* has `p̄` nonzeros drawn from
//! U[-1,1]; X is 250×10000 with either i.i.d. N(0,1) entries (Synthetic 1)
//! or pairwise feature correlation 0.5^{|i−j|} (Synthetic 2).

use super::Dataset;
use crate::linalg::DenseMatrix;
use crate::util::rng::Rng;

/// i.i.d. standard Gaussian design matrix.
pub fn gaussian_iid(n: usize, p: usize, rng: &mut Rng) -> DenseMatrix {
    let mut data = vec![0.0; n * p];
    rng.fill_normal(&mut data);
    DenseMatrix::from_col_major(n, p, data)
}

/// Design with feature correlation `corr(x_i, x_j) = rho^{|i−j|}` (AR(1)
/// across the feature index, independently per sample/row): for each row,
/// x₀ = ε₀ and x_j = ρ·x_{j−1} + √(1−ρ²)·ε_j, which gives exactly the
/// stationary AR(1) autocorrelation ρ^{|i−j|} with unit marginal variance.
pub fn gaussian_ar1(n: usize, p: usize, rho: f64, rng: &mut Rng) -> DenseMatrix {
    assert!((0.0..1.0).contains(&rho));
    let mut m = DenseMatrix::zeros(n, p);
    let innov = (1.0 - rho * rho).sqrt();
    // Row-wise recursion; generation is O(np) once, so strided writes are fine.
    let mut prev = vec![0.0; n];
    for i in 0..n {
        prev[i] = rng.normal();
        m.set(i, 0, prev[i]);
    }
    for j in 1..p {
        for i in 0..n {
            let v = rho * prev[i] + innov * rng.normal();
            m.set(i, j, v);
            prev[i] = v;
        }
    }
    m
}

/// Ground truth β*: `nnz` random positions populated from U[-1,1].
pub fn sparse_ground_truth(p: usize, nnz: usize, rng: &mut Rng) -> Vec<f64> {
    let mut beta = vec![0.0; p];
    for j in rng.sample_indices(p, nnz.min(p)) {
        beta[j] = rng.uniform(-1.0, 1.0);
    }
    beta
}

/// Assemble `y = Xβ* + σ·ε`.
pub fn linear_response(x: &DenseMatrix, beta: &[f64], sigma: f64, rng: &mut Rng) -> Vec<f64> {
    let mut y = vec![0.0; x.n_rows()];
    x.gemv(beta, &mut y);
    for v in y.iter_mut() {
        *v += sigma * rng.normal();
    }
    y
}

/// Synthetic 1: i.i.d. design (paper default 250×10000, σ = 0.1).
pub fn synthetic1(n: usize, p: usize, nnz: usize, sigma: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5E01);
    let x = gaussian_iid(n, p, &mut rng);
    let beta = sparse_ground_truth(p, nnz, &mut rng);
    let y = linear_response(&x, &beta, sigma, &mut rng);
    Dataset {
        name: format!("synthetic1-nnz{nnz}"),
        x: x.into(),
        y,
        beta_true: Some(beta),
        groups: None,
    }
}

/// Synthetic 2: correlated design, corr(x_i, x_j) = 0.5^{|i−j|}.
pub fn synthetic2(n: usize, p: usize, nnz: usize, sigma: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5E02);
    let x = gaussian_ar1(n, p, 0.5, &mut rng);
    let beta = sparse_ground_truth(p, nnz, &mut rng);
    let y = linear_response(&x, &beta, sigma, &mut rng);
    Dataset {
        name: format!("synthetic2-nnz{nnz}"),
        x: x.into(),
        y,
        beta_true: Some(beta),
        groups: None,
    }
}

/// Group-Lasso synthetic problem (§4.2): X is N×p i.i.d. standard Gaussian,
/// y i.i.d. standard Gaussian, p split into `n_groups` equal groups.
pub fn group_synthetic(n: usize, p: usize, n_groups: usize, seed: u64) -> Dataset {
    assert!(n_groups > 0 && p % n_groups == 0, "p must divide into equal groups");
    let mut rng = Rng::new(seed ^ 0x6E0);
    let x = gaussian_iid(n, p, &mut rng);
    let mut y = vec![0.0; n];
    rng.fill_normal(&mut y);
    let gsize = p / n_groups;
    let groups = (0..n_groups).map(|g| (g * gsize, gsize)).collect();
    Dataset {
        name: format!("group-ng{n_groups}"),
        x: x.into(),
        y,
        beta_true: None,
        groups: Some(groups),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;
    use crate::util::stats;

    #[test]
    fn iid_columns_nearly_unit_variance() {
        let mut rng = Rng::new(1);
        let x = gaussian_iid(2000, 4, &mut rng);
        for j in 0..4 {
            let c = x.col(j);
            let var = dot(c, c) / c.len() as f64;
            assert!((var - 1.0).abs() < 0.1, "var={var}");
        }
    }

    #[test]
    fn ar1_adjacent_correlation_near_rho() {
        let mut rng = Rng::new(2);
        let rho = 0.5;
        let x = gaussian_ar1(4000, 6, rho, &mut rng);
        // sample correlation between adjacent feature columns ≈ 0.5,
        // lag-2 ≈ 0.25
        let corr = |a: &[f64], b: &[f64]| {
            let n = a.len() as f64;
            let (ma, mb) = (stats::mean(a), stats::mean(b));
            let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum::<f64>() / n;
            let (sa, sb) = (
                (a.iter().map(|v| (v - ma) * (v - ma)).sum::<f64>() / n).sqrt(),
                (b.iter().map(|v| (v - mb) * (v - mb)).sum::<f64>() / n).sqrt(),
            );
            cov / (sa * sb)
        };
        let c1 = corr(x.col(2), x.col(3));
        let c2 = corr(x.col(2), x.col(4));
        assert!((c1 - rho).abs() < 0.06, "lag1 corr={c1}");
        assert!((c2 - rho * rho).abs() < 0.06, "lag2 corr={c2}");
    }

    #[test]
    fn ground_truth_sparsity() {
        let mut rng = Rng::new(3);
        let b = sparse_ground_truth(1000, 50, &mut rng);
        let nnz = b.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nnz, 50);
        assert!(b.iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn synthetic_datasets_shape_and_determinism() {
        let a = synthetic1(50, 200, 10, 0.1, 9);
        let b = synthetic1(50, 200, 10, 0.1, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        assert_eq!((a.n(), a.p()), (50, 200));
        let c = synthetic2(50, 200, 10, 0.1, 9);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn response_reflects_model() {
        // with sigma=0 the response is exactly X beta*
        let ds = synthetic1(30, 60, 5, 0.0, 4);
        let beta = ds.beta_true.as_ref().unwrap();
        let mut y = vec![0.0; 30];
        ds.x.gemv(beta, &mut y);
        for (a, b) in y.iter().zip(ds.y.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn group_synthetic_partitions() {
        let ds = group_synthetic(40, 120, 30, 5);
        let groups = ds.groups.as_ref().unwrap();
        assert_eq!(groups.len(), 30);
        let total: usize = groups.iter().map(|(_, l)| l).sum();
        assert_eq!(total, 120);
        // contiguous, non-overlapping
        for w in groups.windows(2) {
            assert_eq!(w[0].0 + w[0].1, w[1].0);
        }
    }

    #[test]
    #[should_panic]
    fn group_synthetic_requires_divisible_p() {
        group_synthetic(10, 100, 33, 1);
    }
}
