//! Simulated stand-ins for the paper's nine real datasets.
//!
//! The container is offline, so the original corpora (Prostate [27],
//! PIE [30], MNIST [21], Colon [1], Lung [6], COIL-100 [24], Breast [33],
//! Leukemia [2], SVHN [25]) cannot be fetched. Screening behaviour depends on
//! the geometry of the problem — column-correlation structure, column-norm
//! dispersion, and the alignment of y with the column space — not on semantic
//! content, so each stand-in reproduces the paper's matrix shape and a
//! matched statistical character (DESIGN.md §8):
//!
//! * gene-expression sets (colon/lung/breast/leukemia/prostate): lognormal
//!   magnitudes with co-expressed blocks driven by shared latent factors;
//!   y ∈ {±1} correlated with a handful of informative columns.
//! * image sets (PIE/COIL/SVHN): smooth random fields per column (box-blurred
//!   white noise) ⇒ strongly correlated neighbour columns; y is a held-out
//!   sample (the paper's protocol: regress one image on the rest).
//! * MNIST: sparse stroke-like blobs around 10 cluster prototypes.

use super::{Dataset, RealDataset};
use crate::linalg::DenseMatrix;
use crate::util::rng::Rng;

/// Generate the stand-in for `which` at paper scale (`full`) or scaled-down.
pub fn generate(which: RealDataset, full: bool, seed: u64) -> Dataset {
    let (n, p) = which.shape(full);
    let mut rng = Rng::new(seed ^ 0xDA7A ^ (which.name().len() as u64) << 17);
    let (mut x, mut y, style) = match which {
        RealDataset::ProstateCancer => {
            // protein mass spectrometry: sharp peaks over a smooth baseline
            let x = spectrometry(n, p, &mut rng);
            let y = binary_labels(&x, 24, &mut rng);
            (x, y, "spectra")
        }
        RealDataset::ColonCancer
        | RealDataset::LungCancer
        | RealDataset::BreastCancer
        | RealDataset::Leukemia => {
            let blocks = (p / 40).max(4);
            let x = gene_expression(n, p, blocks, &mut rng);
            let y = binary_labels(&x, 16, &mut rng);
            (x, y, "expression")
        }
        RealDataset::Pie | RealDataset::Coil100 | RealDataset::Svhn => {
            let x = smooth_images(n, p, &mut rng);
            let y = held_out_image(&x, &mut rng);
            (x, y, "images")
        }
        RealDataset::Mnist => {
            let x = stroke_digits(n, p, 10, &mut rng);
            let y = held_out_image(&x, &mut rng);
            (x, y, "digits")
        }
    };
    center_columns(&mut x);
    center(&mut y);
    Dataset {
        name: format!("{}-sim-{}", which.name(), style),
        x: x.into(),
        y,
        beta_true: None,
        groups: None,
    }
}

fn center(v: &mut [f64]) {
    let m = if v.is_empty() { 0.0 } else { crate::linalg::ops::seq_sum(v) / v.len() as f64 };
    for x in v.iter_mut() {
        *x -= m;
    }
}

fn center_columns(x: &mut DenseMatrix) {
    for j in 0..x.n_cols() {
        center(x.col_mut(j));
    }
}

/// Lognormal expression values; genes inside a block share a latent factor,
/// giving the within-block correlation real microarray data shows.
fn gene_expression(n: usize, p: usize, n_blocks: usize, rng: &mut Rng) -> DenseMatrix {
    let mut x = DenseMatrix::zeros(n, p);
    // one latent factor per (sample, block)
    let mut latent = vec![0.0; n * n_blocks];
    rng.fill_normal(&mut latent);
    for j in 0..p {
        let b = j % n_blocks;
        let load = rng.uniform(0.3, 0.9); // block loading
        let base_mu = rng.uniform(-0.5, 0.5);
        let noise = (1.0 - load * load).sqrt();
        for i in 0..n {
            let z = load * latent[i * n_blocks + b] + noise * rng.normal();
            x.set(i, j, (base_mu + 0.6 * z).exp()); // lognormal magnitudes
        }
    }
    x
}

/// Spectrometry-like columns: time-of-flight intensity features — mostly
/// near-baseline with occasional heavy-tailed peaks shared across samples.
fn spectrometry(n: usize, p: usize, rng: &mut Rng) -> DenseMatrix {
    let mut x = DenseMatrix::zeros(n, p);
    for j in 0..p {
        let is_peak = rng.f64() < 0.08;
        let scale = if is_peak { rng.lognormal(1.0, 1.0) } else { rng.lognormal(-1.5, 0.4) };
        // smooth per-sample variation around the shared peak intensity
        for i in 0..n {
            x.set(i, j, scale * (1.0 + 0.5 * rng.normal()).abs());
        }
    }
    x
}

/// ±1 labels driven by `k` informative columns (logistic-free sign model) —
/// mirrors the case/control labels of the biomedical datasets.
fn binary_labels(x: &DenseMatrix, k: usize, rng: &mut Rng) -> Vec<f64> {
    let p = x.n_cols();
    let n = x.n_rows();
    let info = rng.sample_indices(p, k.min(p));
    let mut score = vec![0.0; n];
    for &j in &info {
        let w = rng.uniform(0.5, 1.5) * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        let c = x.col(j);
        for i in 0..n {
            score[i] += w * c[i];
        }
    }
    center(&mut score);
    score.iter().map(|s| if *s >= 0.0 { 1.0 } else { -1.0 }).collect()
}

/// Smooth image-like columns: white noise box-blurred along the (virtual)
/// pixel grid, so neighbouring columns in the dictionary are correlated the
/// way natural-image dictionaries are.
fn smooth_images(n: usize, p: usize, rng: &mut Rng) -> DenseMatrix {
    let side = (n as f64).sqrt().ceil() as usize;
    let mut x = DenseMatrix::zeros(n, p);
    let mut field = vec![0.0; side * side];
    let mut blurred = vec![0.0; side * side];
    // a small bank of shared low-frequency layouts makes distinct columns
    // correlated (images of the same objects/poses)
    let n_protos = (p / 64).clamp(4, 128);
    let mut protos = vec![0.0; n_protos * n];
    rng.fill_normal(&mut protos);
    for j in 0..p {
        rng.fill_normal(&mut field);
        box_blur(&field, &mut blurred, side, 2);
        box_blur(&blurred, &mut field, side, 2);
        let proto = j % n_protos;
        let mix = rng.uniform(0.4, 0.8);
        let c = x.col_mut(j);
        for i in 0..n {
            c[i] = mix * protos[proto * n + i] * 0.3 + (1.0 - mix) * field[i] * 3.0;
        }
    }
    x
}

fn box_blur(src: &[f64], dst: &mut [f64], side: usize, radius: usize) {
    for r in 0..side {
        for c in 0..side {
            let (mut s, mut cnt) = (0.0, 0.0);
            let r0 = r.saturating_sub(radius);
            let r1 = (r + radius).min(side - 1);
            let c0 = c.saturating_sub(radius);
            let c1 = (c + radius).min(side - 1);
            for rr in r0..=r1 {
                for cc in c0..=c1 {
                    s += src[rr * side + cc];
                    cnt += 1.0;
                }
            }
            dst[r * side + c] = s / cnt;
        }
    }
}

/// Sparse stroke-like columns clustered around `k` digit prototypes.
fn stroke_digits(n: usize, p: usize, k: usize, rng: &mut Rng) -> DenseMatrix {
    let side = (n as f64).sqrt().ceil() as usize;
    let mut x = DenseMatrix::zeros(n, p);
    // prototypes: a few random strokes each
    let mut protos = vec![vec![0.0; n]; k];
    for proto in protos.iter_mut() {
        for _ in 0..4 {
            draw_stroke(proto, side, rng);
        }
    }
    for j in 0..p {
        let c = x.col_mut(j);
        let proto = &protos[j % k];
        for i in 0..n {
            c[i] = proto[i];
        }
        // per-sample deformation: one extra stroke + pixel dropout
        draw_stroke(c, side, rng);
        for v in c.iter_mut() {
            if rng.f64() < 0.15 {
                *v = 0.0;
            }
        }
    }
    x
}

fn draw_stroke(img: &mut [f64], side: usize, rng: &mut Rng) {
    let (mut r, mut c) = (rng.usize(side) as f64, rng.usize(side) as f64);
    let (mut dr, mut dc) = (rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0));
    for _ in 0..side {
        let (ri, ci) = (r as usize, c as usize);
        if ri < side && ci < side {
            let idx = ri * side + ci;
            if idx < img.len() {
                img[idx] = (img[idx] + 1.0).min(2.0);
            }
        }
        r += dr;
        c += dc;
        dr += rng.uniform(-0.3, 0.3);
        dc += rng.uniform(-0.3, 0.3);
        if r < 0.0 || c < 0.0 || r >= side as f64 || c >= side as f64 {
            break;
        }
    }
}

/// Paper protocol for image datasets: pick a random sample as the response
/// and regress it on the remaining dictionary. We synthesize the held-out
/// sample the same way as a dictionary column (same generator family) so it
/// lies near — but not inside — the dictionary's span.
fn held_out_image(x: &DenseMatrix, rng: &mut Rng) -> Vec<f64> {
    // mix two random columns + noise: a "new" image correlated with atoms
    let n = x.n_rows();
    let j1 = rng.usize(x.n_cols());
    let j2 = rng.usize(x.n_cols());
    let (a, b) = (rng.uniform(0.3, 0.7), rng.uniform(0.2, 0.5));
    let (c1, c2) = (x.col(j1), x.col(j2));
    (0..n).map(|i| a * c1[i] + b * c2[i] + 0.1 * rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dot, nrm2};
    use crate::util::stats;

    #[test]
    fn all_datasets_generate_with_declared_shape() {
        for d in RealDataset::ALL {
            let ds = generate(d, false, 1);
            let (n, p) = d.small_shape();
            assert_eq!((ds.n(), ds.p()), (n, p), "{}", d.name());
            assert!(ds.y.iter().all(|v| v.is_finite()));
            assert!(ds.x.dense().unwrap().data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(RealDataset::ColonCancer, false, 7);
        let b = generate(RealDataset::ColonCancer, false, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(RealDataset::ColonCancer, false, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn columns_are_centered_and_nondegenerate() {
        let ds = generate(RealDataset::BreastCancer, false, 2);
        let mut zero_cols = 0;
        for j in 0..ds.p() {
            let c = ds.x.dense().unwrap().col(j);
            assert!(stats::mean(c).abs() < 1e-9, "col {j} not centered");
            if nrm2(c) < 1e-12 {
                zero_cols += 1;
            }
        }
        assert!(zero_cols == 0, "{zero_cols} zero columns");
    }

    #[test]
    fn image_sets_have_correlated_columns() {
        // smooth-field generators share prototypes ⇒ same-prototype columns
        // must correlate far more than generic gaussian pairs would
        let ds = generate(RealDataset::Pie, false, 3);
        let n_protos = (ds.p() / 64).clamp(4, 128);
        let x = ds.x.dense().unwrap();
        let (a, b) = (x.col(0), x.col(n_protos)); // same prototype class
        let corr = dot(a, b) / (nrm2(a) * nrm2(b));
        assert!(corr.abs() > 0.05, "corr={corr}");
    }

    #[test]
    fn labels_are_binary_centered() {
        let ds = generate(RealDataset::LungCancer, false, 4);
        // after centering, values are the two shifted label levels
        let distinct: std::collections::BTreeSet<String> =
            ds.y.iter().map(|v| format!("{v:.6}")).collect();
        assert!(distinct.len() <= 2, "{distinct:?}");
    }

    #[test]
    fn response_alignment_nontrivial() {
        // y must be meaningfully correlated with at least one column so the
        // lasso path is non-degenerate (λmax >> 0)
        for d in [RealDataset::Mnist, RealDataset::Svhn, RealDataset::ProstateCancer] {
            let ds = generate(d, false, 5);
            let mut scores = vec![0.0; ds.p()];
            ds.x.gemv_t(&ds.y, &mut scores);
            let lam_max = scores.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            assert!(lam_max > 1e-6, "{} degenerate", d.name());
        }
    }
}
