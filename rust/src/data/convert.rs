//! Streaming conversion of row-major text datasets (LIBSVM / CSV) into the
//! on-disk `dppcsc` shard format that [`crate::linalg::MmapCscMatrix`]
//! pages from (`dpp convert`; layout in DESIGN.md §2b).
//!
//! The transpose (row-major input → column-major CSC) is done in **two
//! passes over the input file** so peak memory is O(p) counters plus one
//! line buffer — independent of N and nnz:
//!
//! 1. count non-zeros per column (and stream `y.bin` out as labels are
//!    seen), then prefix-sum the counts into `col_ptr.bin`;
//! 2. re-read the input and scatter each entry to its final offset in
//!    `row_idx.bin` / `values.bin` with positioned writes (one cursor per
//!    column; the OS page cache absorbs the small writes, and a
//!    bounded sorted-run buffer that coalesces them into contiguous
//!    writes is the known follow-up if syscall overhead ever dominates
//!    at the 10⁸-nnz scale).
//!
//! Rows are processed in order, so each column receives its row indices
//! already strictly increasing — the CSC invariant holds by construction
//! once per-line indices are sorted and duplicates rejected
//! (`io::parse_libsvm_pairs`).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::os::unix::fs::FileExt;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::io::{parse_csv_fields, parse_libsvm_pairs};
use crate::linalg::mmap::{COL_PTR_FILE, META_FILE, ROW_IDX_FILE, VALUES_FILE, Y_FILE};
use crate::linalg::sharded::SHARDSET_FILE;
use crate::linalg::{DesignMatrix, MmapCscMatrix};

/// What a conversion produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvertSummary {
    pub n_rows: usize,
    pub n_cols: usize,
    pub nnz: usize,
    /// Whether `y.bin` was written (the text converters always write it;
    /// `shard_from_design` only when given a response vector).
    pub has_y: bool,
    /// `values.bin` stored as f32 (`dpp convert --f32`): halves the
    /// window/shard traffic; widened to f64 on read with the safety-slack
    /// discipline of DESIGN.md §1.
    pub f32_values: bool,
}

impl ConvertSummary {
    /// Total shard bytes on disk (entry arrays + col_ptr, + y if written).
    pub fn disk_bytes(&self) -> usize {
        let y = if self.has_y { self.n_rows * 8 } else { 0 };
        let entry = if self.f32_values { 8 } else { 12 };
        self.nnz * entry + (self.n_cols + 1) * 8 + y
    }
}

/// Narrow a value for an f32 shard, rejecting finite f64s that overflow to
/// ±Inf — a silently-Inf shard would poison every later sweep with nothing
/// pointing back at the conversion. Source NaN/Inf pass through (storing
/// them is faithful) and subnormal flush-to-zero is accepted quantization
/// loss the safety slack covers.
fn narrow_f32(v: f64) -> std::io::Result<f32> {
    let n = v as f32;
    if v.is_finite() && !n.is_finite() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("value {v:e} overflows the f32 range; convert without --f32"),
        ));
    }
    Ok(n)
}

/// Positioned write of one value in the shard's value dtype.
fn write_value_at(out: &File, v: f64, entry: u64, f32_values: bool) -> std::io::Result<()> {
    if f32_values {
        out.write_all_at(&narrow_f32(v)?.to_le_bytes(), entry * 4)
    } else {
        out.write_all_at(&v.to_le_bytes(), entry * 8)
    }
}

/// After the pass-2 scatter, every column cursor must have landed exactly
/// on the next column's start — otherwise the input lost entries between
/// the passes and `set_len`'s zero-filled tail would masquerade as
/// spurious `(row 0, 0.0)` entries in the shard.
fn verify_cursors(cursor: &[u64], col_ptr: &[u64], input: &Path) -> Result<()> {
    for (j, &c) in cursor.iter().enumerate() {
        if c != col_ptr[j + 1] {
            bail!(
                "{input:?} changed between convert passes (column {j} underfilled: \
                 {c} of {} entries)",
                col_ptr[j + 1]
            );
        }
    }
    Ok(())
}

/// Convert `input` into a shard at `out_dir`, dispatching on the file
/// extension (`.svm`/`.libsvm` → LIBSVM, anything else → CSV).
pub fn convert_to_shard(
    input: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    p_hint: Option<usize>,
) -> Result<ConvertSummary> {
    convert_to_shard_opts(input, out_dir, p_hint, false)
}

/// [`convert_to_shard`] with the value dtype explicit (`f32_values` =
/// `dpp convert --f32`).
pub fn convert_to_shard_opts(
    input: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    p_hint: Option<usize>,
    f32_values: bool,
) -> Result<ConvertSummary> {
    let path = input.as_ref();
    let name = path.to_string_lossy();
    if name.ends_with(".svm") || name.ends_with(".libsvm") {
        libsvm_to_shard_opts(path, out_dir, p_hint, f32_values)
    } else {
        csv_to_shard_opts(path, out_dir, f32_values)
    }
}

/// LIBSVM (`y idx:val …`, 1-based indices) → shard, two bounded-memory
/// passes. `p_hint` forces the feature count (else max index seen).
pub fn libsvm_to_shard(
    input: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    p_hint: Option<usize>,
) -> Result<ConvertSummary> {
    libsvm_to_shard_opts(input, out_dir, p_hint, false)
}

/// [`libsvm_to_shard`] with the value dtype explicit.
pub fn libsvm_to_shard_opts(
    input: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    p_hint: Option<usize>,
    f32_values: bool,
) -> Result<ConvertSummary> {
    let input = input.as_ref();
    let out_dir = out_dir.as_ref();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating shard dir {out_dir:?}"))?;

    // ---- pass 1: per-column counts, n, p, y.bin ----
    let mut counts: Vec<u64> = Vec::new();
    let mut n_rows = 0usize;
    let mut pairs: Vec<(u32, f64)> = Vec::new();
    {
        let f = File::open(input).with_context(|| format!("opening {input:?}"))?;
        let mut y_out = BufWriter::new(
            File::create(out_dir.join(Y_FILE))
                .with_context(|| format!("creating {:?}", out_dir.join(Y_FILE)))?,
        );
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line.context("reading line")?;
            let Some(yi) = parse_libsvm_pairs(&line, lineno, &mut pairs)? else {
                continue;
            };
            y_out.write_all(&yi.to_le_bytes())?;
            for &(j, _) in &pairs {
                let j = j as usize;
                if j >= counts.len() {
                    counts.resize(j + 1, 0);
                }
                counts[j] += 1;
            }
            n_rows += 1;
        }
        y_out.flush()?;
    }
    if n_rows == 0 {
        bail!("no data rows in {input:?}");
    }
    if n_rows > u32::MAX as usize {
        bail!("{} rows exceed u32 row-index range", n_rows);
    }
    let n_cols = match p_hint {
        Some(p) => {
            if counts.len() > p {
                bail!("index {} exceeds p_hint {}", counts.len(), p);
            }
            p
        }
        None => counts.len(),
    };
    counts.resize(n_cols, 0);

    let col_ptr = write_col_ptr(out_dir, &counts)?;
    let nnz = col_ptr[n_cols] as usize;

    // ---- pass 2: scatter entries to their final offsets ----
    {
        let idx_out = File::create(out_dir.join(ROW_IDX_FILE))?;
        let val_out = File::create(out_dir.join(VALUES_FILE))?;
        idx_out.set_len((nnz * 4) as u64)?;
        val_out.set_len((nnz * if f32_values { 4 } else { 8 }) as u64)?;
        let mut cursor: Vec<u64> = col_ptr[..n_cols].to_vec();
        let f = File::open(input)?;
        let mut row = 0u32;
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line.context("reading line")?;
            let Some(_) = parse_libsvm_pairs(&line, lineno, &mut pairs)? else {
                continue;
            };
            for &(j, v) in &pairs {
                let j = j as usize;
                if j >= n_cols || cursor[j] >= col_ptr[j + 1] {
                    bail!("{input:?} changed between convert passes (column {j} overflow)");
                }
                idx_out.write_all_at(&row.to_le_bytes(), cursor[j] * 4)?;
                write_value_at(&val_out, v, cursor[j], f32_values)?;
                cursor[j] += 1;
            }
            row += 1;
        }
        if row as usize != n_rows {
            bail!("{input:?} changed between convert passes (row count)");
        }
        verify_cursors(&cursor, &col_ptr, input)?;
    }

    write_meta(out_dir, n_rows, n_cols, nnz, f32_values, None)?;
    Ok(ConvertSummary { n_rows, n_cols, nnz, has_y: true, f32_values })
}

/// CSV (`y,x1,…,xp` per line) → shard, two bounded-memory passes; exact
/// zeros are dropped (CSV is a dense format, the shard is sparse).
pub fn csv_to_shard(input: impl AsRef<Path>, out_dir: impl AsRef<Path>) -> Result<ConvertSummary> {
    csv_to_shard_opts(input, out_dir, false)
}

/// [`csv_to_shard`] with the value dtype explicit.
pub fn csv_to_shard_opts(
    input: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    f32_values: bool,
) -> Result<ConvertSummary> {
    let input = input.as_ref();
    let out_dir = out_dir.as_ref();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating shard dir {out_dir:?}"))?;

    // ---- pass 1 ----
    let mut counts: Vec<u64> = Vec::new();
    let mut n_rows = 0usize;
    let mut n_cols = 0usize;
    let mut fields: Vec<f64> = Vec::new();
    let mut pairs: Vec<(usize, f64)> = Vec::new();
    {
        let f = File::open(input).with_context(|| format!("opening {input:?}"))?;
        let mut y_out = BufWriter::new(File::create(out_dir.join(Y_FILE))?);
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line.context("reading line")?;
            let Some((yi, ncols)) = parse_csv_entries(&line, lineno, &mut fields, &mut pairs)?
            else {
                continue;
            };
            if n_rows == 0 {
                n_cols = ncols;
            } else if ncols != n_cols {
                bail!("line {}: {} features, expected {}", lineno + 1, ncols, n_cols);
            }
            for &(j, _) in &pairs {
                if j >= counts.len() {
                    counts.resize(j + 1, 0);
                }
                counts[j] += 1;
            }
            y_out.write_all(&yi.to_le_bytes())?;
            n_rows += 1;
        }
        y_out.flush()?;
    }
    if n_rows == 0 {
        bail!("no data rows in {input:?}");
    }
    if n_rows > u32::MAX as usize {
        bail!("{} rows exceed u32 row-index range", n_rows);
    }
    counts.resize(n_cols, 0);

    let col_ptr = write_col_ptr(out_dir, &counts)?;
    let nnz = col_ptr[n_cols] as usize;

    // ---- pass 2 ----
    {
        let idx_out = File::create(out_dir.join(ROW_IDX_FILE))?;
        let val_out = File::create(out_dir.join(VALUES_FILE))?;
        idx_out.set_len((nnz * 4) as u64)?;
        val_out.set_len((nnz * if f32_values { 4 } else { 8 }) as u64)?;
        let mut cursor: Vec<u64> = col_ptr[..n_cols].to_vec();
        let f = File::open(input)?;
        let mut row = 0u32;
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line.context("reading line")?;
            if parse_csv_entries(&line, lineno, &mut fields, &mut pairs)?.is_none() {
                continue;
            }
            for &(j, v) in &pairs {
                if j >= n_cols || cursor[j] >= col_ptr[j + 1] {
                    bail!("{input:?} changed between convert passes (column {j} overflow)");
                }
                idx_out.write_all_at(&row.to_le_bytes(), cursor[j] * 4)?;
                write_value_at(&val_out, v, cursor[j], f32_values)?;
                cursor[j] += 1;
            }
            row += 1;
        }
        if row as usize != n_rows {
            bail!("{input:?} changed between convert passes (row count)");
        }
        verify_cursors(&cursor, &col_ptr, input)?;
    }

    write_meta(out_dir, n_rows, n_cols, nnz, f32_values, None)?;
    Ok(ConvertSummary { n_rows, n_cols, nnz, has_y: true, f32_values })
}

/// Write a shard directly from an in-process backend (tests, benches, the
/// experiments runner's `DPP_MATRIX=mmap` mode). Streams one densified
/// column at a time — O(N) scratch, never the whole matrix.
pub fn shard_from_design(
    x: &dyn DesignMatrix,
    y: Option<&[f64]>,
    out_dir: impl AsRef<Path>,
) -> Result<ConvertSummary> {
    shard_from_design_opts(x, y, out_dir, false)
}

/// [`shard_from_design`] with the value dtype explicit.
pub fn shard_from_design_opts(
    x: &dyn DesignMatrix,
    y: Option<&[f64]>,
    out_dir: impl AsRef<Path>,
    f32_values: bool,
) -> Result<ConvertSummary> {
    let out_dir = out_dir.as_ref();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating shard dir {out_dir:?}"))?;
    let (n, p) = (x.n_rows(), x.n_cols());
    if n > u32::MAX as usize {
        bail!("n_rows {} exceeds u32 row-index range", n);
    }
    let mut idx_out = BufWriter::new(File::create(out_dir.join(ROW_IDX_FILE))?);
    let mut val_out = BufWriter::new(File::create(out_dir.join(VALUES_FILE))?);
    let mut ptr_out = BufWriter::new(File::create(out_dir.join(COL_PTR_FILE))?);
    let mut col = vec![0.0; n];
    let mut nnz = 0u64;
    ptr_out.write_all(&0u64.to_le_bytes())?;
    for j in 0..p {
        x.col_into(j, &mut col);
        for (i, v) in col.iter().enumerate() {
            if *v != 0.0 {
                idx_out.write_all(&(i as u32).to_le_bytes())?;
                if f32_values {
                    val_out.write_all(&narrow_f32(*v)?.to_le_bytes())?;
                } else {
                    val_out.write_all(&v.to_le_bytes())?;
                }
                nnz += 1;
            }
        }
        ptr_out.write_all(&nnz.to_le_bytes())?;
    }
    idx_out.flush()?;
    val_out.flush()?;
    ptr_out.flush()?;
    if let Some(y) = y {
        let mut y_out = BufWriter::new(File::create(out_dir.join(Y_FILE))?);
        for v in y {
            y_out.write_all(&v.to_le_bytes())?;
        }
        y_out.flush()?;
    }
    write_meta(out_dir, n, p, nnz as usize, f32_values, None)?;
    Ok(ConvertSummary {
        n_rows: n,
        n_cols: p,
        nnz: nnz as usize,
        has_y: y.is_some(),
        f32_values,
    })
}

/// Load the shard's response vector, if the converter wrote one.
pub fn read_shard_y(dir: impl AsRef<Path>) -> Result<Option<Vec<f64>>> {
    let path = dir.as_ref().join(Y_FILE);
    if !path.exists() {
        return Ok(None);
    }
    let raw = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
    if raw.len() % 8 != 0 {
        bail!("{path:?} length {} is not a multiple of 8", raw.len());
    }
    Ok(Some(
        raw.chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect(),
    ))
}

/// Prefix-sum `counts` into `col_ptr.bin`; returns the in-RAM pointer
/// array (O(p), also needed for the scatter cursors).
fn write_col_ptr(out_dir: &Path, counts: &[u64]) -> Result<Vec<u64>> {
    let mut col_ptr = Vec::with_capacity(counts.len() + 1);
    col_ptr.push(0u64);
    for &c in counts {
        col_ptr.push(col_ptr.last().unwrap() + c);
    }
    let mut out = BufWriter::new(File::create(out_dir.join(COL_PTR_FILE))?);
    for v in &col_ptr {
        out.write_all(&v.to_le_bytes())?;
    }
    out.flush()?;
    Ok(col_ptr)
}

fn write_meta(
    out_dir: &Path,
    n_rows: usize,
    n_cols: usize,
    nnz: usize,
    f32_values: bool,
    row_offset: Option<usize>,
) -> Result<()> {
    let mut text = format!(
        "format=dppcsc\nversion=1\nn_rows={n_rows}\nn_cols={n_cols}\nnnz={nnz}\ndtype={}\n",
        if f32_values { "f32" } else { "f64" }
    );
    if let Some(off) = row_offset {
        // the shard's global row offset inside a shard set; plain readers
        // ignore the key (forward-compatible), the manifest is authoritative
        text.push_str(&format!("row_offset={off}\n"));
    }
    std::fs::write(out_dir.join(META_FILE), text)
        .with_context(|| format!("writing {:?}", out_dir.join(META_FILE)))
}

/// What `split_shard` produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSetSummary {
    pub n_rows: usize,
    pub n_cols: usize,
    pub nnz: usize,
    pub shards: usize,
    pub has_y: bool,
    pub f32_values: bool,
}

/// Split a converted `dppcsc` shard into a **shard set**: `k` row-range
/// shards (each a complete `dppcsc` directory over its row slice, row
/// indices rebased, `row_offset` recorded in its `meta.txt`) plus a
/// top-level `shardset.txt` manifest and a copy of `y.bin` — the layout
/// [`crate::linalg::ShardSetMatrix::open`] consumes (`dpp shard --shards K`,
/// DESIGN.md §2c).
///
/// Streaming and bounded-memory: the source is paged through one window
/// (`MmapCscMatrix`) and entries are appended to K open shard writers, so
/// peak memory is O(window + K) regardless of nnz. The source dtype
/// (f64/f32) is preserved.
pub fn split_shard(
    src: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    k: usize,
) -> Result<ShardSetSummary> {
    let src = src.as_ref();
    let out_dir = out_dir.as_ref();
    if k == 0 {
        bail!("--shards must be ≥ 1");
    }
    let mm = MmapCscMatrix::open(src)
        .with_context(|| format!("opening source shard {src:?} (run `dpp convert` first)"))?;
    let (n, p) = (mm.n_rows(), mm.n_cols());
    let f32_values = mm.is_f32();
    let splits = crate::linalg::sharded::row_splits(n, k);
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating shard-set dir {out_dir:?}"))?;

    struct ShardWriter {
        idx: BufWriter<File>,
        val: BufWriter<File>,
        ptr: BufWriter<File>,
        nnz: u64,
    }
    let mut writers: Vec<ShardWriter> = Vec::with_capacity(k);
    let mut names: Vec<String> = Vec::with_capacity(k);
    for s in 0..k {
        let name = format!("shard-{s:04}");
        let dir = out_dir.join(&name);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating shard dir {dir:?}"))?;
        let mut ptr = BufWriter::new(File::create(dir.join(COL_PTR_FILE))?);
        ptr.write_all(&0u64.to_le_bytes())?;
        writers.push(ShardWriter {
            idx: BufWriter::new(File::create(dir.join(ROW_IDX_FILE))?),
            val: BufWriter::new(File::create(dir.join(VALUES_FILE))?),
            ptr,
            nnz: 0,
        });
        names.push(name);
    }

    // one pass over the source in column order; entries within a column
    // ascend by row, so the owning shard index only moves forward
    for j in 0..p {
        let mut s_cur = 0usize;
        let mut werr: Option<std::io::Error> = None;
        mm.for_col(j, |idx, vals| {
            if werr.is_some() {
                return;
            }
            for (i, v) in idx.iter().zip(vals.iter()) {
                let gi = *i as usize;
                while gi >= splits[s_cur + 1] {
                    s_cur += 1;
                }
                let w = &mut writers[s_cur];
                let local = (gi - splits[s_cur]) as u32;
                let r = w.idx.write_all(&local.to_le_bytes()).and_then(|_| {
                    if f32_values {
                        narrow_f32(*v).and_then(|nv| w.val.write_all(&nv.to_le_bytes()))
                    } else {
                        w.val.write_all(&v.to_le_bytes())
                    }
                });
                if let Err(e) = r {
                    werr = Some(e);
                    return;
                }
                w.nnz += 1;
            }
        });
        if let Some(e) = werr {
            return Err(anyhow::Error::from(e)
                .context(format!("writing shard set {out_dir:?} (column {j})")));
        }
        for w in writers.iter_mut() {
            w.ptr.write_all(&w.nnz.to_le_bytes())?;
        }
    }

    let mut total = 0u64;
    for (s, w) in writers.iter_mut().enumerate() {
        w.idx.flush()?;
        w.val.flush()?;
        w.ptr.flush()?;
        total += w.nnz;
        write_meta(
            &out_dir.join(&names[s]),
            splits[s + 1] - splits[s],
            p,
            w.nnz as usize,
            f32_values,
            Some(splits[s]),
        )?;
    }
    if total as usize != mm.nnz() {
        bail!(
            "{src:?} changed while splitting: wrote {total} entries, source meta says {}",
            mm.nnz()
        );
    }

    // response vector travels at the set's top level
    let y = read_shard_y(src)?;
    if let Some(y) = &y {
        let mut y_out = BufWriter::new(File::create(out_dir.join(Y_FILE))?);
        for v in y {
            y_out.write_all(&v.to_le_bytes())?;
        }
        y_out.flush()?;
    }

    let mut manifest = format!(
        "format=dppshardset\nversion=1\nn_rows={n}\nn_cols={p}\nnnz={}\nshards={k}\n",
        mm.nnz()
    );
    for (s, name) in names.iter().enumerate() {
        manifest.push_str(&format!(
            "shard={name}:{}:{}:{}\n",
            splits[s],
            splits[s + 1] - splits[s],
            writers[s].nnz
        ));
    }
    std::fs::write(out_dir.join(SHARDSET_FILE), manifest)
        .with_context(|| format!("writing {:?}", out_dir.join(SHARDSET_FILE)))?;

    Ok(ShardSetSummary {
        n_rows: n,
        n_cols: p,
        nnz: mm.nnz(),
        shards: k,
        has_y: y.is_some(),
        f32_values,
    })
}

/// Parse one CSV line into **non-zero** `(column, value)` entries (reusing
/// `fields` as tokenizer scratch and `out` for the entries). Tokenization
/// is `io::parse_csv_fields` — the same parser the in-RAM CSV reader uses,
/// so the two paths can never drift apart (the LIBSVM converter shares
/// `io::parse_libsvm_pairs` the same way). Returns `None` for
/// blank/comment lines, else `(y, n_features)`.
fn parse_csv_entries(
    line: &str,
    lineno: usize,
    fields: &mut Vec<f64>,
    out: &mut Vec<(usize, f64)>,
) -> Result<Option<(f64, usize)>> {
    let Some(yi) = parse_csv_fields(line, lineno, fields)? else {
        return Ok(None);
    };
    out.clear();
    for (j, &v) in fields.iter().enumerate() {
        if v != 0.0 {
            out.push((j, v));
        }
    }
    Ok(Some((yi, fields.len())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::{read_libsvm, write_csv, write_libsvm};
    use crate::data::synthetic;
    use crate::linalg::MmapCscMatrix;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("dpp-convert-tests");
        let _ = std::fs::create_dir_all(&dir);
        let p = dir.join(name);
        let _ = std::fs::remove_dir_all(&p);
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sparse_dataset(seed: u64) -> crate::data::Dataset {
        let mut ds = synthetic::synthetic1(12, 9, 3, 0.1, seed);
        for j in 0..9 {
            for v in ds.x.dense_mut().unwrap().col_mut(j).iter_mut() {
                if v.abs() < 0.7 {
                    *v = 0.0;
                }
            }
        }
        ds
    }

    #[test]
    fn libsvm_conversion_matches_in_ram_reader() {
        let ds = sparse_dataset(1);
        let svm = tmp("conv.svm");
        write_libsvm(&ds, &svm).unwrap();
        let shard = tmp("conv.dppcsc");
        let sum = libsvm_to_shard(&svm, &shard, Some(9)).unwrap();
        assert_eq!((sum.n_rows, sum.n_cols), (12, 9));
        // the two code paths must build the identical CSC
        let in_ram = read_libsvm(&svm, Some(9)).unwrap();
        let mm = MmapCscMatrix::open_with_budget(&shard, 64).unwrap();
        assert_eq!(mm.to_csc(), in_ram.x.to_csc());
        assert_eq!(sum.nnz, in_ram.x.nnz());
        // y round-trips through y.bin
        let y = read_shard_y(&shard).unwrap().unwrap();
        assert_eq!(y.len(), 12);
        for (a, b) in y.iter().zip(in_ram.y.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn csv_conversion_matches_in_ram_reader() {
        let ds = sparse_dataset(2);
        let csv = tmp("conv.csv");
        write_csv(&ds, &csv).unwrap();
        let shard = tmp("convcsv.dppcsc");
        let sum = csv_to_shard(&csv, &shard).unwrap();
        assert_eq!((sum.n_rows, sum.n_cols), (12, 9));
        let mm = MmapCscMatrix::open_with_budget(&shard, 64).unwrap();
        let dense = crate::data::io::read_csv(&csv).unwrap();
        assert_eq!(mm.to_csc().to_dense(), dense.x.to_dense());
        assert_eq!(read_shard_y(&shard).unwrap().unwrap(), dense.y);
    }

    #[test]
    fn shard_from_design_round_trips() {
        let ds = sparse_dataset(3);
        let csc = ds.x.to_csc();
        let dir = tmp("direct.dppcsc");
        let sum = shard_from_design(&csc, Some(&ds.y), &dir).unwrap();
        assert_eq!(sum.nnz, csc.nnz());
        assert!(sum.disk_bytes() > 0);
        let mm = MmapCscMatrix::open_with_budget(&dir, 48).unwrap();
        assert_eq!(mm.to_csc(), csc);
        assert_eq!(read_shard_y(&dir).unwrap().unwrap(), ds.y);
    }

    #[test]
    fn p_hint_violation_and_empty_input_fail() {
        let svm = tmp("hint.svm");
        std::fs::write(&svm, "1 5:2.0\n").unwrap();
        assert!(libsvm_to_shard(&svm, tmp("hint.dppcsc"), Some(3)).is_err());
        let empty = tmp("empty.svm");
        std::fs::write(&empty, "# nothing\n").unwrap();
        assert!(libsvm_to_shard(&empty, tmp("empty.dppcsc"), None).is_err());
    }

    #[test]
    fn duplicate_indices_error_with_line_number() {
        let svm = tmp("dup.svm");
        std::fs::write(&svm, "1 1:1.0\n-1 3:2.0 3:4.0\n").unwrap();
        let err = libsvm_to_shard(&svm, tmp("dup.dppcsc"), None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("duplicate"), "{msg}");
    }

    #[test]
    fn split_shard_round_trips_through_the_shardset() {
        use crate::linalg::ShardSetMatrix;
        let ds = sparse_dataset(4);
        let csc = ds.x.to_csc();
        let shard = tmp("split-src.dppcsc");
        shard_from_design(&csc, Some(&ds.y), &shard).unwrap();
        let set = tmp("split.shards");
        let sum = split_shard(&shard, &set, 3).unwrap();
        assert_eq!((sum.n_rows, sum.n_cols, sum.shards), (12, 9, 3));
        assert_eq!(sum.nnz, csc.nnz());
        assert!(sum.has_y && !sum.f32_values);
        // out-of-core and in-RAM openings both reproduce the source exactly
        let sh = ShardSetMatrix::open_with_budget(&set, 64).unwrap();
        assert_eq!(sh.shard_count(), 3);
        assert_eq!(sh.to_csc(), csc);
        assert_eq!(ShardSetMatrix::open_in_ram(&set).unwrap().to_csc(), csc);
        // y travels at the set's top level
        assert_eq!(read_shard_y(&set).unwrap().unwrap(), ds.y);
        let _ = std::fs::remove_dir_all(&set);
        let _ = std::fs::remove_dir_all(&shard);
    }

    #[test]
    fn split_with_more_shards_than_rows_leaves_empty_shards() {
        use crate::linalg::ShardSetMatrix;
        let ds = sparse_dataset(5);
        let csc = ds.x.to_csc(); // 12 rows
        let shard = tmp("split-many.dppcsc");
        shard_from_design(&csc, None, &shard).unwrap();
        let set = tmp("split-many.shards");
        let sum = split_shard(&shard, &set, 20).unwrap();
        assert_eq!(sum.shards, 20);
        let sh = ShardSetMatrix::open_with_budget(&set, 32).unwrap();
        assert_eq!(sh.to_csc(), csc);
        let _ = std::fs::remove_dir_all(&set);
        let _ = std::fs::remove_dir_all(&shard);
    }

    #[test]
    fn f32_shard_quantizes_and_round_trips() {
        use crate::linalg::MmapCscMatrix;
        let ds = sparse_dataset(6);
        let csc = ds.x.to_csc();
        let dir = tmp("f32.dppcsc");
        let sum = shard_from_design_opts(&csc, Some(&ds.y), &dir, true).unwrap();
        assert!(sum.f32_values);
        // half the per-entry value bytes on disk
        let vals_len = std::fs::metadata(dir.join(VALUES_FILE)).unwrap().len();
        assert_eq!(vals_len, (sum.nnz * 4) as u64);
        assert!(sum.disk_bytes() < sum.nnz * 12 + 200);
        let mm = MmapCscMatrix::open_with_budget(&dir, 48).unwrap();
        assert!(mm.is_f32());
        // every stored value is exactly the f32-quantized source value,
        // widened back to f64
        let q = mm.to_csc();
        let dense_src = csc.to_dense();
        let dense_q = q.to_dense();
        for j in 0..9 {
            for i in 0..12 {
                let want = dense_src.get(i, j) as f32 as f64;
                assert_eq!(dense_q.get(i, j), want, "({i},{j})");
            }
        }
        // y stays full-precision
        assert_eq!(read_shard_y(&dir).unwrap().unwrap(), ds.y);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn f32_conversion_rejects_overflowing_values() {
        let csv = tmp("overflow.csv");
        std::fs::write(&csv, "1.0,1e39,0\n-1.0,2.0,3.0\n").unwrap();
        let err = csv_to_shard_opts(&csv, tmp("overflow.dppcsc"), true).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("f32"), "{msg}");
        // the same file converts fine at full precision
        assert!(csv_to_shard(&csv, tmp("overflow64.dppcsc")).is_ok());
    }

    #[test]
    fn split_preserves_the_f32_dtype() {
        use crate::linalg::ShardSetMatrix;
        let ds = sparse_dataset(7);
        let csc = ds.x.to_csc();
        let shard = tmp("f32-split.dppcsc");
        shard_from_design_opts(&csc, None, &shard, true).unwrap();
        let set = tmp("f32-split.shards");
        let sum = split_shard(&shard, &set, 2).unwrap();
        assert!(sum.f32_values);
        let sh = ShardSetMatrix::open_with_budget(&set, 32).unwrap();
        assert!(sh.is_f32());
        // in-RAM loading widens the slices to f64 CSC but must still report
        // the quantization, or the safety-slack contract silently vanishes
        assert!(ShardSetMatrix::open_in_ram(&set).unwrap().is_f32());
        // the split of the quantized shard equals the quantized source
        let src = crate::linalg::MmapCscMatrix::open_with_budget(&shard, 32).unwrap();
        assert_eq!(sh.to_csc(), src.to_csc());
        let _ = std::fs::remove_dir_all(&set);
        let _ = std::fs::remove_dir_all(&shard);
    }
}
