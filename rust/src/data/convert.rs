//! Streaming conversion of row-major text datasets (LIBSVM / CSV) into the
//! on-disk `dppcsc` shard format that [`crate::linalg::MmapCscMatrix`]
//! pages from (`dpp convert`; layout in DESIGN.md §2b).
//!
//! The transpose (row-major input → column-major CSC) is done in **two
//! passes over the input file** so peak memory is O(p) counters plus one
//! line buffer — independent of N and nnz:
//!
//! 1. count non-zeros per column (and stream `y.bin` out as labels are
//!    seen), then prefix-sum the counts into `col_ptr.bin`;
//! 2. re-read the input and scatter each entry to its final offset in
//!    `row_idx.bin` / `values.bin` with positioned writes (one cursor per
//!    column; the OS page cache absorbs the small writes, and a
//!    bounded sorted-run buffer that coalesces them into contiguous
//!    writes is the known follow-up if syscall overhead ever dominates
//!    at the 10⁸-nnz scale).
//!
//! Rows are processed in order, so each column receives its row indices
//! already strictly increasing — the CSC invariant holds by construction
//! once per-line indices are sorted and duplicates rejected
//! (`io::parse_libsvm_pairs`).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::os::unix::fs::FileExt;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::io::{parse_csv_fields, parse_libsvm_pairs};
use crate::linalg::mmap::{COL_PTR_FILE, META_FILE, ROW_IDX_FILE, VALUES_FILE, Y_FILE};
use crate::linalg::DesignMatrix;

/// What a conversion produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvertSummary {
    pub n_rows: usize,
    pub n_cols: usize,
    pub nnz: usize,
    /// Whether `y.bin` was written (the text converters always write it;
    /// `shard_from_design` only when given a response vector).
    pub has_y: bool,
}

impl ConvertSummary {
    /// Total shard bytes on disk (entry arrays + col_ptr, + y if written).
    pub fn disk_bytes(&self) -> usize {
        let y = if self.has_y { self.n_rows * 8 } else { 0 };
        self.nnz * 12 + (self.n_cols + 1) * 8 + y
    }
}

/// After the pass-2 scatter, every column cursor must have landed exactly
/// on the next column's start — otherwise the input lost entries between
/// the passes and `set_len`'s zero-filled tail would masquerade as
/// spurious `(row 0, 0.0)` entries in the shard.
fn verify_cursors(cursor: &[u64], col_ptr: &[u64], input: &Path) -> Result<()> {
    for (j, &c) in cursor.iter().enumerate() {
        if c != col_ptr[j + 1] {
            bail!(
                "{input:?} changed between convert passes (column {j} underfilled: \
                 {c} of {} entries)",
                col_ptr[j + 1]
            );
        }
    }
    Ok(())
}

/// Convert `input` into a shard at `out_dir`, dispatching on the file
/// extension (`.svm`/`.libsvm` → LIBSVM, anything else → CSV).
pub fn convert_to_shard(
    input: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    p_hint: Option<usize>,
) -> Result<ConvertSummary> {
    let path = input.as_ref();
    let name = path.to_string_lossy();
    if name.ends_with(".svm") || name.ends_with(".libsvm") {
        libsvm_to_shard(path, out_dir, p_hint)
    } else {
        csv_to_shard(path, out_dir)
    }
}

/// LIBSVM (`y idx:val …`, 1-based indices) → shard, two bounded-memory
/// passes. `p_hint` forces the feature count (else max index seen).
pub fn libsvm_to_shard(
    input: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    p_hint: Option<usize>,
) -> Result<ConvertSummary> {
    let input = input.as_ref();
    let out_dir = out_dir.as_ref();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating shard dir {out_dir:?}"))?;

    // ---- pass 1: per-column counts, n, p, y.bin ----
    let mut counts: Vec<u64> = Vec::new();
    let mut n_rows = 0usize;
    let mut pairs: Vec<(u32, f64)> = Vec::new();
    {
        let f = File::open(input).with_context(|| format!("opening {input:?}"))?;
        let mut y_out = BufWriter::new(
            File::create(out_dir.join(Y_FILE))
                .with_context(|| format!("creating {:?}", out_dir.join(Y_FILE)))?,
        );
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line.context("reading line")?;
            let Some(yi) = parse_libsvm_pairs(&line, lineno, &mut pairs)? else {
                continue;
            };
            y_out.write_all(&yi.to_le_bytes())?;
            for &(j, _) in &pairs {
                let j = j as usize;
                if j >= counts.len() {
                    counts.resize(j + 1, 0);
                }
                counts[j] += 1;
            }
            n_rows += 1;
        }
        y_out.flush()?;
    }
    if n_rows == 0 {
        bail!("no data rows in {input:?}");
    }
    if n_rows > u32::MAX as usize {
        bail!("{} rows exceed u32 row-index range", n_rows);
    }
    let n_cols = match p_hint {
        Some(p) => {
            if counts.len() > p {
                bail!("index {} exceeds p_hint {}", counts.len(), p);
            }
            p
        }
        None => counts.len(),
    };
    counts.resize(n_cols, 0);

    let col_ptr = write_col_ptr(out_dir, &counts)?;
    let nnz = col_ptr[n_cols] as usize;

    // ---- pass 2: scatter entries to their final offsets ----
    {
        let idx_out = File::create(out_dir.join(ROW_IDX_FILE))?;
        let val_out = File::create(out_dir.join(VALUES_FILE))?;
        idx_out.set_len((nnz * 4) as u64)?;
        val_out.set_len((nnz * 8) as u64)?;
        let mut cursor: Vec<u64> = col_ptr[..n_cols].to_vec();
        let f = File::open(input)?;
        let mut row = 0u32;
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line.context("reading line")?;
            let Some(_) = parse_libsvm_pairs(&line, lineno, &mut pairs)? else {
                continue;
            };
            for &(j, v) in &pairs {
                let j = j as usize;
                if j >= n_cols || cursor[j] >= col_ptr[j + 1] {
                    bail!("{input:?} changed between convert passes (column {j} overflow)");
                }
                idx_out.write_all_at(&row.to_le_bytes(), cursor[j] * 4)?;
                val_out.write_all_at(&v.to_le_bytes(), cursor[j] * 8)?;
                cursor[j] += 1;
            }
            row += 1;
        }
        if row as usize != n_rows {
            bail!("{input:?} changed between convert passes (row count)");
        }
        verify_cursors(&cursor, &col_ptr, input)?;
    }

    write_meta(out_dir, n_rows, n_cols, nnz)?;
    Ok(ConvertSummary { n_rows, n_cols, nnz, has_y: true })
}

/// CSV (`y,x1,…,xp` per line) → shard, two bounded-memory passes; exact
/// zeros are dropped (CSV is a dense format, the shard is sparse).
pub fn csv_to_shard(input: impl AsRef<Path>, out_dir: impl AsRef<Path>) -> Result<ConvertSummary> {
    let input = input.as_ref();
    let out_dir = out_dir.as_ref();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating shard dir {out_dir:?}"))?;

    // ---- pass 1 ----
    let mut counts: Vec<u64> = Vec::new();
    let mut n_rows = 0usize;
    let mut n_cols = 0usize;
    let mut fields: Vec<f64> = Vec::new();
    let mut pairs: Vec<(usize, f64)> = Vec::new();
    {
        let f = File::open(input).with_context(|| format!("opening {input:?}"))?;
        let mut y_out = BufWriter::new(File::create(out_dir.join(Y_FILE))?);
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line.context("reading line")?;
            let Some((yi, ncols)) = parse_csv_entries(&line, lineno, &mut fields, &mut pairs)?
            else {
                continue;
            };
            if n_rows == 0 {
                n_cols = ncols;
            } else if ncols != n_cols {
                bail!("line {}: {} features, expected {}", lineno + 1, ncols, n_cols);
            }
            for &(j, _) in &pairs {
                if j >= counts.len() {
                    counts.resize(j + 1, 0);
                }
                counts[j] += 1;
            }
            y_out.write_all(&yi.to_le_bytes())?;
            n_rows += 1;
        }
        y_out.flush()?;
    }
    if n_rows == 0 {
        bail!("no data rows in {input:?}");
    }
    if n_rows > u32::MAX as usize {
        bail!("{} rows exceed u32 row-index range", n_rows);
    }
    counts.resize(n_cols, 0);

    let col_ptr = write_col_ptr(out_dir, &counts)?;
    let nnz = col_ptr[n_cols] as usize;

    // ---- pass 2 ----
    {
        let idx_out = File::create(out_dir.join(ROW_IDX_FILE))?;
        let val_out = File::create(out_dir.join(VALUES_FILE))?;
        idx_out.set_len((nnz * 4) as u64)?;
        val_out.set_len((nnz * 8) as u64)?;
        let mut cursor: Vec<u64> = col_ptr[..n_cols].to_vec();
        let f = File::open(input)?;
        let mut row = 0u32;
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line.context("reading line")?;
            if parse_csv_entries(&line, lineno, &mut fields, &mut pairs)?.is_none() {
                continue;
            }
            for &(j, v) in &pairs {
                if j >= n_cols || cursor[j] >= col_ptr[j + 1] {
                    bail!("{input:?} changed between convert passes (column {j} overflow)");
                }
                idx_out.write_all_at(&row.to_le_bytes(), cursor[j] * 4)?;
                val_out.write_all_at(&v.to_le_bytes(), cursor[j] * 8)?;
                cursor[j] += 1;
            }
            row += 1;
        }
        if row as usize != n_rows {
            bail!("{input:?} changed between convert passes (row count)");
        }
        verify_cursors(&cursor, &col_ptr, input)?;
    }

    write_meta(out_dir, n_rows, n_cols, nnz)?;
    Ok(ConvertSummary { n_rows, n_cols, nnz, has_y: true })
}

/// Write a shard directly from an in-process backend (tests, benches, the
/// experiments runner's `DPP_MATRIX=mmap` mode). Streams one densified
/// column at a time — O(N) scratch, never the whole matrix.
pub fn shard_from_design(
    x: &dyn DesignMatrix,
    y: Option<&[f64]>,
    out_dir: impl AsRef<Path>,
) -> Result<ConvertSummary> {
    let out_dir = out_dir.as_ref();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating shard dir {out_dir:?}"))?;
    let (n, p) = (x.n_rows(), x.n_cols());
    if n > u32::MAX as usize {
        bail!("n_rows {} exceeds u32 row-index range", n);
    }
    let mut idx_out = BufWriter::new(File::create(out_dir.join(ROW_IDX_FILE))?);
    let mut val_out = BufWriter::new(File::create(out_dir.join(VALUES_FILE))?);
    let mut ptr_out = BufWriter::new(File::create(out_dir.join(COL_PTR_FILE))?);
    let mut col = vec![0.0; n];
    let mut nnz = 0u64;
    ptr_out.write_all(&0u64.to_le_bytes())?;
    for j in 0..p {
        x.col_into(j, &mut col);
        for (i, v) in col.iter().enumerate() {
            if *v != 0.0 {
                idx_out.write_all(&(i as u32).to_le_bytes())?;
                val_out.write_all(&v.to_le_bytes())?;
                nnz += 1;
            }
        }
        ptr_out.write_all(&nnz.to_le_bytes())?;
    }
    idx_out.flush()?;
    val_out.flush()?;
    ptr_out.flush()?;
    if let Some(y) = y {
        let mut y_out = BufWriter::new(File::create(out_dir.join(Y_FILE))?);
        for v in y {
            y_out.write_all(&v.to_le_bytes())?;
        }
        y_out.flush()?;
    }
    write_meta(out_dir, n, p, nnz as usize)?;
    Ok(ConvertSummary { n_rows: n, n_cols: p, nnz: nnz as usize, has_y: y.is_some() })
}

/// Load the shard's response vector, if the converter wrote one.
pub fn read_shard_y(dir: impl AsRef<Path>) -> Result<Option<Vec<f64>>> {
    let path = dir.as_ref().join(Y_FILE);
    if !path.exists() {
        return Ok(None);
    }
    let raw = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
    if raw.len() % 8 != 0 {
        bail!("{path:?} length {} is not a multiple of 8", raw.len());
    }
    Ok(Some(
        raw.chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect(),
    ))
}

/// Prefix-sum `counts` into `col_ptr.bin`; returns the in-RAM pointer
/// array (O(p), also needed for the scatter cursors).
fn write_col_ptr(out_dir: &Path, counts: &[u64]) -> Result<Vec<u64>> {
    let mut col_ptr = Vec::with_capacity(counts.len() + 1);
    col_ptr.push(0u64);
    for &c in counts {
        col_ptr.push(col_ptr.last().unwrap() + c);
    }
    let mut out = BufWriter::new(File::create(out_dir.join(COL_PTR_FILE))?);
    for v in &col_ptr {
        out.write_all(&v.to_le_bytes())?;
    }
    out.flush()?;
    Ok(col_ptr)
}

fn write_meta(out_dir: &Path, n_rows: usize, n_cols: usize, nnz: usize) -> Result<()> {
    let text = format!(
        "format=dppcsc\nversion=1\nn_rows={n_rows}\nn_cols={n_cols}\nnnz={nnz}\n"
    );
    std::fs::write(out_dir.join(META_FILE), text)
        .with_context(|| format!("writing {:?}", out_dir.join(META_FILE)))
}

/// Parse one CSV line into **non-zero** `(column, value)` entries (reusing
/// `fields` as tokenizer scratch and `out` for the entries). Tokenization
/// is `io::parse_csv_fields` — the same parser the in-RAM CSV reader uses,
/// so the two paths can never drift apart (the LIBSVM converter shares
/// `io::parse_libsvm_pairs` the same way). Returns `None` for
/// blank/comment lines, else `(y, n_features)`.
fn parse_csv_entries(
    line: &str,
    lineno: usize,
    fields: &mut Vec<f64>,
    out: &mut Vec<(usize, f64)>,
) -> Result<Option<(f64, usize)>> {
    let Some(yi) = parse_csv_fields(line, lineno, fields)? else {
        return Ok(None);
    };
    out.clear();
    for (j, &v) in fields.iter().enumerate() {
        if v != 0.0 {
            out.push((j, v));
        }
    }
    Ok(Some((yi, fields.len())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::{read_libsvm, write_csv, write_libsvm};
    use crate::data::synthetic;
    use crate::linalg::MmapCscMatrix;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("dpp-convert-tests");
        let _ = std::fs::create_dir_all(&dir);
        let p = dir.join(name);
        let _ = std::fs::remove_dir_all(&p);
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sparse_dataset(seed: u64) -> crate::data::Dataset {
        let mut ds = synthetic::synthetic1(12, 9, 3, 0.1, seed);
        for j in 0..9 {
            for v in ds.x.dense_mut().col_mut(j).iter_mut() {
                if v.abs() < 0.7 {
                    *v = 0.0;
                }
            }
        }
        ds
    }

    #[test]
    fn libsvm_conversion_matches_in_ram_reader() {
        let ds = sparse_dataset(1);
        let svm = tmp("conv.svm");
        write_libsvm(&ds, &svm).unwrap();
        let shard = tmp("conv.dppcsc");
        let sum = libsvm_to_shard(&svm, &shard, Some(9)).unwrap();
        assert_eq!((sum.n_rows, sum.n_cols), (12, 9));
        // the two code paths must build the identical CSC
        let in_ram = read_libsvm(&svm, Some(9)).unwrap();
        let mm = MmapCscMatrix::open_with_budget(&shard, 64).unwrap();
        assert_eq!(mm.to_csc(), in_ram.x.to_csc());
        assert_eq!(sum.nnz, in_ram.x.nnz());
        // y round-trips through y.bin
        let y = read_shard_y(&shard).unwrap().unwrap();
        assert_eq!(y.len(), 12);
        for (a, b) in y.iter().zip(in_ram.y.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn csv_conversion_matches_in_ram_reader() {
        let ds = sparse_dataset(2);
        let csv = tmp("conv.csv");
        write_csv(&ds, &csv).unwrap();
        let shard = tmp("convcsv.dppcsc");
        let sum = csv_to_shard(&csv, &shard).unwrap();
        assert_eq!((sum.n_rows, sum.n_cols), (12, 9));
        let mm = MmapCscMatrix::open_with_budget(&shard, 64).unwrap();
        let dense = crate::data::io::read_csv(&csv).unwrap();
        assert_eq!(mm.to_csc().to_dense(), dense.x.to_dense());
        assert_eq!(read_shard_y(&shard).unwrap().unwrap(), dense.y);
    }

    #[test]
    fn shard_from_design_round_trips() {
        let ds = sparse_dataset(3);
        let csc = ds.x.to_csc();
        let dir = tmp("direct.dppcsc");
        let sum = shard_from_design(&csc, Some(&ds.y), &dir).unwrap();
        assert_eq!(sum.nnz, csc.nnz());
        assert!(sum.disk_bytes() > 0);
        let mm = MmapCscMatrix::open_with_budget(&dir, 48).unwrap();
        assert_eq!(mm.to_csc(), csc);
        assert_eq!(read_shard_y(&dir).unwrap().unwrap(), ds.y);
    }

    #[test]
    fn p_hint_violation_and_empty_input_fail() {
        let svm = tmp("hint.svm");
        std::fs::write(&svm, "1 5:2.0\n").unwrap();
        assert!(libsvm_to_shard(&svm, tmp("hint.dppcsc"), Some(3)).is_err());
        let empty = tmp("empty.svm");
        std::fs::write(&empty, "# nothing\n").unwrap();
        assert!(libsvm_to_shard(&empty, tmp("empty.dppcsc"), None).is_err());
    }

    #[test]
    fn duplicate_indices_error_with_line_number() {
        let svm = tmp("dup.svm");
        std::fs::write(&svm, "1 1:1.0\n-1 3:2.0 3:4.0\n").unwrap();
        let err = libsvm_to_shard(&svm, tmp("dup.dppcsc"), None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("duplicate"), "{msg}");
    }
}
