//! The routing tier itself: `dpp front --listen --backend …`
//! (DESIGN.md §4c).
//!
//! [`Front`] accepts the same framed client protocol as a backend server
//! and forwards each `Submit` to the one backend its session lives on.
//! Placement is rendezvous hashing ([`super::placement`]) over the live
//! backends — preferring, for sessions that already exist somewhere, the
//! backends that advertised them — biased by the probe-refreshed load
//! view, and pinned in a routing table on first use: a stateful session
//! is never silently re-homed.
//!
//! Per connection the shape mirrors `net::NetServer`: a reader thread
//! forwards frames in arrival order (per-backend writes are serialized by
//! the link lock, so per-session FIFO survives the hop — and with it the
//! bit-identity contract), and a responder thread completes replies in
//! submission order. The responder is also where `Overloaded` answers are
//! retried: each retry waits the backend's deterministic `retry_after_ms`
//! hint (capped) and re-forwards, up to a bounded budget, after which the
//! typed error propagates to the client unchanged.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::backend::BackendLink;
use super::placement::{pick, Candidate};
use crate::coordinator::{Request, RequestError, Response};
use crate::net::frame::{read_frame, write_frame};
use crate::net::wire::{
    decode_client_msg, encode_server_msg, ClientMsg, ServerMsg, StatsReport, WIRE_VERSION,
};
use crate::runtime::timer::Ticker;

/// Accept-loop poll interval (mirrors `net::NetServer`).
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Tunables for probing and retry behaviour.
#[derive(Debug, Clone)]
pub struct FrontConfig {
    /// Health/load probe period per backend.
    pub probe_interval: Duration,
    /// Consecutive unanswered probes before a backend is marked down.
    pub unanswered_probes_down: u32,
    /// `Overloaded` answers retried per request before the error
    /// propagates typed to the client.
    pub retry_budget: u32,
    /// Cap on each retry wait, bounding worst-case added latency to
    /// `retry_budget × retry_wait_cap_ms` (the backend hint itself is
    /// deterministic but grows with queue depth).
    pub retry_wait_cap_ms: u64,
}

impl Default for FrontConfig {
    fn default() -> Self {
        FrontConfig {
            probe_interval: Duration::from_millis(500),
            unanswered_probes_down: 3,
            retry_budget: 3,
            retry_wait_cap_ms: 250,
        }
    }
}

/// Counters and final backend rows returned by [`Front::run`].
#[derive(Debug, Clone)]
pub struct FrontSummary {
    /// Submits forwarded (first attempts, not counting retries).
    pub forwarded: u64,
    /// Re-forwards triggered by `Overloaded` answers.
    pub retries: u64,
    /// Final load/health row per backend, in `--backend` order.
    pub backends: Vec<StatsReport>,
}

struct FrontShared {
    links: Vec<BackendLink>,
    /// session name → index into `links`; pinned at first placement.
    placement: Mutex<BTreeMap<String, usize>>,
    cfg: FrontConfig,
    forwarded: AtomicU64,
    retries: AtomicU64,
}

impl FrontShared {
    /// Resolve (or make) the placement for `session`. A session already
    /// pinned keeps its backend even when that backend is down — the
    /// typed backend-down error surfaces at forward time instead.
    fn place(&self, session: &str) -> Result<usize, RequestError> {
        let mut map = self.placement.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(&i) = map.get(session) {
            return Ok(i);
        }
        let up: Vec<usize> =
            (0..self.links.len()).filter(|&i| self.links[i].is_up()).collect();
        if up.is_empty() {
            return Err(RequestError::Disconnected(
                "front: no live backends".to_string(),
            ));
        }
        // sessions that already live somewhere must route to a holder;
        // brand-new sessions may go to any live backend
        let holders: Vec<usize> = up
            .iter()
            .copied()
            .filter(|&i| self.links[i].advertises(session))
            .collect();
        let pool = if holders.is_empty() { &up } else { &holders };
        // load = probed session count + sessions we placed since the probe
        let mut placed = vec![0u64; self.links.len()];
        for &i in map.values() {
            placed[i] += 1;
        }
        let cands: Vec<Candidate<'_>> = pool
            .iter()
            .map(|&i| Candidate {
                addr: self.links[i].addr(),
                load: self.links[i].session_load() + placed[i],
            })
            .collect();
        let Some(k) = pick(session, &cands) else {
            return Err(RequestError::Disconnected(
                "front: no live backends".to_string(),
            ));
        };
        let idx = pool[k];
        map.insert(session.to_string(), idx);
        Ok(idx)
    }

    fn forward(
        &self,
        session: &str,
        request: &Request,
    ) -> Result<Receiver<Response>, RequestError> {
        let idx = self.place(session)?;
        self.links[idx].forward(session, request)
    }

    fn stats_rows(&self) -> Vec<StatsReport> {
        self.links.iter().map(|l| l.report()).collect()
    }

    fn probe_all(&self) {
        for l in &self.links {
            l.probe(self.cfg.unanswered_probes_down);
        }
    }

    /// Union of the backends' advertised sessions, sorted + deduped (the
    /// front's own hello payload).
    fn advertised_union(&self) -> Vec<String> {
        let mut all: Vec<String> =
            self.links.iter().flat_map(|l| l.advertised()).collect();
        all.sort();
        all.dedup();
        all
    }
}

/// A bound, not-yet-running front tier.
pub struct Front {
    listener: TcpListener,
    shared: Arc<FrontShared>,
    stop: Arc<AtomicBool>,
}

impl Front {
    /// Connect to every backend (fail fast if one refuses at startup —
    /// backends dying *later* are handled by down-marking) and bind the
    /// client-facing listener.
    pub fn bind(listen: &str, backends: &[String], cfg: FrontConfig) -> Result<Front> {
        if backends.is_empty() {
            bail!("dpp front needs at least one --backend address");
        }
        let mut links = Vec::with_capacity(backends.len());
        for addr in backends {
            links.push(BackendLink::connect(addr)?);
        }
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding front listener on {listen}"))?;
        listener
            .set_nonblocking(true)
            .context("setting front listener non-blocking")?;
        Ok(Front {
            listener,
            shared: Arc::new(FrontShared {
                links,
                placement: Mutex::new(BTreeMap::new()),
                cfg,
                forwarded: AtomicU64::new(0),
                retries: AtomicU64::new(0),
            }),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound client-facing address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading front listener address")
    }

    /// Route until a client sends `Shutdown` (which stops the front only —
    /// backends keep serving and keep their sessions). Returns forwarding
    /// counters and the final per-backend load view.
    pub fn run(self) -> FrontSummary {
        let probe_shared = Arc::clone(&self.shared);
        let ticker = Ticker::spawn(
            "dpp-front-probe",
            self.shared.cfg.probe_interval,
            move || probe_shared.probe_all(),
        );
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let shared = Arc::clone(&self.shared);
                    let stop = Arc::clone(&self.stop);
                    if let Err(e) = std::thread::Builder::new()
                        .name("dpp-front-conn".to_string())
                        .spawn(move || serve_front_connection(stream, shared, stop))
                    {
                        eprintln!("dpp-front: connection thread spawn failed: {e}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => break,
            }
        }
        ticker.stop();
        FrontSummary {
            forwarded: self.shared.forwarded.load(Ordering::SeqCst),
            retries: self.shared.retries.load(Ordering::SeqCst),
            backends: self.shared.stats_rows(),
        }
    }
}

/// One queued reply handed from the connection's reader to its responder.
enum FrontReply {
    /// A forwarded submit: the responder blocks on `rx` (retrying
    /// `Overloaded` answers) and writes the reply with the client's id.
    Forwarded { id: u64, session: String, request: Request, rx: Receiver<Response> },
    /// A submit that failed before reaching a backend (typed error).
    Ready { id: u64, response: Response },
    /// Control-plane stats: answered from the front's own load view.
    Stats,
    Shutdown,
}

fn serve_front_connection(
    stream: TcpStream,
    shared: Arc<FrontShared>,
    stop: Arc<AtomicBool>,
) {
    let Ok(mut reader) = stream.try_clone() else { return };
    let mut writer = stream;
    let client_version = match read_frame(&mut reader).map(|p| decode_client_msg(&p)) {
        Ok(Ok(ClientMsg::Hello { version })) => version,
        _ => return,
    };
    let hello = encode_server_msg(&ServerMsg::Hello {
        version: WIRE_VERSION,
        sessions: shared.advertised_union(),
    });
    if write_frame(&mut writer, &hello).is_err() || client_version != WIRE_VERSION {
        return;
    }

    let (rtx, rrx) = channel::<FrontReply>();
    let resp_shared = Arc::clone(&shared);
    let responder = match std::thread::Builder::new()
        .name("dpp-front-reply".to_string())
        .spawn(move || front_respond_loop(writer, rrx, resp_shared))
    {
        Ok(handle) => handle,
        Err(e) => {
            eprintln!("dpp-front: responder thread spawn failed: {e}");
            return;
        }
    };
    loop {
        let Ok(payload) = read_frame(&mut reader) else {
            break;
        };
        match decode_client_msg(&payload) {
            Ok(ClientMsg::Submit { id, session, request }) => {
                shared.forwarded.fetch_add(1, Ordering::SeqCst);
                let item = match shared.forward(&session, &request) {
                    Ok(rx) => FrontReply::Forwarded { id, session, request, rx },
                    Err(e) => FrontReply::Ready { id, response: Response::Error(e) },
                };
                if rtx.send(item).is_err() {
                    break;
                }
            }
            Ok(ClientMsg::Stats) => {
                if rtx.send(FrontReply::Stats).is_err() {
                    break;
                }
            }
            Ok(ClientMsg::Shutdown) => {
                let _ = rtx.send(FrontReply::Shutdown);
                break;
            }
            Ok(ClientMsg::Hello { .. }) | Err(_) => break,
        }
    }
    drop(rtx);
    if responder.join().unwrap_or(false) {
        stop.store(true, Ordering::SeqCst);
    }
}

/// Complete replies in submission order. `Overloaded` answers are retried
/// here — the wait honours the backend's deterministic hint (capped), the
/// attempt budget bounds the total, and exhaustion propagates the typed
/// error unchanged. Returns true when the connection asked the front to
/// shut down.
fn front_respond_loop(
    mut writer: TcpStream,
    rrx: Receiver<FrontReply>,
    shared: Arc<FrontShared>,
) -> bool {
    while let Ok(item) = rrx.recv() {
        match item {
            FrontReply::Forwarded { id, session, request, mut rx } => {
                let mut budget = shared.cfg.retry_budget;
                let response = loop {
                    let resp = rx.recv().unwrap_or_else(|_| {
                        Response::Error(RequestError::Disconnected(
                            "front: backend reply slot vanished".to_string(),
                        ))
                    });
                    let hint = match &resp {
                        Response::Error(RequestError::Overloaded { retry_after_ms })
                            if budget > 0 =>
                        {
                            *retry_after_ms
                        }
                        _ => break resp,
                    };
                    budget -= 1;
                    shared.retries.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(
                        hint.min(shared.cfg.retry_wait_cap_ms),
                    ));
                    match shared.forward(&session, &request) {
                        Ok(new_rx) => rx = new_rx,
                        Err(e) => break Response::Error(e),
                    }
                };
                let bytes = encode_server_msg(&ServerMsg::Reply { id, response });
                if write_frame(&mut writer, &bytes).is_err() {
                    return false;
                }
            }
            FrontReply::Ready { id, response } => {
                let bytes = encode_server_msg(&ServerMsg::Reply { id, response });
                if write_frame(&mut writer, &bytes).is_err() {
                    return false;
                }
            }
            FrontReply::Stats => {
                let bytes = encode_server_msg(&ServerMsg::Stats {
                    backends: shared.stats_rows(),
                });
                if write_frame(&mut writer, &bytes).is_err() {
                    return false;
                }
            }
            FrontReply::Shutdown => {
                let bytes = encode_server_msg(&ServerMsg::ShuttingDown);
                let _ = write_frame(&mut writer, &bytes);
                return true;
            }
        }
    }
    false
}
