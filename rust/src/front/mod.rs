//! Front tier: session-affine routing across `dpp serve` processes
//! (DESIGN.md §4c).
//!
//! `dpp front --listen ADDR --backend A1,A2,…` speaks the client-facing
//! protocol of `net::NetServer` but owns no coordinator: every session is
//! *placed* on exactly one backend process and all of its frames forward
//! over that backend's persistent connection in arrival order — so the
//! per-session FIFO + descending-λ contract that makes socket responses
//! bit-identical to in-process ones (DESIGN.md §4b.3) extends across
//! processes for free.
//!
//! The three pieces:
//!
//! * [`placement`] — deterministic rendezvous hashing by session name,
//!   biased by the load view (no RNG, no clock: pure function of name and
//!   candidates).
//! * [`BackendLink`] (in `backend`) — one persistent connection per
//!   backend: id-multiplexed forwarding, reply routing, the control-plane
//!   `Stats` probe as health check, and typed down-marking.
//! * [`Front`] (in `server`) — the accept loop, the per-connection
//!   reader/responder pair, and bounded `Overloaded`-honoring retries.
//!
//! Failure semantics are typed end-to-end: a dead backend fails its
//! sessions with `SessionClosed { reason: "backend … down: …" }` (in
//! flight and ever after — stateful sessions are never silently
//! re-homed), while *new* sessions route around it; an exhausted retry
//! budget propagates `Overloaded { retry_after_ms }` unchanged.

mod backend;
pub mod placement;
mod server;

pub use backend::BackendLink;
pub use server::{Front, FrontConfig, FrontSummary};
