//! One persistent connection from the front to a backend `dpp serve`
//! process (DESIGN.md §4c).
//!
//! A [`BackendLink`] multiplexes every session placed on its backend over
//! a single TCP connection: forwarding writes a `Submit` frame under the
//! link lock (so per-session FIFO order is exactly the arrival order at
//! the front), and a dedicated reply thread routes each `Reply` back to
//! the waiting forwarder by id. Control-plane probes travel on the same
//! connection — a `Stats` answer refreshes the load view the placement
//! rule biases on, and doubles as the health check.
//!
//! Failure semantics: any connect/IO error, protocol error, or a budget of
//! unanswered probes marks the link **down** with a reason. Marking down
//! fails every in-flight request with a typed
//! [`RequestError::SessionClosed`] naming the backend, and every later
//! forward for a session placed here gets the same typed error — sessions
//! are stateful, so the front never silently re-homes them; only *new*
//! sessions route around a down backend.

use std::collections::BTreeMap;
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::{Request, RequestError, Response};
use crate::net::frame::{read_frame, write_frame};
use crate::net::wire::{
    decode_server_msg, encode_client_msg, ClientMsg, ServerMsg, StatsReport, WIRE_VERSION,
};

struct LinkState {
    /// Write half of the persistent connection; `None` once down.
    writer: Option<TcpStream>,
    next_id: u64,
    /// In-flight forwards by backend-assigned id: the session name (for
    /// typed errors) and the slot the responder is blocked on.
    pending: BTreeMap<u64, (String, Sender<Response>)>,
    /// Down reason, once marked down (never cleared — links do not heal).
    down: Option<String>,
    /// Session names the backend advertised in its hello.
    advertised: Vec<String>,
    /// Probe-refreshed load/health row for this backend.
    report: StatsReport,
    /// Probes sent but not yet answered (reset by every `Stats` reply).
    unanswered_probes: u32,
}

/// A live (or down) backend: address, persistent connection, load view.
pub struct BackendLink {
    addr: String,
    state: Arc<Mutex<LinkState>>,
}

impl BackendLink {
    /// Connect and shake hands with `dpp serve --listen addr`, then start
    /// the reply-routing thread.
    pub fn connect(addr: &str) -> Result<BackendLink> {
        let mut stream = TcpStream::connect(addr).with_context(|| {
            format!("connecting to backend {addr} — is `dpp serve --listen {addr}` running?")
        })?;
        let hello = encode_client_msg(&ClientMsg::Hello { version: WIRE_VERSION });
        write_frame(&mut stream, &hello)
            .with_context(|| format!("sending hello to backend {addr}"))?;
        let payload = read_frame(&mut stream)
            .with_context(|| format!("reading hello reply from backend {addr}"))?;
        let advertised = match decode_server_msg(&payload)
            .with_context(|| format!("decoding hello reply from backend {addr}"))?
        {
            ServerMsg::Hello { version, sessions } => {
                if version != WIRE_VERSION {
                    bail!(
                        "backend {addr} speaks wire version {version}, \
                         this front speaks {WIRE_VERSION}"
                    );
                }
                sessions
            }
            other => bail!("expected a hello from backend {addr}, got {other:?}"),
        };
        let reader = stream
            .try_clone()
            .with_context(|| format!("cloning backend {addr} stream"))?;
        let report = StatsReport {
            backend: addr.to_string(),
            up: true,
            sessions: advertised.len() as u64,
            admission: Default::default(),
        };
        let state = Arc::new(Mutex::new(LinkState {
            writer: Some(stream),
            next_id: 0,
            pending: BTreeMap::new(),
            down: None,
            advertised,
            report,
            unanswered_probes: 0,
        }));
        let thread_state = Arc::clone(&state);
        let thread_addr = addr.to_string();
        // reply router: detached; exits when the link goes down (it owns
        // marking it down on read errors, so it never outlives the socket)
        if let Err(e) = std::thread::Builder::new()
            .name("dpp-front-link".to_string())
            .spawn(move || reply_loop(reader, thread_addr, thread_state))
        {
            bail!("spawning reply thread for backend {addr}: {e}");
        }
        Ok(BackendLink { addr: addr.to_string(), state })
    }

    /// Backend address (placement hashes on it).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// True until the link is marked down.
    pub fn is_up(&self) -> bool {
        self.lock().down.is_none()
    }

    /// Did the backend advertise `session` in its hello?
    pub fn advertises(&self, session: &str) -> bool {
        self.lock().advertised.iter().any(|s| s == session)
    }

    /// Session names from the backend's hello (connect-time snapshot).
    pub fn advertised(&self) -> Vec<String> {
        self.lock().advertised.clone()
    }

    /// Load for the placement bias: the probed live-session count.
    pub fn session_load(&self) -> u64 {
        self.lock().report.sessions
    }

    /// Current load/health row (the `up` flag reflects down-marking).
    pub fn report(&self) -> StatsReport {
        self.lock().report.clone()
    }

    /// Forward one request, returning the slot its reply will arrive on.
    /// The frame is written under the link lock, so concurrent client
    /// connections serialize here and per-session FIFO order is the
    /// front's arrival order.
    pub fn forward(
        &self,
        session: &str,
        request: &Request,
    ) -> Result<Receiver<Response>, RequestError> {
        let mut st = self.lock();
        if let Some(reason) = &st.down {
            return Err(self.closed(session, reason));
        }
        let id = st.next_id;
        st.next_id += 1;
        let msg = encode_client_msg(&ClientMsg::Submit {
            id,
            session: session.to_string(),
            request: request.clone(),
        });
        let Some(writer) = st.writer.as_mut() else {
            return Err(self.closed(session, "connection closed"));
        };
        if let Err(e) = write_frame(writer, &msg) {
            drop(st);
            let reason = format!("write failed: {e}");
            self.mark_down(&reason);
            return Err(self.closed(session, &reason));
        }
        let (tx, rx) = channel();
        st.pending.insert(id, (session.to_string(), tx));
        Ok(rx)
    }

    /// Send one health/load probe. A backend that has not answered
    /// `unanswered_down` earlier probes — or whose socket rejects the
    /// write — is marked down.
    pub fn probe(&self, unanswered_down: u32) {
        let mut st = self.lock();
        if st.down.is_some() {
            return;
        }
        if st.unanswered_probes >= unanswered_down {
            let n = st.unanswered_probes;
            drop(st);
            self.mark_down(&format!("{n} unanswered health probes"));
            return;
        }
        st.unanswered_probes += 1;
        let msg = encode_client_msg(&ClientMsg::Stats);
        let Some(writer) = st.writer.as_mut() else {
            return;
        };
        if let Err(e) = write_frame(writer, &msg) {
            drop(st);
            self.mark_down(&format!("probe write failed: {e}"));
        }
    }

    /// Mark the link down: fail all in-flight requests with a typed
    /// `SessionClosed` naming this backend, close the socket so the reply
    /// thread exits, and flip the report's `up` flag. Idempotent.
    pub fn mark_down(&self, why: &str) {
        mark_down(&self.state, &self.addr, why);
    }

    fn closed(&self, session: &str, reason: &str) -> RequestError {
        RequestError::SessionClosed {
            session: session.to_string(),
            reason: format!("backend {} down: {reason}", self.addr),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, LinkState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

fn mark_down(state: &Arc<Mutex<LinkState>>, addr: &str, why: &str) {
    let mut st = state.lock().unwrap_or_else(|e| e.into_inner());
    if st.down.is_some() {
        return;
    }
    st.down = Some(why.to_string());
    st.report.up = false;
    if let Some(writer) = st.writer.take() {
        let _ = writer.shutdown(Shutdown::Both);
    }
    let pending = std::mem::take(&mut st.pending);
    drop(st);
    for (_, (session, tx)) in pending {
        let _ = tx.send(Response::Error(RequestError::SessionClosed {
            session,
            reason: format!("backend {addr} down: {why}"),
        }));
    }
}

/// Per-link reply router: `Reply` frames complete pending forwards in
/// order; `Stats` frames refresh the load view. Any read or protocol
/// error takes the link down with a typed reason.
fn reply_loop(mut reader: TcpStream, addr: String, state: Arc<Mutex<LinkState>>) {
    loop {
        let payload = match read_frame(&mut reader) {
            Ok(p) => p,
            Err(e) => {
                mark_down(&state, &addr, &format!("read failed: {e}"));
                return;
            }
        };
        match decode_server_msg(&payload) {
            Ok(ServerMsg::Reply { id, response }) => {
                let slot = {
                    let mut st = state.lock().unwrap_or_else(|e| e.into_inner());
                    st.pending.remove(&id)
                };
                if let Some((_, tx)) = slot {
                    let _ = tx.send(response);
                }
            }
            Ok(ServerMsg::Stats { backends }) => {
                let mut st = state.lock().unwrap_or_else(|e| e.into_inner());
                st.unanswered_probes = 0;
                // a backend reports one row about itself
                if let Some(row) = backends.into_iter().next() {
                    st.report.sessions = row.sessions;
                    st.report.admission = row.admission;
                }
            }
            Ok(ServerMsg::ShuttingDown) => {
                mark_down(&state, &addr, "backend shutting down");
                return;
            }
            Ok(ServerMsg::Hello { .. }) => {
                mark_down(&state, &addr, "unexpected mid-stream hello");
                return;
            }
            Err(e) => {
                mark_down(&state, &addr, &format!("undecodable reply: {e}"));
                return;
            }
        }
    }
}
