//! Deterministic session placement — rendezvous hashing with a load bias
//! (DESIGN.md §4c).
//!
//! Every (backend, session) pair gets a pseudo-random score from FNV-1a
//! over `addr \0 session`; the session goes to the highest *biased* score,
//! where the bias divides the raw score by `1 + load`. The hash makes
//! placement independent of backend list order and of every other session;
//! the integer division makes a backend's win probability shrink roughly
//! as `1/(1 + load)` without any floating point or RNG — the whole rule is
//! a pure function of (session name, candidate list), so two fronts with
//! the same load view place identically, and replacing a candidate only
//! ever moves the sessions that candidate had won (minimal disruption).
//!
//! Placement runs once per session: the front pins the winner in its
//! routing table and never silently re-homes a stateful session (a dead
//! backend surfaces as a typed error instead — see [`super::Front`]).

/// One placement candidate: a backend address plus its current load
/// (live session count from the probe-refreshed view, plus sessions this
/// front has already placed there between probes).
#[derive(Debug, Clone)]
pub struct Candidate<'a> {
    /// Backend address — the stable identity hashed against the session.
    pub addr: &'a str,
    /// Current load; higher load shrinks the candidate's win probability.
    pub load: u64,
}

/// FNV-1a 64-bit over `addr \0 session` — the raw rendezvous score.
pub fn score(addr: &str, session: &str) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in addr.as_bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(PRIME);
    }
    // separator byte: "ab"+"c" must not collide with "a"+"bc"
    h = h.wrapping_mul(PRIME);
    for &b in session.as_bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(PRIME);
    }
    h
}

/// Pick the winning candidate for `session`: highest load-biased score,
/// first index winning ties. Returns an index into `candidates`, or
/// `None` when the list is empty.
pub fn pick(session: &str, candidates: &[Candidate<'_>]) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (i, c) in candidates.iter().enumerate() {
        let biased = score(c.addr, session) / (1 + c.load);
        match best {
            Some((_, b)) if b >= biased => {}
            _ => best = Some((i, biased)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs() -> Vec<String> {
        (0..4).map(|i| format!("10.0.0.{i}:7700")).collect()
    }

    fn even(addrs: &[String]) -> Vec<Candidate<'_>> {
        addrs.iter().map(|a| Candidate { addr: a, load: 0 }).collect()
    }

    #[test]
    fn placement_is_deterministic_and_order_independent() {
        let addrs = addrs();
        let cands = even(&addrs);
        let mut reversed: Vec<Candidate<'_>> = cands.clone();
        reversed.reverse();
        for s in 0..100 {
            let session = format!("tenant-{s}");
            let a = pick(&session, &cands).unwrap();
            let b = pick(&session, &reversed).unwrap();
            assert_eq!(cands[a].addr, reversed[b].addr, "{session}");
        }
    }

    #[test]
    fn every_backend_wins_some_sessions() {
        let addrs = addrs();
        let cands = even(&addrs);
        let mut hits = vec![0usize; cands.len()];
        for s in 0..200 {
            hits[pick(&format!("s{s}"), &cands).unwrap()] += 1;
        }
        for (i, &h) in hits.iter().enumerate() {
            assert!(h > 0, "backend {i} never chosen: {hits:?}");
        }
    }

    #[test]
    fn removing_a_loser_does_not_move_a_winner() {
        // rendezvous minimal disruption: a session placed on A among
        // {A,B,C,D} stays on A in any subset that still contains A.
        let addrs = addrs();
        let cands = even(&addrs);
        for s in 0..100 {
            let session = format!("s{s}");
            let winner = cands[pick(&session, &cands).unwrap()].addr;
            for drop_idx in 0..cands.len() {
                if cands[drop_idx].addr == winner {
                    continue;
                }
                let subset: Vec<Candidate<'_>> = cands
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| *i != drop_idx)
                    .map(|(_, c)| c.clone())
                    .collect();
                let now = subset[pick(&session, &subset).unwrap()].addr;
                assert_eq!(now, winner, "{session} moved when a loser left");
            }
        }
    }

    #[test]
    fn load_bias_sheds_new_sessions_off_a_loaded_backend() {
        let addrs = addrs();
        let balanced = even(&addrs);
        let mut skewed = even(&addrs);
        skewed[0].load = 50;
        let (mut before, mut after) = (0usize, 0usize);
        for s in 0..300 {
            let session = format!("s{s}");
            if balanced[pick(&session, &balanced).unwrap()].addr == addrs[0] {
                before += 1;
            }
            if skewed[pick(&session, &skewed).unwrap()].addr == addrs[0] {
                after += 1;
            }
        }
        assert!(before > 0);
        // with a 1/(1+50) bias the loaded backend should win almost nothing
        assert!(
            after * 10 < before,
            "load bias too weak: {after} wins vs {before} unbiased"
        );
    }

    #[test]
    fn empty_candidate_list_yields_none() {
        assert_eq!(pick("s0", &[]), None);
    }
}
