//! Distributed row-range shards: a shard node that hosts one
//! [`ShardBackend`] behind a socket, and the [`RemoteShard`] client that
//! implements the same per-shard sweep interface over the connection
//! (DESIGN.md §4b).
//!
//! ## The reduce contract, over a network
//!
//! The sharded backend's bit-exactness rests on one invariant: each
//! column's dot-product accumulator folds through shard 0's rows, then
//! shard 1's, … entry by entry, exactly as one flat CSC sweep would
//! (DESIGN.md §2). The RPC grammar preserves that *by construction*: a
//! [`ShardRequest::FoldDot`] carries the columns' *running* accumulators to
//! the node, the node continues each fold over its local rows with the
//! identical `s += w[i]·v` sequence, and returns the updated accumulators
//! for the next shard in order. Scatter/gather changes where the flops
//! run, never their order — keep-sets and CD trajectories are bit-identical
//! to local execution, and only `w` slices, accumulators and requested
//! sparse columns cross the wire. The design matrix never leaves its node.
//!
//! ## Failure surface
//!
//! A lost node maps to a line-actionable `anyhow` error naming the address
//! (and, mid-sweep, to a session-closing panic the coordinator catches and
//! reports as `RequestError::SessionClosed` — never a hang). On the node,
//! each request is answered under `catch_unwind`, so a poisoned request
//! (column out of range, length mismatch) produces a [`ShardReply::Error`]
//! instead of killing the node.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::frame::{read_frame, write_frame, FrameError};
use super::wire::{Dec, Enc, WireError};
use crate::linalg::ShardBackend;
use crate::runtime::pool::panic_message;

/// Version of the shard RPC grammar (negotiated via the hellos).
pub const SHARD_WIRE_VERSION: u32 = 1;

/// Poll interval for the node's non-blocking accept loop.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Coordinator → shard node RPCs.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum ShardRequest {
    /// Open the conversation; the node answers with its shard's shape.
    Hello { version: u32 },
    /// Continue `accs[k] += Σᵢ w_local[i]·x[i, cols[k]]` over the node's
    /// rows, entry by entry from the carried-in running accumulators.
    FoldDot { cols: Vec<usize>, w_local: Vec<f64>, accs: Vec<f64> },
    /// Continue `accs[k] += Σᵢ x[i, cols[k]]²` likewise.
    FoldSqNorm { cols: Vec<usize>, accs: Vec<f64> },
    /// Ship column j's local sparse entries (row order).
    Col { j: usize },
    /// Stop the node after replying.
    Shutdown,
}

/// Shard node → coordinator replies.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum ShardReply {
    Hello { version: u32, n_rows: usize, n_cols: usize, nnz: usize, f32_values: bool },
    /// Updated accumulators, same order as the request's `cols`.
    Accs(Vec<f64>),
    /// One sparse column slice: local row indices + values, in row order.
    Col { idx: Vec<u32>, vals: Vec<f64> },
    ShuttingDown,
    /// The request failed on the node (caught panic or validation).
    Error(String),
}

fn encode_request(r: &ShardRequest) -> Vec<u8> {
    let mut e = Enc::new();
    match r {
        ShardRequest::Hello { version } => {
            e.u8(0);
            e.u32(*version);
        }
        ShardRequest::FoldDot { cols, w_local, accs } => {
            e.u8(1);
            e.usizes(cols);
            e.f64s(w_local);
            e.f64s(accs);
        }
        ShardRequest::FoldSqNorm { cols, accs } => {
            e.u8(2);
            e.usizes(cols);
            e.f64s(accs);
        }
        ShardRequest::Col { j } => {
            e.u8(3);
            e.usize(*j);
        }
        ShardRequest::Shutdown => e.u8(4),
    }
    e.0
}

fn decode_request(buf: &[u8]) -> std::result::Result<ShardRequest, WireError> {
    let mut d = Dec::new(buf);
    let r = match d.u8()? {
        0 => ShardRequest::Hello { version: d.u32()? },
        1 => ShardRequest::FoldDot {
            cols: d.usizes()?,
            w_local: d.f64s()?,
            accs: d.f64s()?,
        },
        2 => ShardRequest::FoldSqNorm { cols: d.usizes()?, accs: d.f64s()? },
        3 => ShardRequest::Col { j: d.usize()? },
        4 => ShardRequest::Shutdown,
        t => return Err(WireError(format!("bad ShardRequest tag {t}"))),
    };
    d.finish()?;
    Ok(r)
}

fn encode_reply(r: &ShardReply) -> Vec<u8> {
    let mut e = Enc::new();
    match r {
        ShardReply::Hello { version, n_rows, n_cols, nnz, f32_values } => {
            e.u8(0);
            e.u32(*version);
            e.usize(*n_rows);
            e.usize(*n_cols);
            e.usize(*nnz);
            e.bool(*f32_values);
        }
        ShardReply::Accs(a) => {
            e.u8(1);
            e.f64s(a);
        }
        ShardReply::Col { idx, vals } => {
            e.u8(2);
            e.u32s(idx);
            e.f64s(vals);
        }
        ShardReply::ShuttingDown => e.u8(3),
        ShardReply::Error(msg) => {
            e.u8(4);
            e.str(msg);
        }
    }
    e.0
}

fn decode_reply(buf: &[u8]) -> std::result::Result<ShardReply, WireError> {
    let mut d = Dec::new(buf);
    let r = match d.u8()? {
        0 => ShardReply::Hello {
            version: d.u32()?,
            n_rows: d.usize()?,
            n_cols: d.usize()?,
            nnz: d.usize()?,
            f32_values: d.bool()?,
        },
        1 => ShardReply::Accs(d.f64s()?),
        2 => ShardReply::Col { idx: d.u32s()?, vals: d.f64s()? },
        3 => ShardReply::ShuttingDown,
        4 => ShardReply::Error(d.str()?),
        t => return Err(WireError(format!("bad ShardReply tag {t}"))),
    };
    d.finish()?;
    Ok(r)
}

// ---------------------------------------------------------------------------
// node (server) side

/// Handle to a running shard node (accept loop on its own thread).
pub struct ShardNodeHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: JoinHandle<()>,
}

impl ShardNodeHandle {
    /// Bound listen address (resolves `:0` to the assigned port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the accept loop to exit at its next poll.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Block until the accept loop exits (it does once stopped — via
    /// [`ShardNodeHandle::stop`] or a client's `Shutdown`).
    pub fn join(self) {
        let _ = self.handle.join();
    }
}

/// Serve one [`ShardBackend`] on `listen`. Each accepted connection gets
/// its own handler thread; the accept loop polls non-blocking so a
/// `Shutdown` (or [`ShardNodeHandle::stop`]) takes effect promptly.
pub fn spawn_shard_node(backend: ShardBackend, listen: &str) -> Result<ShardNodeHandle> {
    let listener = TcpListener::bind(listen)
        .with_context(|| format!("shard node: binding {listen}"))?;
    let addr = listener.local_addr().context("shard node: local_addr")?;
    listener.set_nonblocking(true).context("shard node: set_nonblocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_loop = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name("dpp-shard-node".to_string())
        .spawn(move || loop {
            if stop_loop.load(Ordering::SeqCst) {
                return;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let backend = backend.clone();
                    let stop_conn = Arc::clone(&stop_loop);
                    let _ = std::thread::Builder::new()
                        .name("dpp-shard-conn".to_string())
                        .spawn(move || serve_connection(stream, &backend, &stop_conn));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => std::thread::sleep(ACCEPT_POLL),
            }
        })
        .context("shard node: spawning accept thread")?;
    Ok(ShardNodeHandle { addr, stop, handle })
}

fn serve_connection(mut stream: TcpStream, backend: &ShardBackend, stop: &AtomicBool) {
    let _ = stream.set_nodelay(true);
    // The conversation must open with a Hello; anything else (or a version
    // we don't speak) closes the connection after an Error reply.
    match read_frame(&mut stream).map(|buf| decode_request(&buf)) {
        Ok(Ok(ShardRequest::Hello { version })) if version == SHARD_WIRE_VERSION => {
            let hello = ShardReply::Hello {
                version: SHARD_WIRE_VERSION,
                n_rows: backend.n_rows(),
                n_cols: backend.n_cols(),
                nnz: backend.nnz(),
                f32_values: backend.is_f32(),
            };
            if write_frame(&mut stream, &encode_reply(&hello)).is_err() {
                return;
            }
        }
        Ok(Ok(ShardRequest::Hello { version })) => {
            let msg = format!(
                "shard wire version mismatch: node speaks {SHARD_WIRE_VERSION}, \
                 client sent {version}"
            );
            let _ = write_frame(&mut stream, &encode_reply(&ShardReply::Error(msg)));
            return;
        }
        _ => return,
    }
    loop {
        let req = match read_frame(&mut stream) {
            Ok(buf) => match decode_request(&buf) {
                Ok(r) => r,
                Err(e) => {
                    let _ = write_frame(
                        &mut stream,
                        &encode_reply(&ShardReply::Error(e.to_string())),
                    );
                    return;
                }
            },
            // Closed / Truncated / Io: the peer is gone, nothing to answer.
            Err(_) => return,
        };
        if let ShardRequest::Shutdown = req {
            let _ = write_frame(&mut stream, &encode_reply(&ShardReply::ShuttingDown));
            stop.store(true, Ordering::SeqCst);
            return;
        }
        // A bad request (column out of range, mismatched lengths) must not
        // kill the node — catch the panic and answer with a typed error.
        let reply = match catch_unwind(AssertUnwindSafe(|| serve_one(backend, req))) {
            Ok(reply) => reply,
            Err(p) => ShardReply::Error(format!("shard request panicked: {}", panic_message(p))),
        };
        if write_frame(&mut stream, &encode_reply(&reply)).is_err() {
            return;
        }
    }
}

fn serve_one(backend: &ShardBackend, req: ShardRequest) -> ShardReply {
    match req {
        ShardRequest::FoldDot { cols, w_local, mut accs } => {
            if cols.len() != accs.len() {
                return ShardReply::Error(format!(
                    "FoldDot: {} cols but {} accumulators",
                    cols.len(),
                    accs.len()
                ));
            }
            if w_local.len() != backend.n_rows() {
                return ShardReply::Error(format!(
                    "FoldDot: w has {} rows, shard has {}",
                    w_local.len(),
                    backend.n_rows()
                ));
            }
            for (k, &j) in cols.iter().enumerate() {
                backend.fold_col_dot(j, &w_local, &mut accs[k]);
            }
            ShardReply::Accs(accs)
        }
        ShardRequest::FoldSqNorm { cols, mut accs } => {
            if cols.len() != accs.len() {
                return ShardReply::Error(format!(
                    "FoldSqNorm: {} cols but {} accumulators",
                    cols.len(),
                    accs.len()
                ));
            }
            for (k, &j) in cols.iter().enumerate() {
                backend.fold_col_sq_norm(j, &mut accs[k]);
            }
            ShardReply::Accs(accs)
        }
        ShardRequest::Col { j } => {
            let mut idx = Vec::new();
            let mut vals = Vec::new();
            backend.for_col_entries(j, |i, v| {
                idx.push(i);
                vals.push(v);
            });
            ShardReply::Col { idx, vals }
        }
        ShardRequest::Hello { .. } | ShardRequest::Shutdown => {
            ShardReply::Error("unexpected control message mid-stream".to_string())
        }
    }
}

/// Connect to a node and ask it to shut down (CLI teardown path).
pub fn stop_shard_node(addr: &str) -> Result<()> {
    let shard = RemoteShard::connect(addr)?;
    match shard.rpc(&ShardRequest::Shutdown)? {
        ShardReply::ShuttingDown => Ok(()),
        other => bail!("shard node {addr}: unexpected shutdown reply {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// client side

/// A row-range shard living in another process, speaking the fold RPCs
/// above. Implements the same per-shard sweep interface as a local
/// [`ShardBackend`], with the identical reduce order.
pub struct RemoteShard {
    addr: String,
    conn: Arc<Mutex<TcpStream>>,
    n_rows: usize,
    n_cols: usize,
    nnz: usize,
    f32_values: bool,
}

impl Clone for RemoteShard {
    /// Clones share the connection (strict request→reply under a mutex);
    /// parallel sweep workers get independent sockets via
    /// [`RemoteShard::reconnect`] instead.
    fn clone(&self) -> RemoteShard {
        RemoteShard {
            addr: self.addr.clone(),
            conn: Arc::clone(&self.conn),
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            nnz: self.nnz,
            f32_values: self.f32_values,
        }
    }
}

impl std::fmt::Debug for RemoteShard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RemoteShard")
            .field("addr", &self.addr)
            .field("n_rows", &self.n_rows)
            .field("n_cols", &self.n_cols)
            .field("nnz", &self.nnz)
            .finish()
    }
}

impl PartialEq for RemoteShard {
    fn eq(&self, other: &Self) -> bool {
        self.addr == other.addr
            && self.n_rows == other.n_rows
            && self.n_cols == other.n_cols
            && self.nnz == other.nnz
    }
}

impl RemoteShard {
    /// Dial a shard node, negotiate versions, and cache its shape.
    pub fn connect(addr: &str) -> Result<RemoteShard> {
        let stream = TcpStream::connect(addr).with_context(|| {
            format!(
                "connecting to shard node {addr} — is `dpp shard-node --listen {addr}` \
                 running?"
            )
        })?;
        stream.set_nodelay(true).ok();
        let mut shard = RemoteShard {
            addr: addr.to_string(),
            conn: Arc::new(Mutex::new(stream)),
            n_rows: 0,
            n_cols: 0,
            nnz: 0,
            f32_values: false,
        };
        match shard.rpc(&ShardRequest::Hello { version: SHARD_WIRE_VERSION })? {
            ShardReply::Hello { version, n_rows, n_cols, nnz, f32_values } => {
                if version != SHARD_WIRE_VERSION {
                    bail!(
                        "shard node {addr} speaks wire version {version}, \
                         this build speaks {SHARD_WIRE_VERSION}"
                    );
                }
                shard.n_rows = n_rows;
                shard.n_cols = n_cols;
                shard.nnz = nnz;
                shard.f32_values = f32_values;
                Ok(shard)
            }
            other => bail!("shard node {addr}: unexpected hello reply {other:?}"),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }
    pub fn nnz(&self) -> usize {
        self.nnz
    }
    pub fn is_f32(&self) -> bool {
        self.f32_values
    }

    /// A fresh connection to the same node (used for per-worker private
    /// sweep handles). `None` degrades the worker to the shared mutexed
    /// connection — slower, never wrong.
    pub fn reconnect(&self) -> Option<RemoteShard> {
        RemoteShard::connect(&self.addr).ok()
    }

    /// One strict request→reply exchange. Every failure names the node and
    /// what to check — a lost node must be line-actionable, not a mystery
    /// hang.
    fn rpc(&self, req: &ShardRequest) -> Result<ShardReply> {
        let mut conn = self.conn.lock().unwrap_or_else(|e| e.into_inner());
        let addr = &self.addr;
        write_frame(&mut *conn, &encode_request(req)).map_err(|e| self.lost(e))?;
        let buf = read_frame(&mut *conn).map_err(|e| self.lost(e))?;
        drop(conn);
        let reply = decode_reply(&buf)
            .with_context(|| format!("shard node {addr}: undecodable reply"))?;
        if let ShardReply::Error(msg) = reply {
            bail!("shard node {addr} rejected a request: {msg}");
        }
        Ok(reply)
    }

    fn lost(&self, e: FrameError) -> anyhow::Error {
        anyhow::anyhow!(
            "lost shard node {} ({e}) — restart it with `dpp shard-node --listen {}` \
             and re-register the session",
            self.addr,
            self.addr
        )
    }

    /// Continue the columns' running dot-product accumulators over this
    /// node's rows (one RPC for the whole column block).
    pub(crate) fn fold_cols_dot(
        &self,
        cols: &[usize],
        w_local: &[f64],
        accs: &mut [f64],
    ) -> Result<()> {
        let req = ShardRequest::FoldDot {
            cols: cols.to_vec(),
            w_local: w_local.to_vec(),
            accs: accs.to_vec(),
        };
        match self.rpc(&req)? {
            ShardReply::Accs(a) if a.len() == accs.len() => {
                accs.copy_from_slice(&a);
                Ok(())
            }
            other => bail!("shard node {}: bad FoldDot reply {other:?}", self.addr),
        }
    }

    /// Continue the columns' running squared-norm accumulators likewise.
    pub(crate) fn fold_cols_sq_norm(&self, cols: &[usize], accs: &mut [f64]) -> Result<()> {
        let req = ShardRequest::FoldSqNorm { cols: cols.to_vec(), accs: accs.to_vec() };
        match self.rpc(&req)? {
            ShardReply::Accs(a) if a.len() == accs.len() => {
                accs.copy_from_slice(&a);
                Ok(())
            }
            other => bail!("shard node {}: bad FoldSqNorm reply {other:?}", self.addr),
        }
    }

    /// Fetch column j's local sparse entries (row order) — the basis for
    /// the coordinator-side replicas of axpy/densify/gather/Gram, which
    /// re-run the exact CSC flop sequences on the fetched slice.
    pub(crate) fn fetch_col(&self, j: usize) -> Result<(Vec<u32>, Vec<f64>)> {
        match self.rpc(&ShardRequest::Col { j })? {
            ShardReply::Col { idx, vals } if idx.len() == vals.len() => Ok((idx, vals)),
            other => bail!("shard node {}: bad Col reply {other:?}", self.addr),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CscMatrix, DenseMatrix, DesignMatrix, ShardSetMatrix};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_csc(rng: &mut Rng, n: usize, p: usize) -> CscMatrix {
        let mut x = DenseMatrix::zeros(n, p);
        for j in 0..p {
            for v in x.col_mut(j).iter_mut() {
                if rng.f64() < 0.3 {
                    *v = rng.normal();
                }
            }
        }
        CscMatrix::from_dense(&x)
    }

    #[test]
    fn shard_messages_round_trip() {
        let reqs = [
            ShardRequest::Hello { version: SHARD_WIRE_VERSION },
            ShardRequest::FoldDot {
                cols: vec![0, 3, 7],
                w_local: vec![0.5, -1.0],
                accs: vec![1.0, 2.0, 3.0],
            },
            ShardRequest::FoldSqNorm { cols: vec![2], accs: vec![0.25] },
            ShardRequest::Col { j: 11 },
            ShardRequest::Shutdown,
        ];
        for r in &reqs {
            assert_eq!(&decode_request(&encode_request(r)).unwrap(), r);
        }
        let replies = [
            ShardReply::Hello {
                version: 1,
                n_rows: 10,
                n_cols: 20,
                nnz: 55,
                f32_values: true,
            },
            ShardReply::Accs(vec![1.5, -2.5]),
            ShardReply::Col { idx: vec![0, 4, 9], vals: vec![1.0, -1.0, 0.5] },
            ShardReply::ShuttingDown,
            ShardReply::Error("boom".to_string()),
        ];
        for r in &replies {
            assert_eq!(&decode_reply(&encode_reply(r)).unwrap(), r);
        }
        assert!(decode_request(&[77]).is_err());
        assert!(decode_reply(&[77]).is_err());
    }

    /// The ISSUE's core claim, at the shard level: a `ShardSetMatrix` of
    /// `RemoteShard`s is **bit-identical** to the same matrix sharded
    /// locally, across the whole `DesignMatrix` contract.
    #[test]
    fn remote_shards_match_local_bitwise_on_all_ops() {
        prop::check("remote-bitwise", 0x5EA7, 4, |rng| {
            let n = 8 + rng.usize(10);
            let p = 6 + rng.usize(10);
            let csc = random_csc(rng, n, p);
            let local = ShardSetMatrix::split_csc(&csc, 2);

            let mut nodes = Vec::new();
            let mut addrs = Vec::new();
            for shard in local.shards() {
                let node =
                    spawn_shard_node(shard.backend().clone(), "127.0.0.1:0").unwrap();
                addrs.push(node.addr().to_string());
                nodes.push(node);
            }
            let remote = ShardSetMatrix::connect(&addrs).unwrap();
            assert_eq!(remote.n_rows(), n);
            assert_eq!(remote.n_cols(), p);
            assert_eq!(remote.nnz(), csc.nnz());

            let mut w = vec![0.0; n];
            rng.fill_normal(&mut w);

            let (mut a, mut b) = (vec![0.0; p], vec![0.0; p]);
            local.xt_w(&w, &mut a);
            remote.xt_w(&w, &mut b);
            assert_eq!(a, b, "xt_w diverged");

            local.col_norms(&mut a);
            remote.col_norms(&mut b);
            assert_eq!(a, b, "col_norms diverged");

            let cols: Vec<usize> = (0..p).step_by(2).collect();
            let (mut sa, mut sb) = (vec![0.0; cols.len()], vec![0.0; cols.len()]);
            local.xt_w_subset(&cols, &w, &mut sa);
            remote.xt_w_subset(&cols, &w, &mut sb);
            assert_eq!(sa, sb, "xt_w_subset diverged");

            for j in [0, p / 2, p - 1] {
                assert_eq!(
                    local.col_dot_w(j, &w).to_bits(),
                    remote.col_dot_w(j, &w).to_bits(),
                    "col_dot_w({j}) diverged"
                );
                assert_eq!(
                    local.col_sq_norm(j).to_bits(),
                    remote.col_sq_norm(j).to_bits(),
                    "col_sq_norm({j}) diverged"
                );
                assert_eq!(
                    local.col_dot_col(0, j).to_bits(),
                    remote.col_dot_col(0, j).to_bits(),
                    "col_dot_col(0,{j}) diverged"
                );
                let (mut ca, mut cb) = (vec![0.0; n], vec![0.0; n]);
                local.col_into(j, &mut ca);
                remote.col_into(j, &mut cb);
                assert_eq!(ca, cb, "col_into({j}) diverged");
                let (mut xa, mut xb) = (w.clone(), w.clone());
                local.col_axpy_into(j, 0.75, &mut xa);
                remote.col_axpy_into(j, 0.75, &mut xb);
                assert_eq!(xa, xb, "col_axpy_into({j}) diverged");
            }

            let rows: Vec<usize> = (0..n).step_by(3).collect();
            let (mut ga, mut gb) = (vec![0.0; rows.len()], vec![0.0; rows.len()]);
            local.col_gather(1, &rows, &mut ga);
            remote.col_gather(1, &rows, &mut gb);
            assert_eq!(ga, gb, "col_gather diverged");

            let mut beta = vec![0.0; p];
            rng.fill_normal(&mut beta);
            beta[rng.usize(p)] = 0.0;
            let (mut ya, mut yb) = (vec![0.0; n], vec![0.0; n]);
            local.gemv(&beta, &mut ya);
            remote.gemv(&beta, &mut yb);
            assert_eq!(ya, yb, "gemv diverged");

            for node in &nodes {
                node.stop();
            }
            for node in nodes {
                node.join();
            }
        });
    }

    #[test]
    fn lost_node_is_a_line_actionable_error() {
        // nothing listening here
        let err = RemoteShard::connect("127.0.0.1:1").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("127.0.0.1:1"), "{msg}");
        assert!(msg.contains("dpp shard-node"), "{msg}");

        // a node that dies mid-conversation surfaces the address too
        let rng = &mut Rng::new(0xDEAD);
        let csc = random_csc(rng, 6, 4);
        let node = spawn_shard_node(ShardBackend::Csc(csc), "127.0.0.1:0").unwrap();
        let addr = node.addr().to_string();
        let shard = RemoteShard::connect(&addr).unwrap();
        node.stop();
        node.join();
        // Existing connections were accepted by handler threads that only
        // exit when their socket closes; kill the stream from our side so
        // the next rpc fails deterministically.
        {
            let conn = shard.conn.lock().unwrap();
            conn.shutdown(std::net::Shutdown::Both).unwrap();
        }
        let err = shard.fetch_col(0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(&addr), "{msg}");
        assert!(msg.contains("restart it"), "{msg}");
    }

    #[test]
    fn node_survives_bad_requests_and_stops_on_shutdown() {
        let rng = &mut Rng::new(0xBEEF);
        let csc = random_csc(rng, 6, 4);
        let node = spawn_shard_node(ShardBackend::Csc(csc), "127.0.0.1:0").unwrap();
        let addr = node.addr().to_string();
        let shard = RemoteShard::connect(&addr).unwrap();

        // out-of-range column → typed error, connection stays usable
        let err = shard.fetch_col(99).unwrap_err();
        assert!(format!("{err:#}").contains("rejected"), "{err:#}");
        let (idx, vals) = shard.fetch_col(0).unwrap();
        assert_eq!(idx.len(), vals.len());

        // mismatched fold lengths → typed error, not a node crash
        let err = shard.fold_cols_dot(&[0, 1], &[0.0; 6], &mut [0.0]).unwrap_err();
        assert!(format!("{err:#}").contains("accumulators"), "{err:#}");

        stop_shard_node(&addr).unwrap();
        node.join();
        assert!(RemoteShard::connect(&addr).is_err());
    }
}
