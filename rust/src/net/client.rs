//! Thin blocking client for the framed serving protocol (DESIGN.md §4b.3).
//!
//! [`NetClient::connect`] performs the hello handshake (version check +
//! session discovery), then [`NetClient::request`] round-trips one typed
//! [`Request`] per call. Pipelining callers use [`NetClient::submit`] /
//! [`NetClient::recv_reply`] directly: submissions are answered in order,
//! with ids to prove it. Transport failures mid-request surface as
//! [`RequestError::Disconnected`] — the same typed error an in-process
//! caller sees when the coordinator goes away, so callers handle a dead
//! socket and a dead router identically.

use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::frame::{read_frame, write_frame};
use super::wire::{
    decode_server_msg, encode_client_msg, ClientMsg, ServerMsg, StatsReport, WIRE_VERSION,
};
use crate::coordinator::{Request, RequestError, Response};

/// A connected client: one TCP stream, monotonically increasing request
/// ids, and the session names the server advertised in its hello.
pub struct NetClient {
    stream: TcpStream,
    next_id: u64,
    server_sessions: Vec<String>,
}

impl NetClient {
    /// Connect and shake hands. Fails with an actionable error when nobody
    /// listens at `addr` or the server speaks a different wire version.
    pub fn connect(addr: &str) -> Result<NetClient> {
        let mut stream = TcpStream::connect(addr).with_context(|| {
            format!(
                "connecting to dpp server at {addr} — is `dpp serve --listen {addr}` running?"
            )
        })?;
        let hello = encode_client_msg(&ClientMsg::Hello { version: WIRE_VERSION });
        write_frame(&mut stream, &hello)
            .with_context(|| format!("sending hello to {addr}"))?;
        let payload = read_frame(&mut stream)
            .with_context(|| format!("reading hello reply from {addr}"))?;
        let msg = decode_server_msg(&payload)
            .with_context(|| format!("decoding hello reply from {addr}"))?;
        match msg {
            ServerMsg::Hello { version, sessions } => {
                if version != WIRE_VERSION {
                    bail!(
                        "server at {addr} speaks wire version {version}, \
                         this client speaks {WIRE_VERSION}"
                    );
                }
                Ok(NetClient { stream, next_id: 0, server_sessions: sessions })
            }
            other => bail!("expected a hello from {addr}, got {other:?}"),
        }
    }

    /// Session names the server advertised at connect time.
    pub fn sessions(&self) -> &[String] {
        &self.server_sessions
    }

    /// Send one request without waiting (pipelining). Returns the id the
    /// server will echo in the matching [`Response`].
    pub fn submit(&mut self, session: &str, request: Request) -> Result<u64, RequestError> {
        let id = self.next_id;
        self.next_id += 1;
        let msg = encode_client_msg(&ClientMsg::Submit {
            id,
            session: session.to_string(),
            request,
        });
        write_frame(&mut self.stream, &msg)
            .map_err(|e| disconnected(format!("sending request: {e}")))?;
        Ok(id)
    }

    /// Block for the next reply, in submission order.
    pub fn recv_reply(&mut self) -> Result<(u64, Response), RequestError> {
        let payload = read_frame(&mut self.stream)
            .map_err(|e| disconnected(format!("reading reply: {e}")))?;
        match decode_server_msg(&payload) {
            Ok(ServerMsg::Reply { id, response }) => Ok((id, response)),
            Ok(ServerMsg::ShuttingDown) => {
                Err(disconnected("server is shutting down".to_string()))
            }
            Ok(ServerMsg::Hello { .. }) => {
                Err(disconnected("unexpected mid-stream hello from server".to_string()))
            }
            Ok(ServerMsg::Stats { .. }) => {
                Err(disconnected("unsolicited stats report from server".to_string()))
            }
            Err(e) => Err(disconnected(format!("decoding reply: {e}"))),
        }
    }

    /// Blocking round trip: submit, wait for that submission's reply.
    pub fn request(&mut self, session: &str, request: Request) -> Result<Response, RequestError> {
        let id = self.submit(session, request)?;
        let (got, response) = self.recv_reply()?;
        if got != id {
            return Err(disconnected(format!(
                "reply id {got} does not match request id {id}"
            )));
        }
        Ok(response)
    }

    /// Blocking round trip with admission-shed retries: a typed
    /// `Overloaded { retry_after_ms }` answer is retried up to
    /// `max_retries` times, then propagates typed to the caller.
    ///
    /// The retry is deterministic and bounded: the attempt count is the
    /// budget, and the server's `retry_after_ms` hint is itself a pure
    /// function of queue depth. Wall time is spent **only** when the
    /// request already carries a deadline budget — a clock-free request
    /// (no deadline) retries immediately, so the clock-free path stays
    /// clock-free; with a deadline, each wait is the hint capped by that
    /// deadline.
    pub fn request_with_retry(
        &mut self,
        session: &str,
        request: Request,
        max_retries: u32,
    ) -> Result<Response, RequestError> {
        let budget = match &request {
            Request::Screen { opts, .. }
            | Request::FitPath { opts, .. }
            | Request::Predict { opts, .. } => opts.deadline,
            Request::Warm { .. } | Request::SessionStats => None,
        };
        let mut attempt = 0u32;
        loop {
            let response = self.request(session, request.clone())?;
            let hint = match &response {
                Response::Error(RequestError::Overloaded { retry_after_ms }) => {
                    *retry_after_ms
                }
                _ => return Ok(response),
            };
            if attempt >= max_retries {
                return Ok(response); // typed Overloaded propagates to the caller
            }
            attempt += 1;
            if let Some(deadline) = budget {
                std::thread::sleep(Duration::from_millis(hint).min(deadline));
            }
        }
    }

    /// Control-plane probe: ask the server for its load/health rows
    /// ([`StatsReport`] per backend — one row from a `dpp serve` process,
    /// one per configured backend from a `dpp front`). Must not be called
    /// with pipelined submissions outstanding: replies are FIFO, so the
    /// next frame after the probe is its answer.
    pub fn stats(&mut self) -> Result<Vec<StatsReport>, RequestError> {
        let msg = encode_client_msg(&ClientMsg::Stats);
        write_frame(&mut self.stream, &msg)
            .map_err(|e| disconnected(format!("sending stats probe: {e}")))?;
        let payload = read_frame(&mut self.stream)
            .map_err(|e| disconnected(format!("reading stats report: {e}")))?;
        match decode_server_msg(&payload) {
            Ok(ServerMsg::Stats { backends }) => Ok(backends),
            Ok(other) => Err(disconnected(format!(
                "expected a stats report, got {other:?} — \
                 stats() with pipelined submissions outstanding?"
            ))),
            Err(e) => Err(disconnected(format!("decoding stats report: {e}"))),
        }
    }

    /// Ask the server to shut down; returns once it acknowledges (any
    /// still-pipelined replies are drained first).
    pub fn shutdown_server(mut self) -> Result<()> {
        let msg = encode_client_msg(&ClientMsg::Shutdown);
        write_frame(&mut self.stream, &msg).context("sending shutdown")?;
        loop {
            let payload =
                read_frame(&mut self.stream).context("waiting for shutdown ack")?;
            match decode_server_msg(&payload).context("decoding shutdown ack")? {
                ServerMsg::ShuttingDown => return Ok(()),
                ServerMsg::Reply { .. } | ServerMsg::Stats { .. } => continue,
                ServerMsg::Hello { .. } => bail!("unexpected mid-stream hello from server"),
            }
        }
    }
}

fn disconnected(msg: String) -> RequestError {
    RequestError::Disconnected(msg)
}
