//! Wire encode/decode for the coordinator protocol (DESIGN.md §4b).
//!
//! One frame payload (see [`super::frame`]) carries one message. The codec
//! covers the *full* [`crate::coordinator`] grammar — every [`Request`] and
//! [`Response`] variant, every [`RequestError`], the deadline / tolerance /
//! pipeline-override options, and the `gap`/`partial` tags that keep
//! deadline-bounded answers meaningful remotely. All floats travel as raw
//! IEEE-754 bits (`to_bits`/`from_bits`), so responses survive the socket
//! hop **bit-exactly** — including NaN payloads and the duality-gap
//! certificates the partial-answer contract leans on.
//!
//! Version negotiation: a connection opens with [`ClientMsg::Hello`]; the
//! server answers [`ServerMsg::Hello`] carrying its [`WIRE_VERSION`] and
//! session names, then closes if the versions differ. The frame layer has
//! its own (lower) version byte; the wire version covers the grammar.

use std::time::Duration;

use crate::coordinator::{
    AdmissionStats, PathSummary, Prediction, Request, RequestError, RequestOptions,
    Response, ScreenResponse, ServiceMetrics, SessionStats, WarmResponse,
};
use crate::path::SolverKind;
use crate::screening::{ScreenPipeline, StageCount};
use crate::util::stats::OnlineStats;

/// Version of the message grammar (negotiated via the hellos).
///
/// v2: `RequestOptions` gained the per-request solver override, and
/// `RequestError` gained `Overloaded` (tag 6) for admission-control load
/// shedding.
///
/// v3: control plane — `ClientMsg::Stats` (tag 3) and `ServerMsg::Stats`
/// (tag 3) carry per-backend [`StatsReport`] rows (`AdmissionStats` +
/// session count + liveness), the load/health signal the front tier
/// routes on.
pub const WIRE_VERSION: u32 = 3;

/// Message tag bytes — the committed grammar surface. `rust/wire.lock` is
/// the golden copy; `dpp audit` re-parses this module and fails on tag
/// reuse within a namespace or any change not matched by a
/// [`WIRE_VERSION`] bump plus a lock update (DESIGN.md §5).
pub mod tag {
    // Request (`enc_request`/`dec_request`)
    pub const REQ_SCREEN: u8 = 0;
    pub const REQ_FIT_PATH: u8 = 1;
    pub const REQ_PREDICT: u8 = 2;
    pub const REQ_WARM: u8 = 3;
    pub const REQ_SESSION_STATS: u8 = 4;
    // Response (`enc_response`/`dec_response`)
    pub const RESP_SCREEN: u8 = 0;
    pub const RESP_PATH: u8 = 1;
    pub const RESP_PREDICT: u8 = 2;
    pub const RESP_WARMED: u8 = 3;
    pub const RESP_STATS: u8 = 4;
    pub const RESP_ERROR: u8 = 5;
    // RequestError (`enc_error`/`dec_error`)
    pub const ERR_INVALID_LAMBDA: u8 = 0;
    pub const ERR_UNKNOWN_SESSION: u8 = 1;
    pub const ERR_DUPLICATE_SESSION: u8 = 2;
    pub const ERR_SESSION_CLOSED: u8 = 3;
    pub const ERR_INVALID_REQUEST: u8 = 4;
    pub const ERR_DISCONNECTED: u8 = 5;
    pub const ERR_OVERLOADED: u8 = 6;
    // ClientMsg (`encode_client_msg`/`decode_client_msg`)
    pub const CLIENT_HELLO: u8 = 0;
    pub const CLIENT_SUBMIT: u8 = 1;
    pub const CLIENT_SHUTDOWN: u8 = 2;
    pub const CLIENT_STATS: u8 = 3;
    // ServerMsg (`encode_server_msg`/`decode_server_msg`)
    pub const SERVER_HELLO: u8 = 0;
    pub const SERVER_REPLY: u8 = 1;
    pub const SERVER_SHUTTING_DOWN: u8 = 2;
    pub const SERVER_STATS: u8 = 3;
}

/// Typed decode failure: truncated buffer, unknown tag, bad UTF-8, or a
/// name (pipeline / solver) the receiving build doesn't know.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError(pub String);

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire decode error: {}", self.0)
    }
}

impl std::error::Error for WireError {}

fn err<T>(msg: impl Into<String>) -> Result<T, WireError> {
    Err(WireError(msg.into()))
}

/// First message on every connection (client → server).
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    /// Open the conversation and state the client's grammar version.
    Hello { version: u32 },
    /// One request for one named session. `id` is echoed in the reply so a
    /// pipelining client can match answers to questions.
    Submit { id: u64, session: String, request: Request },
    /// Ask the server to shut down (drains in-flight replies first).
    Shutdown,
    /// Control-plane probe (v3): ask for admission counters and session
    /// count. Doubles as the health check — a backend that cannot answer
    /// it is down. Answered in FIFO order with the pipelined replies.
    Stats,
}

/// One serving process's load/health row inside [`ServerMsg::Stats`].
///
/// A backend answering directly reports one row about itself with an
/// empty `backend` name; the front tier answers one row per configured
/// backend, named by address, from its probe-refreshed load view.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StatsReport {
    /// Backend address ("" when a server reports about itself).
    pub backend: String,
    /// False once the reporter has marked this backend down.
    pub up: bool,
    /// Registered (live) session count.
    pub sessions: u64,
    /// Admission counters (submitted / shed / evicted sessions).
    pub admission: AdmissionStats,
}

/// Server → client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    /// Hello reply: the server's version and its registered session names.
    Hello { version: u32, sessions: Vec<String> },
    /// Answer to the [`ClientMsg::Submit`] with the same `id`.
    Reply { id: u64, response: Response },
    /// Acknowledges [`ClientMsg::Shutdown`]; the server closes after this.
    ShuttingDown,
    /// Answer to [`ClientMsg::Stats`] (v3): one row per known backend.
    Stats { backends: Vec<StatsReport> },
}

// ---------------------------------------------------------------------------
// primitive encoder / decoder

/// Byte-buffer encoder. Integers are LE; floats travel as raw bits.
pub struct Enc(pub Vec<u8>);

impl Enc {
    pub fn new() -> Enc {
        Enc(Vec::new())
    }

    pub fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    pub fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
    pub fn f64s(&mut self, xs: &[f64]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.f64(x);
        }
    }
    pub fn usizes(&mut self, xs: &[usize]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.usize(x);
        }
    }
    pub fn u32s(&mut self, xs: &[u32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.u32(x);
        }
    }
    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }
    /// `Duration` as whole nanoseconds (u64 — caps at ~584 years).
    pub fn duration(&mut self, d: Duration) {
        self.u64(d.as_nanos().min(u64::MAX as u128) as u64);
    }
}

impl Default for Enc {
    fn default() -> Self {
        Enc::new()
    }
}

/// Cursor-style decoder over a received payload.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return err(format!(
                "truncated message: wanted {n} bytes at offset {} of {}",
                self.pos,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Fails unless every byte was consumed — trailing garbage is a
    /// protocol error, not padding.
    pub fn finish(&self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return err(format!(
                "{} trailing bytes after message",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }
    pub fn bool(&mut self) -> Result<bool, WireError> {
        Ok(self.u8()? != 0)
    }
    pub fn usize(&mut self) -> Result<usize, WireError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| WireError(format!("{v} overflows usize")))
    }
    pub fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|e| WireError(format!("bad UTF-8: {e}")))
    }
    pub fn f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n.min(self.buf.len() / 8 + 1));
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }
    pub fn usizes(&mut self) -> Result<Vec<usize>, WireError> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n.min(self.buf.len() / 8 + 1));
        for _ in 0..n {
            v.push(self.usize()?);
        }
        Ok(v)
    }
    pub fn u32s(&mut self) -> Result<Vec<u32>, WireError> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n.min(self.buf.len() / 4 + 1));
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }
    pub fn opt_f64(&mut self) -> Result<Option<f64>, WireError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            t => err(format!("bad Option tag {t}")),
        }
    }
    pub fn duration(&mut self) -> Result<Duration, WireError> {
        Ok(Duration::from_nanos(self.u64()?))
    }
}

// ---------------------------------------------------------------------------
// protocol codecs

fn enc_options(e: &mut Enc, o: &RequestOptions) {
    match o.deadline {
        Some(d) => {
            e.u8(1);
            e.duration(d);
        }
        None => e.u8(0),
    }
    e.opt_f64(o.tol_gap);
    // A pipeline override travels by name: `name()` ↔ `parse()` round-trip
    // for the whole grammar, including the `dynamic:` prefix.
    match &o.pipeline {
        Some(p) => {
            e.u8(1);
            e.str(&p.name());
        }
        None => e.u8(0),
    }
    // A solver override travels by name (`SolverKind::name` ↔ `from_name`).
    match o.solver {
        Some(k) => {
            e.u8(1);
            e.str(k.name());
        }
        None => e.u8(0),
    }
}

fn dec_options(d: &mut Dec<'_>) -> Result<RequestOptions, WireError> {
    let deadline = match d.u8()? {
        0 => None,
        1 => Some(d.duration()?),
        t => return err(format!("bad deadline tag {t}")),
    };
    let tol_gap = d.opt_f64()?;
    let pipeline = match d.u8()? {
        0 => None,
        1 => {
            let name = d.str()?;
            Some(
                ScreenPipeline::parse(&name)
                    .map_err(|e| WireError(format!("bad pipeline `{name}`: {e}")))?,
            )
        }
        t => return err(format!("bad pipeline tag {t}")),
    };
    let solver = match d.u8()? {
        0 => None,
        1 => {
            let name = d.str()?;
            Some(
                SolverKind::from_name(&name)
                    .ok_or_else(|| WireError(format!("unknown solver `{name}`")))?,
            )
        }
        t => return err(format!("bad solver tag {t}")),
    };
    Ok(RequestOptions { deadline, tol_gap, pipeline, solver })
}

/// Encode a [`Request`] into `e`.
pub fn enc_request(e: &mut Enc, r: &Request) {
    match r {
        Request::Screen { lam, opts } => {
            e.u8(tag::REQ_SCREEN);
            e.f64(*lam);
            enc_options(e, opts);
        }
        Request::FitPath { grid, lo, opts } => {
            e.u8(tag::REQ_FIT_PATH);
            e.usize(*grid);
            e.f64(*lo);
            enc_options(e, opts);
        }
        Request::Predict { features, lam, opts } => {
            e.u8(tag::REQ_PREDICT);
            e.f64s(features);
            e.f64(*lam);
            enc_options(e, opts);
        }
        Request::Warm { lam } => {
            e.u8(tag::REQ_WARM);
            e.f64(*lam);
        }
        Request::SessionStats => e.u8(tag::REQ_SESSION_STATS),
    }
}

/// Decode a [`Request`] from `d`.
pub fn dec_request(d: &mut Dec<'_>) -> Result<Request, WireError> {
    Ok(match d.u8()? {
        tag::REQ_SCREEN => Request::Screen { lam: d.f64()?, opts: dec_options(d)? },
        tag::REQ_FIT_PATH => {
            Request::FitPath { grid: d.usize()?, lo: d.f64()?, opts: dec_options(d)? }
        }
        tag::REQ_PREDICT => Request::Predict {
            features: d.f64s()?,
            lam: d.f64()?,
            opts: dec_options(d)?,
        },
        tag::REQ_WARM => Request::Warm { lam: d.f64()? },
        tag::REQ_SESSION_STATS => Request::SessionStats,
        t => return err(format!("bad Request tag {t}")),
    })
}

fn enc_stage_counts(e: &mut Enc, xs: &[StageCount]) {
    e.u32(xs.len() as u32);
    for s in xs {
        e.str(&s.stage);
        e.usize(s.discarded);
    }
}

fn dec_stage_counts(d: &mut Dec<'_>) -> Result<Vec<StageCount>, WireError> {
    let n = d.u32()? as usize;
    let mut v = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        v.push(StageCount { stage: d.str()?, discarded: d.usize()? });
    }
    Ok(v)
}

fn enc_online(e: &mut Enc, s: &OnlineStats) {
    let (n, mean, m2, min, max) = s.to_raw();
    e.u64(n);
    e.f64(mean);
    e.f64(m2);
    e.f64(min);
    e.f64(max);
}

fn dec_online(d: &mut Dec<'_>) -> Result<OnlineStats, WireError> {
    Ok(OnlineStats::from_raw(d.u64()?, d.f64()?, d.f64()?, d.f64()?, d.f64()?))
}

fn enc_metrics(e: &mut Enc, m: &ServiceMetrics) {
    e.u64(m.requests);
    e.u64(m.batches);
    enc_online(e, &m.latency);
    enc_online(e, &m.batch_size);
    enc_online(e, &m.rejection_ratio);
    enc_online(e, &m.kept_features);
    e.u64(m.partials);
    e.f64s(m.latency_samples());
}

fn dec_metrics(d: &mut Dec<'_>) -> Result<ServiceMetrics, WireError> {
    Ok(ServiceMetrics::from_parts(
        d.u64()?,
        d.u64()?,
        dec_online(d)?,
        dec_online(d)?,
        dec_online(d)?,
        dec_online(d)?,
        d.u64()?,
        d.f64s()?,
    ))
}

fn enc_error(e: &mut Enc, re: &RequestError) {
    match re {
        RequestError::InvalidLambda(lam) => {
            e.u8(tag::ERR_INVALID_LAMBDA);
            e.f64(*lam);
        }
        RequestError::UnknownSession(s) => {
            e.u8(tag::ERR_UNKNOWN_SESSION);
            e.str(s);
        }
        RequestError::DuplicateSession(s) => {
            e.u8(tag::ERR_DUPLICATE_SESSION);
            e.str(s);
        }
        RequestError::SessionClosed { session, reason } => {
            e.u8(tag::ERR_SESSION_CLOSED);
            e.str(session);
            e.str(reason);
        }
        RequestError::InvalidRequest(msg) => {
            e.u8(tag::ERR_INVALID_REQUEST);
            e.str(msg);
        }
        RequestError::Disconnected(msg) => {
            e.u8(tag::ERR_DISCONNECTED);
            e.str(msg);
        }
        RequestError::Overloaded { retry_after_ms } => {
            e.u8(tag::ERR_OVERLOADED);
            e.u64(*retry_after_ms);
        }
    }
}

fn dec_error(d: &mut Dec<'_>) -> Result<RequestError, WireError> {
    Ok(match d.u8()? {
        tag::ERR_INVALID_LAMBDA => RequestError::InvalidLambda(d.f64()?),
        tag::ERR_UNKNOWN_SESSION => RequestError::UnknownSession(d.str()?),
        tag::ERR_DUPLICATE_SESSION => RequestError::DuplicateSession(d.str()?),
        tag::ERR_SESSION_CLOSED => {
            RequestError::SessionClosed { session: d.str()?, reason: d.str()? }
        }
        tag::ERR_INVALID_REQUEST => RequestError::InvalidRequest(d.str()?),
        tag::ERR_DISCONNECTED => RequestError::Disconnected(d.str()?),
        tag::ERR_OVERLOADED => RequestError::Overloaded { retry_after_ms: d.u64()? },
        t => return err(format!("bad RequestError tag {t}")),
    })
}

/// Encode a [`Response`] into `e`.
pub fn enc_response(e: &mut Enc, r: &Response) {
    match r {
        Response::Screen(s) => {
            e.u8(tag::RESP_SCREEN);
            e.f64(s.lam);
            e.usizes(&s.kept);
            e.f64s(&s.beta);
            e.usize(s.discarded);
            e.usize(s.true_zeros);
            e.f64(s.latency_s);
            enc_stage_counts(e, &s.stage_discards);
            e.usize(s.dynamic_discards);
            e.f64(s.gap);
            e.bool(s.partial);
        }
        Response::Path(p) => {
            e.u8(tag::RESP_PATH);
            e.str(&p.rule);
            e.str(p.solver);
            e.usize(p.steps);
            e.f64(p.mean_rejection);
            e.f64(p.screen_secs);
            e.f64(p.solve_secs);
            e.f64(p.max_gap);
            e.bool(p.partial);
            e.f64(p.latency_s);
        }
        Response::Predict(p) => {
            e.u8(tag::RESP_PREDICT);
            e.f64(p.lam);
            e.f64(p.yhat);
            e.f64(p.gap);
            e.bool(p.partial);
            e.f64(p.latency_s);
        }
        Response::Warmed(w) => {
            e.u8(tag::RESP_WARMED);
            e.f64(w.lam);
            e.f64(w.gap);
            e.f64(w.latency_s);
        }
        Response::Stats(s) => {
            e.u8(tag::RESP_STATS);
            e.str(&s.session);
            e.str(&s.backend);
            e.str(&s.pipeline);
            e.usize(s.n);
            e.usize(s.p);
            e.f64(s.lam_max);
            e.f64(s.anchor_lam);
            enc_metrics(e, &s.metrics);
        }
        Response::Error(re) => {
            e.u8(tag::RESP_ERROR);
            enc_error(e, re);
        }
    }
}

/// Decode a [`Response`] from `d`.
pub fn dec_response(d: &mut Dec<'_>) -> Result<Response, WireError> {
    Ok(match d.u8()? {
        tag::RESP_SCREEN => Response::Screen(ScreenResponse {
            lam: d.f64()?,
            kept: d.usizes()?,
            beta: d.f64s()?,
            discarded: d.usize()?,
            true_zeros: d.usize()?,
            latency_s: d.f64()?,
            stage_discards: dec_stage_counts(d)?,
            dynamic_discards: d.usize()?,
            gap: d.f64()?,
            partial: d.bool()?,
        }),
        tag::RESP_PATH => {
            let rule = d.str()?;
            let solver_name = d.str()?;
            // `solver` is `&'static str`: map the wire name back onto the
            // matching SolverKind's static name.
            let solver = SolverKind::from_name(&solver_name)
                .map(|k| k.name())
                .ok_or_else(|| WireError(format!("unknown solver `{solver_name}`")))?;
            Response::Path(PathSummary {
                rule,
                solver,
                steps: d.usize()?,
                mean_rejection: d.f64()?,
                screen_secs: d.f64()?,
                solve_secs: d.f64()?,
                max_gap: d.f64()?,
                // local working-set diagnostics — not carried on the wire
                mean_working_set: 0.0,
                kkt_passes: 0,
                partial: d.bool()?,
                latency_s: d.f64()?,
            })
        }
        tag::RESP_PREDICT => Response::Predict(Prediction {
            lam: d.f64()?,
            yhat: d.f64()?,
            gap: d.f64()?,
            partial: d.bool()?,
            latency_s: d.f64()?,
        }),
        tag::RESP_WARMED => Response::Warmed(WarmResponse {
            lam: d.f64()?,
            gap: d.f64()?,
            latency_s: d.f64()?,
        }),
        tag::RESP_STATS => Response::Stats(SessionStats {
            session: d.str()?,
            backend: d.str()?,
            pipeline: d.str()?,
            n: d.usize()?,
            p: d.usize()?,
            lam_max: d.f64()?,
            anchor_lam: d.f64()?,
            metrics: dec_metrics(d)?,
        }),
        tag::RESP_ERROR => Response::Error(dec_error(d)?),
        t => return err(format!("bad Response tag {t}")),
    })
}

/// Serialize a [`ClientMsg`] to one frame payload.
pub fn encode_client_msg(m: &ClientMsg) -> Vec<u8> {
    let mut e = Enc::new();
    match m {
        ClientMsg::Hello { version } => {
            e.u8(tag::CLIENT_HELLO);
            e.u32(*version);
        }
        ClientMsg::Submit { id, session, request } => {
            e.u8(tag::CLIENT_SUBMIT);
            e.u64(*id);
            e.str(session);
            enc_request(&mut e, request);
        }
        ClientMsg::Shutdown => e.u8(tag::CLIENT_SHUTDOWN),
        ClientMsg::Stats => e.u8(tag::CLIENT_STATS),
    }
    e.0
}

/// Deserialize a [`ClientMsg`] from one frame payload.
pub fn decode_client_msg(buf: &[u8]) -> Result<ClientMsg, WireError> {
    let mut d = Dec::new(buf);
    let m = match d.u8()? {
        tag::CLIENT_HELLO => ClientMsg::Hello { version: d.u32()? },
        tag::CLIENT_SUBMIT => ClientMsg::Submit {
            id: d.u64()?,
            session: d.str()?,
            request: dec_request(&mut d)?,
        },
        tag::CLIENT_SHUTDOWN => ClientMsg::Shutdown,
        tag::CLIENT_STATS => ClientMsg::Stats,
        t => return err(format!("bad ClientMsg tag {t}")),
    };
    d.finish()?;
    Ok(m)
}

/// Serialize a [`ServerMsg`] to one frame payload.
pub fn encode_server_msg(m: &ServerMsg) -> Vec<u8> {
    let mut e = Enc::new();
    match m {
        ServerMsg::Hello { version, sessions } => {
            e.u8(tag::SERVER_HELLO);
            e.u32(*version);
            e.u32(sessions.len() as u32);
            for s in sessions {
                e.str(s);
            }
        }
        ServerMsg::Reply { id, response } => {
            e.u8(tag::SERVER_REPLY);
            e.u64(*id);
            enc_response(&mut e, response);
        }
        ServerMsg::ShuttingDown => e.u8(tag::SERVER_SHUTTING_DOWN),
        ServerMsg::Stats { backends } => {
            e.u8(tag::SERVER_STATS);
            e.u32(backends.len() as u32);
            for b in backends {
                e.str(&b.backend);
                e.bool(b.up);
                e.u64(b.sessions);
                e.u64(b.admission.submitted);
                e.u64(b.admission.shed);
                e.u64(b.admission.evicted);
            }
        }
    }
    e.0
}

/// Deserialize a [`ServerMsg`] from one frame payload.
pub fn decode_server_msg(buf: &[u8]) -> Result<ServerMsg, WireError> {
    let mut d = Dec::new(buf);
    let m = match d.u8()? {
        tag::SERVER_HELLO => {
            let version = d.u32()?;
            let n = d.u32()? as usize;
            let mut sessions = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                sessions.push(d.str()?);
            }
            ServerMsg::Hello { version, sessions }
        }
        tag::SERVER_REPLY => {
            ServerMsg::Reply { id: d.u64()?, response: dec_response(&mut d)? }
        }
        tag::SERVER_SHUTTING_DOWN => ServerMsg::ShuttingDown,
        tag::SERVER_STATS => {
            let n = d.u32()? as usize;
            let mut backends = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                backends.push(StatsReport {
                    backend: d.str()?,
                    up: d.bool()?,
                    sessions: d.u64()?,
                    admission: AdmissionStats {
                        submitted: d.u64()?,
                        shed: d.u64()?,
                        evicted: d.u64()?,
                    },
                });
            }
            ServerMsg::Stats { backends }
        }
        t => return err(format!("bad ServerMsg tag {t}")),
    };
    d.finish()?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn request_payload(r: &Request) -> Vec<u8> {
        let mut e = Enc::new();
        enc_request(&mut e, r);
        e.0
    }

    fn response_payload(r: &Response) -> Vec<u8> {
        let mut e = Enc::new();
        enc_response(&mut e, r);
        e.0
    }

    fn roundtrip_response(r: &Response) -> Response {
        let buf = response_payload(r);
        let mut d = Dec::new(&buf);
        let got = dec_response(&mut d).unwrap();
        d.finish().unwrap();
        got
    }

    fn rand_options(rng: &mut Rng) -> RequestOptions {
        let deadline = if rng.f64() < 0.5 {
            Some(Duration::from_nanos(rng.next_u64() >> 20))
        } else {
            None
        };
        let tol_gap = if rng.f64() < 0.5 { Some(rng.f64() * 1e-3) } else { None };
        let specs =
            ["edpp", "hybrid:strong+edpp", "cascade:dome,edpp", "dynamic:edpp", "safe"];
        let pipeline = if rng.f64() < 0.5 {
            Some(ScreenPipeline::parse(specs[rng.usize(specs.len())]).unwrap())
        } else {
            None
        };
        let solvers = [SolverKind::Cd, SolverKind::Fista, SolverKind::Lars];
        let solver = if rng.f64() < 0.5 {
            Some(solvers[rng.usize(solvers.len())])
        } else {
            None
        };
        RequestOptions { deadline, tol_gap, pipeline, solver }
    }

    fn rand_request(rng: &mut Rng) -> Request {
        match rng.usize(5) {
            0 => Request::Screen { lam: rng.f64(), opts: rand_options(rng) },
            1 => Request::FitPath {
                grid: 1 + rng.usize(40),
                lo: 0.01 + rng.f64() * 0.9,
                opts: rand_options(rng),
            },
            2 => Request::Predict {
                features: (0..rng.usize(20)).map(|_| rng.normal()).collect(),
                lam: rng.f64(),
                opts: rand_options(rng),
            },
            3 => Request::Warm { lam: rng.f64() },
            _ => Request::SessionStats,
        }
    }

    fn rand_online(rng: &mut Rng) -> OnlineStats {
        let mut s = OnlineStats::new();
        for _ in 0..rng.usize(8) {
            s.push(rng.normal());
        }
        s
    }

    fn rand_metrics(rng: &mut Rng) -> ServiceMetrics {
        ServiceMetrics::from_parts(
            rng.next_u64() >> 40,
            rng.next_u64() >> 40,
            rand_online(rng),
            rand_online(rng),
            rand_online(rng),
            rand_online(rng),
            rng.next_u64() >> 40,
            (0..rng.usize(16)).map(|_| rng.f64()).collect(),
        )
    }

    fn rand_error(rng: &mut Rng) -> RequestError {
        match rng.usize(7) {
            0 => {
                // exercise the non-finite λ payloads too
                let lam = match rng.usize(3) {
                    0 => f64::NAN,
                    1 => f64::NEG_INFINITY,
                    _ => -rng.f64(),
                };
                RequestError::InvalidLambda(lam)
            }
            1 => RequestError::UnknownSession("ghost".into()),
            2 => RequestError::DuplicateSession("twin".into()),
            3 => RequestError::SessionClosed {
                session: "s1".into(),
                reason: "worker panicked: boom".into(),
            },
            4 => RequestError::InvalidRequest("features.len() = 3 ≠ p = 5".into()),
            5 => RequestError::Overloaded { retry_after_ms: rng.next_u64() >> 32 },
            _ => RequestError::Disconnected("router gone".into()),
        }
    }

    fn rand_response(rng: &mut Rng) -> Response {
        match rng.usize(6) {
            0 => Response::Screen(ScreenResponse {
                lam: rng.f64(),
                kept: (0..rng.usize(12)).map(|_| rng.usize(500)).collect(),
                beta: (0..rng.usize(12)).map(|_| rng.normal()).collect(),
                discarded: rng.usize(500),
                true_zeros: rng.usize(500),
                latency_s: rng.f64(),
                stage_discards: vec![
                    StageCount { stage: "strong".into(), discarded: rng.usize(400) },
                    StageCount { stage: "edpp".into(), discarded: rng.usize(100) },
                ],
                dynamic_discards: rng.usize(50),
                gap: rng.f64() * 1e-6,
                partial: rng.f64() < 0.5,
            }),
            1 => Response::Path(PathSummary {
                rule: "hybrid:strong+edpp".into(),
                solver: SolverKind::Cd.name(),
                steps: rng.usize(40),
                mean_rejection: rng.f64(),
                screen_secs: rng.f64(),
                solve_secs: rng.f64(),
                max_gap: rng.f64() * 1e-5,
                // zero on both sides: these diagnostics never hit the wire
                mean_working_set: 0.0,
                kkt_passes: 0,
                partial: rng.f64() < 0.5,
                latency_s: rng.f64(),
            }),
            2 => Response::Predict(Prediction {
                lam: rng.f64(),
                yhat: rng.normal(),
                gap: rng.f64() * 1e-7,
                partial: rng.f64() < 0.5,
                latency_s: rng.f64(),
            }),
            3 => Response::Warmed(WarmResponse {
                lam: rng.f64(),
                gap: rng.f64() * 1e-7,
                latency_s: rng.f64(),
            }),
            4 => Response::Stats(SessionStats {
                session: "s0".into(),
                backend: "sharded".into(),
                pipeline: "dynamic:edpp".into(),
                n: rng.usize(1000),
                p: rng.usize(5000),
                lam_max: rng.f64() * 10.0,
                anchor_lam: rng.f64(),
                metrics: rand_metrics(rng),
            }),
            _ => Response::Error(rand_error(rng)),
        }
    }

    #[test]
    fn responses_round_trip_bit_exactly() {
        prop::check("response-roundtrip", 0x31A7, 64, |rng| {
            let r = rand_response(rng);
            assert_eq!(roundtrip_response(&r), r);
        });
    }

    #[test]
    fn requests_round_trip_to_identical_bytes() {
        // Byte-level comparison (encode → decode → re-encode) also pins
        // encoder determinism, which value equality alone would not.
        prop::check("request-roundtrip", 0x31A8, 64, |rng| {
            let r = rand_request(rng);
            let bytes = request_payload(&r);
            let mut d = Dec::new(&bytes);
            let back = dec_request(&mut d).unwrap();
            d.finish().unwrap();
            assert_eq!(request_payload(&back), bytes);
        });
    }

    #[test]
    fn every_error_variant_round_trips() {
        let nan_lam = RequestError::InvalidLambda(f64::NAN);
        let errors = [
            nan_lam.clone(),
            RequestError::InvalidLambda(-1.5),
            RequestError::UnknownSession("ghost".into()),
            RequestError::DuplicateSession("twin".into()),
            RequestError::SessionClosed { session: "s".into(), reason: "r".into() },
            RequestError::InvalidRequest("bad".into()),
            RequestError::Disconnected("gone".into()),
            RequestError::Overloaded { retry_after_ms: 125 },
        ];
        for e in &errors {
            let got = roundtrip_response(&Response::Error(e.clone()));
            if matches!(e, RequestError::InvalidLambda(l) if l.is_nan()) {
                // NaN != NaN under PartialEq: check the bits came through.
                match got {
                    Response::Error(RequestError::InvalidLambda(l)) => {
                        assert_eq!(l.to_bits(), f64::NAN.to_bits());
                    }
                    other => panic!("wrong decode: {other:?}"),
                }
            } else {
                assert_eq!(got, Response::Error(e.clone()));
            }
        }
    }

    #[test]
    fn gap_and_partial_tags_survive() {
        let r = Response::Screen(ScreenResponse {
            lam: 0.25,
            kept: vec![1, 4],
            beta: vec![0.5, -0.25],
            discarded: 98,
            true_zeros: 98,
            latency_s: 0.012,
            stage_discards: vec![StageCount { stage: "edpp".into(), discarded: 98 }],
            dynamic_discards: 0,
            gap: 3.5e-4,
            partial: true,
        });
        match roundtrip_response(&r) {
            Response::Screen(s) => {
                assert!(s.partial);
                assert_eq!(s.gap.to_bits(), (3.5e-4f64).to_bits());
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn hello_and_control_messages_round_trip() {
        let msgs = [
            ClientMsg::Hello { version: WIRE_VERSION },
            ClientMsg::Submit {
                id: 7,
                session: "s0".into(),
                request: Request::Warm { lam: 0.5 },
            },
            ClientMsg::Shutdown,
            ClientMsg::Stats,
        ];
        for m in &msgs {
            let got = decode_client_msg(&encode_client_msg(m)).unwrap();
            assert_eq!(&got, m);
        }
        let msgs = [
            ServerMsg::Hello { version: WIRE_VERSION, sessions: vec!["s0".into(), "s1".into()] },
            ServerMsg::Reply {
                id: 7,
                response: Response::Error(RequestError::UnknownSession("x".into())),
            },
            ServerMsg::ShuttingDown,
            ServerMsg::Stats {
                backends: vec![
                    StatsReport {
                        backend: String::new(),
                        up: true,
                        sessions: 3,
                        admission: AdmissionStats { submitted: 41, shed: 2, evicted: 1 },
                    },
                    StatsReport {
                        backend: "127.0.0.1:7711".into(),
                        up: false,
                        sessions: 0,
                        admission: AdmissionStats::default(),
                    },
                ],
            },
        ];
        for m in &msgs {
            let got = decode_server_msg(&encode_server_msg(m)).unwrap();
            assert_eq!(&got, m);
        }
    }

    #[test]
    fn corrupt_payloads_are_typed_errors() {
        // unknown top-level tag
        assert!(decode_client_msg(&[99]).is_err());
        assert!(decode_server_msg(&[99]).is_err());
        // truncated submit
        let full = encode_client_msg(&ClientMsg::Submit {
            id: 1,
            session: "s0".into(),
            request: Request::SessionStats,
        });
        for cut in 1..full.len() {
            assert!(decode_client_msg(&full[..cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage
        let mut noisy = encode_client_msg(&ClientMsg::Shutdown);
        noisy.push(0);
        assert!(decode_client_msg(&noisy).is_err());
        // unknown solver name inside a Path response
        let mut e = Enc::new();
        e.u8(1);
        e.str("edpp");
        e.str("not-a-solver");
        let errmsg = dec_response(&mut Dec::new(&e.0)).unwrap_err();
        assert!(errmsg.0.contains("not-a-solver"), "{errmsg}");
        // unknown pipeline name inside request options
        let mut e = Enc::new();
        e.u8(0); // Screen
        e.f64(0.5);
        e.u8(0); // no deadline
        e.u8(0); // no tol override
        e.u8(1); // pipeline present…
        e.str("bogus:rule"); // …but unparseable
        let errmsg = dec_request(&mut Dec::new(&e.0)).unwrap_err();
        assert!(errmsg.0.contains("bogus"), "{errmsg}");
        // unknown solver override name inside request options
        let mut e = Enc::new();
        e.u8(0); // Screen
        e.f64(0.5);
        e.u8(0); // no deadline
        e.u8(0); // no tol override
        e.u8(0); // no pipeline
        e.u8(1); // solver present…
        e.str("not-a-solver"); // …but unknown
        let errmsg = dec_request(&mut Dec::new(&e.0)).unwrap_err();
        assert!(errmsg.0.contains("not-a-solver"), "{errmsg}");
    }
}
