//! L4 network layer: the serving protocol over TCP, with zero new
//! dependencies (DESIGN.md §4b).
//!
//! * [`frame`] — length-prefixed, checksummed, versioned binary framing
//!   over any `Read`/`Write` pair, with typed rejection of oversized,
//!   truncated, or wrong-version frames;
//! * [`wire`] — the byte-level encoding of the full
//!   [`crate::coordinator::protocol`] grammar (requests, responses, every
//!   error variant, gap/partial diagnostics) plus the connection-level
//!   hello/submit/shutdown envelope;
//! * [`server`] — [`NetServer`]: `dpp serve --listen` routes framed
//!   requests into a [`crate::coordinator::Coordinator`] keyed by session
//!   name, preserving batch formation for pipelined clients;
//! * [`client`] — [`NetClient`]: blocking or pipelined typed requests with
//!   [`crate::coordinator::RequestError::Disconnected`] on transport loss;
//! * [`remote_shard`] — `dpp shard-node` hosts one shard of a
//!   [`crate::linalg::ShardSetMatrix`]; [`RemoteShard`] runs the per-shard
//!   sweep interface over a connection so the coordinator scatters fold
//!   requests and gathers accumulators without the data ever leaving its
//!   node — bit-identical to local execution by the chained-accumulator
//!   contract (DESIGN.md §4b.4).

pub mod client;
pub mod frame;
pub mod remote_shard;
pub mod server;
pub mod wire;

pub use client::NetClient;
pub use remote_shard::{spawn_shard_node, stop_shard_node, RemoteShard, ShardNodeHandle};
pub use server::NetServer;
