//! Length-prefixed, versioned, checksummed binary framing (DESIGN.md §4b).
//!
//! Every message on a `dpp` socket — coordinator requests, shard RPCs —
//! travels as one frame:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"DPPN"
//!      4     1  version (FRAME_VERSION)
//!      5     1  reserved (0)
//!      6     4  payload length, u32 LE
//!     10     4  payload CRC-32 (IEEE), u32 LE
//!     14     4  header CRC-32 over bytes [0, 14), u32 LE
//!     18     …  payload
//! ```
//!
//! The header checksum means a corrupt or misaligned length prefix is
//! rejected *before* we trust it to size a read; the payload checksum
//! catches torn writes. Oversized frames (beyond [`MAX_PAYLOAD`]) are
//! refused without allocating. A peer that closes the socket cleanly
//! between frames yields [`FrameError::Closed`]; one that dies mid-frame
//! yields [`FrameError::Truncated`] — callers map both onto their own
//! disconnect handling instead of panicking or hanging.

use std::io::{ErrorKind, Read, Write};

/// Frame magic — first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"DPPN";
/// Current frame-format version.
pub const FRAME_VERSION: u8 = 1;
/// Header size in bytes (fixed).
pub const HEADER_LEN: usize = 18;
/// Maximum accepted payload (64 MiB) — refuse anything larger up front.
pub const MAX_PAYLOAD: usize = 64 << 20;

/// Typed framing failure. Everything a hostile or dying peer can do to the
/// byte stream maps to one of these; none of them panic or hang.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// First four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Frame version we don't speak.
    BadVersion(u8),
    /// Length prefix exceeds [`MAX_PAYLOAD`].
    Oversized { len: usize, cap: usize },
    /// Header checksum mismatch — the length prefix cannot be trusted.
    BadHeaderChecksum,
    /// Payload checksum mismatch — torn or corrupted payload.
    BadPayloadChecksum,
    /// Peer closed the connection cleanly at a frame boundary.
    Closed,
    /// Peer disappeared mid-frame (EOF inside a header or payload).
    Truncated,
    /// Underlying socket error.
    Io(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:?}"),
            FrameError::BadVersion(v) => {
                write!(f, "unsupported frame version {v} (expected {FRAME_VERSION})")
            }
            FrameError::Oversized { len, cap } => {
                write!(f, "frame payload of {len} bytes exceeds the {cap}-byte cap")
            }
            FrameError::BadHeaderChecksum => write!(f, "frame header checksum mismatch"),
            FrameError::BadPayloadChecksum => write!(f, "frame payload checksum mismatch"),
            FrameError::Closed => write!(f, "peer closed the connection"),
            FrameError::Truncated => write!(f, "peer disconnected mid-frame"),
            FrameError::Io(e) => write!(f, "socket error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320). Bitwise — framing is
/// not the bottleneck next to a λ-path solve, and the build is offline so
/// we keep it dependency-free.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = !0;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Write one frame (header + payload) and flush.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), FrameError> {
    if payload.len() > MAX_PAYLOAD {
        return Err(FrameError::Oversized { len: payload.len(), cap: MAX_PAYLOAD });
    }
    let mut header = [0u8; HEADER_LEN];
    header[0..4].copy_from_slice(&MAGIC);
    header[4] = FRAME_VERSION;
    header[5] = 0;
    header[6..10].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    header[10..14].copy_from_slice(&crc32(payload).to_le_bytes());
    let hcrc = crc32(&header[0..14]);
    header[14..18].copy_from_slice(&hcrc.to_le_bytes());
    let io = |e: std::io::Error| FrameError::Io(e.to_string());
    w.write_all(&header).map_err(io)?;
    w.write_all(payload).map_err(io)?;
    w.flush().map_err(io)
}

/// Read exactly `buf.len()` bytes. EOF before the first byte is a clean
/// [`FrameError::Closed`]; EOF after is [`FrameError::Truncated`].
fn read_exact_or_closed(r: &mut impl Read, buf: &mut [u8]) -> Result<(), FrameError> {
    let mut off = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => {
                return Err(if off == 0 { FrameError::Closed } else { FrameError::Truncated });
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read one frame, validating magic, header checksum, version, size cap
/// and payload checksum — in that order, so the length prefix is never
/// trusted before the header proves intact.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    read_exact_or_closed(r, &mut header)?;
    let mut magic = [0u8; 4];
    magic.copy_from_slice(&header[0..4]);
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let hcrc = u32::from_le_bytes([header[14], header[15], header[16], header[17]]);
    if crc32(&header[0..14]) != hcrc {
        return Err(FrameError::BadHeaderChecksum);
    }
    if header[4] != FRAME_VERSION {
        return Err(FrameError::BadVersion(header[4]));
    }
    let len = u32::from_le_bytes([header[6], header[7], header[8], header[9]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(FrameError::Oversized { len, cap: MAX_PAYLOAD });
    }
    let pcrc = u32::from_le_bytes([header[10], header[11], header[12], header[13]]);
    let mut payload = vec![0u8; len];
    if let Err(e) = read_exact_or_closed(r, &mut payload) {
        // EOF anywhere inside the payload is a truncation, even at offset 0:
        // the header promised `len` more bytes.
        return Err(match e {
            FrameError::Closed => FrameError::Truncated,
            other => other,
        });
    }
    if crc32(&payload) != pcrc {
        return Err(FrameError::BadPayloadChecksum);
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn frame_bytes(payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, payload).unwrap();
        buf
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn round_trips_payloads() {
        for payload in [&b""[..], b"x", b"hello frame", &[0u8; 4096][..]] {
            let buf = frame_bytes(payload);
            assert_eq!(buf.len(), HEADER_LEN + payload.len());
            let got = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(got, payload);
        }
    }

    #[test]
    fn multiple_frames_in_sequence() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"one").unwrap();
        write_frame(&mut buf, b"two").unwrap();
        let mut cur = Cursor::new(&buf);
        assert_eq!(read_frame(&mut cur).unwrap(), b"one");
        assert_eq!(read_frame(&mut cur).unwrap(), b"two");
        assert_eq!(read_frame(&mut cur), Err(FrameError::Closed));
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = frame_bytes(b"payload");
        buf[0] = b'X';
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(FrameError::BadMagic(_))
        ));
    }

    #[test]
    fn rejects_corrupt_length_via_header_checksum() {
        let mut buf = frame_bytes(b"payload");
        // Flip a length byte: the header CRC must catch it before the
        // bogus length sizes a read.
        buf[6] ^= 0xFF;
        assert_eq!(
            read_frame(&mut Cursor::new(&buf)),
            Err(FrameError::BadHeaderChecksum)
        );
    }

    #[test]
    fn rejects_bad_version() {
        let mut buf = frame_bytes(b"payload");
        buf[4] = 99;
        // Re-seal the header so only the version is wrong.
        let hcrc = crc32(&buf[0..14]);
        buf[14..18].copy_from_slice(&hcrc.to_le_bytes());
        assert_eq!(read_frame(&mut Cursor::new(&buf)), Err(FrameError::BadVersion(99)));
    }

    #[test]
    fn rejects_oversized_declared_length() {
        let mut buf = frame_bytes(b"p");
        let big = (MAX_PAYLOAD as u32 + 1).to_le_bytes();
        buf[6..10].copy_from_slice(&big);
        let hcrc = crc32(&buf[0..14]);
        buf[14..18].copy_from_slice(&hcrc.to_le_bytes());
        assert_eq!(
            read_frame(&mut Cursor::new(&buf)),
            Err(FrameError::Oversized { len: MAX_PAYLOAD + 1, cap: MAX_PAYLOAD })
        );
    }

    #[test]
    fn oversized_write_is_refused() {
        struct Sink;
        impl std::io::Write for Sink {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let payload = vec![0u8; MAX_PAYLOAD + 1];
        assert!(matches!(
            write_frame(&mut Sink, &payload),
            Err(FrameError::Oversized { .. })
        ));
    }

    #[test]
    fn rejects_corrupt_payload() {
        let mut buf = frame_bytes(b"payload");
        let last = buf.len() - 1;
        buf[last] ^= 0x01;
        assert_eq!(
            read_frame(&mut Cursor::new(&buf)),
            Err(FrameError::BadPayloadChecksum)
        );
    }

    #[test]
    fn eof_mid_header_and_mid_payload_are_truncated() {
        let buf = frame_bytes(b"payload");
        // Cut inside the header (but after byte 0).
        let cut = &buf[..HEADER_LEN / 2];
        assert_eq!(read_frame(&mut Cursor::new(cut)), Err(FrameError::Truncated));
        // Cut inside the payload.
        let cut = &buf[..HEADER_LEN + 3];
        assert_eq!(read_frame(&mut Cursor::new(cut)), Err(FrameError::Truncated));
        // Header complete, zero payload bytes delivered.
        let cut = &buf[..HEADER_LEN];
        assert_eq!(read_frame(&mut Cursor::new(cut)), Err(FrameError::Truncated));
    }

    #[test]
    fn clean_eof_is_closed() {
        assert_eq!(read_frame(&mut Cursor::new(&[])), Err(FrameError::Closed));
    }
}
