//! Framed TCP front door for a [`Coordinator`] (DESIGN.md §4b.3).
//!
//! [`NetServer`] owns a listener and a coordinator; each accepted
//! connection gets a reader thread (decodes [`ClientMsg`]s, submits to the
//! coordinator) and a responder thread (blocks on each request's reply
//! slot, writes [`ServerMsg::Reply`]s back in submission order). Because
//! `Coordinator::submit` never blocks, a pipelining client's burst lands in
//! the router as one tick and batches exactly as in-process submissions do
//! — the serving semantics (and the responses, bit for bit) are those of
//! the in-process coordinator; only the transport changes.
//!
//! Protocol per connection: the client speaks first with
//! [`ClientMsg::Hello`]; the server answers [`ServerMsg::Hello`] carrying
//! its wire version and session names, then closes if the versions differ
//! (the client saw both versions and can report the mismatch). Any framing
//! or grammar error afterwards drops that connection only — in-flight
//! replies for a vanished peer are discarded, never panicked on.
//! [`ClientMsg::Shutdown`] drains the connection's queued replies, answers
//! [`ServerMsg::ShuttingDown`], and stops the accept loop.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::frame::{read_frame, write_frame};
use super::wire::{
    decode_client_msg, encode_server_msg, ClientMsg, ServerMsg, StatsReport, WIRE_VERSION,
};
use crate::coordinator::metrics::ServiceMetrics;
use crate::coordinator::{Coordinator, PendingResponse, Response};

/// Accept-loop poll interval (the listener is non-blocking so the loop can
/// observe the shutdown flag).
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// A bound, not-yet-running server. Register sessions on the
/// [`Coordinator`] first, then hand it over; [`NetServer::run`] serves
/// until a client asks for shutdown and returns per-session metrics.
pub struct NetServer {
    listener: TcpListener,
    coord: Arc<Mutex<Coordinator>>,
    stop: Arc<AtomicBool>,
}

impl NetServer {
    /// Bind `listen` (e.g. `127.0.0.1:7700`, or port 0 for an ephemeral
    /// port — read it back with [`NetServer::local_addr`]).
    pub fn bind(coord: Coordinator, listen: &str) -> Result<NetServer> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding serve listener on {listen}"))?;
        listener.set_nonblocking(true).context("setting serve listener non-blocking")?;
        Ok(NetServer {
            listener,
            coord: Arc::new(Mutex::new(coord)),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading serve listener address")
    }

    /// Serve connections until a client sends [`ClientMsg::Shutdown`], then
    /// sever every remaining connection (so peers holding persistent links —
    /// a `dpp front` in particular — observe the shutdown as EOF instead of
    /// blocking on a zombie socket), close every session, and return its
    /// metrics in registration order.
    pub fn run(self) -> Vec<(String, ServiceMetrics)> {
        let conns: Mutex<Vec<TcpStream>> = Mutex::new(Vec::new());
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if let Ok(dup) = stream.try_clone() {
                        conns.lock().unwrap_or_else(|e| e.into_inner()).push(dup);
                    }
                    let coord = Arc::clone(&self.coord);
                    let stop = Arc::clone(&self.stop);
                    // detached: a connection thread blocked on an idle
                    // peer's next frame exits on its own when the peer
                    // hangs up; joining it here could wait forever
                    // spawn failure (thread exhaustion) drops this
                    // connection; the listener keeps accepting
                    if let Err(e) = std::thread::Builder::new()
                        .name("dpp-serve-conn".to_string())
                        .spawn(move || serve_connection(stream, coord, stop))
                    {
                        eprintln!("dpp-serve: connection thread spawn failed: {e}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => break,
            }
        }
        // the handles accumulate for the server's lifetime (already-closed
        // sockets just fail the shutdown call harmlessly)
        for s in conns.lock().unwrap_or_else(|e| e.into_inner()).drain(..) {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        let coord = self.coord.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = Vec::new();
        for name in coord.sessions() {
            if let Some(metrics) = coord.close_session(&name) {
                out.push((name, metrics));
            }
        }
        out
    }
}

/// One queued reply slot (or the shutdown marker) handed from the reader
/// to the responder thread.
enum ConnReply {
    Reply { id: u64, slot: PendingResponse },
    /// Control-plane stats row, snapshotted at decode time; queued through
    /// the same channel so it stays FIFO with pipelined replies.
    Stats(StatsReport),
    Shutdown,
}

fn serve_connection(stream: TcpStream, coord: Arc<Mutex<Coordinator>>, stop: Arc<AtomicBool>) {
    let Ok(mut reader) = stream.try_clone() else { return };
    let mut writer = stream;
    // hello-first: anything else on a fresh connection is not our client
    let client_version = match read_frame(&mut reader).map(|p| decode_client_msg(&p)) {
        Ok(Ok(ClientMsg::Hello { version })) => version,
        _ => return,
    };
    let sessions = coord.lock().unwrap_or_else(|e| e.into_inner()).sessions();
    let hello = encode_server_msg(&ServerMsg::Hello { version: WIRE_VERSION, sessions });
    if write_frame(&mut writer, &hello).is_err() || client_version != WIRE_VERSION {
        return;
    }

    let (rtx, rrx) = channel::<ConnReply>();
    let responder = match std::thread::Builder::new()
        .name("dpp-serve-reply".to_string())
        .spawn(move || respond_loop(writer, rrx))
    {
        Ok(handle) => handle,
        // no responder thread ⇒ we can never reply; drop the connection
        Err(e) => {
            eprintln!("dpp-serve: responder thread spawn failed: {e}");
            return;
        }
    };
    loop {
        let Ok(payload) = read_frame(&mut reader) else {
            break; // disconnect or corrupt frame → this connection only
        };
        match decode_client_msg(&payload) {
            Ok(ClientMsg::Submit { id, session, request }) => {
                let slot = coord
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .submit(&session, request);
                if rtx.send(ConnReply::Reply { id, slot }).is_err() {
                    break; // responder lost its socket
                }
            }
            Ok(ClientMsg::Stats) => {
                let report = {
                    let c = coord.lock().unwrap_or_else(|e| e.into_inner());
                    StatsReport {
                        backend: String::new(), // "" = this process
                        up: true,
                        sessions: c.sessions().len() as u64,
                        admission: c.admission_stats(),
                    }
                };
                if rtx.send(ConnReply::Stats(report)).is_err() {
                    break;
                }
            }
            Ok(ClientMsg::Shutdown) => {
                let _ = rtx.send(ConnReply::Shutdown);
                break;
            }
            // a second hello or an undecodable frame is a protocol error
            Ok(ClientMsg::Hello { .. }) | Err(_) => break,
        }
    }
    drop(rtx);
    if responder.join().unwrap_or(false) {
        stop.store(true, Ordering::SeqCst);
    }
}

/// Write replies in submission order (FIFO through the channel), so a
/// pipelining client can match `id`s without reordering. Returns true when
/// the connection asked the whole server to shut down.
fn respond_loop(mut writer: TcpStream, rrx: Receiver<ConnReply>) -> bool {
    while let Ok(msg) = rrx.recv() {
        match msg {
            ConnReply::Reply { id, slot } => {
                let response = slot.recv_response().unwrap_or_else(Response::Error);
                let bytes = encode_server_msg(&ServerMsg::Reply { id, response });
                if write_frame(&mut writer, &bytes).is_err() {
                    return false; // peer hung up; drop remaining replies
                }
            }
            ConnReply::Stats(report) => {
                let bytes = encode_server_msg(&ServerMsg::Stats { backends: vec![report] });
                if write_frame(&mut writer, &bytes).is_err() {
                    return false;
                }
            }
            ConnReply::Shutdown => {
                let bytes = encode_server_msg(&ServerMsg::ShuttingDown);
                let _ = write_frame(&mut writer, &bytes);
                return true;
            }
        }
    }
    false
}
