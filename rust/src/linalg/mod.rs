//! Linear-algebra substrate: the matrix-free [`DesignMatrix`] trait
//! (DESIGN.md §2) and its backends.
//!
//! The dense backend stores X (N×p) **column-major**: screening and
//! coordinate descent both sweep features, and a contiguous column makes
//! `xᵢᵀw` a streaming dot product. The sparse backend ([`CscMatrix`]) stores
//! only non-zeros, so the same sweep costs O(nnz). The out-of-core backend
//! ([`MmapCscMatrix`]) pages the same CSC triple from an on-disk shard
//! through a bounded window, so X never has to fit in memory at all.
//! The row-sharded backend ([`ShardSetMatrix`]) stacks row-range shards
//! (in-RAM CSC slices or out-of-core `dppcsc` directories) behind a
//! reducing facade whose sweeps run on the persistent worker pool.
//! [`DesignStore`] is the owned enum over all four that `data::Dataset`
//! carries. All consumers (screening rules, solvers, path drivers, the
//! service) talk to `&dyn DesignMatrix`; the two hot operations are
//! [`DesignMatrix::xt_w`] (the screening sweep `Xᵀw`) and the per-column
//! dots/axpys inside the solvers.

pub mod design;
pub mod mmap;
pub mod ops;
pub mod sharded;
pub mod sparse;
pub mod store;

pub use design::DesignMatrix;
pub use mmap::MmapCscMatrix;
pub use ops::{axpy, dist_sq_scaled, dot, nrm1, nrm2, scale, seq_mean, seq_sum};
pub use sharded::ShardSetMatrix;
pub use sparse::CscMatrix;
pub use store::DesignStore;

/// Column-major dense matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    n_rows: usize,
    n_cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix of the given shape.
    pub fn zeros(n_rows: usize, n_cols: usize) -> Self {
        DenseMatrix { n_rows, n_cols, data: vec![0.0; n_rows * n_cols] }
    }

    /// Build from a column-major data vector (len must be n_rows*n_cols).
    pub fn from_col_major(n_rows: usize, n_cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n_rows * n_cols, "shape/data mismatch");
        DenseMatrix { n_rows, n_cols, data }
    }

    /// Build from a row-major iterator of rows (convenience for tests).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let n_rows = rows.len();
        let n_cols = if n_rows == 0 { 0 } else { rows[0].len() };
        let mut m = DenseMatrix::zeros(n_rows, n_cols);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), n_cols);
            for (j, &v) in r.iter().enumerate() {
                m.data[j * n_rows + i] = v;
            }
        }
        m
    }

    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }
    #[inline]
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Contiguous column slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.n_cols);
        &self.data[j * self.n_rows..(j + 1) * self.n_rows]
    }

    /// Mutable column slice.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.n_cols);
        &mut self.data[j * self.n_rows..(j + 1) * self.n_rows]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n_rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.n_rows + i] = v;
    }

    /// Raw column-major storage (used by the PJRT runtime to build literals).
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Screening sweep: `out[j] = xⱼᵀ w` for every column j. This is the
    /// O(Np) hot spot of every screening rule (DESIGN.md §10 L3 target).
    ///
    /// Eight columns per pass (perf iteration 2, DESIGN.md §10):
    /// `w` is re-used from L1/L2 across the column block, cutting its
    /// memory traffic 8×, and eight independent accumulators keep the FMA
    /// pipeline full.
    pub fn gemv_t(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        let n = self.n_rows;
        let mut j = 0;
        while j + 8 <= self.n_cols {
            let base = j * n;
            let block = &self.data[base..base + 8 * n];
            let (c0, rest) = block.split_at(n);
            let (c1, rest) = rest.split_at(n);
            let (c2, rest) = rest.split_at(n);
            let (c3, rest) = rest.split_at(n);
            let (c4, rest) = rest.split_at(n);
            let (c5, rest) = rest.split_at(n);
            let (c6, c7) = rest.split_at(n);
            let mut s = [0.0f64; 8];
            for i in 0..n {
                let wi = w[i];
                s[0] += c0[i] * wi;
                s[1] += c1[i] * wi;
                s[2] += c2[i] * wi;
                s[3] += c3[i] * wi;
                s[4] += c4[i] * wi;
                s[5] += c5[i] * wi;
                s[6] += c6[i] * wi;
                s[7] += c7[i] * wi;
            }
            out[j..j + 8].copy_from_slice(&s);
            j += 8;
        }
        while j < self.n_cols {
            out[j] = dot(self.col(j), w);
            j += 1;
        }
    }

    /// Like [`gemv_t`] but only over the listed columns (screened problems).
    pub fn gemv_t_subset(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), cols.len());
        for (k, &j) in cols.iter().enumerate() {
            out[k] = dot(self.col(j), w);
        }
    }

    /// `out += Σⱼ betaⱼ · xⱼ` over the given (column, coefficient) pairs —
    /// how solvers materialize Xβ for a sparse β.
    pub fn accum_cols(&self, cols: &[usize], beta: &[f64], out: &mut [f64]) {
        assert_eq!(cols.len(), beta.len());
        assert_eq!(out.len(), self.n_rows);
        for (k, &j) in cols.iter().enumerate() {
            if beta[k] != 0.0 {
                axpy(beta[k], self.col(j), out);
            }
        }
    }

    /// Dense `y = X β` for a full-length β (test/reference use).
    pub fn gemv(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.n_cols);
        assert_eq!(out.len(), self.n_rows);
        out.fill(0.0);
        for j in 0..self.n_cols {
            if beta[j] != 0.0 {
                axpy(beta[j], self.col(j), out);
            }
        }
    }

    /// ℓ2 norm of every column.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.n_cols).map(|j| nrm2(self.col(j))).collect()
    }

    /// Scale every column to unit ℓ2 norm (zero columns left untouched).
    /// Returns the original norms. DOME requires unit-norm features (§4.1.1).
    pub fn normalize_columns(&mut self) -> Vec<f64> {
        let mut norms = Vec::with_capacity(self.n_cols);
        let n = self.n_rows;
        for j in 0..self.n_cols {
            let c = &mut self.data[j * n..(j + 1) * n];
            let nj = nrm2(c);
            norms.push(nj);
            if nj > 0.0 {
                scale(1.0 / nj, c);
            }
        }
        norms
    }
}

impl DesignMatrix for DenseMatrix {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        self.gemv_t(w, out);
    }

    fn col_dot_w(&self, j: usize, w: &[f64]) -> f64 {
        dot(self.col(j), w)
    }

    fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]) {
        axpy(a, self.col(j), out);
    }

    fn col_sq_norm(&self, j: usize) -> f64 {
        let c = self.col(j);
        dot(c, c)
    }

    fn col_dot_col(&self, i: usize, j: usize) -> f64 {
        dot(self.col(i), self.col(j))
    }

    fn col_into(&self, j: usize, out: &mut [f64]) {
        out.copy_from_slice(self.col(j));
    }

    fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]) {
        assert_eq!(rows.len(), out.len());
        let c = self.col(j);
        for (o, &r) in out.iter_mut().zip(rows.iter()) {
            *o = c[r];
        }
    }

    fn nnz(&self) -> usize {
        self.n_rows * self.n_cols
    }

    fn col_norms(&self) -> Vec<f64> {
        DenseMatrix::col_norms(self)
    }

    fn xt_w_subset(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        self.gemv_t_subset(cols, w, out);
    }

    fn accum_cols(&self, cols: &[usize], beta: &[f64], out: &mut [f64]) {
        DenseMatrix::accum_cols(self, cols, beta, out);
    }

    fn gemv(&self, beta: &[f64], out: &mut [f64]) {
        DenseMatrix::gemv(self, beta, out);
    }

    // op_norm_sq_subset: the trait default's power iteration already runs
    // on this backend's fused accum_cols/xt_w_subset kernels.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn small() -> DenseMatrix {
        DenseMatrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]])
    }

    #[test]
    fn shape_and_access() {
        let m = small();
        assert_eq!((m.n_rows(), m.n_cols()), (2, 3));
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.col(1), &[2.0, 5.0]);
    }

    #[test]
    fn gemv_t_matches_manual() {
        let m = small();
        let w = [1.0, -1.0];
        let mut out = [0.0; 3];
        m.gemv_t(&w, &mut out);
        assert_eq!(out, [-3.0, -3.0, -3.0]);
    }

    #[test]
    fn gemv_t_unrolled_matches_naive_randomized() {
        prop::check("gemv_t unrolled == naive", 0xA1, 30, |rng| {
            let n = 1 + rng.usize(17);
            let p = 1 + rng.usize(23);
            let mut data = vec![0.0; n * p];
            rng.fill_normal(&mut data);
            let m = DenseMatrix::from_col_major(n, p, data);
            let mut w = vec![0.0; n];
            rng.fill_normal(&mut w);
            let mut fast = vec![0.0; p];
            m.gemv_t(&w, &mut fast);
            for j in 0..p {
                let naive = dot(m.col(j), &w);
                assert!((fast[j] - naive).abs() <= 1e-10 * (1.0 + naive.abs()));
            }
        });
    }

    #[test]
    fn gemv_roundtrip_transpose() {
        // (Xβ)·w == β·(Xᵀw)
        prop::check("gemv adjoint identity", 0xA2, 20, |rng| {
            let n = 1 + rng.usize(10);
            let p = 1 + rng.usize(10);
            let mut data = vec![0.0; n * p];
            rng.fill_normal(&mut data);
            let m = DenseMatrix::from_col_major(n, p, data);
            let mut beta = vec![0.0; p];
            rng.fill_normal(&mut beta);
            let mut w = vec![0.0; n];
            rng.fill_normal(&mut w);
            let mut xb = vec![0.0; n];
            m.gemv(&beta, &mut xb);
            let mut xtw = vec![0.0; p];
            m.gemv_t(&w, &mut xtw);
            let lhs = dot(&xb, &w);
            let rhs = dot(&beta, &xtw);
            assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
        });
    }

    #[test]
    fn accum_cols_matches_gemv() {
        let m = small();
        let mut full = vec![0.0; 2];
        m.gemv(&[0.5, 0.0, -2.0], &mut full);
        let mut sparse = vec![0.0; 2];
        m.accum_cols(&[0, 2], &[0.5, -2.0], &mut sparse);
        assert_eq!(full, sparse);
    }

    #[test]
    fn col_norms_and_normalize() {
        let mut m = small();
        let norms = m.col_norms();
        assert!((norms[0] - (17.0f64).sqrt()).abs() < 1e-12);
        let orig = m.normalize_columns();
        assert_eq!(orig, norms);
        for j in 0..3 {
            assert!((nrm2(m.col(j)) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn op_norm_matches_gram_eig_small() {
        // For a 2-column orthogonal design, ||X_A||^2 = max column norm^2.
        let m = DenseMatrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0]]);
        let lam = m.op_norm_sq_subset(&[0, 1], 50, 1);
        assert!((lam - 16.0).abs() < 1e-6, "{lam}");
    }

    #[test]
    fn op_norm_upper_bounds_rayleigh() {
        prop::check("power iteration dominates random Rayleigh quotients", 0xA3, 10, |rng| {
            let n = 4 + rng.usize(8);
            let p = 3 + rng.usize(6);
            let mut data = vec![0.0; n * p];
            rng.fill_normal(&mut data);
            let m = DenseMatrix::from_col_major(n, p, data);
            let cols: Vec<usize> = (0..p).collect();
            let lam = m.op_norm_sq_subset(&cols, 100, 7);
            // Rayleigh quotient of any unit vector must be ≤ λmax (+ slack).
            let mut v = vec![0.0; p];
            rng.fill_normal(&mut v);
            let nv = nrm2(&v);
            scale(1.0 / nv, &mut v);
            let mut xb = vec![0.0; n];
            m.accum_cols(&cols, &v, &mut xb);
            let q = dot(&xb, &xb);
            assert!(q <= lam * 1.0 + 1e-6 + lam * 0.05, "rayleigh {q} > lam {lam}");
        });
    }

    #[test]
    fn from_rows_empty() {
        let m = DenseMatrix::from_rows(&[]);
        assert_eq!((m.n_rows(), m.n_cols()), (0, 0));
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        DenseMatrix::from_col_major(2, 2, vec![0.0; 3]);
    }

    #[test]
    fn rng_matrix_deterministic() {
        let mk = || {
            let mut r = Rng::new(5);
            let mut d = vec![0.0; 12];
            r.fill_normal(&mut d);
            DenseMatrix::from_col_major(3, 4, d)
        };
        assert_eq!(mk(), mk());
    }
}
