//! Sparse (CSC) feature-matrix substrate.
//!
//! The paper's motivation (§1) is that at MNIST/SVHN scale "we may not even
//! be able to load the data matrix into main memory"; image/stroke data is
//! naturally sparse. The CSC matrix implements the same correlation-sweep
//! contract as [`DenseMatrix`] ([`crate::screening::CorrelationSweep`]), so
//! every screening rule runs unchanged on sparse data, and
//! [`sparse_cd_solve`] provides a reduced-problem solver whose epoch cost is
//! O(nnz of the surviving columns).

use super::DenseMatrix;
use crate::screening::CorrelationSweep;

/// Compressed-sparse-column matrix (f64 values).
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    n_rows: usize,
    n_cols: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<u32>,
    values: Vec<f64>,
}

impl CscMatrix {
    /// Build from a dense matrix, dropping exact zeros.
    pub fn from_dense(x: &DenseMatrix) -> CscMatrix {
        let (n, p) = (x.n_rows(), x.n_cols());
        assert!(n <= u32::MAX as usize);
        let mut col_ptr = Vec::with_capacity(p + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for j in 0..p {
            for (i, &v) in x.col(j).iter().enumerate() {
                if v != 0.0 {
                    row_idx.push(i as u32);
                    values.push(v);
                }
            }
            col_ptr.push(values.len());
        }
        CscMatrix { n_rows: n, n_cols: p, col_ptr, row_idx, values }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }
    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }
    /// Fill fraction.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n_rows * self.n_cols).max(1) as f64
    }

    /// (row indices, values) of column j.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.col_ptr[j], self.col_ptr[j + 1]);
        (&self.row_idx[a..b], &self.values[a..b])
    }

    /// Sparse dot `xⱼᵀw`.
    #[inline]
    pub fn col_dot(&self, j: usize, w: &[f64]) -> f64 {
        let (idx, vals) = self.col(j);
        let mut s = 0.0;
        for (i, v) in idx.iter().zip(vals.iter()) {
            s += w[*i as usize] * v;
        }
        s
    }

    /// `out[j] = xⱼᵀw` for all j — the sparse screening sweep, O(nnz).
    pub fn gemv_t(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        for j in 0..self.n_cols {
            out[j] = self.col_dot(j, w);
        }
    }

    /// `out += a·xⱼ` (scatter-axpy).
    #[inline]
    pub fn col_axpy(&self, j: usize, a: f64, out: &mut [f64]) {
        let (idx, vals) = self.col(j);
        for (i, v) in idx.iter().zip(vals.iter()) {
            out[*i as usize] += a * v;
        }
    }

    /// ℓ2 norm per column.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.n_cols)
            .map(|j| {
                let (_, vals) = self.col(j);
                vals.iter().map(|v| v * v).sum::<f64>().sqrt()
            })
            .collect()
    }

    /// Densify (tests / small problems).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut x = DenseMatrix::zeros(self.n_rows, self.n_cols);
        for j in 0..self.n_cols {
            let (idx, vals) = self.col(j);
            let c = x.col_mut(j);
            for (i, v) in idx.iter().zip(vals.iter()) {
                c[*i as usize] = *v;
            }
        }
        x
    }
}

impl CorrelationSweep for CscMatrix {
    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        self.gemv_t(w, out);
    }
}

/// Coordinate descent on a column subset of a CSC matrix — epoch cost
/// O(Σ_{j∈cols} nnz(xⱼ)) instead of O(N·|cols|).
pub fn sparse_cd_solve(
    x: &CscMatrix,
    y: &[f64],
    cols: &[usize],
    lam: f64,
    beta0: Option<&[f64]>,
    opts: &crate::solver::SolveOptions,
) -> crate::solver::SolveResult {
    use crate::linalg::ops::soft_threshold;
    let m = cols.len();
    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; m]);
    let mut r = y.to_vec();
    for (k, &j) in cols.iter().enumerate() {
        if beta[k] != 0.0 {
            x.col_axpy(j, -beta[k], &mut r);
        }
    }
    let sq: Vec<f64> = cols
        .iter()
        .map(|&j| {
            let (_, vals) = x.col(j);
            vals.iter().map(|v| v * v).sum::<f64>()
        })
        .collect();
    let y_scale = crate::linalg::nrm2(y).max(1.0);
    let mut epoch = 0;
    let mut gap = f64::INFINITY;
    while epoch < opts.max_iters {
        let mut max_delta = 0.0f64;
        for k in 0..m {
            if sq[k] == 0.0 {
                continue;
            }
            let old = beta[k];
            let c = x.col_dot(cols[k], &r) + sq[k] * old;
            let new = soft_threshold(c, lam) / sq[k];
            if new != old {
                x.col_axpy(cols[k], old - new, &mut r);
                beta[k] = new;
                max_delta = max_delta.max((new - old).abs() * sq[k].sqrt());
            }
        }
        epoch += 1;
        if max_delta <= 1e-11 * y_scale || epoch % opts.gap_check_every == 0 {
            gap = sparse_gap(x, y, cols, &beta, &r, lam);
            if gap <= opts.tol_gap || max_delta <= 1e-13 * y_scale {
                break;
            }
        }
    }
    if gap.is_infinite() {
        gap = sparse_gap(x, y, cols, &beta, &r, lam);
    }
    crate::solver::SolveResult { beta, iters: epoch, gap }
}

fn sparse_gap(
    x: &CscMatrix,
    y: &[f64],
    cols: &[usize],
    beta: &[f64],
    r: &[f64],
    lam: f64,
) -> f64 {
    use crate::linalg::{dot, nrm1};
    let mut xtr_inf = 0.0f64;
    for &j in cols {
        xtr_inf = xtr_inf.max(x.col_dot(j, r).abs());
    }
    let s = if xtr_inf <= lam || xtr_inf == 0.0 { 1.0 / lam } else { 1.0 / xtr_inf };
    let rr = dot(r, r);
    let ry = dot(r, y);
    let yy = dot(y, y);
    let primal = 0.5 * rr + lam * nrm1(beta);
    let dist = s * s * rr - 2.0 * s / lam * ry + yy / (lam * lam);
    let dual = 0.5 * yy - 0.5 * lam * lam * dist;
    ((primal - dual) / (0.5 * yy).max(1.0)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::{cd::CdSolver, dual, LassoSolver, SolveOptions};
    use crate::util::{prop, rng::Rng};

    fn sparse_problem(n: usize, p: usize, density: f64, seed: u64) -> (DenseMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = DenseMatrix::zeros(n, p);
        for j in 0..p {
            let c = x.col_mut(j);
            for v in c.iter_mut() {
                if rng.f64() < density {
                    *v = rng.normal();
                }
            }
        }
        let beta = synthetic::sparse_ground_truth(p, p / 8 + 1, &mut rng);
        let y = synthetic::linear_response(&x, &beta, 0.1, &mut rng);
        (x, y)
    }

    #[test]
    fn roundtrip_dense_csc_dense() {
        let (x, _) = sparse_problem(20, 30, 0.2, 1);
        let csc = CscMatrix::from_dense(&x);
        assert_eq!(csc.to_dense(), x);
        assert!(csc.density() < 0.3);
    }

    #[test]
    fn sweep_matches_dense_randomized() {
        prop::check("csc gemv_t == dense gemv_t", 0xC5C, 20, |rng| {
            let n = 1 + rng.usize(30);
            let p = 1 + rng.usize(40);
            let (x, _) = sparse_problem(n, p, rng.uniform(0.05, 0.5), rng.next_u64());
            let csc = CscMatrix::from_dense(&x);
            let mut w = vec![0.0; n];
            rng.fill_normal(&mut w);
            let mut a = vec![0.0; p];
            let mut b = vec![0.0; p];
            csc.gemv_t(&w, &mut a);
            x.gemv_t(&w, &mut b);
            for j in 0..p {
                assert!((a[j] - b[j]).abs() < 1e-10 * (1.0 + b[j].abs()));
            }
        });
    }

    #[test]
    fn col_norms_match_dense() {
        let (x, _) = sparse_problem(25, 35, 0.3, 3);
        let csc = CscMatrix::from_dense(&x);
        for (a, b) in csc.col_norms().iter().zip(x.col_norms().iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn sparse_cd_matches_dense_cd() {
        let (x, y) = sparse_problem(40, 120, 0.15, 4);
        let csc = CscMatrix::from_dense(&x);
        let lam = 0.3 * dual::lambda_max(&x, &y);
        let cols: Vec<usize> = (0..120).collect();
        let opts = SolveOptions { tol_gap: 1e-11, ..Default::default() };
        let sp = sparse_cd_solve(&csc, &y, &cols, lam, None, &opts);
        let de = CdSolver.solve(&x, &y, &cols, lam, None, &opts);
        let o_sp = dual::primal_objective(&x, &y, &cols, &sp.beta, lam);
        let o_de = dual::primal_objective(&x, &y, &cols, &de.beta, lam);
        assert!((o_sp - o_de).abs() < 1e-6 * (1.0 + o_de.abs()));
        assert!(sp.gap < 1e-7);
    }

    #[test]
    fn screening_rules_run_on_sparse_sweep() {
        // EDPP through the CSC CorrelationSweep must equal the dense path
        use crate::screening::{edpp::EdppRule, ScreenContext, ScreeningRule, StepInput};
        let (x, y) = sparse_problem(30, 80, 0.2, 5);
        let csc = CscMatrix::from_dense(&x);
        let dense_ctx = ScreenContext::new(&x, &y);
        let sparse_ctx = ScreenContext::with_sweep(&x, &y, &csc);
        let theta: Vec<f64> = y.iter().map(|v| v / dense_ctx.lam_max).collect();
        let step = StepInput {
            lam_prev: dense_ctx.lam_max,
            lam: 0.5 * dense_ctx.lam_max,
            theta_prev: &theta,
        };
        let mut keep_d = vec![true; 80];
        let mut keep_s = vec![true; 80];
        EdppRule.screen(&dense_ctx, &step, &mut keep_d);
        EdppRule.screen(&sparse_ctx, &step, &mut keep_s);
        assert_eq!(keep_d, keep_s);
    }

    #[test]
    fn empty_and_zero_column_edge_cases() {
        let x = DenseMatrix::zeros(5, 3);
        let csc = CscMatrix::from_dense(&x);
        assert_eq!(csc.nnz(), 0);
        let mut out = vec![1.0; 3];
        csc.gemv_t(&[1.0; 5], &mut out);
        assert_eq!(out, vec![0.0; 3]);
        let res = sparse_cd_solve(
            &csc,
            &[1.0; 5],
            &[0, 1, 2],
            0.5,
            None,
            &SolveOptions::default(),
        );
        assert!(res.beta.iter().all(|b| *b == 0.0));
    }
}
